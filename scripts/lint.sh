#!/usr/bin/env bash
# Static-analysis gate (ISSUEs 8 + 10): permlint (the repo's
# determinism & precision invariants, see docs/INVARIANTS.md), the
# geometry auditor (kernel/plan shape validation, no device work),
# permprove (IR-level PLI contracts + golden-trace drift gating), and a
# ruff pyflakes baseline when ruff is installed (the offline dev image
# may not have it; CI installs it).
#
#   scripts/lint.sh [--no-jax]      # --no-jax skips the auditor's
#                                   # jax-importing audits
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== permlint (invariants as lint rules)"
python -m repro.analysis.lint src tests

echo "== geometry auditor (static plan/kernel validation)"
python -m repro.analysis.geometry --check "$@"

# Abstract tracing + compile-only HLO audit on CPU; the __main__ entry
# forces 8 host devices so the PLI104 collective audit sees a real mesh.
# IR_REPORT (optional) captures the JSON report for the CI artifact.
echo "== permprove (IR contracts + golden-trace drift gate)"
python -m repro.analysis.ir --check -q ${IR_REPORT:+--report "$IR_REPORT"}

if command -v ruff >/dev/null 2>&1; then
    echo "== ruff (pyflakes + E9 baseline, pyproject.toml)"
    ruff check src tests benchmarks
else
    echo "== ruff not installed; skipping the baseline layer" \
         "(permlint's PLF01/PLE901 cover the F401/E9 classes)"
fi
