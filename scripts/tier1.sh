#!/usr/bin/env bash
# Offline-safe tier-1 test runner: fast suite only (slow multi-device
# subprocess tests are deselected).  Works without hypothesis installed
# (tests/conftest.py installs a deterministic stub).
#
#   scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
bash scripts/lint.sh
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"
