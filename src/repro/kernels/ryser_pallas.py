"""Pallas TPU kernels for Gray-code Ryser permanents (paper Sec. 3).

Geometry (one ``pallas_call``):

    grid = (num_blocks,)                 one block per VMEM-resident lane set
    block = TB chunks (lanes)            each lane owns one Alg.-3 chunk
    chunk = C = Wu * M Gray steps        M macro-windows of Wu steps each

TPU mapping of the paper's GPU optimizations (DESIGN.md Sec. 2):

* CEG (Sec. 3.2.1): chunks are power-of-2 sized and window-aligned, so for
  local steps ``w = 1 .. Wu-1`` the changed bit ``ctz(w)`` and (almost
  always) the sign are *host constants* -- the column update is a broadcast
  ``X += s * A[:, j]`` with zero gathers.  Only each window's boundary step
  has per-lane columns; it is resolved with a one-hot MXU matmul.
* x in registers (Sec. 3.3): the whole X tile (n_pad, TB) lives in VMEM and
  the Wu-step schedule is unrolled at trace time -- the analogue of the
  paper's matrix-specific rebuild.
* A in shared memory (Sec. 3.2): A is a replicated (n_pad, n_pad) VMEM
  block.
* 64-bit step indices: TPU has no i64; chunk ids/steps use uint32-pair
  emulation (kernels/u64emu.py).

Two modes:

* ``baseline``  -- paper-faithful Alg. 3: sequential X updates per step.
* ``batched``   -- beyond-paper window-batched form: per-window states are
  generated as ``X0 + A @ cumsig`` (one MXU matmul, lane-shared), removing
  the serial X dependency and all per-step X writes (see DESIGN.md and
  EXPERIMENTS.md Sec. Perf).

Accumulation: ``dd`` (plain), ``kahan``, ``dq_acc`` (twofloat) per lane;
the cross-lane / cross-block reduction happens outside in ops.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import gray as G
from ..core.stepspace import kernel_geometry
from ..utils.compat import shape_dtype_struct
from . import u64emu as U

__all__ = ["ryser_pallas_call", "ryser_pallas_call_batched",
           "kernel_geometry", "device_base_u32"]


def device_base_u32(dev_chunk_base):
    """Encode a device chunk base as a (1, 1) uint32 (hi, lo) pair.

    Accepts a host int or a traced scalar (the distributed shard_map path):
    uint64 under x64 keeps the full range; 32-bit ints cover per-device
    ranges in tests.  Shared by the real and complex kernel wrappers.
    """
    if isinstance(dev_chunk_base, (int, np.integer)):
        base_hi = jnp.full((1, 1), (int(dev_chunk_base) >> 32) & 0xFFFFFFFF,
                           jnp.uint32)
        base_lo = jnp.full((1, 1), int(dev_chunk_base) & 0xFFFFFFFF,
                           jnp.uint32)
        return base_hi, base_lo
    b = jnp.asarray(dev_chunk_base)
    if b.dtype in (jnp.uint64, jnp.int64):
        base_hi = (b >> 32).astype(jnp.uint32).reshape(1, 1)
        base_lo = b.astype(jnp.uint32).reshape(1, 1)
    else:
        base_hi = jnp.zeros((1, 1), jnp.uint32) * b.astype(jnp.uint32)
        base_lo = b.astype(jnp.uint32).reshape(1, 1)
    return base_hi.reshape(1, 1), base_lo


def _signed_const_schedule(Wu: int):
    """Host schedule for inner steps w = 1..Wu-1 of any aligned window.

    Returns [(j, s_const, is_mid, parity)], where the true sign is
    ``s_const`` except at the mid step (w = Wu/2), where lanes whose window
    base has bit kw set use ``-s_const`` (see core/gray.py notes).
    """
    kw = int(math.log2(Wu))
    out = []
    for w in range(1, Wu):
        j = G.ctz(w)
        if j + 1 < kw or kw == 0:
            bit = ((w >> j) ^ (w >> (j + 1))) & 1
            is_mid = False
        else:  # w == Wu // 2, j == kw - 1
            bit = ((w >> j)) & 1  # == 1; true bit = 1 ^ bit_kw(base)
            is_mid = True
        s = 2 * bit - 1
        parity = w & 1
        out.append((j, s, is_mid, parity))
    return out


def _accum_make(dtype, shape):
    z = jnp.zeros(shape, dtype)
    return (z, z)


def _accum_add(acc, term, precision):
    s, c = acc
    if precision == "kahan":
        y = term - c
        t = s + y
        return (t, (t - s) - y)
    if precision == "dq_acc":
        # two_sum based twofloat accumulate
        hi = s + term
        bp = hi - s
        e = (s - (hi - bp)) + (term - bp)
        return (hi, c + e)
    if precision == "dq_fast":
        # Dekker-style sloppy twofloat accumulate (tf_add_fast): two_sum
        # into the hi limb, then renormalize with fast_two_sum
        hi = s + term
        bp = hi - s
        e = (s - (hi - bp)) + (term - bp) + c
        s2 = hi + e
        return (s2, e - (s2 - hi))
    return (s + term, c)  # dd (and qq: no twofloat product in-kernel)


def _accum_value(acc, precision):
    if precision in ("dq_acc", "dq_fast"):
        return acc[0], acc[1]
    return acc[0], jnp.zeros_like(acc[1])


def _sched_select_host(sched, n_pad: int) -> np.ndarray:
    """Per-step signed one-hot selection matrix (n_pad, Wu-1):
    column idx holds s_const(w) e_{j(w)}.  The wrapper multiplies by A to
    get the signed schedule columns (the 'schedmat' beyond-paper mode:
    the per-step broadcast-multiply and column slice both disappear --
    each inner step is ONE vector add + the product chain)."""
    S = np.zeros((n_pad, max(1, len(sched))), dtype=np.float64)
    for idx, (j, sgn, _is_mid, _) in enumerate(sched):
        S[j, idx] = sgn
    return S


def _cumsig_host(sched, n_pad: int) -> np.ndarray:
    """Cumulative signed one-hot schedule (n_pad, Wu-1) for batched mode.

    Column idx holds sum_{w' <= w} s_const(w') e_{j(w')}; the mid step's
    lane-dependent sign is corrected in-kernel.
    """
    C0 = np.zeros((n_pad, max(1, len(sched))), dtype=np.float64)
    run = np.zeros(n_pad, dtype=np.float64)
    for idx, (j, s, _is_mid, _) in enumerate(sched):
        run[j] += s
        C0[:, idx] = run
    return C0


def _ryser_block(i, A, xb, c0, dev_base, *,
                 n: int, n_pad: int, TB: int, C: int, Wu: int,
                 space: int, precision: str, mode: str, dtype):
    """One grid block: TB chunks x C Gray steps; returns (hi, lo) scalars.

    Shared between the single-matrix kernel (grid over blocks) and the
    batch-grid kernel (grid over (batch, block)); ``i`` is the block id
    along the chunk axis and ``dev_base`` the u32-pair device chunk base.
    """
    k = int(math.log2(C))
    kw = int(math.log2(Wu))
    M = C // Wu

    # ---- chunk ids & start steps (u64 lane math) ----
    # (1, TB) iota then reshape: Mosaic requires >= 2D iota on TPU
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, TB), 1).reshape(TB)
    block_first = (i * TB).astype(jnp.uint32)
    chunk64 = U.u64_add_u32((jnp.broadcast_to(dev_base[0], (TB,)),
                             jnp.broadcast_to(dev_base[1], (TB,))),
                            block_first + lane)
    start64 = U.u64_shl(chunk64, k)

    # ---- init X = xb + A @ graybits(start) (MXU) ----
    gbits_start = U.u64_gray(start64)
    rows = []
    for j in range(n_pad):
        if j < n:
            rows.append(U.u64_bit(gbits_start, np.uint32(j)).astype(dtype))
        else:
            rows.append(jnp.zeros((TB,), dtype))
    Gb = jnp.stack(rows, axis=0)                     # (n_pad, TB)
    X = xb + jax.lax.dot_general(
        A, Gb, (((1,), (0,)), ((), ())), preferred_element_type=dtype)

    sched = _signed_const_schedule(Wu)
    space_m1 = U.u64_from_int(space - 1, like=lane)
    row_iota = jax.lax.broadcasted_iota(jnp.uint32, (n_pad, TB), 0)

    # schedule-matrix kernel input: cumulative signed one-hots (batched)
    # or A-premultiplied signed columns (schedmat)
    if mode in ("batched", "schedmat"):
        C0 = c0                                      # (n_pad, Wu-1)
        mid_idx = next((ix for ix, st in enumerate(sched) if st[2]), None)

    def macro_body(m, carry):
        X, acc = carry
        m_u = m.astype(jnp.uint32) * np.uint32(Wu)
        macro64 = U.u64_add_u32(start64, m_u)
        # per-lane bit kw of the macro base (mid-step sign correction)
        bitk = U.u64_bit(macro64, np.uint32(kw)).astype(dtype)  # (TB,)
        mid_flip = 1 - 2 * bitk                                  # +-1

        if mode == "baseline":
            for (j, s, is_mid, parity) in sched:
                colj = jax.lax.dynamic_slice_in_dim(A, j, 1, 1)  # (n_pad,1)
                if is_mid:
                    slane = (s * mid_flip)[None, :]              # (1, TB)
                    X = X + colj * slane
                else:
                    X = X + colj * float(s)
                prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
                term = -prod if parity else prod
                acc = _accum_add(acc, term, precision)
        elif mode == "schedmat":
            # beyond-paper: per-step signed column precomputed (C0 = A@Sel);
            # inner step = one add + product; mid step adds one correction
            col_mid = jax.lax.dynamic_slice_in_dim(A, kw - 1, 1, 1) \
                if kw >= 1 else jnp.zeros((n_pad, 1), dtype)
            for idx, (j, s, is_mid, parity) in enumerate(sched):
                X = X + C0[:, idx][:, None]
                if is_mid:
                    X = X + col_mid * (float(-2.0 * s) * bitk)[None, :]
                prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
                term = -prod if parity else prod
                acc = _accum_add(acc, term, precision)
        else:
            # window-batched: states from one shared matmul, X never written
            D = jax.lax.dot_general(A, C0, (((1,), (0,)), ((), ())),
                                    preferred_element_type=dtype)  # (n_pad,Wu-1)
            col_mid = jax.lax.dynamic_slice_in_dim(A, kw - 1, 1, 1) if kw >= 1 \
                else jnp.zeros((n_pad, 1), dtype)
            # lanes with bitk=1 need mid sign -s i.e. subtract 2*s*col_mid
            s_mid = sched[mid_idx][1] if mid_idx is not None else 0
            corr = col_mid * (float(-2.0 * s_mid) * bitk)[None, :]
            for idx, (j, s, is_mid, parity) in enumerate(sched):
                state = X + D[:, idx][:, None]
                if mid_idx is not None and idx >= mid_idx:
                    state = state + corr
                prod = jnp.prod(state, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
                term = -prod if parity else prod
                acc = _accum_add(acc, term, precision)
            # advance X to the last inner state for the boundary step
            X = X + D[:, Wu - 2][:, None] if Wu >= 2 else X
            if mid_idx is not None:
                X = X + corr

        # ---- boundary step w = Wu (per-lane column via one-hot MXU) ----
        gb64 = U.u64_add_u32(macro64, np.uint32(Wu))
        jb = U.u64_ctz(gb64)                                    # (TB,)
        sign_bit = U.u64_bit(U.u64_gray(gb64), jb).astype(dtype)
        sb = 2 * sign_bit - 1                                   # (TB,)
        live = U.u64_leq(gb64, space_m1).astype(dtype)          # (TB,)
        onehot = (row_iota == jb[None, :].astype(jnp.uint32)).astype(dtype)
        colb = jax.lax.dot_general(A, onehot, (((1,), (0,)), ((), ())),
                                   preferred_element_type=dtype)
        X = X + colb * (sb * live)[None, :]
        prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
        # (-1)^{g_boundary} == (-1)^{Wu} == +1 (Wu is even)
        acc = _accum_add(acc, prod * live, precision)
        return (X, acc)

    acc0 = _accum_make(dtype, (TB,))
    if M == 1:
        X, acc = macro_body(jnp.int32(0), (X, acc0))
    else:
        X, acc = jax.lax.fori_loop(0, M, macro_body, (X, acc0))

    hi, lo = _accum_value(acc, precision)
    # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    return jnp.sum(hi), jnp.sum(lo)


def _ryser_kernel(base_hi_ref, base_lo_ref, A_ref, xb_ref, c0_ref, out_ref,
                  **geom):
    """Single-matrix kernel: grid = (num_blocks,); writes (1, 2) partials."""
    dev_base = (base_hi_ref[0, 0].astype(jnp.uint32),
                base_lo_ref[0, 0].astype(jnp.uint32))
    hi, lo = _ryser_block(pl.program_id(0), A_ref[...], xb_ref[...],
                          c0_ref[...], dev_base, **geom)
    out_ref[0, 0] = hi
    out_ref[0, 1] = lo


def _ryser_kernel_batched(A_ref, xb_ref, c0_ref, out_ref, **geom):
    """Batch-grid kernel: grid = (B, num_blocks); one launch covers the
    whole stack.  Block b of the A/xb stacks is selected by the BlockSpec;
    the chunk base is 0 (each matrix owns its full iteration space)."""
    zero = jnp.uint32(0)
    hi, lo = _ryser_block(pl.program_id(1), A_ref[0], xb_ref[0],
                          c0_ref[...], (zero, zero), **geom)
    out_ref[0, 0, 0] = hi
    out_ref[0, 0, 1] = lo


def ryser_pallas_call(A_pad, x_base_pad, dev_chunk_base, *,
                      n: int, TB: int, C: int, Wu: int, num_blocks: int,
                      precision: str = "dq_acc", mode: str = "baseline",
                      interpret: bool = True, vma=None):
    """Launch the kernel over ``num_blocks`` blocks; returns (blocks, 2)
    per-block (hi, lo) partial sums (base g=0 term NOT included)."""
    n_pad = A_pad.shape[0]
    dtype = A_pad.dtype
    space = 1 << (n - 1)
    base_hi, base_lo = device_base_u32(dev_chunk_base)
    sched = _signed_const_schedule(Wu)
    if mode == "schedmat":
        sel = jnp.asarray(_sched_select_host(sched, n_pad), dtype)
        c0 = A_pad @ sel                             # signed schedule columns
    else:
        c0 = jnp.asarray(_cumsig_host(sched, n_pad), dtype)

    kernel = functools.partial(
        _ryser_kernel, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu, space=space,
        precision=precision, mode=mode, dtype=dtype)

    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((n_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec(c0.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=shape_dtype_struct((num_blocks, 2), dtype, vma=vma),
        interpret=interpret,
    )(base_hi, base_lo, A_pad, x_base_pad, c0)


def ryser_pallas_call_batched(A_pads, x_base_pads, *,
                              n: int, TB: int, C: int, Wu: int,
                              num_blocks: int, precision: str = "dq_acc",
                              mode: str = "batched", interpret: bool = True):
    """Launch ONE kernel over a (B, n_pad, n_pad) stack: grid is
    (batch, block), so a single ``pallas_call`` covers every matrix's full
    2^{n-1} step space.  Returns (B, num_blocks, 2) (hi, lo) partials
    (base g=0 terms NOT included).

    ``schedmat`` mode premultiplies the schedule by A and is therefore
    per-matrix; the batch grid shares one schedule input, so only the
    A-independent ``baseline``/``batched`` modes are supported here.
    """
    if mode not in ("baseline", "batched"):
        raise ValueError(f"batch grid supports baseline|batched, got {mode}")
    B, n_pad, _ = A_pads.shape
    dtype = A_pads.dtype
    space = 1 << (n - 1)
    sched = _signed_const_schedule(Wu)
    c0 = jnp.asarray(_cumsig_host(sched, n_pad), dtype)

    kernel = functools.partial(
        _ryser_kernel_batched, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=space, precision=precision, mode=mode, dtype=dtype)

    return pl.pallas_call(
        kernel,
        grid=(B, num_blocks),
        in_specs=[
            pl.BlockSpec((1, n_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, n_pad, 1), lambda b, i: (b, 0, 0)),
            pl.BlockSpec(c0.shape, lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 2), lambda b, i: (b, i, 0)),
        out_shape=shape_dtype_struct((B, num_blocks, 2), dtype),
        interpret=interpret,
    )(A_pads, x_base_pads, c0)
