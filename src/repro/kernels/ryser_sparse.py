"""Pallas SpaRyser kernels over the padded-CCS layout (paper Alg. 2).

The dense kernels (``ryser_pallas`` / ``ryser_complex``) update the
row-sum state X with whole matrix columns; here the Gray-code column
updates come from the shape-static padded CCS arrays that
``sparyser.pack_padded_ccs`` already produces -- per column ``j`` a
``(rows[j], vals[j])`` pair of length ``maxdeg``, padded with
``(row=n, val=0)`` entries that scatter into the dummy row (or nowhere
at all when ``n == n_pad``) and are arithmetically inert.

TPU mapping: a data-dependent scatter does not vectorize on the VPU, so
each padded column is first *densified in VMEM* with a one-hot compare
against a row iota -- ``u_j[i] = sum_d [rows[j, d] == i] * vals[j, d]``,
an (n_pad, maxdeg) compare + matvec instead of the dense kernels'
(n_pad,) column slice.  The CEG window schedule only ever flips the
``kw = log2(Wu)`` low columns at inner steps, so the kernel scatters
exactly ``kw`` columns once per block and generates the per-window
states from the *same* cumulative signed schedule as the dense batched
mode (``_cumsig_host``), restricted to those rows:

    D = U @ c0[:kw]        instead of        D = A @ c0

-- an (n_pad, kw, Wu) contraction instead of (n_pad, n_pad, Wu).  Chunk
init and the per-lane boundary column keep the dense one-hot MXU path
(the dense matrix is resident anyway, exactly like the jnp SpaRyser
engine keeps A for its init matmul).

Geometry (``kernel_geometry``), the u64 lane math, the window schedule
(``_signed_const_schedule`` / ``_cumsig_host``) and the
``device_base_u32`` traced-chunk-base convention are all shared with the
dense kernels, so the scalar launch runs under ``shard_map`` unchanged.
Launch shapes mirror ``ryser_pallas`` / ``ryser_complex``:

* ``ryser_sparse_pallas_call``                  -- grid (num_blocks,), one
  matrix, host-int OR traced device chunk base; (num_blocks, 2) partials.
* ``ryser_sparse_pallas_call_batched``          -- grid (batch, block),
  one launch covers a same-size bucket; (B, num_blocks, 2) partials.
* ``ryser_sparse_pallas_call_complex``          -- split re/im planes,
  (num_blocks, 4) partials (re_hi, re_err, im_hi, im_err).
* ``ryser_sparse_pallas_call_complex_batched``  -- (B, num_blocks, 4).

Real and complex share one pair of block bodies (``_ryser_block_sp`` /
``_ryser_block_sp_cx``), the body-sharing pattern the complex kernels
established.  Accumulation: ``dd``/``kahan``/``dq_acc``/``dq_fast`` per
lane (``qq`` runs as ``dd``, like every kernel); the cross-block twofloat
reduction lives in ops.py (``kernel_reduce``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..utils.compat import shape_dtype_struct
from . import u64emu as U
from .ryser_complex import _cprod
from .ryser_pallas import (_accum_add, _accum_make, _accum_value,
                           _cumsig_host, _signed_const_schedule,
                           device_base_u32)

__all__ = ["ryser_sparse_pallas_call", "ryser_sparse_pallas_call_batched",
           "ryser_sparse_pallas_call_complex",
           "ryser_sparse_pallas_call_complex_batched"]


def _chunk_starts(i, dev_base, TB: int, C: int):
    """(start64, lane iota) of this block's TB chunks -- u64 lane math
    identical to the dense block bodies."""
    k = int(math.log2(C))
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, TB), 1).reshape(TB)
    chunk64 = U.u64_add_u32((jnp.broadcast_to(dev_base[0], (TB,)),
                             jnp.broadcast_to(dev_base[1], (TB,))),
                            (i * TB).astype(jnp.uint32) + lane)
    return U.u64_shl(chunk64, k), lane


def _gray_init_bits(start64, n: int, n_pad: int, TB: int, dtype):
    """(n_pad, TB) Gray-code bit matrix of the chunk start steps."""
    gbits = U.u64_gray(start64)
    rows = [U.u64_bit(gbits, np.uint32(j)).astype(dtype) if j < n
            else jnp.zeros((TB,), dtype) for j in range(n_pad)]
    return jnp.stack(rows, axis=0)


def _scatter_low_columns(rows, vals, kw: int, n_pad: int, dtype):
    """Densify the ``kw`` low CCS columns the window schedule flips.

    ``rows``/``vals`` are the (n, maxdeg) padded CCS arrays; returns
    U (n_pad, kw) with ``U[i, j] = sum_d [rows[j, d] == i] vals[j, d]``.
    Padding entries point at the dummy row ``n``: when ``n < n_pad`` they
    scatter ``val = 0`` (inert), when ``n == n_pad`` the compare matches
    nothing -- either way padded X rows stay exactly 1.
    """
    maxdeg = rows.shape[-1]
    riota = jax.lax.broadcasted_iota(jnp.int32, (n_pad, maxdeg), 0)
    cols = []
    for j in range(kw):
        onehot = (riota == rows[j][None, :].astype(jnp.int32)).astype(dtype)
        cols.append(jax.lax.dot_general(
            onehot, vals[j][:, None].astype(dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=dtype))               # (n_pad, 1)
    return jnp.concatenate(cols, axis=1)                 # (n_pad, kw)


def _boundary_inputs(macro64, Wu: int, space: int, lane, n_pad: int, TB: int,
                     dtype):
    """Per-lane boundary-step (w = Wu) schedule: one-hot column selector,
    signed liveness mask -- shared verbatim with the dense block bodies."""
    space_m1 = U.u64_from_int(space - 1, like=lane)
    gb64 = U.u64_add_u32(macro64, np.uint32(Wu))
    jb = U.u64_ctz(gb64)
    sb = 2 * U.u64_bit(U.u64_gray(gb64), jb).astype(dtype) - 1
    live = U.u64_leq(gb64, space_m1).astype(dtype)
    row_iota = jax.lax.broadcasted_iota(jnp.uint32, (n_pad, TB), 0)
    onehot = (row_iota == jb[None, :].astype(jnp.uint32)).astype(dtype)
    return onehot, sb * live, live


def _ryser_block_sp(i, A, rows, vals, xb, c0, dev_base, *, n: int,
                    n_pad: int, TB: int, C: int, Wu: int, space: int,
                    precision: str, dtype):
    """One grid block of the sparse kernel: TB chunks x C Gray steps.

    Shared between the single-matrix kernel (grid over blocks) and the
    batch-grid kernel (grid over (batch, block)); ``i`` is the block id
    along the chunk axis, ``dev_base`` the u32-pair device chunk base.
    Returns (hi, lo) scalars.
    """
    kw = int(math.log2(Wu))
    M = C // Wu
    dd = (((1,), (0,)), ((), ()))

    start64, lane = _chunk_starts(i, dev_base, TB, C)
    Gb = _gray_init_bits(start64, n, n_pad, TB, dtype)
    X = xb + jax.lax.dot_general(A, Gb, dd, preferred_element_type=dtype)

    sched = _signed_const_schedule(Wu)
    mid_idx = next((ix for ix, st in enumerate(sched) if st[2]), None)
    s_mid = sched[mid_idx][1] if mid_idx is not None else 0

    # window states from the scattered low columns -- macro-invariant:
    # the inner schedule flips columns 0..kw-1 in every window
    Ucols = _scatter_low_columns(rows, vals, kw, n_pad, dtype)
    D = jax.lax.dot_general(Ucols, c0[:kw, :], dd,
                            preferred_element_type=dtype)  # (n_pad, Wu-1)
    col_mid = Ucols[:, kw - 1:kw]

    def macro_body(m, carry):
        X, acc = carry
        macro64 = U.u64_add_u32(start64,
                                m.astype(jnp.uint32) * np.uint32(Wu))
        bitk = U.u64_bit(macro64, np.uint32(kw)).astype(dtype)
        corr = col_mid * (float(-2.0 * s_mid) * bitk)[None, :]
        for idx, (j, s, is_mid, parity) in enumerate(sched):
            state = X + D[:, idx][:, None]
            if mid_idx is not None and idx >= mid_idx:
                state = state + corr
            prod = jnp.prod(state, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
            acc = _accum_add(acc, -prod if parity else prod, precision)
        X = X + D[:, Wu - 2][:, None] if Wu >= 2 else X
        if mid_idx is not None:
            X = X + corr

        # boundary step w = Wu: per-lane column via one-hot MXU (dense A
        # is resident for the init matmul anyway -- same as jnp SpaRyser)
        onehot, sgn, live = _boundary_inputs(macro64, Wu, space, lane,
                                             n_pad, TB, dtype)
        colb = jax.lax.dot_general(A, onehot, dd, preferred_element_type=dtype)
        X = X + colb * sgn[None, :]
        prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis lane product inside one block
        acc = _accum_add(acc, prod * live, precision)  # (-1)^Wu == +1
        return (X, acc)

    acc0 = _accum_make(dtype, (TB,))
    if M == 1:
        X, acc = macro_body(jnp.int32(0), (X, acc0))
    else:
        X, acc = jax.lax.fori_loop(0, M, macro_body, (X, acc0))

    hi, lo = _accum_value(acc, precision)
    # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    return jnp.sum(hi), jnp.sum(lo)


def _ryser_block_sp_cx(i, Ar, Ai, rows, vals_r, vals_i, xbr, xbi, c0,
                       dev_base, *, n: int, n_pad: int, TB: int, C: int,
                       Wu: int, space: int, precision: str, dtype):
    """Split-plane complex sparse block body; mirrors ``_ryser_block_sp``
    with the matrix carried as (re, im) planes and the product chain as
    the complex multiply recurrence (``ryser_complex._cprod``).  Returns
    the four scalars (re_hi, re_err, im_hi, im_err)."""
    kw = int(math.log2(Wu))
    M = C // Wu
    dd = (((1,), (0,)), ((), ()))

    start64, lane = _chunk_starts(i, dev_base, TB, C)
    Gb = _gray_init_bits(start64, n, n_pad, TB, dtype)
    Xr = xbr + jax.lax.dot_general(Ar, Gb, dd, preferred_element_type=dtype)
    Xi = xbi + jax.lax.dot_general(Ai, Gb, dd, preferred_element_type=dtype)

    sched = _signed_const_schedule(Wu)
    mid_idx = next((ix for ix, st in enumerate(sched) if st[2]), None)
    s_mid = sched[mid_idx][1] if mid_idx is not None else 0

    Ur = _scatter_low_columns(rows, vals_r, kw, n_pad, dtype)
    Ui = _scatter_low_columns(rows, vals_i, kw, n_pad, dtype)
    Dr = jax.lax.dot_general(Ur, c0[:kw, :], dd, preferred_element_type=dtype)
    Di = jax.lax.dot_general(Ui, c0[:kw, :], dd, preferred_element_type=dtype)
    cmr = Ur[:, kw - 1:kw]
    cmi = Ui[:, kw - 1:kw]

    def macro_body(m, carry):
        Xr, Xi, acc_r, acc_i = carry
        macro64 = U.u64_add_u32(start64,
                                m.astype(jnp.uint32) * np.uint32(Wu))
        bitk = U.u64_bit(macro64, np.uint32(kw)).astype(dtype)
        corr = (float(-2.0 * s_mid) * bitk)[None, :]
        for idx, (j, s, is_mid, parity) in enumerate(sched):
            sr = Xr + Dr[:, idx][:, None]
            si = Xi + Di[:, idx][:, None]
            if mid_idx is not None and idx >= mid_idx:
                sr = sr + cmr * corr
                si = si + cmi * corr
            pr, pi = _cprod(sr, si, n_pad)
            acc_r = _accum_add(acc_r, -pr if parity else pr, precision)
            acc_i = _accum_add(acc_i, -pi if parity else pi, precision)
        Xr = Xr + Dr[:, Wu - 2][:, None]
        Xi = Xi + Di[:, Wu - 2][:, None]
        if mid_idx is not None:
            Xr = Xr + cmr * corr
            Xi = Xi + cmi * corr

        # boundary step (dense one-hot MXU, both planes)
        onehot, sgn, live = _boundary_inputs(macro64, Wu, space, lane,
                                             n_pad, TB, dtype)
        colr = jax.lax.dot_general(Ar, onehot, dd,
                                   preferred_element_type=dtype)
        coli = jax.lax.dot_general(Ai, onehot, dd,
                                   preferred_element_type=dtype)
        Xr = Xr + colr * sgn[None, :]
        Xi = Xi + coli * sgn[None, :]
        pr, pi = _cprod(Xr, Xi, n_pad)
        acc_r = _accum_add(acc_r, pr * live, precision)  # (-1)^Wu == +1
        acc_i = _accum_add(acc_i, pi * live, precision)
        return (Xr, Xi, acc_r, acc_i)

    acc_r = _accum_make(dtype, (TB,))
    acc_i = _accum_make(dtype, (TB,))
    if M == 1:
        Xr, Xi, acc_r, acc_i = macro_body(jnp.int32(0),
                                          (Xr, Xi, acc_r, acc_i))
    else:
        Xr, Xi, acc_r, acc_i = jax.lax.fori_loop(
            0, M, macro_body, (Xr, Xi, acc_r, acc_i))

    zero = jnp.zeros((), dtype)
    keep_err = precision in ("dq_acc", "dq_fast")
    re_err = jnp.sum(acc_r[1]) if keep_err else zero  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    im_err = jnp.sum(acc_i[1]) if keep_err else zero  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    return jnp.sum(acc_r[0]), re_err, jnp.sum(acc_i[0]), im_err  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract


# ---------------------------------------------------------------------------
# pallas_call wrappers (launch shapes mirror ryser_pallas / ryser_complex)
# ---------------------------------------------------------------------------

def _ryser_sp_kernel(base_hi_ref, base_lo_ref, A_ref, rows_ref, vals_ref,
                     xb_ref, c0_ref, out_ref, **geom):
    """Single-matrix kernel: grid = (num_blocks,); writes (1, 2) partials."""
    dev = (base_hi_ref[0, 0].astype(jnp.uint32),
           base_lo_ref[0, 0].astype(jnp.uint32))
    hi, lo = _ryser_block_sp(pl.program_id(0), A_ref[...], rows_ref[...],
                             vals_ref[...], xb_ref[...], c0_ref[...], dev,
                             **geom)
    out_ref[0, 0] = hi
    out_ref[0, 1] = lo


def _ryser_sp_kernel_batched(A_ref, rows_ref, vals_ref, xb_ref, c0_ref,
                             out_ref, **geom):
    """Batch-grid kernel: grid = (B, num_blocks); one launch covers the
    whole bucket.  Block b of the stacks is selected by the BlockSpec;
    the chunk base is 0 (each matrix owns its full iteration space)."""
    zero = jnp.uint32(0)
    hi, lo = _ryser_block_sp(pl.program_id(1), A_ref[0], rows_ref[0],
                             vals_ref[0], xb_ref[0], c0_ref[...],
                             (zero, zero), **geom)
    out_ref[0, 0, 0] = hi
    out_ref[0, 0, 1] = lo


def _ryser_sp_kernel_cx(base_hi_ref, base_lo_ref, Ar_ref, Ai_ref, rows_ref,
                        vr_ref, vi_ref, xbr_ref, xbi_ref, c0_ref, out_ref,
                        **geom):
    """Single-matrix complex kernel: grid = (num_blocks,); (1, 4) partials."""
    dev = (base_hi_ref[0, 0].astype(jnp.uint32),
           base_lo_ref[0, 0].astype(jnp.uint32))
    hr, er, hi, ei = _ryser_block_sp_cx(
        pl.program_id(0), Ar_ref[...], Ai_ref[...], rows_ref[...],
        vr_ref[...], vi_ref[...], xbr_ref[...], xbi_ref[...], c0_ref[...],
        dev, **geom)
    out_ref[0, 0] = hr
    out_ref[0, 1] = er
    out_ref[0, 2] = hi
    out_ref[0, 3] = ei


def _ryser_sp_kernel_cx_batched(Ar_ref, Ai_ref, rows_ref, vr_ref, vi_ref,
                                xbr_ref, xbi_ref, c0_ref, out_ref, **geom):
    """Batch-grid complex kernel: grid = (B, num_blocks); (1, 1, 4)."""
    zero = jnp.uint32(0)
    hr, er, hi, ei = _ryser_block_sp_cx(
        pl.program_id(1), Ar_ref[0], Ai_ref[0], rows_ref[0], vr_ref[0],
        vi_ref[0], xbr_ref[0], xbi_ref[0], c0_ref[...], (zero, zero),
        **geom)
    out_ref[0, 0, 0] = hr
    out_ref[0, 0, 1] = er
    out_ref[0, 0, 2] = hi
    out_ref[0, 0, 3] = ei


def _c0_input(Wu: int, n_pad: int, dtype):
    return jnp.asarray(_cumsig_host(_signed_const_schedule(Wu), n_pad), dtype)


def ryser_sparse_pallas_call(A_pad, rows, vals, xb, dev_chunk_base, *,
                             n: int, TB: int, C: int, Wu: int,
                             num_blocks: int, precision: str = "dq_acc",
                             interpret: bool = True, vma=None):
    """(num_blocks, 2) sparse (hi, lo) partials, base g=0 term NOT included.

    ``rows``/``vals`` are the (n, maxdeg) padded CCS arrays of ONE matrix;
    ``dev_chunk_base`` may be a host int or a traced scalar (the
    distributed shard_map path), exactly like the dense kernels.
    """
    n_pad = A_pad.shape[0]
    dtype = A_pad.dtype
    maxdeg = rows.shape[-1]
    base_hi, base_lo = device_base_u32(dev_chunk_base)
    c0 = _c0_input(Wu, n_pad, dtype)
    kernel = functools.partial(
        _ryser_sp_kernel, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=1 << (n - 1), precision=precision, dtype=dtype)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), rep), pl.BlockSpec((1, 1), rep),
            pl.BlockSpec((n_pad, n_pad), rep),
            pl.BlockSpec((n, maxdeg), rep),
            pl.BlockSpec((n, maxdeg), rep),
            pl.BlockSpec((n_pad, 1), rep),
            pl.BlockSpec(c0.shape, rep),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=shape_dtype_struct((num_blocks, 2), dtype, vma=vma),
        interpret=interpret,
    )(base_hi, base_lo, A_pad, rows, vals, xb, c0)


def ryser_sparse_pallas_call_batched(A_pads, rows_stack, vals_stack,
                                     xb_pads, *, n: int, TB: int, C: int,
                                     Wu: int, num_blocks: int,
                                     precision: str = "dq_acc",
                                     interpret: bool = True):
    """Launch ONE sparse kernel over a (B, n_pad, n_pad) + (B, n, maxdeg)
    padded-CCS bucket: grid is (batch, block), the sparse analogue of
    ``ryser_pallas_call_batched`` (same geometry inputs and window
    schedule).  Returns (B, num_blocks, 2) (hi, lo) partials."""
    B, n_pad, _ = A_pads.shape
    dtype = A_pads.dtype
    maxdeg = rows_stack.shape[-1]
    c0 = _c0_input(Wu, n_pad, dtype)
    kernel = functools.partial(
        _ryser_sp_kernel_batched, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=1 << (n - 1), precision=precision, dtype=dtype)
    sel = lambda b, i: (b, 0, 0)
    return pl.pallas_call(
        kernel,
        grid=(B, num_blocks),
        in_specs=[
            pl.BlockSpec((1, n_pad, n_pad), sel),
            pl.BlockSpec((1, n, maxdeg), sel),
            pl.BlockSpec((1, n, maxdeg), sel),
            pl.BlockSpec((1, n_pad, 1), sel),
            pl.BlockSpec(c0.shape, lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 2), lambda b, i: (b, i, 0)),
        out_shape=shape_dtype_struct((B, num_blocks, 2), dtype),
        interpret=interpret,
    )(A_pads, rows_stack, vals_stack, xb_pads, c0)


def ryser_sparse_pallas_call_complex(Ar_pad, Ai_pad, rows, vals_r, vals_i,
                                     xbr, xbi, dev_chunk_base, *, n: int,
                                     TB: int, C: int, Wu: int,
                                     num_blocks: int,
                                     precision: str = "dq_acc",
                                     interpret: bool = True, vma=None):
    """(num_blocks, 4) split-plane sparse partials
    (re_hi, re_err, im_hi, im_err); chunk base host int or traced."""
    n_pad = Ar_pad.shape[0]
    dtype = Ar_pad.dtype
    maxdeg = rows.shape[-1]
    base_hi, base_lo = device_base_u32(dev_chunk_base)
    c0 = _c0_input(Wu, n_pad, dtype)
    kernel = functools.partial(
        _ryser_sp_kernel_cx, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=1 << (n - 1), precision=precision, dtype=dtype)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), rep), pl.BlockSpec((1, 1), rep),
            pl.BlockSpec((n_pad, n_pad), rep),
            pl.BlockSpec((n_pad, n_pad), rep),
            pl.BlockSpec((n, maxdeg), rep),
            pl.BlockSpec((n, maxdeg), rep),
            pl.BlockSpec((n, maxdeg), rep),
            pl.BlockSpec((n_pad, 1), rep), pl.BlockSpec((n_pad, 1), rep),
            pl.BlockSpec(c0.shape, rep),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        out_shape=shape_dtype_struct((num_blocks, 4), dtype, vma=vma),
        interpret=interpret,
    )(base_hi, base_lo, Ar_pad, Ai_pad, rows, vals_r, vals_i, xbr, xbi, c0)


def ryser_sparse_pallas_call_complex_batched(Ar_pads, Ai_pads, rows_stack,
                                             vals_r_stack, vals_i_stack,
                                             xbr_pads, xbi_pads, *, n: int,
                                             TB: int, C: int, Wu: int,
                                             num_blocks: int,
                                             precision: str = "dq_acc",
                                             interpret: bool = True):
    """(B, num_blocks, 4) split-plane sparse partials over a (batch, block)
    grid -- the complex analogue of ``ryser_sparse_pallas_call_batched``."""
    B, n_pad, _ = Ar_pads.shape
    dtype = Ar_pads.dtype
    maxdeg = rows_stack.shape[-1]
    c0 = _c0_input(Wu, n_pad, dtype)
    kernel = functools.partial(
        _ryser_sp_kernel_cx_batched, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=1 << (n - 1), precision=precision, dtype=dtype)
    sel = lambda b, i: (b, 0, 0)
    return pl.pallas_call(
        kernel,
        grid=(B, num_blocks),
        in_specs=[
            pl.BlockSpec((1, n_pad, n_pad), sel),
            pl.BlockSpec((1, n_pad, n_pad), sel),
            pl.BlockSpec((1, n, maxdeg), sel),
            pl.BlockSpec((1, n, maxdeg), sel),
            pl.BlockSpec((1, n, maxdeg), sel),
            pl.BlockSpec((1, n_pad, 1), sel),
            pl.BlockSpec((1, n_pad, 1), sel),
            pl.BlockSpec(c0.shape, lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 4), lambda b, i: (b, i, 0)),
        out_shape=shape_dtype_struct((B, num_blocks, 4), dtype),
        interpret=interpret,
    )(Ar_pads, Ai_pads, rows_stack, vals_r_stack, vals_i_stack,
      xbr_pads, xbi_pads, c0)
