"""Emulated 64-bit unsigned integers as uint32 (hi, lo) pairs.

TPUs have no native 64-bit integer vector units; the Gray-code iteration
space of an n x n permanent reaches 2^{n-1} - 1 (n up to ~64), so global
step indices do not fit in 32 bits.  The Pallas kernels therefore carry
chunk/step indices as uint32 pairs and use these helpers for the handful
of bit manipulations the Ryser schedule needs:

    shift-left (chunk id -> start step), xor-shift (Gray code),
    bit extraction (signs, init bits), and ctz (changed-bit index).

Everything is element-wise over lane vectors and lowers to plain VPU
integer ops.  Validated against Python bigints in tests/test_u64emu.py.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "u64", "u64_from_int", "u64_add", "u64_add_u32", "u64_shl",
    "u64_shr1", "u64_xor", "u64_gray", "u64_bit", "u64_ctz", "u64_leq",
    "ctz32",
]

U1 = np.uint32(1)
U0 = np.uint32(0)


def u64(hi, lo):
    return (jnp.asarray(hi, jnp.uint32), jnp.asarray(lo, jnp.uint32))


def u64_from_int(v: int, like=None):
    """Host int -> (hi, lo) broadcast against `like` (a uint32 array)."""
    hi = np.uint32((v >> 32) & 0xFFFFFFFF)
    lo = np.uint32(v & 0xFFFFFFFF)
    if like is not None:
        return (jnp.full_like(like, hi), jnp.full_like(like, lo))
    return (hi, lo)


def u64_add(a, b):
    ahi, alo = a
    bhi, blo = b
    lo = alo + blo
    carry = (lo < alo).astype(jnp.uint32)
    return (ahi + bhi + carry, lo)


def u64_add_u32(a, v):
    ahi, alo = a
    v = jnp.asarray(v, jnp.uint32)
    lo = alo + v
    carry = (lo < alo).astype(jnp.uint32)
    return (ahi + carry, lo)


def u64_shl(a, k: int):
    """Shift left by a static 0 <= k < 32."""
    ahi, alo = a
    if k == 0:
        return a
    kk = np.uint32(k)
    hi = (ahi << kk) | (alo >> np.uint32(32 - k))
    lo = alo << kk
    return (hi, lo)


def u64_shr1(a):
    ahi, alo = a
    lo = (alo >> U1) | (ahi << np.uint32(31))
    hi = ahi >> U1
    return (hi, lo)


def u64_xor(a, b):
    return (a[0] ^ b[0], a[1] ^ b[1])


def u64_gray(a):
    """g ^ (g >> 1) across the pair."""
    return u64_xor(a, u64_shr1(a))


def u64_bit(a, j):
    """Bit j (0..63, traced per-lane uint32 array) as uint32 {0, 1}."""
    hi, lo = a
    j = jnp.asarray(j, jnp.uint32)
    jlo = jnp.minimum(j, np.uint32(31))
    jhi = jnp.minimum(j - np.uint32(32), np.uint32(31))
    from_lo = (lo >> jlo) & U1
    from_hi = (hi >> jhi) & U1
    return jnp.where(j < np.uint32(32), from_lo, from_hi)


def ctz32(v):
    """Count trailing zeros of nonzero uint32 via exact float32 exponent.

    v & -v isolates the lowest set bit (a power of two <= 2^31); its f32
    representation is exact, so the unbiased exponent equals the index.
    Avoids relying on popcount support in the TPU vector ISA.
    """
    import jax
    low = v & (~v + U1)
    f = low.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(f, jnp.uint32)
    exp = (bits >> np.uint32(23)).astype(jnp.int32) - 127
    return exp.astype(jnp.uint32)


def u64_ctz(a):
    hi, lo = a
    lo_zero = lo == U0
    safe_lo = jnp.where(lo_zero, U1, lo)
    safe_hi = jnp.where(hi == U0, U1, hi)
    return jnp.where(lo_zero, np.uint32(32) + ctz32(safe_hi), ctz32(safe_lo))


def u64_leq(a, b):
    """a <= b (element-wise)."""
    ahi, alo = a
    bhi, blo = b
    return (ahi < bhi) | ((ahi == bhi) & (alo <= blo))
