"""Pure-jnp oracle mirroring the Pallas kernel semantics exactly.

``block_partials_ref`` reproduces the kernel's block/chunk/window geometry
and accumulation order with plain jnp ops, so kernel-vs-ref comparisons
isolate Pallas-specific bugs from algorithmic ones.  The ground truth for
*values* remains core.oracle; this oracle additionally pins down the
*decomposition* (per-block partial sums).
"""

from __future__ import annotations

import jax.numpy as jnp
from ..core import precision as P
from ..core.ryser import chunk_partial_sums, nw_base_vector, _final_factor

__all__ = ["block_partials_ref", "permanent_ref"]


def block_partials_ref(A, *, TB: int, C: int, num_blocks: int,
                       dev_chunk_base: int = 0, precision: str = "dq_acc"):
    """(num_blocks, 2) partial sums with the same chunk->block mapping as
    the kernel (block b owns chunks [base + b*TB, base + (b+1)*TB))."""
    A = jnp.asarray(A)
    n = A.shape[0]
    space = 1 << (n - 1)
    total_chunks = space // C
    outs = []
    for b in range(num_blocks):
        parts = chunk_partial_sums(
            A, TB, C, precision,
            chunk_offset=dev_chunk_base + b * TB,
            total_chunks=total_chunks)
        # permlint: disable=PL001  # parts shape fixed by (TB, C) geometry; reference path
        hi, lo = P.two_sum(jnp.sum(parts.hi), jnp.sum(parts.lo))
        # permlint: disable=PL001  # same fixed (TB,) shape as above
        outs.append((hi, lo + jnp.sum(parts.lo) * 0))
    return jnp.asarray(outs)


def permanent_ref(A, *, TB: int, C: int, num_blocks: int,
                  precision: str = "dq_acc"):
    A = jnp.asarray(A)
    n = A.shape[0]
    out = block_partials_ref(A, TB=TB, C=C, num_blocks=num_blocks,
                             precision=precision)
    # permlint: disable=PL001  # num_blocks axis fixed by the plan; reference path
    hi, e = P.two_sum(jnp.sum(out[:, 0]), jnp.sum(out[:, 1]))
    p0 = jnp.prod(nw_base_vector(A))  # permlint: disable=PL001  # length-n product
    total = P.tf_add_acc(P.TwoFloat(hi, e), p0)
    return P.tf_value(total) * _final_factor(n)
