"""Complex-matrix Pallas Ryser kernels (boson-sampling workloads, Sec. 1).

TPU VPUs have no complex dtype, so the kernels carry split re/im planes:
the row-sum state is (Xr, Xi), column updates are two real adds, and the
product chain is the complex multiply recurrence

    (pr, pi) <- (pr*xr - pi*xi, pr*xi + pi*xr)

unrolled over rows (4 mults + 2 adds per row per lane).  Geometry, u64
lane math, CEG window alignment and the boundary one-hot matmul are shared
with the real kernel (window-batched mode: per-window states from two real
MXU matmuls).  Padded rows multiply by (1 + 0i).

Two launch shapes, mirroring ``ryser_pallas``:

* ``ryser_pallas_call_complex``          -- grid (num_blocks,), one matrix;
  accepts a host int OR traced device chunk base, so the distributed
  step-space split can run it per device under shard_map.
* ``ryser_pallas_call_complex_batched``  -- grid (batch, block), one launch
  covers a whole same-size stack (the complex analogue of
  ``ryser_pallas_call_batched``); chunk bases are 0.

Both wrap the same block body ``_ryser_block_cx``.  Accumulation: dd or
kahan or dq_acc per component; output columns are
(re_hi, re_err, im_hi, im_err).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..utils.compat import shape_dtype_struct
from . import u64emu as U
from .ryser_pallas import (_accum_add, _accum_make, _cumsig_host,
                           _signed_const_schedule, device_base_u32)

__all__ = ["ryser_pallas_call_complex", "ryser_pallas_call_complex_batched"]


def _cprod(Xr, Xi, n_pad):
    """Complex product over rows: (n_pad, TB) x2 -> (TB,) x2."""
    pr, pi = Xr[0], Xi[0]
    for i in range(1, n_pad):
        pr, pi = pr * Xr[i] - pi * Xi[i], pr * Xi[i] + pi * Xr[i]
    return pr, pi


def _ryser_block_cx(i, Ar, Ai, xbr, xbi, c0, dev_base, *, n: int, n_pad: int,
                    TB: int, C: int, Wu: int, space: int, precision: str,
                    dtype):
    """One grid block of the split-plane kernel: TB chunks x C Gray steps.

    Shared between the single-matrix kernel (grid over blocks) and the
    batch-grid kernel (grid over (batch, block)), exactly like the real
    kernel's ``_ryser_block``; ``i`` is the block id along the chunk axis
    and ``dev_base`` the u32-pair device chunk base.  Returns the four
    scalars (re_hi, re_err, im_hi, im_err).
    """
    k = int(math.log2(C))
    kw = int(math.log2(Wu))
    M = C // Wu

    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, TB), 1).reshape(TB)
    chunk64 = U.u64_add_u32((jnp.broadcast_to(dev_base[0], (TB,)),
                             jnp.broadcast_to(dev_base[1], (TB,))),
                            (i * TB).astype(jnp.uint32) + lane)
    start64 = U.u64_shl(chunk64, k)

    gbits = U.u64_gray(start64)
    rows = [U.u64_bit(gbits, np.uint32(j)).astype(dtype) if j < n
            else jnp.zeros((TB,), dtype) for j in range(n_pad)]
    Gb = jnp.stack(rows, axis=0)
    dd = (((1,), (0,)), ((), ()))
    Xr = xbr + jax.lax.dot_general(Ar, Gb, dd, preferred_element_type=dtype)
    Xi = xbi + jax.lax.dot_general(Ai, Gb, dd, preferred_element_type=dtype)

    sched = _signed_const_schedule(Wu)
    space_m1 = U.u64_from_int(space - 1, like=lane)
    row_iota = jax.lax.broadcasted_iota(jnp.uint32, (n_pad, TB), 0)
    C0 = c0
    mid_idx = next((ix for ix, st in enumerate(sched) if st[2]), None)

    def macro_body(m, carry):
        Xr, Xi, acc_r, acc_i = carry
        macro64 = U.u64_add_u32(start64,
                                m.astype(jnp.uint32) * np.uint32(Wu))
        bitk = U.u64_bit(macro64, np.uint32(kw)).astype(dtype)

        # window-batched states: D = A @ cumsig for both planes
        Dr = jax.lax.dot_general(Ar, C0, dd, preferred_element_type=dtype)
        Di = jax.lax.dot_general(Ai, C0, dd, preferred_element_type=dtype)
        cmr = jax.lax.dynamic_slice_in_dim(Ar, kw - 1, 1, 1)
        cmi = jax.lax.dynamic_slice_in_dim(Ai, kw - 1, 1, 1)
        s_mid = sched[mid_idx][1] if mid_idx is not None else 0
        corr = (float(-2.0 * s_mid) * bitk)[None, :]
        for idx, (j, s, is_mid, parity) in enumerate(sched):
            sr = Xr + Dr[:, idx][:, None]
            si = Xi + Di[:, idx][:, None]
            if mid_idx is not None and idx >= mid_idx:
                sr = sr + cmr * corr
                si = si + cmi * corr
            pr, pi = _cprod(sr, si, n_pad)
            acc_r = _accum_add(acc_r, -pr if parity else pr, precision)
            acc_i = _accum_add(acc_i, -pi if parity else pi, precision)
        Xr = Xr + Dr[:, Wu - 2][:, None]
        Xi = Xi + Di[:, Wu - 2][:, None]
        if mid_idx is not None:
            Xr = Xr + cmr * corr
            Xi = Xi + cmi * corr

        # boundary step
        gb64 = U.u64_add_u32(macro64, np.uint32(Wu))
        jb = U.u64_ctz(gb64)
        sb = 2 * U.u64_bit(U.u64_gray(gb64), jb).astype(dtype) - 1
        live = U.u64_leq(gb64, space_m1).astype(dtype)
        onehot = (row_iota == jb[None, :].astype(jnp.uint32)).astype(dtype)
        colr = jax.lax.dot_general(Ar, onehot, dd,
                                   preferred_element_type=dtype)
        coli = jax.lax.dot_general(Ai, onehot, dd,
                                   preferred_element_type=dtype)
        Xr = Xr + colr * (sb * live)[None, :]
        Xi = Xi + coli * (sb * live)[None, :]
        pr, pi = _cprod(Xr, Xi, n_pad)
        acc_r = _accum_add(acc_r, pr * live, precision)  # (-1)^Wu == +1
        acc_i = _accum_add(acc_i, pi * live, precision)
        return (Xr, Xi, acc_r, acc_i)

    acc_r = _accum_make(dtype, (TB,))
    acc_i = _accum_make(dtype, (TB,))
    if M == 1:
        Xr, Xi, acc_r, acc_i = macro_body(jnp.int32(0),
                                          (Xr, Xi, acc_r, acc_i))
    else:
        Xr, Xi, acc_r, acc_i = jax.lax.fori_loop(
            0, M, macro_body, (Xr, Xi, acc_r, acc_i))

    zero = jnp.zeros((), dtype)
    keep_err = precision in ("dq_acc", "dq_fast")
    # in-kernel lane reduce: fixed (TB,) lane axis inside one block; kernel
    # values are covered by the 1e-9 kernel-vs-jnp contract, not mesh identity
    re_err = jnp.sum(acc_r[1]) if keep_err else zero  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    im_err = jnp.sum(acc_i[1]) if keep_err else zero  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract
    return jnp.sum(acc_r[0]), re_err, jnp.sum(acc_i[0]), im_err  # permlint: disable=PL001  # in-kernel lane reduce, under the 1e-9 kernel contract


def _ryser_kernel_cx(base_hi_ref, base_lo_ref, Ar_ref, Ai_ref, xbr_ref,
                     xbi_ref, c0_ref, out_ref, **geom):
    """Single-matrix kernel: grid = (num_blocks,); writes (1, 4) partials."""
    dev = (base_hi_ref[0, 0].astype(jnp.uint32),
           base_lo_ref[0, 0].astype(jnp.uint32))
    hr, er, hi, ei = _ryser_block_cx(
        pl.program_id(0), Ar_ref[...], Ai_ref[...], xbr_ref[...],
        xbi_ref[...], c0_ref[...], dev, **geom)
    out_ref[0, 0] = hr
    out_ref[0, 1] = er
    out_ref[0, 2] = hi
    out_ref[0, 3] = ei


def _ryser_kernel_cx_batched(Ar_ref, Ai_ref, xbr_ref, xbi_ref, c0_ref,
                             out_ref, **geom):
    """Batch-grid kernel: grid = (B, num_blocks); one launch covers the
    whole stack.  Block b of the plane stacks is selected by the
    BlockSpec; the chunk base is 0 (each matrix owns its full space)."""
    zero = jnp.uint32(0)
    hr, er, hi, ei = _ryser_block_cx(
        pl.program_id(1), Ar_ref[0], Ai_ref[0], xbr_ref[0], xbi_ref[0],
        c0_ref[...], (zero, zero), **geom)
    out_ref[0, 0, 0] = hr
    out_ref[0, 0, 1] = er
    out_ref[0, 0, 2] = hi
    out_ref[0, 0, 3] = ei


def ryser_pallas_call_complex(Ar_pad, Ai_pad, xbr, xbi,
                              dev_chunk_base, *, n: int, TB: int,
                              C: int, Wu: int, num_blocks: int,
                              precision: str = "dq_acc",
                              interpret: bool = True, vma=None):
    """(num_blocks, 4) partials: (re_hi, re_err, im_hi, im_err).

    ``dev_chunk_base`` may be a host int or a traced scalar (the
    distributed shard_map path), exactly like the real kernel.
    """
    n_pad = Ar_pad.shape[0]
    dtype = Ar_pad.dtype
    space = 1 << (n - 1)
    base_hi, base_lo = device_base_u32(dev_chunk_base)
    c0 = jnp.asarray(_cumsig_host(_signed_const_schedule(Wu), n_pad), dtype)
    kernel = functools.partial(
        _ryser_kernel_cx, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu, space=space,
        precision=precision, dtype=dtype)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), rep), pl.BlockSpec((1, 1), rep),
            pl.BlockSpec((n_pad, n_pad), rep),
            pl.BlockSpec((n_pad, n_pad), rep),
            pl.BlockSpec((n_pad, 1), rep), pl.BlockSpec((n_pad, 1), rep),
            pl.BlockSpec(c0.shape, rep),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        out_shape=shape_dtype_struct((num_blocks, 4), dtype, vma=vma),
        interpret=interpret,
    )(base_hi, base_lo, Ar_pad, Ai_pad, xbr, xbi, c0)


def ryser_pallas_call_complex_batched(Ar_pads, Ai_pads, xbr_pads, xbi_pads,
                                      *, n: int, TB: int, C: int, Wu: int,
                                      num_blocks: int,
                                      precision: str = "dq_acc",
                                      interpret: bool = True):
    """Launch ONE split-plane kernel over a (B, n_pad, n_pad) plane pair:
    grid is (batch, block), so a single ``pallas_call`` covers every
    matrix's full 2^{n-1} step space -- the complex analogue of
    ``ryser_pallas_call_batched``, sharing its geometry inputs
    (``kernel_geometry``) and the window schedule (``_cumsig_host``).
    Returns (B, num_blocks, 4) (re_hi, re_err, im_hi, im_err) partials
    (base g=0 terms NOT included).
    """
    B, n_pad, _ = Ar_pads.shape
    dtype = Ar_pads.dtype
    space = 1 << (n - 1)
    c0 = jnp.asarray(_cumsig_host(_signed_const_schedule(Wu), n_pad), dtype)

    kernel = functools.partial(
        _ryser_kernel_cx_batched, n=n, n_pad=n_pad, TB=TB, C=C, Wu=Wu,
        space=space, precision=precision, dtype=dtype)

    return pl.pallas_call(
        kernel,
        grid=(B, num_blocks),
        in_specs=[
            pl.BlockSpec((1, n_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, n_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, n_pad, 1), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, n_pad, 1), lambda b, i: (b, 0, 0)),
            pl.BlockSpec(c0.shape, lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 4), lambda b, i: (b, i, 0)),
        out_shape=shape_dtype_struct((B, num_blocks, 4), dtype),
        interpret=interpret,
    )(Ar_pads, Ai_pads, xbr_pads, xbi_pads, c0)
