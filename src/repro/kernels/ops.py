"""Public jitted wrappers around the Pallas Ryser kernels.

``permanent_pallas(A)`` computes perm(A) with the TPU kernel (interpret mode
on CPU).  ``block_partials_pallas`` exposes the raw per-block partial sums
for the distributed runtime (each device runs the kernel over its own chunk
range; the cross-device reduction is a psum, exactly like the jnp engine).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import precision as P
from ..core.ryser import nw_base_vector, _final_factor
from .ryser_pallas import (kernel_geometry, ryser_pallas_call,
                           ryser_pallas_call_batched)

__all__ = ["permanent_pallas", "permanent_pallas_batched",
           "block_partials_pallas", "pad_matrix"]

_SUBLANE = 8  # f32 sublane quantum on TPU


def pad_matrix(A, n_pad: int | None = None):
    """Pad A to (n_pad, n_pad) with zeros; padded x entries must be 1 so
    products are unaffected -- handled by pad_base_vector."""
    A = jnp.asarray(A)
    n = A.shape[0]
    if n_pad is None:
        n_pad = max(_SUBLANE, int(math.ceil(n / _SUBLANE)) * _SUBLANE)
    out = jnp.zeros((n_pad, n_pad), dtype=A.dtype)
    return out.at[:n, :n].set(A)


def pad_base_vector(x, n_pad: int):
    n = x.shape[0]
    out = jnp.ones((n_pad,), dtype=x.dtype)
    return out.at[:n].set(x)


def block_partials_pallas(A, *, dev_chunk_base: int = 0,
                          num_blocks: int | None = None,
                          lanes: int = 128, steps_per_chunk: int = 64,
                          window: int = 16, precision: str = "dq_acc",
                          mode: str = "baseline", interpret: bool = True):
    """Run the kernel over ``num_blocks`` blocks starting at chunk
    ``dev_chunk_base``; returns (num_blocks, 2) (hi, lo) partials."""
    A = jnp.asarray(A)
    n = A.shape[0]
    TB, C, Wu, full_blocks = kernel_geometry(
        n, lanes=lanes, steps_per_chunk=steps_per_chunk, window=window)
    if num_blocks is None:
        num_blocks = full_blocks
    A_pad = pad_matrix(A)
    xb = pad_base_vector(nw_base_vector(A), A_pad.shape[0]).reshape(-1, 1)
    out = ryser_pallas_call(
        A_pad, xb, dev_chunk_base, n=n, TB=TB, C=C, Wu=Wu,
        num_blocks=num_blocks, precision=precision, mode=mode,
        interpret=interpret)
    return out, (TB, C, Wu, full_blocks)


def permanent_pallas(A, *, precision: str = "dq_acc", mode: str = "baseline",
                     lanes: int = 128, steps_per_chunk: int = 64,
                     window: int = 16, interpret: bool = True):
    """perm(A) via the Pallas kernel (full iteration space, one device).

    Complex matrices run the split re/im kernel (window-batched mode)."""
    A = jnp.asarray(A)
    n = A.shape[0]
    if n == 1:
        return A[0, 0]
    if n == 2:
        return A[0, 0] * A[1, 1] + A[0, 1] * A[1, 0]
    if jnp.iscomplexobj(A):
        return _permanent_pallas_complex(
            A, precision=precision, lanes=lanes,
            steps_per_chunk=steps_per_chunk, window=window,
            interpret=interpret)
    out, _ = block_partials_pallas(
        A, lanes=lanes, steps_per_chunk=steps_per_chunk, window=window,
        precision=precision, mode=mode, interpret=interpret)
    # outer reduction in twofloat (paper: quad outer sum)
    hi, e = P.two_sum(jnp.sum(out[:, 0]), jnp.sum(out[:, 1]))
    p0 = jnp.prod(nw_base_vector(A))
    total = P.tf_add_acc(P.TwoFloat(hi, e), p0)
    return P.tf_value(total) * _final_factor(n)


@partial(jax.jit, static_argnames=("n", "precision", "mode", "lanes",
                                   "steps_per_chunk", "window", "interpret"))
def _pallas_batched_jit(As, n: int, precision: str, mode: str, lanes: int,
                        steps_per_chunk: int, window: int, interpret: bool):
    TB, C, Wu, blocks = kernel_geometry(
        n, lanes=lanes, steps_per_chunk=steps_per_chunk, window=window)
    A_pads = jax.vmap(lambda A: pad_matrix(A))(As)       # (B, n_pad, n_pad)
    n_pad = A_pads.shape[1]
    xbs = jax.vmap(nw_base_vector)(As)                   # (B, n)
    xb_pads = jax.vmap(
        lambda x: pad_base_vector(x, n_pad))(xbs)[:, :, None]
    out = ryser_pallas_call_batched(
        A_pads, xb_pads, n=n, TB=TB, C=C, Wu=Wu, num_blocks=blocks,
        precision=precision, mode=mode, interpret=interpret)
    # per-matrix outer reduction in twofloat (paper: quad outer sum)
    hi, e = P.two_sum(jnp.sum(out[:, :, 0], axis=1),
                      jnp.sum(out[:, :, 1], axis=1))
    p0 = jnp.prod(xbs, axis=1)
    total = P.tf_add_acc(P.TwoFloat(hi, e), p0)
    return P.tf_value(total) * _final_factor(n)


def permanent_pallas_batched(As, *, precision: str = "dq_acc",
                             mode: str = "batched", lanes: int = 128,
                             steps_per_chunk: int = 64, window: int = 16,
                             interpret: bool = True):
    """perm of a (B, n, n) real stack via ONE batch-grid kernel launch.

    The grid is (batch, block): every matrix's full iteration space runs
    inside a single ``pallas_call``, so compilation and dispatch are
    amortized over the stack (vs B separate ``permanent_pallas`` calls).
    Complex stacks are not supported here -- the engine routes those to
    the vmapped jnp path (``ryser.perm_ryser_batched``).
    """
    As = jnp.asarray(As)
    if As.ndim != 3 or As.shape[1] != As.shape[2]:
        raise ValueError(f"(B, n, n) stack required, got {As.shape}")
    if jnp.iscomplexobj(As):
        raise ValueError("complex stacks: use ryser.perm_ryser_batched")
    n = As.shape[1]
    if n == 1:
        return As[:, 0, 0]
    if n == 2:
        return As[:, 0, 0] * As[:, 1, 1] + As[:, 0, 1] * As[:, 1, 0]
    # precision passes through untouched so bucket members and scalar
    # stragglers share semantics (the kernel accumulates unknown modes as
    # dd, same as permanent_pallas)
    return _pallas_batched_jit(As, n, precision, mode, lanes,
                               steps_per_chunk, window, interpret)


def _permanent_pallas_complex(A, *, precision, lanes, steps_per_chunk,
                              window, interpret):
    from .ryser_complex import ryser_pallas_call_complex
    n = A.shape[0]
    prec = precision if precision in ("dd", "kahan", "dq_acc") else "dq_acc"
    TB, C, Wu, blocks = kernel_geometry(
        n, lanes=lanes, steps_per_chunk=steps_per_chunk, window=window)
    Ar = pad_matrix(jnp.real(A))
    Ai = pad_matrix(jnp.imag(A))
    xb = nw_base_vector(A)
    xbr = pad_base_vector(jnp.real(xb), Ar.shape[0]).reshape(-1, 1)
    # padded rows multiply by (1 + 0i)
    xbi = jnp.zeros((Ar.shape[0], 1), Ar.dtype).at[:n, 0].set(jnp.imag(xb))
    out = ryser_pallas_call_complex(
        Ar, Ai, xbr, xbi, 0, n=n, TB=TB, C=C, Wu=Wu, num_blocks=blocks,
        precision=prec, interpret=interpret)
    re_hi, e1 = P.two_sum(jnp.sum(out[:, 0]), jnp.sum(out[:, 1]))
    im_hi, e2 = P.two_sum(jnp.sum(out[:, 2]), jnp.sum(out[:, 3]))
    p0 = jnp.prod(xb)
    tot_r = P.tf_add_acc(P.TwoFloat(re_hi, e1), jnp.real(p0))
    tot_i = P.tf_add_acc(P.TwoFloat(im_hi, e2), jnp.imag(p0))
    return (P.tf_value(tot_r) + 1j * P.tf_value(tot_i)) * _final_factor(n)
