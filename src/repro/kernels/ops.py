"""Public jitted wrappers around the Pallas Ryser kernels.

``permanent_pallas(A)`` computes perm(A) with the TPU kernel (interpret mode
on CPU); ``permanent_pallas_batched(As)`` covers a whole same-size stack
with one (batch, block)-grid launch.  Both route real AND complex input
through one dispatch helper (``_pallas_values``): geometry, padding, base
vectors and the twofloat cross-block epilogue are computed once, and only
the kernel entry differs -- real matrices run ``ryser_pallas``, complex
matrices run the split re/im plane kernels in ``ryser_complex`` (same
geometry, same window schedule).  The sparse route has the same shape:
``permanent_pallas_sparse(sp)`` / ``permanent_pallas_sparse_batched(sps)``
drive the padded-CCS SpaRyser kernels (``ryser_sparse``) through the
sparse arm of the helper (``_pallas_sparse_values``), sharing
``kernel_geometry`` and ``kernel_reduce`` with the dense arm.
``block_partials_pallas`` exposes the raw per-block partial sums for the
distributed runtime (each device runs the kernel over its own chunk
range; the cross-device reduction is a psum, exactly like the jnp
engine).

Precision passes through untouched on every route: the kernels implement
``dd``/``dq_fast``/``dq_acc``/``kahan`` accumulation and run ``qq`` (no
in-kernel twofloat product) as ``dd`` -- identically for scalar and
batched, real and complex, so bucket members and scalar stragglers share
semantics.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..core import precision as P
from ..core.ryser import nw_base_vector, _final_factor
from ..core.stepspace import DEFAULT_GEOMETRY, Geometry
from .ryser_pallas import ryser_pallas_call, ryser_pallas_call_batched

__all__ = ["Geometry", "DEFAULT_GEOMETRY",
           "permanent_pallas", "permanent_pallas_batched",
           "permanent_pallas_sparse", "permanent_pallas_sparse_batched",
           "sparse_batched_values_pallas",
           "block_partials_pallas", "kernel_reduce", "pad_matrix",
           "pad_base_vector", "split_matrix_planes", "split_base_planes"]

_SUBLANE = 8  # f32 sublane quantum on TPU


def pad_matrix(A, n_pad: int | None = None):
    """Pad A to (n_pad, n_pad) with zeros; padded x entries must be 1 so
    products are unaffected -- handled by pad_base_vector."""
    A = jnp.asarray(A)
    n = A.shape[0]
    if n_pad is None:
        n_pad = max(_SUBLANE, int(math.ceil(n / _SUBLANE)) * _SUBLANE)
    out = jnp.zeros((n_pad, n_pad), dtype=A.dtype)
    return out.at[:n, :n].set(A)


def pad_base_vector(x, n_pad: int):
    n = x.shape[0]
    out = jnp.ones((n_pad,), dtype=x.dtype)
    return out.at[:n].set(x)


def split_matrix_planes(A):
    """Zero-padded (re, im) planes of a complex matrix or (B, n, n) stack."""
    pad = pad_matrix if A.ndim == 2 else jax.vmap(pad_matrix)
    return pad(jnp.real(A)), pad(jnp.imag(A))


def split_base_planes(xb, n_pad: int):
    """Padded (re, im) planes of NW base vector(s), trailing unit column.

    Padded rows multiply by (1 + 0i): the re plane pads with ones, the im
    plane with zeros.  ``xb`` is (n,) or (B, n); returns (..., n_pad, 1).
    """
    n = xb.shape[-1]
    shape = xb.shape[:-1] + (n_pad,)
    dtype = jnp.real(xb).dtype
    xbr = jnp.ones(shape, dtype).at[..., :n].set(jnp.real(xb))
    xbi = jnp.zeros(shape, dtype).at[..., :n].set(jnp.imag(xb))
    return xbr[..., None], xbi[..., None]


def kernel_reduce(parts_hi, parts_lo, p0, n: int, axis=None):
    """Cross-block twofloat epilogue shared by every kernel entry.

    Sums the per-block (hi, lo) partials, folds in the base (g = 0)
    product and applies the final Ryser factor -- the "quad outer sum" of
    the paper, per matrix (``axis=1`` for batched partials) and per
    complex component (callers run it once per plane).
    """
    # partials axis length = num_blocks, fixed by kernel geometry per plan --
    # association never varies with batch or device count
    hi, e = P.two_sum(jnp.sum(parts_hi, axis=axis),    # permlint: disable=PL001  # shape-stable by kernel geometry
                      jnp.sum(parts_lo, axis=axis))    # permlint: disable=PL001  # shape-stable by kernel geometry
    total = P.tf_add_acc(P.TwoFloat(hi, e), p0)
    return P.tf_value(total) * _final_factor(n)


def block_partials_pallas(A, *, dev_chunk_base: int = 0,
                          num_blocks: int | None = None,
                          geometry: Geometry | None = None,
                          precision: str = "dq_acc",
                          mode: str = "baseline", interpret: bool = True):
    """Run the kernel over ``num_blocks`` blocks starting at chunk
    ``dev_chunk_base``; returns (num_blocks, 2) (hi, lo) partials."""
    A = jnp.asarray(A)
    n = A.shape[0]
    TB, C, Wu, full_blocks = (geometry or DEFAULT_GEOMETRY).kernel_geometry(n)
    if num_blocks is None:
        num_blocks = full_blocks
    A_pad = pad_matrix(A)
    xb = pad_base_vector(nw_base_vector(A), A_pad.shape[0]).reshape(-1, 1)
    out = ryser_pallas_call(
        A_pad, xb, dev_chunk_base, n=n, TB=TB, C=C, Wu=Wu,
        num_blocks=num_blocks, precision=precision, mode=mode,
        interpret=interpret)
    return out, (TB, C, Wu, full_blocks)


# ---------------------------------------------------------------------------
# The real/complex x scalar/batched dispatch helpers
# ---------------------------------------------------------------------------
# Shared scaffolding: padding + NW base vectors on the way in, the
# twofloat ``kernel_reduce`` epilogue on the way out -- one copy serving
# both the dense and the sparse arm, which differ only in kernel entry
# points (and the extra padded-CCS operands the sparse kernels take).

def _prep_real(As, batched: bool):
    """(A_pads, xb_pads, xbs) for a real matrix or stack."""
    pad = jax.vmap(pad_matrix) if batched else pad_matrix
    A_pads = pad(As)
    n_pad = A_pads.shape[-1]
    xbs = (jax.vmap(nw_base_vector) if batched else nw_base_vector)(As)
    pad_xb = lambda x: pad_base_vector(x, n_pad)
    xb_pads = (jax.vmap(pad_xb) if batched else pad_xb)(xbs)[..., None]
    return A_pads, xb_pads, xbs


def _prep_complex(As, batched: bool):
    """Split (re, im) planes + padded base-vector planes for complex."""
    Ar_pads, Ai_pads = split_matrix_planes(As)
    # nw_base_vector is elementwise prep (row sums / padding), not an
    # accumulation body -- vmap here shares the exact scalar adds with
    # the unbatched path
    xbs = (jax.vmap(nw_base_vector) if batched else nw_base_vector)(As)  # permlint: disable=PL002  # elementwise prep, not an engine body
    xbr, xbi = split_base_planes(xbs, Ar_pads.shape[-1])
    return Ar_pads, Ai_pads, xbr, xbi, xbs


def _reduce_real(out, xbs, n: int, batched: bool):
    """Cross-block epilogue over (B, blocks, 2) real (hi, lo) partials."""
    p0 = jnp.prod(xbs, axis=-1)  # permlint: disable=PL001  # length-n product, shape set by the matrix
    return kernel_reduce(out[:, :, 0], out[:, :, 1], p0, n, axis=1) \
        if batched else \
        kernel_reduce(out[0, :, 0], out[0, :, 1], p0, n)


def _reduce_complex(out, xbs, n: int, batched: bool):
    """Per-plane epilogue over (B, blocks, 4) split-plane partials."""
    p0 = jnp.prod(xbs, axis=-1)  # permlint: disable=PL001  # length-n product, shape set by the matrix
    if batched:
        re = kernel_reduce(out[:, :, 0], out[:, :, 1], jnp.real(p0), n,
                           axis=1)
        im = kernel_reduce(out[:, :, 2], out[:, :, 3], jnp.imag(p0), n,
                           axis=1)
    else:
        re = kernel_reduce(out[0, :, 0], out[0, :, 1], jnp.real(p0), n)
        im = kernel_reduce(out[0, :, 2], out[0, :, 3], jnp.imag(p0), n)
    return re + 1j * im


def _pallas_values(As, *, batched: bool, precision: str, mode: str,
                   geometry: Geometry, interpret: bool):
    """One traced body behind every public dense pallas entry.

    ``As`` is (n, n) (``batched=False``) or (B, n, n); real input launches
    the real kernel, complex input the split-plane kernels -- everything
    else (geometry, padding, NW base vectors, the twofloat epilogue) is
    shared.  ``geometry`` is the single frozen knob bundle the tuner
    injects; its requested sizes are clamped to n's step space here.
    """
    n = As.shape[-1]
    TB, C, Wu, blocks = geometry.kernel_geometry(n)

    if not jnp.iscomplexobj(As):
        A_pads, xb_pads, xbs = _prep_real(As, batched)
        if batched:
            out = ryser_pallas_call_batched(
                A_pads, xb_pads, n=n, TB=TB, C=C, Wu=Wu, num_blocks=blocks,
                precision=precision, mode=mode, interpret=interpret)
        else:
            out = ryser_pallas_call(
                A_pads, xb_pads, 0, n=n, TB=TB, C=C, Wu=Wu,
                num_blocks=blocks, precision=precision, mode=mode,
                interpret=interpret)[None]
        return _reduce_real(out, xbs, n, batched)

    from .ryser_complex import (ryser_pallas_call_complex,
                                ryser_pallas_call_complex_batched)
    Ar_pads, Ai_pads, xbr, xbi, xbs = _prep_complex(As, batched)
    if batched:
        out = ryser_pallas_call_complex_batched(
            Ar_pads, Ai_pads, xbr, xbi, n=n, TB=TB, C=C, Wu=Wu,
            num_blocks=blocks, precision=precision, interpret=interpret)
    else:
        out = ryser_pallas_call_complex(
            Ar_pads, Ai_pads, xbr, xbi, 0, n=n, TB=TB, C=C, Wu=Wu,
            num_blocks=blocks, precision=precision, interpret=interpret)[None]
    return _reduce_complex(out, xbs, n, batched)


@partial(jax.jit, static_argnames=("batched", "precision", "mode",
                                   "geometry", "interpret"))
def _pallas_values_jit(As, batched, precision, mode, geometry, interpret):
    return _pallas_values(As, batched=batched, precision=precision,
                          mode=mode, geometry=geometry, interpret=interpret)


def _pallas_sparse_values(A_stack, rows_stack, vals_stack, *, batched: bool,
                          precision: str, geometry: Geometry,
                          interpret: bool):
    """Sparse arm of the dispatch helper (SpaRyser on Pallas).

    Mirrors ``_pallas_values`` over the padded-CCS layout of
    ``sparyser.pack_padded_ccs``: ``A_stack`` is (n, n) / (B, n, n) (the
    dense form, used only for the init matmul, NW base vectors and the
    boundary one-hot columns -- like the jnp SpaRyser engine),
    ``rows_stack``/``vals_stack`` are the (n, maxdeg) / (B, n, maxdeg)
    padded column arrays driving the Gray-code updates.  Geometry,
    padding and the twofloat epilogue (``kernel_reduce``) are shared with
    the dense arm; real input launches the real sparse kernel, complex
    input the split-plane ones.  The trace is specialized per
    (n, maxdeg) -- the batched analogue of the paper's per-pattern kernel
    generation, amortized over the bucket.
    """
    n = A_stack.shape[-1]
    TB, C, Wu, blocks = geometry.kernel_geometry(n)
    from .ryser_sparse import (ryser_sparse_pallas_call,
                               ryser_sparse_pallas_call_batched,
                               ryser_sparse_pallas_call_complex,
                               ryser_sparse_pallas_call_complex_batched)

    rows_stack = jnp.asarray(rows_stack)
    if not jnp.iscomplexobj(vals_stack):
        A_pads, xb_pads, xbs = _prep_real(A_stack, batched)
        if batched:
            out = ryser_sparse_pallas_call_batched(
                A_pads, rows_stack, vals_stack, xb_pads, n=n, TB=TB, C=C,
                Wu=Wu, num_blocks=blocks, precision=precision,
                interpret=interpret)
        else:
            out = ryser_sparse_pallas_call(
                A_pads, rows_stack, vals_stack, xb_pads, 0, n=n, TB=TB,
                C=C, Wu=Wu, num_blocks=blocks, precision=precision,
                interpret=interpret)[None]
        return _reduce_real(out, xbs, n, batched)

    Ar_pads, Ai_pads, xbr, xbi, xbs = _prep_complex(A_stack, batched)
    vr = jnp.real(vals_stack)
    vi = jnp.imag(vals_stack)
    if batched:
        out = ryser_sparse_pallas_call_complex_batched(
            Ar_pads, Ai_pads, rows_stack, vr, vi, xbr, xbi, n=n, TB=TB,
            C=C, Wu=Wu, num_blocks=blocks, precision=precision,
            interpret=interpret)
    else:
        out = ryser_sparse_pallas_call_complex(
            Ar_pads, Ai_pads, rows_stack, vr, vi, xbr, xbi, 0, n=n, TB=TB,
            C=C, Wu=Wu, num_blocks=blocks, precision=precision,
            interpret=interpret)[None]
    return _reduce_complex(out, xbs, n, batched)


@partial(jax.jit, static_argnames=("batched", "precision", "geometry",
                                   "interpret"))
def _pallas_sparse_values_jit(A_stack, rows_stack, vals_stack, batched,
                              precision, geometry, interpret):
    return _pallas_sparse_values(A_stack, rows_stack, vals_stack,
                                 batched=batched, precision=precision,
                                 geometry=geometry, interpret=interpret)


def sparse_batched_values_pallas(A_stack, rows_stack, vals_stack, *,
                                 precision: str = "dq_acc",
                                 geometry: Geometry | None = None,
                                 interpret: bool = True):
    """Traced (B,) sparse kernel values of a packed padded-CCS stack.

    The un-jitted traced body behind ``permanent_pallas_sparse_batched``,
    exposed so ``distributed.sparse_batch_permanents_on_mesh`` can run it
    per device under ``shard_map`` (``backend="pallas"``) -- the sparse
    analogue of the dense kernels' traced-chunk-base reuse.
    """
    return _pallas_sparse_values(A_stack, rows_stack, vals_stack,
                                 batched=True, precision=precision,
                                 geometry=geometry or DEFAULT_GEOMETRY,
                                 interpret=interpret)


def permanent_pallas(A, *, precision: str = "dq_acc", mode: str = "baseline",
                     geometry: Geometry | None = None,
                     interpret: bool = True):
    """perm(A) via the Pallas kernel (full iteration space, one device).

    Complex matrices run the split re/im kernel (window-batched mode)."""
    A = jnp.asarray(A)
    n = A.shape[0]
    if n == 1:
        return A[0, 0]
    if n == 2:
        return A[0, 0] * A[1, 1] + A[0, 1] * A[1, 0]
    if jnp.iscomplexobj(A):
        mode = "batched"             # the split-plane kernel's only mode
    return _pallas_values_jit(A, False, precision, mode,
                              geometry or DEFAULT_GEOMETRY, interpret)


def permanent_pallas_batched(As, *, precision: str = "dq_acc",
                             mode: str = "batched",
                             geometry: Geometry | None = None,
                             interpret: bool = True):
    """perm of a (B, n, n) stack via ONE batch-grid kernel launch.

    The grid is (batch, block): every matrix's full iteration space runs
    inside a single ``pallas_call``, so compilation and dispatch are
    amortized over the stack (vs B separate ``permanent_pallas`` calls).
    Complex stacks launch the split re/im plane kernel
    (``ryser_complex.ryser_pallas_call_complex_batched``) with the same
    grid and geometry.
    """
    As = jnp.asarray(As)
    if As.ndim != 3 or As.shape[1] != As.shape[2]:
        raise ValueError(f"(B, n, n) stack required, got {As.shape}")
    n = As.shape[1]
    if n == 1:
        return As[:, 0, 0]
    if n == 2:
        return As[:, 0, 0] * As[:, 1, 1] + As[:, 0, 1] * As[:, 1, 0]
    if jnp.iscomplexobj(As):
        mode = "batched"             # the split-plane kernel's only mode
    elif mode not in ("baseline", "batched"):
        raise ValueError(f"batch grid supports baseline|batched, got {mode}")
    return _pallas_values_jit(As, True, precision, mode,
                              geometry or DEFAULT_GEOMETRY, interpret)


def permanent_pallas_sparse(sp, *, precision: str = "dq_acc",
                            geometry: Geometry | None = None,
                            interpret: bool = True):
    """perm of one ``sparyser.SparseMatrix`` via the SpaRyser kernel.

    The scalar sparse entry the executor's pallas backend dispatches to:
    the matrix's padded CCS columns drive the Gray-code updates, the
    dense form serves only the init matmul / base vector / boundary
    one-hots.  Complex matrices run the split re/im plane sparse kernel.
    """
    n = sp.n
    A = jnp.asarray(sp.to_dense())
    if n == 1:
        return A[0, 0]
    if n == 2:
        return A[0, 0] * A[1, 1] + A[0, 1] * A[1, 0]
    rows, vals = sp.padded_columns()
    return _pallas_sparse_values_jit(A, jnp.asarray(rows),
                                     jnp.asarray(vals), False, precision,
                                     geometry or DEFAULT_GEOMETRY, interpret)


def permanent_pallas_sparse_batched(sps, *, precision: str = "dq_acc",
                                    geometry: Geometry | None = None,
                                    interpret: bool = True):
    """perms of a same-size ``SparseMatrix`` bucket via ONE (batch, block)
    grid SpaRyser kernel launch.

    The bucket is packed once on the host (``sparyser.pack_padded_ccs``,
    bucket-wide maxdeg; the extra padding scatters into the dummy row and
    never perturbs numerics) and a single ``pallas_call`` covers every
    matrix's full 2^{n-1} step space -- the sparse analogue of
    ``permanent_pallas_batched``.  Complex buckets launch the split-plane
    sparse kernel with the same grid and geometry.
    """
    from ..core.sparyser import pack_padded_ccs
    assert sps, "empty bucket"
    n = sps[0].n
    if n <= 2:
        return jnp.stack([jnp.asarray(permanent_pallas_sparse(
            sp, precision=precision)) for sp in sps])
    A_stack, rows_stack, vals_stack = pack_padded_ccs(sps)
    return _pallas_sparse_values_jit(jnp.asarray(A_stack),
                                     jnp.asarray(rows_stack),
                                     jnp.asarray(vals_stack), True,
                                     precision, geometry or DEFAULT_GEOMETRY,
                                     interpret)
