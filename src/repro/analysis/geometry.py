"""Static plan/kernel auditor: ``python -m repro.analysis.geometry --check``.

The AST rules in ``rules.py`` police *source*; this module polices the
*numbers the source produces* -- kernel geometry, VMEM block budgets,
step-space coverage, sentinel masking, and the executor's route registry
-- entirely on the host.  Nothing here dispatches a device program:
shape validation of the Pallas entry points goes through
``jax.eval_shape`` (abstract evaluation only) and the wave-formation
audit replays ``run_campaign``'s slice bookkeeping on a host ``JobState``
with synthetic partials.  That is exactly the layer where the PR 6
slice-0-recompute bug lived, so a regression of that shape fails here at
lint time instead of in a multi-hour campaign.

Audits (each returns a list of violation strings; empty = pass):

* ``audit_kernel_geometry``  -- ``kernel_geometry`` invariants: every
  component a power of two, ``TB * C * num_blocks == 2^{n-1}``,
  ``2 <= Wu <= C``, over a spread of n and tiling configs.
* ``audit_vmem_budget``      -- per-block VMEM estimate from the actual
  BlockSpec shapes (A, xb, C0 schedule matrix, the X lane state and the
  window matmul workspace) against the ~16 MB/core budget.
* ``audit_step_coverage``    -- ``chunk_geometry`` / ``plan_slices``
  products exactly tile the 2^{n-1} step space at every device count.
* ``audit_sentinel_masking`` -- host replay of the campaign wave loop:
  every slice recorded exactly once, sentinel (-1) padded lanes
  discarded, straggler re-queue never double-records.
* ``audit_routes``           -- every registered backend resolves
  ``value_backend`` to a registered producer for both routes, batched
  and scalar, across the n spread (the result-cache identity closure).
* ``audit_eval_shape``       -- ``jax.eval_shape`` over the dense
  real/complex Pallas entries: (hi, lo) partials come back as
  ``(num_blocks, 2)`` / ``(B, num_blocks, 2|4)`` with the input's real
  dtype, proving the launch geometry composes before any compile.

The jax-importing audits are split out so ``--no-jax`` (and the lint.py
import) stay usable in a bare interpreter.
"""

from __future__ import annotations

import argparse
import sys

__all__ = ["validate_tiling", "audit_kernel_geometry", "audit_vmem_budget",
           "audit_step_coverage", "audit_sentinel_masking",
           "audit_routes", "audit_eval_shape", "audit_tuning_table",
           "run_audits", "main"]

# The n spread: small enough to stay fast, wide enough to cross every
# geometry regime (clamped tiny-n tiles, the lane knee at TB=lanes, and
# multi-block step spaces past steps_per_chunk saturation).
N_SPREAD = (3, 4, 5, 6, 8, 10, 12, 14, 16, 20, 24, 30)

# (lanes, steps_per_chunk, window) tilings worth auditing: the default,
# a narrow-lane config, and a wide-window config.
TILINGS = ((128, 64, 16), (32, 64, 8), (128, 256, 32))

# VMEM budget per core (bytes).  The TPU guide gives ~16 MiB of VMEM per
# core; kernels must leave headroom for Mosaic's own spills, so audit
# against half of it.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET = VMEM_BYTES // 2

_SUBLANE = 8  # f32 sublane quantum (mirrors kernels/ops.py)


def _pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def _pad(n: int) -> int:
    return max(_SUBLANE, -(-n // _SUBLANE) * _SUBLANE)


def block_vmem_bytes(n: int, TB: int, Wu: int, *, complex_planes: bool,
                     itemsize: int = 4) -> int:
    """Per-block VMEM residency estimate of the dense kernel.

    Counted per block (the BlockSpec shapes in ``ryser_pallas_call`` plus
    the kernel's live intermediates): A (n_pad, n_pad), xb (n_pad, 1),
    C0 (n_pad, Wu-1), the lane state X (n_pad, TB), the windowed matmul
    product D (n_pad, Wu-1), the twofloat accumulator (2 x TB) and the
    (1, 2) output tile.  Complex doubles the matrix-plane share.
    """
    n_pad = _pad(n)
    planes = (n_pad * n_pad          # A block
              + n_pad                # xb block
              + n_pad * (Wu - 1)     # C0 schedule block
              + n_pad * TB           # X lane state
              + n_pad * (Wu - 1)     # D = A @ C0 workspace
              + 2 * TB               # twofloat accumulator
              + 2)                   # (1, 2) out tile
    return planes * (2 if complex_planes else 1) * itemsize


# ---------------------------------------------------------------------------
# jax-free audits
# ---------------------------------------------------------------------------

def validate_tiling(n: int, lanes: int, spc: int, window: int,
                    *, itemsize: int = 4) -> list[str]:
    """Every geometry invariant one (lanes, steps_per_chunk, window)
    candidate must satisfy at matrix size n; empty list = valid.

    The single source of truth the tuner (``repro.tune``) and the
    on-disk ``TuningTable`` (PL007) delegate candidate validity to:
    power-of-two components, exact step-space tiling, window range, and
    the VMEM block budget (checked for the complex split-plane kernel,
    the larger of the two residencies).
    """
    from ..core.stepspace import kernel_geometry
    space = 1 << (n - 1)
    TB, C, Wu, nb = kernel_geometry(
        n, lanes=lanes, steps_per_chunk=spc, window=window)
    tag = f"n={n} tiling=({lanes},{spc},{window})"
    bad = []
    for name, v in (("lanes", lanes), ("steps_per_chunk", spc),
                    ("window", window), ("TB", TB), ("C", C), ("Wu", Wu),
                    ("num_blocks", nb)):
        if not _pow2(v):
            bad.append(f"{tag}: {name}={v} is not a power of two")
    if TB * C * nb != space:
        bad.append(f"{tag}: TB*C*num_blocks = {TB * C * nb} != "
                   f"2^(n-1) = {space} -- grid does not tile the "
                   "step space")
    if not (2 <= Wu <= C):
        bad.append(f"{tag}: window Wu={Wu} outside [2, C={C}]")
    est = block_vmem_bytes(n, TB, Wu, complex_planes=True,
                           itemsize=itemsize)
    if est > VMEM_BUDGET:
        bad.append(f"{tag}: block VMEM estimate {est} B exceeds budget "
                   f"{VMEM_BUDGET} B ({VMEM_BYTES} B/core with Mosaic "
                   "headroom)")
    return bad


def audit_kernel_geometry(ns=N_SPREAD, tilings=TILINGS) -> list[str]:
    from ..core.stepspace import kernel_geometry
    bad = []
    for n in ns:
        space = 1 << (n - 1)
        for (lanes, spc, window) in tilings:
            TB, C, Wu, nb = kernel_geometry(
                n, lanes=lanes, steps_per_chunk=spc, window=window)
            tag = f"n={n} tiling=({lanes},{spc},{window})"
            for name, v in (("TB", TB), ("C", C), ("Wu", Wu),
                            ("num_blocks", nb)):
                if not _pow2(v):
                    bad.append(f"{tag}: {name}={v} is not a power of two")
            if TB * C * nb != space:
                bad.append(f"{tag}: TB*C*num_blocks = {TB * C * nb} != "
                           f"2^(n-1) = {space} -- grid does not tile the "
                           "step space")
            if not (2 <= Wu <= C):
                bad.append(f"{tag}: window Wu={Wu} outside [2, C={C}]")
    return bad


def audit_vmem_budget(ns=N_SPREAD, tilings=TILINGS,
                      itemsize: int = 4) -> list[str]:
    """Bound the per-block VMEM residency of the dense kernel
    (see :func:`block_vmem_bytes` for the counted shapes)."""
    from ..core.stepspace import kernel_geometry
    bad = []
    for n in ns:
        for (lanes, spc, window) in tilings:
            TB, C, Wu, nb = kernel_geometry(
                n, lanes=lanes, steps_per_chunk=spc, window=window)
            for kind, cplx in (("real", False), ("complex", True)):
                est = block_vmem_bytes(n, TB, Wu, complex_planes=cplx,
                                       itemsize=itemsize)
                if est > VMEM_BUDGET:
                    bad.append(
                        f"n={n} tiling=({lanes},{spc},{window}) {kind}: "
                        f"block VMEM estimate {est} B exceeds budget "
                        f"{VMEM_BUDGET} B ({VMEM_BYTES} B/core with "
                        "Mosaic headroom)")
    return bad


def audit_tuning_table(path: str | None = None) -> list[str]:
    """PL007: every persisted TuningTable entry re-validates.

    A table edited by hand (or produced by a stale tuner) could smuggle
    a geometry past the VMEM/step-space invariants straight into the
    planner; this audit re-runs :func:`validate_tiling` over every entry
    of the table at ``path`` (default: the ``REPRO_TUNING_TABLE``
    environment variable; no table configured = nothing to check).
    ``TuningTable.load`` runs the same validation loudly at load time --
    the audit exists so lint catches a bad table before any run does.
    """
    import os

    from ..tune.table import TuningTable
    path = path or os.environ.get("REPRO_TUNING_TABLE")
    if not path or not os.path.exists(path):
        return []
    try:
        table = TuningTable.load(path)
    except ValueError as e:
        return [f"tuning table {path}: failed to load: {e}"]
    bad = []
    for key, entry in table.entries.items():
        g = entry.geometry
        for v in validate_tiling(entry.n, g.lanes, g.steps_per_chunk,
                                 g.window):
            bad.append(f"tuning table {path} [{key}]: {v}")
    return bad


def audit_step_coverage(ns=N_SPREAD) -> list[str]:
    from ..core.stepspace import chunk_geometry, plan_slices
    bad = []
    for n in ns:
        space = 1 << (n - 1)
        for nc in (1, 64, 4096, space * 4):
            T, C, k = chunk_geometry(n, nc)
            if T * C != space:
                bad.append(f"chunk_geometry(n={n}, num_chunks={nc}): "
                           f"T*C = {T * C} != 2^(n-1) = {space}")
            if not (_pow2(C) and C >= 2 and C == 1 << k):
                bad.append(f"chunk_geometry(n={n}, num_chunks={nc}): "
                           f"C={C}, k={k} not a power-of-two chunk >= 2")
        for D in (1, 2, 4, 8, 32):
            ts, cps, C = plan_slices(n, D)
            if ts * cps * C != space:
                bad.append(f"plan_slices(n={n}, D={D}): ts*cps*C = "
                           f"{ts * cps * C} != 2^(n-1) = {space} -- "
                           "campaign slices do not cover the step space")
            if not (_pow2(C) and C >= 2):
                bad.append(f"plan_slices(n={n}, D={D}): chunk_size={C} "
                           "not a power-of-two >= 2")
    return bad


def audit_sentinel_masking(ns=(8, 12), device_counts=(1, 3, 4, 8),
                           ) -> list[str]:
    """Replay run_campaign's wave bookkeeping on the host.

    Forms waves exactly like the driver (``pending[:D]`` padded with the
    -1 sentinel to the device count), records synthetic per-slice
    partials truncated to ``his[:len(wave)]``, and injects one straggler
    failure -- then checks every slice is recorded exactly once and the
    fixed-order reduce sees exactly the synthetic values.  This is the
    PR 6 slice-0-recompute bug shape, caught without a mesh.
    """
    import numpy as np

    from ..core.resume import JobState
    from ..core.stepspace import plan_slices
    bad = []
    for n in ns:
        for D in device_counts:
            ts, cps, C = plan_slices(n, min(D, 2))
            A = np.arange(n * n, dtype=np.float64).reshape(n, n) / n
            state = JobState.create(A, ts, chunks_per_slice=cps,
                                    chunk_size=C)
            recorded: dict[int, float] = {}
            failed_once = False
            while True:
                pending = state.pending_slices()
                if not pending:
                    break
                wave = pending[:D]
                ids = np.array(wave + [-1] * (D - len(wave)),
                               dtype=np.int32)
                if (ids < 0).any() and not (ids[:len(wave)] >= 0).all():
                    bad.append(f"n={n} D={D}: sentinel leaked into the "
                               f"live lane prefix: {ids}")
                    break
                if not failed_once and len(recorded) > 0:
                    # straggler: the wave records nothing; its slices
                    # must stay pending and be re-formed next round
                    failed_once = True
                    continue
                his = np.array([float(s) + 1.0 for s in ids])
                los = np.zeros_like(his)
                state.record_wave(wave, his[:len(wave)], los[:len(wave)])
                for s in wave:
                    if s in recorded:
                        bad.append(f"n={n} D={D}: slice {s} recorded "
                                   "twice -- wave formation re-issued a "
                                   "completed slice")
                    recorded[s] = float(s) + 1.0
            if len(recorded) != ts:
                bad.append(f"n={n} D={D}: {len(recorded)} of {ts} slices "
                           "recorded -- coverage hole in wave formation")
            if not state.done.all():
                bad.append(f"n={n} D={D}: JobState still has pending "
                           "slices after the drain loop")
            got = {i: float(state.hi[i]) for i in range(ts)}
            want = {i: float(i) + 1.0 for i in range(ts)}
            if got != want:
                bad.append(f"n={n} D={D}: recorded partials corrupted by "
                           "padded lanes (sentinel values crossed into "
                           f"live slices): {got} != {want}")
    return bad


# ---------------------------------------------------------------------------
# jax-importing audits (abstract evaluation only -- no device programs)
# ---------------------------------------------------------------------------

def audit_routes(ns=N_SPREAD) -> list[str]:
    from ..core.executor import available_backends, get_backend
    from ..core.planner import ROUTE_DENSE, ROUTE_SPARSE
    names = available_backends()
    bad = []
    required = {"jnp", "pallas", "distributed", "distributed_batch",
                "campaign"}
    missing = required - set(names)
    if missing:
        bad.append(f"backend registry lost routes: {sorted(missing)} "
                   f"(registered: {sorted(names)})")
    for name in names:
        backend = get_backend(name)
        for route in (ROUTE_DENSE, ROUTE_SPARSE):
            for n in ns:
                for batched in (False, True):
                    try:
                        prod = backend.value_backend(route, n,
                                                     batched=batched)
                    except Exception as e:  # noqa: BLE001 -- audit surface
                        bad.append(f"{name}.value_backend({route}, n={n}, "
                                   f"batched={batched}) raised {e!r}")
                        continue
                    if prod not in names:
                        bad.append(
                            f"{name}.value_backend({route}, n={n}, "
                            f"batched={batched}) -> {prod!r} is not a "
                            "registered backend -- cache keys would "
                            "carry an unresolvable producer")
    return bad


def audit_eval_shape(ns=(6, 10, 14), batch: int = 3) -> list[str]:
    """Abstract-evaluate the dense Pallas entries for every route shape.

    ``jax.eval_shape`` traces ``_pallas_values``'s launch (BlockSpecs,
    grids, the kernel jaxpr) without compiling or running anything, so a
    grid/BlockSpec mismatch fails here on any host.
    """
    import jax
    import jax.numpy as jnp

    from ..core.stepspace import DEFAULT_GEOMETRY
    from ..kernels.ops import _pallas_values
    bad = []
    for n in ns:
        for dtype, kind in ((jnp.float64, "real"),
                            (jnp.complex128, "complex")):
            for batched in (False, True):
                shape = (batch, n, n) if batched else (n, n)
                spec = jax.ShapeDtypeStruct(shape, dtype)
                tag = f"n={n} {kind} batched={batched}"
                try:
                    out = jax.eval_shape(
                        lambda As: _pallas_values(
                            As, batched=batched, precision="dq_acc",
                            mode="baseline", geometry=DEFAULT_GEOMETRY,
                            interpret=True),
                        spec)
                except Exception as e:  # noqa: BLE001 -- audit surface
                    bad.append(f"{tag}: eval_shape raised {e!r}")
                    continue
                want_shape = (batch,) if batched else ()
                if out.shape != want_shape:
                    bad.append(f"{tag}: value shape {out.shape} != "
                               f"{want_shape}")
                if (out.dtype != dtype):
                    bad.append(f"{tag}: value dtype {out.dtype} != {dtype}")
    return bad


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

AUDITS = (
    ("kernel-geometry", audit_kernel_geometry, False),
    ("vmem-budget", audit_vmem_budget, False),
    ("step-coverage", audit_step_coverage, False),
    ("sentinel-masking", audit_sentinel_masking, False),
    ("tuning-table", audit_tuning_table, False),   # PL007
    ("routes", audit_routes, True),       # True: imports jax
    ("eval-shape", audit_eval_shape, True),
)


def run_audits(with_jax: bool = True) -> dict[str, list[str]]:
    results = {}
    for name, fn, needs_jax in AUDITS:
        if needs_jax and not with_jax:
            continue
        results[name] = fn()
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.geometry",
        description="static plan/kernel geometry auditor (no device work)")
    ap.add_argument("--check", action="store_true",
                    help="run every audit; exit 1 on any violation")
    ap.add_argument("--no-jax", action="store_true",
                    help="skip the jax-importing audits (routes, "
                         "eval-shape)")
    args = ap.parse_args(argv)
    if not args.check:
        ap.print_help()
        return 2

    if not args.no_jax:
        # eval_shape must see the dtypes the solver actually plans with
        import jax
        jax.config.update("jax_enable_x64", True)

    failures = 0
    for name, violations in run_audits(with_jax=not args.no_jax).items():
        status = "ok" if not violations else f"{len(violations)} violation(s)"
        print(f"geometry: {name}: {status}")
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        failures += len(violations)
    print(f"geometry: {failures} violation(s) total")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
