"""Static verification of the repo's determinism & precision invariants.

Three layers, all device-free:

* ``rules.py``   -- permlint's rule registry (PL001..PL006 +
  pyflakes-class hygiene rules), each encoding one hard-won invariant
  from PRs 3-7, checked on the Python AST.
* ``lint.py``    -- the walker and ``python -m repro.analysis.lint`` CLI:
  human/JSON output, ``# permlint: disable=RULE`` inline suppressions
  (inventoried in the report, never hidden), and the orphan-module
  inventory over the import graph.
* ``geometry.py`` -- the static plan/kernel auditor: enumerates every
  registered executor route and validates kernel geometry, VMEM block
  budgets, step-space coverage and sentinel masking of padded lanes via
  ``kernel_geometry``/``jax.eval_shape`` -- no device work.
* ``ir.py`` + ``contracts.py`` -- permprove: traces every public engine
  entry with ``jax.make_jaxpr``, checks the PLI-series contracts
  (PLI101-104) on the emitted IR, and gates drift against golden
  canonical-trace fingerprints under ``tests/ir_goldens/``
  (``python -m repro.analysis.ir --check`` / ``--bless``).

``docs/INVARIANTS.md`` catalogs each rule and the postmortem behind it.
"""

from .rules import RULES, Finding, Rule  # noqa: F401

__all__ = ["RULES", "Finding", "Rule"]
