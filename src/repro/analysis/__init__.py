"""permlint: the repo's determinism & precision invariants as lint rules.

Two jax-free AST passes plus one static plan/kernel auditor:

* ``rules.py``   -- the rule registry (PL001..PL006 + pyflakes-class
  hygiene rules), each encoding one hard-won invariant from PRs 3-7.
* ``lint.py``    -- the walker and ``python -m repro.analysis.lint`` CLI:
  human/JSON output, ``# permlint: disable=RULE`` inline suppressions
  (inventoried in the report, never hidden), and the orphan-module
  inventory over the import graph.
* ``geometry.py`` -- the static plan/kernel auditor: enumerates every
  registered executor route and validates kernel geometry, VMEM block
  budgets, step-space coverage and sentinel masking of padded lanes via
  ``kernel_geometry``/``jax.eval_shape`` -- no device work.

``docs/INVARIANTS.md`` catalogs each rule and the postmortem behind it.
"""

from .rules import RULES, Finding, Rule  # noqa: F401

__all__ = ["RULES", "Finding", "Rule"]
