"""permlint walker + CLI: ``python -m repro.analysis.lint src tests``.

Jax-free by construction (pure ``ast`` + ``os``): the linter must run in
a bare interpreter before any heavy dependency imports, and in CI ahead
of the test matrix.

* Two passes: pass 1 parses every file and builds the cross-file
  signature index (PL003 needs to know which callees accept which
  guarded kwargs); pass 2 runs the rule registry per file.
* ``# permlint: disable=RULE[,RULE...]`` on a flagged line (or on a
  standalone comment line directly above it) suppresses a finding.
  Suppressions are INVENTORIED in the report, never hidden: the exit
  code ignores them, but the human and JSON output count every one, so
  suppression drift shows up in review.
* The orphan-module inventory walks the intra-repo import graph from
  the permanent/solver/serve entry points and reports every module
  under ``src/repro`` nothing reachable imports.  It is how the LM
  seed leftovers (``models/``, ``configs/``, ``train/``) were found
  and, in PR 10, retired.  Informational: orphans never fail the lint.

Exit status: 0 when no unsuppressed findings, 1 otherwise, 2 on usage
errors.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from .rules import RULES, FileContext, Finding, SignatureIndex, run_rules

__all__ = ["lint_paths", "lint_file", "parse_suppressions",
           "orphan_modules", "main"]

# Deliberately-bad rule fixtures live here; the fixture tests lint them
# explicitly, the tree-wide walk must skip them.
DEFAULT_EXCLUDES = ("lint_fixtures",)

# Reachability roots for the orphan inventory: the permanent CLIs, the
# solver session object, the always-on serving loop, and the analysis
# tooling (permlint, geometry audits, permprove's IR verifier).
ENTRY_POINTS = ("repro.launch.permanent", "repro.launch.campaign",
                "repro.launch.tune", "repro.launch.serve",
                "repro.core.solver", "repro.core.engine",
                "repro.serve.loop",
                "repro.analysis.lint", "repro.analysis.geometry",
                "repro.analysis.ir")

_DIRECTIVE = "# permlint: disable="


def iter_py_files(paths, excludes=DEFAULT_EXCLUDES):
    """Every .py file under ``paths`` (files pass through), sorted."""
    out = []
    for path in paths:
        if os.path.isfile(path):
            out.append(path)
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs
                             if d not in excludes
                             and not d.startswith(".")
                             and d != "__pycache__")
            if any(e in _norm(root) for e in excludes):
                continue
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return sorted(set(out))


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def parse_suppressions(source: str) -> dict[int, set[str]]:
    """line -> rules disabled there.

    A directive on a code line covers that line; a directive on a
    comment-only line also covers the line below it (so a justification
    comment can sit above a long call).
    """
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        pos = line.find(_DIRECTIVE)
        if pos < 0:
            continue
        spec = line[pos + len(_DIRECTIVE):].split("#")[0]
        rules = {r.strip() for r in spec.split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if line.strip().startswith("#"):
            out.setdefault(i + 1, set()).update(rules)
    return out


def lint_file(path: str, signatures: SignatureIndex,
              only: set[str] | None = None,
              tree: ast.Module | None = None,
              source: str | None = None):
    """(active findings, suppressed findings) for one file."""
    norm = _norm(path)
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    if tree is None:
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            return [Finding("PLE901", norm, e.lineno or 0, e.offset or 0,
                            f"syntax error: {e.msg}")], []
    ctx = FileContext(path=norm, tree=tree, source=source,
                      signatures=signatures)
    findings = run_rules(ctx, only=only)
    disabled = parse_suppressions(source)
    active, suppressed = [], []
    for f in findings:
        if f.rule in disabled.get(f.line, ()):
            f.suppressed = True
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


def lint_paths(paths, only: set[str] | None = None,
               excludes=DEFAULT_EXCLUDES):
    """Lint every file under ``paths``; returns the full report dict."""
    files = iter_py_files(paths, excludes)
    parsed: dict[str, tuple] = {}
    signatures = SignatureIndex()
    syntax_errors: list[Finding] = []
    for path in files:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            syntax_errors.append(Finding(
                "PLE901", _norm(path), e.lineno or 0, e.offset or 0,
                f"syntax error: {e.msg}"))
            continue
        parsed[path] = (tree, source)
        signatures.add(tree)

    findings: list[Finding] = list(syntax_errors)
    suppressed: list[Finding] = []
    for path, (tree, source) in parsed.items():
        active, supp = lint_file(path, signatures, only=only,
                                 tree=tree, source=source)
        findings.extend(active)
        suppressed.extend(supp)

    return {"version": "permlint/1",
            "files": len(files),
            "findings": findings,
            "suppressions": suppressed,
            "orphans": orphan_modules(paths)}


# ---------------------------------------------------------------------------
# Orphan-module inventory
# ---------------------------------------------------------------------------

def _module_name(path: str) -> str | None:
    """'src/repro/core/ryser.py' -> 'repro.core.ryser' (None outside src)."""
    norm = _norm(path)
    marker = "src/repro/"
    pos = norm.rfind(marker)
    if pos < 0:
        return None
    rel = norm[pos + len("src/"):-len(".py")]
    if rel.endswith("/__init__"):
        rel = rel[:-len("/__init__")]
    return rel.replace("/", ".")


def _import_edges(tree: ast.Module, modname: str) -> set[str]:
    """repro.* modules imported anywhere in the file (lazy imports in
    function bodies included -- they are real runtime edges)."""
    pkg_parts = modname.split(".")
    edges: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro"):
                    edges.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:           # relative: resolve against modname
                base = pkg_parts[:-node.level] if node.level <= \
                    len(pkg_parts) else []
                mod = ".".join(base + ([node.module] if node.module
                                       else []))
            else:
                mod = node.module or ""
            if not mod.startswith("repro"):
                continue
            edges.add(mod)
            # `from pkg import name` may bind submodule pkg.name
            for alias in node.names:
                edges.add(f"{mod}.{alias.name}")
    return edges


def orphan_modules(paths, roots=ENTRY_POINTS) -> list[str]:
    """Modules under src/repro unreachable from the entry points."""
    files = iter_py_files(paths)
    graph: dict[str, set[str]] = {}
    for path in files:
        mod = _module_name(path)
        if mod is None:
            continue
        with open(path, encoding="utf-8") as f:
            try:
                tree = ast.parse(f.read(), filename=path)
            except SyntaxError:
                continue
        graph[mod] = _import_edges(tree, mod)
    if not graph:
        return []

    def closure(mod: str) -> set[str]:
        """mod + every package __init__ above it that exists."""
        out = {mod}
        parts = mod.split(".")
        for i in range(1, len(parts)):
            out.add(".".join(parts[:i + 1]))
        return out

    reachable: set[str] = set()
    frontier = [r for r in roots if r in graph]
    while frontier:
        mod = frontier.pop()
        if mod in reachable:
            continue
        reachable.add(mod)
        for edge in graph.get(mod, ()):
            # an imported name may be a module or an attr; walk up until
            # a known module matches
            probe = edge
            while probe and probe not in graph and "." in probe:
                probe = probe.rsplit(".", 1)[0]
            if probe in graph and probe not in reachable:
                frontier.append(probe)
            # importing a package runs its __init__, which may import
            # siblings -- treat the package itself as reachable too
            for parent in closure(probe if probe in graph else edge):
                if parent in graph and parent not in reachable:
                    frontier.append(parent)
    return sorted(m for m in graph if m not in reachable)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _render(report: dict, show_orphans: bool = True) -> str:
    lines = []
    for f in report["findings"]:
        lines.append(f.render())
    supp = report["suppressions"]
    if supp:
        lines.append(f"suppressions ({len(supp)}):")
        lines.extend(f"  {s.render()} [suppressed]" for s in supp)
    if show_orphans and report["orphans"]:
        orphans = report["orphans"]
        lines.append(f"orphan modules ({len(orphans)}, informational -- "
                     f"unreachable from {', '.join(ENTRY_POINTS)}):")
        lines.extend(f"  {m}" for m in orphans)
    lines.append(
        f"permlint: {len(report['findings'])} finding(s), "
        f"{len(supp)} suppression(s), "
        f"{len(report['orphans'])} orphan module(s) "
        f"in {report['files']} file(s)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="permlint: determinism & precision invariants as "
                    "static analysis (see docs/INVARIANTS.md)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--rules", default=None, metavar="PL001,PL004",
                    help="run only these rules")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--no-orphans", action="store_true",
                    help="skip the orphan-module inventory")
    args = ap.parse_args(argv)

    if args.list:
        for rule in RULES.values():
            scope = ", ".join(rule.scope) if rule.scope else "all files"
            print(f"{rule.name} [{rule.title}] ({scope})\n"
                  f"    {rule.invariant}")
        return 0

    only = None
    if args.rules:
        only = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = only - set(RULES) - {"PLE901"}
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}; "
                  f"registered: {sorted(RULES)}", file=sys.stderr)
            return 2

    paths = [p for p in args.paths if os.path.exists(p)]
    missing = set(args.paths) - set(paths)
    if missing:
        print(f"path(s) not found: {sorted(missing)}", file=sys.stderr)
        return 2

    report = lint_paths(paths, only=only)
    if args.no_orphans:
        report["orphans"] = []
    if args.json:
        print(json.dumps({
            "version": report["version"],
            "files": report["files"],
            "findings": [f.to_json() for f in report["findings"]],
            "suppressions": [s.to_json() for s in report["suppressions"]],
            "orphans": report["orphans"],
        }, indent=1))
    else:
        print(_render(report, show_orphans=not args.no_orphans))
    return 1 if report["findings"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
