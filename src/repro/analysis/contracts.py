"""PLI-series contracts: the determinism & precision invariants checked
on the *traced IR* instead of the Python AST (ISSUE 10).

permlint (``rules.py``) guards the source; these rules guard what the
jax transform stack actually emitted -- the level where the PR 3
(shape-dependent reassociation) and PR 4 (vmap fusion drift) bugs were
born.  ``ir.py`` traces every public engine entry and hands the jaxprs
(and, for sharded programs, compiled HLO text) to the checkers here:

PLI101  no raw float ``reduce``/``dot`` contraction over a
        batch/shard-extent-dependent axis -- the post-transform shadow
        of PL001.  Detected by tracing each batch entry at two coprime
        batch extents and flagging any reduction whose *reduced* extent
        tracks the batch.
PLI102  dtype-flow audit: no ``convert_element_type`` truncation
        (f64->f32, c128->c64, f64->bf16 ...) on any value path.
PLI103  batch-extent invariance: the engine body is structurally
        identical at different batch extents -- every textual
        difference between the two canonical traces must be an integer
        extent scaling exactly with B (the PR 4 ulp-drift bug shape,
        proven statically instead of tested empirically).
PLI104  collective audit on sharded programs via
        ``utils/hlo.collective_bytes``/``count_ops``: only the
        sanctioned psum kinds and counts appear.

Like permlint, sanctioned sites are never hidden: ``SANCTIONED``
matches move a finding into the shared suppression inventory that
every report carries.

This module is import-light (no jax): it consumes canonical trace
lines and walk records produced by ``ir.py``.
"""

from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass

from .rules import Finding
from ..utils import hlo

__all__ = ["PLI_RULES", "SANCTIONED", "Sanction", "apply_sanctions",
           "pli101_reductions", "pli102_dtype_flow",
           "pli103_batch_invariance", "pli104_collectives",
           "ReduceRecord", "ConvertRecord"]


PLI_RULES = {
    "PLI101": "no raw float reduce/dot over a batch/shard-extent axis "
              "outside the sanctioned twofloat patterns",
    "PLI102": "no convert_element_type truncation (f64->f32, c128->c64) "
              "on any value path",
    "PLI103": "engine bodies are structurally batch-extent invariant "
              "(only extents scale with B)",
    "PLI104": "sharded programs carry only the sanctioned collective "
              "kinds/counts",
}


@dataclass(frozen=True)
class Sanction:
    """One deliberately-allowed PLI site.  ``entry`` is an fnmatch
    pattern over entry names, ``match`` a substring of the finding
    message.  Matched findings are inventoried, never dropped."""
    rule: str
    entry: str
    match: str
    reason: str


# The engine bodies currently prove clean with no per-eqn sanctions --
# every reduce extent is pinned by (n, T, C) and no value path narrows.
# This tuple is the hook a future deliberate exception must go through:
# like permlint's inline suppressions, a Sanction moves the finding into
# the report's suppression inventory instead of deleting it.  (The
# PLI104 collective budget below feeds the same inventory: each
# in-budget collective is recorded as a suppressed finding.)
SANCTIONED: tuple[Sanction, ...] = ()


def apply_sanctions(findings: list[Finding]) -> tuple[list[Finding],
                                                      list[Finding]]:
    """Split findings into (active, suppressed) per ``SANCTIONED``."""
    active, suppressed = [], []
    for f in findings:
        hit = None
        for s in SANCTIONED:
            if (s.rule == f.rule and fnmatch.fnmatch(f.path, s.entry)
                    and s.match in f.message):
                hit = s
                break
        if hit is None:
            active.append(f)
        else:
            suppressed.append(Finding(
                rule=f.rule, path=f.path, line=f.line, col=f.col,
                message=f.message + f"  [sanctioned: {hit.reason}]",
                suppressed=True))
    return active, suppressed


# ---------------------------------------------------------------------------
# Walk records (produced by ir.canonical_walk, consumed here)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReduceRecord:
    """One float-dtype contraction eqn from a canonical walk."""
    index: int                 # position in the walk (aligns across B)
    primitive: str             # reduce_sum / reduce_prod / dot_general
    dtype: str                 # short dtype of the reduced operand
    reduced_extents: tuple[int, ...]   # extents of the contracted axes


@dataclass(frozen=True)
class ConvertRecord:
    """One convert_element_type eqn from a canonical walk."""
    index: int
    src: str                   # short dtype in
    dst: str                   # short dtype out


_WIDTHS = {
    "pred": 1, "i8": 8, "u8": 8, "i16": 16, "u16": 16, "f16": 16,
    "bf16": 16, "i32": 32, "u32": 32, "f32": 32, "i64": 64, "u64": 64,
    "f64": 64, "c64": 64, "c128": 128,
}
_FLOATISH = re.compile(r"^(f|bf|c)\d+$")


def _is_floatish(short: str) -> bool:
    return bool(_FLOATISH.match(short))


def pli102_dtype_flow(entry: str, converts: list[ConvertRecord],
                      precision: str) -> list[Finding]:
    """Flag any float/complex narrowing convert on a value path."""
    out = []
    for c in converts:
        if not (_is_floatish(c.src) and _is_floatish(c.dst)):
            continue
        if _WIDTHS.get(c.dst, 0) < _WIDTHS.get(c.src, 0):
            out.append(Finding(
                rule="PLI102", path=entry, line=c.index, col=0,
                message=f"precision={precision}: value path truncates "
                        f"{c.src}->{c.dst} (convert_element_type "
                        f"at walk index {c.index})"))
    return out


# ---------------------------------------------------------------------------
# PLI103: batch-extent invariance of the canonical trace text
# ---------------------------------------------------------------------------

# standalone integers only: '128' in 'f128' or '1.5' must not split
_INT_TOKEN = re.compile(r"(?<![\w.])(\d+)(?![\w.])")


def _proportional(tok_a: str, tok_b: str, b_a: int, b_b: int) -> bool:
    """True when tok_a/tok_b is the same multiple of b_a/b_b -- the only
    sanctioned way a trace may depend on the batch extent."""
    va, vb = int(tok_a), int(tok_b)
    return va % b_a == 0 and vb == (va // b_a) * b_b


def lines_batch_variant(line_a: str, line_b: str,
                        b_a: int, b_b: int) -> bool:
    """True when the two lines differ only by B-proportional extents."""
    toks_a = _INT_TOKEN.split(line_a)
    toks_b = _INT_TOKEN.split(line_b)
    if len(toks_a) != len(toks_b):
        return False
    for i, (ta, tb) in enumerate(zip(toks_a, toks_b)):
        if ta == tb:
            continue
        if i % 2 == 0:          # non-integer text segment differs
            return False
        if not _proportional(ta, tb, b_a, b_b):
            return False
    return True


def pli103_batch_invariance(entry: str, precision: str,
                            lines_a: list[str], lines_b: list[str],
                            b_a: int, b_b: int,
                            max_report: int = 3) -> list[Finding]:
    """Compare canonical traces at two batch extents line by line."""
    out = []
    if len(lines_a) != len(lines_b):
        return [Finding(
            rule="PLI103", path=entry, line=0, col=0,
            message=f"precision={precision}: trace has {len(lines_a)} "
                    f"canonical lines at B={b_a} but {len(lines_b)} at "
                    f"B={b_b} -- the program shape depends on the batch "
                    f"extent")]
    for i, (la, lb) in enumerate(zip(lines_a, lines_b)):
        if la == lb or lines_batch_variant(la, lb, b_a, b_b):
            continue
        out.append(Finding(
            rule="PLI103", path=entry, line=i, col=0,
            message=f"precision={precision}: line {i} differs beyond "
                    f"B-proportional extents:\n"
                    f"    B={b_a}: {la.strip()}\n"
                    f"    B={b_b}: {lb.strip()}"))
        if len(out) >= max_report:
            break
    return out


def pli101_reductions(entry: str, precision: str,
                      reds_a: list[ReduceRecord],
                      reds_b: list[ReduceRecord],
                      b_a: int, b_b: int) -> list[Finding]:
    """Flag float contractions whose *reduced* extent tracks the batch.

    A reduction over the batch/shard axis is exactly the accumulation
    order PL001 bans at the source level: its association would change
    with the shard shape.  Extents pinned by the plan (T, C, n) are
    identical in both traces and pass.
    """
    out = []
    if len(reds_a) != len(reds_b):
        # PLI103 reports the structural divergence; avoid cascading.
        return out
    for ra, rb in zip(reds_a, reds_b):
        for ea, eb in zip(ra.reduced_extents, rb.reduced_extents):
            if ea == eb:
                continue
            if _proportional(str(ea), str(eb), b_a, b_b):
                out.append(Finding(
                    rule="PLI101", path=entry, line=ra.index, col=0,
                    message=f"precision={precision}: primitive="
                            f"{ra.primitive} ({ra.dtype}) contracts a "
                            f"batch-extent axis ({ea} at B={b_a}, {eb} "
                            f"at B={b_b}) -- accumulation order would "
                            f"depend on the shard shape"))
                break
    return out


# ---------------------------------------------------------------------------
# PLI104: collective audit over compiled sharded programs
# ---------------------------------------------------------------------------

def pli104_collectives(program: str, hlo_text: str,
                       sanctioned: dict[str, int]) -> list[Finding]:
    """Only sanctioned collective kinds/counts may appear.

    ``sanctioned`` maps collective kind (``all-reduce`` ...) to the max
    instruction count allowed; kinds absent from the map are banned
    outright.  Counts come from ``hlo.collective_bytes`` (async
    ``-start``/``-done`` pairs count once, at ``-start``).  In-budget
    collectives come back as *suppressed* findings: the deliberate psum
    sites are inventoried in every report, never invisible.
    """
    stats = hlo.collective_bytes(hlo_text)
    out = []
    for kind, v in sorted(stats["by_kind"].items()):
        allowed = sanctioned.get(kind)
        if allowed is None:
            out.append(Finding(
                rule="PLI104", path=program, line=0, col=0,
                message=f"unsanctioned collective kind {kind!r} "
                        f"(count={v['count']}, bytes={v['bytes']})"))
        elif v["count"] > allowed:
            out.append(Finding(
                rule="PLI104", path=program, line=0, col=0,
                message=f"collective {kind!r} appears {v['count']}x "
                        f"(sanctioned max {allowed}) -- an extra "
                        f"reduction changes the cross-device order"))
        else:
            out.append(Finding(
                rule="PLI104", path=program, line=0, col=0, suppressed=True,
                message=f"sanctioned collective {kind!r} x{v['count']} "
                        f"({v['bytes']} bytes) within budget {allowed}"))
    return out
