"""permlint rules: the determinism & precision invariants, machine-checked.

Every rule here encodes an invariant this repo already paid for with a
postmortem (see docs/INVARIANTS.md for the full catalog):

* **PL001** fixed-order reductions: raw ``jnp.sum``/``jnp.prod``/
  ``jnp.dot``/``jnp.matmul`` on accumulation paths reassociate per
  program shape under XLA and broke bitwise mesh identity in PR 3.
* **PL002** no ``vmap`` over complex engine bodies: vmap fuses across
  the batch axis and drifted complex values by ulps between batch
  extents in PR 4 (``lax.map`` shares the scalar trace).
* **PL003** kwarg passthrough: tiny-n fallbacks silently dropped
  ``precision``/``num_chunks`` twice (PRs 5 and 6) -- a function that
  accepts a guarded kwarg must forward it to every callee that also
  accepts it.
* **PL004** injectable clocks: ``time.time``/``time.monotonic`` in
  ``core/``/``serve/`` outside the ``SolverConfig.clock`` default sites
  make deadline behavior untestable (PR 7 made all timing injectable).
* **PL005** config classification: every ``SolverConfig`` field must be
  explicitly numerics-affecting (``ExecutionPlan._NUMERIC_FIELDS``) or
  policy (``_POLICY_FIELDS``) so ``fingerprint()`` can never silently
  ignore a new knob (PR 2's fingerprint bug class).
* **PL006** cache-key completeness: ``ResultCache.key`` call sites must
  bind every component including ``backend``, ``dtype`` and
  ``geometry`` -- kernel/jnp values collided in the cache before PR 5
  carried the producing backend and leaf dtype, and PR 9 made the
  resolved kernel geometry part of numeric identity.

Plus two pyflakes-class hygiene rules so the tree lints clean without
external tools (ruff runs on top when installed): **PLF01** unused
module-level imports, **PLE901** syntax errors (emitted by the walker
when a file fails to parse).

Rules are pure ``ast`` -- no jax import anywhere in this module -- so
the linter runs in a bare interpreter and in CI before any heavy deps.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable

__all__ = ["Finding", "Rule", "RULES", "SignatureIndex",
           "GUARDED_KWARGS", "build_signature_index", "run_rules"]

# Kwargs whose silent loss corrupts numerics (the PR 5/6 bug class).
GUARDED_KWARGS = ("precision", "num_chunks", "backend")

# jnp reductions that XLA reassociates per program shape.
RAW_REDUCERS = ("sum", "prod", "dot", "matmul")

# Scopes are path fragments matched against '/'-normalized file paths.
ACCUM_SCOPE = ("core/ryser.py", "core/sparyser.py", "core/distributed.py",
               "kernels/")
CLOCK_SCOPE = ("core/", "serve/")
PLANNER_SCOPE = ("core/planner.py",)


@dataclass
class Finding:
    """One rule violation (or, when ``suppressed``, an inventoried one)."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "suppressed": self.suppressed}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


@dataclass
class FileContext:
    """Everything a rule checker sees for one file."""
    path: str                        # '/'-normalized, repo-relative-ish
    tree: ast.Module
    source: str
    signatures: "SignatureIndex"


@dataclass
class Rule:
    name: str
    title: str
    scope: tuple[str, ...]           # () = every file
    invariant: str                   # one-liner for --list and the docs
    check: Callable[[FileContext], list[Finding]]

    def in_scope(self, path: str) -> bool:
        return not self.scope or any(s in path for s in self.scope)


RULES: dict[str, Rule] = {}


def _rule(name: str, title: str, scope: tuple[str, ...] = (),
          invariant: str = ""):
    def deco(fn):
        RULES[name] = Rule(name, title, tuple(scope), invariant, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _dotted(node) -> str | None:
    """'jnp.sum' / 'jax.numpy.sum' / 'time.monotonic' for an attribute
    chain rooted at a Name; None for anything fancier."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _names_in(node) -> set[str]:
    """Every bare Name referenced anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _func_params(fn) -> list[str]:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]


# ---------------------------------------------------------------------------
# Signature index (pass 1, feeds PL003)
# ---------------------------------------------------------------------------

@dataclass
class SignatureIndex:
    """Guarded-kwarg acceptance per function name across the linted tree.

    ``guarded[name]`` is the set of GUARDED_KWARGS accepted by EVERY
    definition of ``name`` (intersection: a name defined both with and
    without ``precision`` is ambiguous at a call site, so it is not
    checked -- false negatives over false positives).
    """
    guarded: dict[str, set[str]] = field(default_factory=dict)

    def add(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if not isinstance(node, _FUNC_DEFS):
                continue
            params = set(_func_params(node)) & set(GUARDED_KWARGS)
            if node.name in self.guarded:
                self.guarded[node.name] &= params
            else:
                self.guarded[node.name] = params

    def accepts(self, name: str) -> set[str]:
        return self.guarded.get(name, set())


def build_signature_index(trees) -> SignatureIndex:
    idx = SignatureIndex()
    for tree in trees:
        idx.add(tree)
    return idx


# ---------------------------------------------------------------------------
# PL001 -- fixed-order reductions on accumulation paths
# ---------------------------------------------------------------------------

@_rule("PL001", "fixed-order-reduction", scope=ACCUM_SCOPE,
       invariant="no raw jnp.sum/jnp.prod/jnp.dot/jnp.matmul on engine "
                 "accumulation paths; use the fixed-order twofloat "
                 "reducers (tf_tree_sum / chain_prod / kernel_reduce)")
def _check_raw_reductions(ctx: FileContext) -> list[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name is None:
            continue
        head, _, attr = name.rpartition(".")
        if attr in RAW_REDUCERS and head in ("jnp", "jax.numpy"):
            out.append(Finding(
                "PL001", ctx.path, node.lineno, node.col_offset,
                f"raw {name}() on an accumulation path -- XLA "
                f"reassociates it per program shape, breaking bitwise "
                f"mesh identity; use the fixed-order twofloat reducers "
                f"or suppress with a shape-stability justification"))
    return out


# ---------------------------------------------------------------------------
# PL002 -- no vmap over complex engine bodies
# ---------------------------------------------------------------------------

@_rule("PL002", "no-vmap-complex", scope=ACCUM_SCOPE,
       invariant="complex engine bodies batch with lax.map, never vmap "
                 "(vmap fuses across the batch axis and drifts values "
                 "by ulps between batch extents)")
def _check_vmap_complex(ctx: FileContext) -> list[Finding]:
    out = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, _FUNC_DEFS) or "complex" not in fn.name:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name in ("vmap", "jax.vmap"):
                out.append(Finding(
                    "PL002", ctx.path, node.lineno, node.col_offset,
                    f"{name}() inside complex engine body "
                    f"{fn.name!r} -- vmap's batch-axis fusion drifts "
                    f"complex values between batch extents; use "
                    f"jax.lax.map (shares the scalar trace)"))
    return out


# ---------------------------------------------------------------------------
# PL003 -- guarded kwarg passthrough
# ---------------------------------------------------------------------------

def _alias_closure(fn, seed: str) -> set[str]:
    """Names assigned (directly or transitively) from ``seed`` in ``fn``.

    A light forward dataflow over plain assignments: ``prec = precision
    if ... else "dq_acc"`` makes ``prec`` count as forwarding
    ``precision``.  Two fixpoint passes cover chained aliases.
    """
    aliases = {seed}
    for _ in range(2):
        for node in ast.walk(fn):
            targets = []
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            if _names_in(value) & aliases:
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name):
                            aliases.add(leaf.id)
    return aliases


def _call_forwards(call: ast.Call, aliases: set[str]) -> bool:
    """Does any positional/keyword argument reference one of ``aliases``?"""
    for arg in call.args:
        if _names_in(arg) & aliases:
            return True
    for kw in call.keywords:
        if kw.arg is None:           # **kwargs splat: assume it forwards
            return True
        if _names_in(kw.value) & aliases:
            return True
    return False


@_rule("PL003", "kwarg-passthrough",
       invariant="a function accepting precision/num_chunks/backend must "
                 "forward each to every call whose callee also accepts "
                 "it (the PR 5/6 silent-drop bug class)")
def _check_passthrough(ctx: FileContext) -> list[Finding]:
    out = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, _FUNC_DEFS):
            continue
        own = set(_func_params(fn)) & set(GUARDED_KWARGS)
        if not own:
            continue
        alias_cache = {g: _alias_closure(fn, g) for g in own}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = node.func.attr if isinstance(node.func, ast.Attribute) \
                else (node.func.id if isinstance(node.func, ast.Name)
                      else None)
            if callee is None or callee == fn.name:
                continue
            needed = ctx.signatures.accepts(callee) & own
            for g in sorted(needed):
                if not _call_forwards(node, alias_cache[g]):
                    out.append(Finding(
                        "PL003", ctx.path, node.lineno, node.col_offset,
                        f"call to {callee}() drops {g!r}: both "
                        f"{fn.name}() and {callee}() accept it, so the "
                        f"callee silently runs at its default -- forward "
                        f"it explicitly"))
    return out


# ---------------------------------------------------------------------------
# PL004 -- injectable clocks only
# ---------------------------------------------------------------------------

@_rule("PL004", "injectable-clock", scope=CLOCK_SCOPE,
       invariant="no time.time/time.monotonic in core/ or serve/ outside "
                 "the sanctioned SolverConfig.clock default sites "
                 "(deadline behavior must be deterministic under test)")
def _check_wall_clock(ctx: FileContext) -> list[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Attribute):
            continue
        name = _dotted(node)
        if name in ("time.time", "time.monotonic"):
            out.append(Finding(
                "PL004", ctx.path, node.lineno, node.col_offset,
                f"{name} in {ctx.path.split('/')[-2]}/: timing must flow "
                f"through the injectable SolverConfig.clock (suppress "
                f"only at the sanctioned default sites)"))
    return out


# ---------------------------------------------------------------------------
# PL005 -- SolverConfig fields classified for fingerprint()
# ---------------------------------------------------------------------------

def _class_body(tree: ast.Module, name: str) -> ast.ClassDef | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _str_tuple_assign(cls: ast.ClassDef, name: str) -> set[str] | None:
    """The literal string tuple assigned to ``name`` in a class body."""
    for node in cls.body:
        targets = node.targets if isinstance(node, ast.Assign) else \
            [node.target] if isinstance(node, ast.AnnAssign) else []
        if not any(isinstance(t, ast.Name) and t.id == name
                   for t in targets):
            continue
        value = node.value
        if isinstance(value, ast.Tuple) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in value.elts):
            return {e.value for e in value.elts}
        return None                  # assigned, but not a literal tuple
    return None


@_rule("PL005", "config-classification", scope=PLANNER_SCOPE,
       invariant="every SolverConfig field is explicitly classified as "
                 "numerics-affecting (_NUMERIC_FIELDS) or policy "
                 "(_POLICY_FIELDS) so ExecutionPlan.fingerprint() can "
                 "never silently ignore a new knob")
def _check_config_classified(ctx: FileContext) -> list[Finding]:
    cfg = _class_body(ctx.tree, "SolverConfig")
    plan = _class_body(ctx.tree, "ExecutionPlan")
    if cfg is None or plan is None:
        return []
    fields = {node.target.id for node in cfg.body
              if isinstance(node, ast.AnnAssign)
              and isinstance(node.target, ast.Name)}
    out = []
    numeric = _str_tuple_assign(plan, "_NUMERIC_FIELDS")
    policy = _str_tuple_assign(plan, "_POLICY_FIELDS")
    line, col = cfg.lineno, cfg.col_offset
    if numeric is None or policy is None:
        missing = [n for n, v in (("_NUMERIC_FIELDS", numeric),
                                  ("_POLICY_FIELDS", policy)) if v is None]
        out.append(Finding(
            "PL005", ctx.path, plan.lineno, plan.col_offset,
            f"ExecutionPlan must declare {' and '.join(missing)} as "
            f"literal string tuples classifying every SolverConfig field"))
        return out
    unclassified = fields - numeric - policy
    if unclassified:
        out.append(Finding(
            "PL005", ctx.path, line, col,
            f"SolverConfig field(s) {sorted(unclassified)} are not "
            f"classified in ExecutionPlan._NUMERIC_FIELDS or "
            f"_POLICY_FIELDS -- decide whether each perturbs numerics "
            f"and add it to exactly one tuple"))
    overlap = numeric & policy
    if overlap:
        out.append(Finding(
            "PL005", ctx.path, line, col,
            f"field(s) {sorted(overlap)} appear in BOTH _NUMERIC_FIELDS "
            f"and _POLICY_FIELDS; classification must be exclusive"))
    unknown = (numeric | policy) - fields
    if unknown:
        out.append(Finding(
            "PL005", ctx.path, line, col,
            f"classified name(s) {sorted(unknown)} are not SolverConfig "
            f"fields -- stale entry after a rename?"))
    return out


# ---------------------------------------------------------------------------
# PL006 -- cache keys carry backend + dtype
# ---------------------------------------------------------------------------

_CACHE_KEY_PARAMS = ("leaf_key", "route", "precision", "backend",
                     "num_chunks", "dtype", "geometry")


@_rule("PL006", "cache-key-completeness",
       invariant="ResultCache.key call sites bind every component "
                 "including backend, dtype and geometry (kernel/jnp "
                 "values, real/complex leaves, and distinct kernel "
                 "geometries must never share an entry)")
def _check_cache_key(ctx: FileContext) -> list[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) != "ResultCache.key":
            continue
        bound = set(_CACHE_KEY_PARAMS[:len(node.args)])
        bound |= {kw.arg for kw in node.keywords if kw.arg}
        missing = [p for p in _CACHE_KEY_PARAMS if p not in bound]
        if missing:
            out.append(Finding(
                "PL006", ctx.path, node.lineno, node.col_offset,
                f"ResultCache.key() call leaves {missing} at their "
                f"defaults -- every component (notably backend and "
                f"dtype) must be bound explicitly so ulp-distinct "
                f"producers never share a cache entry"))
    return out


# ---------------------------------------------------------------------------
# PLF01 -- unused module-level imports (pyflakes-class)
# ---------------------------------------------------------------------------

def _used_names(tree: ast.Module) -> set[str]:
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # attribute roots are Names and collected above; nothing extra
            pass
    # names re-exported through __all__ count as used
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            for elt in ast.walk(node.value):
                if isinstance(elt, ast.Constant) and \
                        isinstance(elt.value, str):
                    used.add(elt.value)
    return used


@_rule("PLF01", "unused-import",
       invariant="no unused module-level imports (pyflakes F401 class; "
                 "ruff enforces the superset when installed)")
def _check_unused_imports(ctx: FileContext) -> list[Finding]:
    if ctx.path.endswith("__init__.py"):
        return []                    # re-export surface; ruff handles it
    used = _used_names(ctx.tree)
    out = []
    for node in ctx.tree.body:       # module level only
        if isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            aliases = node.names
        elif isinstance(node, ast.Import):
            aliases = node.names
        else:
            continue
        for alias in aliases:
            if alias.name == "*":
                continue
            bound = alias.asname or alias.name.split(".")[0]
            if bound not in used:
                out.append(Finding(
                    "PLF01", ctx.path, node.lineno, node.col_offset,
                    f"{bound!r} imported but unused"))
    return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_rules(ctx: FileContext,
              only: set[str] | None = None) -> list[Finding]:
    """All findings for one parsed file, every in-scope rule."""
    out: list[Finding] = []
    for rule in RULES.values():
        if only is not None and rule.name not in only:
            continue
        if rule.in_scope(ctx.path):
            out.extend(rule.check(ctx))
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out
