"""permprove: IR-level verification of the determinism & precision
contracts, with golden-trace drift gating (ISSUE 10).

Traces every public permanent entry -- dense/sparse x real/complex x
scalar/batch x jnp/pallas engines plus the campaign wave bodies -- per
precision mode via ``jax.make_jaxpr`` over abstract avals (no device
work, the same discipline as the PR 8 geometry auditor), renders each
jaxpr into a *canonical* text form (stable variable names, sorted
params, recursively inlined sub-jaxprs, const digests -- no memory
addresses or source locations), and:

* checks the PLI-series contracts from ``contracts.py`` on the walks
  (PLI101 batch-axis reductions, PLI102 dtype truncation, PLI103
  batch-extent invariance, PLI104 collective audit on the compiled
  sharded programs);
* fingerprints the canonical text per (route, engine, dtype, arity,
  precision) against goldens under ``tests/ir_goldens/`` -- any
  numerics-affecting IR change becomes an explicit, reviewed diff
  (``--bless`` regenerates; see docs/INVARIANTS.md for etiquette).

CLI::

    python -m repro.analysis.ir --check [--json] [--report PATH]
    python -m repro.analysis.ir --bless
    python -m repro.analysis.ir --check --entries 'dense_jnp.*'

Importing this module is jax-free; jax loads on first trace.  The CLI
forces 8 host devices (before jax import) so the PLI104 collective
audit sees a real mesh on CPU; in-process callers with a single device
get a loud "skipped" marker for PLI104 instead of a silent pass.
"""

from __future__ import annotations

import argparse
import dataclasses
import difflib
import fnmatch
import hashlib
import json
import os
import re
import sys
from dataclasses import dataclass

from . import contracts
from .rules import Finding

__all__ = ["ENTRIES", "Entry", "canonical_lines", "canonical_walk",
           "fingerprint", "trace_entry", "run_check", "bless",
           "golden_path", "GOLDEN_DIR", "PRECISIONS", "main"]

VERSION = "permprove/1"
PRECISIONS = ("dd", "dq_fast", "dq_acc", "kahan", "qq")

# Trace geometry: small enough to trace fast, big enough that every
# schedule/kernel arm is live.  2^(n-1) = 32 = T*C.
N = 6
NUM_CHUNKS = 16
MAXDEG = 3                    # padded-CCS column degree for sparse entries
CPS, CHUNK = 2, 16            # campaign wave: chunks_per_slice, chunk_size
CANON_B = 5                   # canonical batch extent (golden traces)
ALT_B = 7                     # second extent for PLI101/PLI103 (coprime)
TEXT_PRECISION = "dq_acc"     # the precision whose canonical text is
                              # stored verbatim in goldens (diffable);
                              # other precisions gate on fingerprints

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
GOLDEN_DIR = os.path.join(_REPO, "tests", "ir_goldens")


# ---------------------------------------------------------------------------
# Entry registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Entry:
    route: str     # dense | sparse | campaign
    engine: str    # jnp | pallas
    dtype: str     # f64 | c128
    arity: str     # scalar | batch | wave

    @property
    def name(self) -> str:
        return f"{self.route}_{self.engine}.{self.dtype}.{self.arity}"

    @property
    def batched(self) -> bool:
        return self.arity == "batch"


ENTRIES: tuple[Entry, ...] = tuple(
    Entry(route, engine, dtype, arity)
    for route in ("dense", "sparse")
    for engine in ("jnp", "pallas")
    for dtype in ("f64", "c128")
    for arity in ("scalar", "batch")
) + tuple(
    Entry("campaign", engine, dtype, "wave")
    for engine in ("jnp", "pallas")
    for dtype in ("f64", "c128")
)


def _sds(shape, dtype):
    import jax
    return jax.ShapeDtypeStruct(shape, dtype)


def _build(entry: Entry, precision: str, B: int):
    """(fn, abstract args) for one entry: the *production* traced body
    behind the matching public API, not a test double."""
    import numpy as np

    n = N
    f64, c128, i32 = np.float64, np.complex128, np.int32
    dt = f64 if entry.dtype == "f64" else c128
    from ..core.ryser import chunk_geometry
    T, C, _ = chunk_geometry(n, NUM_CHUNKS)

    if entry.route == "dense" and entry.engine == "jnp":
        from ..core import ryser
        if entry.arity == "scalar":
            fn = lambda A: ryser.perm_ryser_chunked(
                A, num_chunks=NUM_CHUNKS, precision=precision)
            return fn, (_sds((n, n), dt),)
        fn = lambda As: ryser.perm_ryser_batched(
            As, num_chunks=NUM_CHUNKS, precision=precision)
        return fn, (_sds((B, n, n), dt),)

    if entry.route == "dense" and entry.engine == "pallas":
        from ..kernels.ops import _pallas_values
        from ..core.stepspace import DEFAULT_GEOMETRY
        mode = "batched" if entry.batched else "baseline"
        fn = lambda As: _pallas_values(
            As, batched=entry.batched, precision=precision, mode=mode,
            geometry=DEFAULT_GEOMETRY, interpret=True)
        shape = (B, n, n) if entry.batched else (n, n)
        return fn, (_sds(shape, dt),)

    if entry.route == "sparse" and entry.engine == "jnp":
        from ..core import sparyser
        if entry.dtype == "f64":
            if entry.arity == "scalar":
                fn = lambda A, r, v: sparyser.sparse_chunked_value(
                    A, r, v, T, C, precision)
                return fn, (_sds((n, n), f64), _sds((n, MAXDEG), i32),
                            _sds((n, MAXDEG), f64))
            fn = lambda As, rs, vs: sparyser.sparse_batched_values(
                As, rs, vs, T, C, precision)
            return fn, (_sds((B, n, n), f64), _sds((B, n, MAXDEG), i32),
                        _sds((B, n, MAXDEG), f64))
        # complex scalar runs as a B=1 batch program in production
        # (perm_sparyser_chunked -> perm_sparyser_batched), so the
        # scalar entry IS the B=1 trace of the batched body.
        Bc = 1 if entry.arity == "scalar" else B
        fn = lambda Ar, Ai, rs, vr, vi: \
            sparyser.sparse_batched_values_complex(
                Ar, Ai, rs, vr, vi, T, C, precision)
        return fn, (_sds((Bc, n, n), f64), _sds((Bc, n, n), f64),
                    _sds((Bc, n, MAXDEG), i32),
                    _sds((Bc, n, MAXDEG), f64), _sds((Bc, n, MAXDEG), f64))

    if entry.route == "sparse" and entry.engine == "pallas":
        from ..kernels.ops import _pallas_sparse_values
        from ..core.stepspace import DEFAULT_GEOMETRY
        fn = lambda As, rs, vs: _pallas_sparse_values(
            As, rs, vs, batched=entry.batched, precision=precision,
            geometry=DEFAULT_GEOMETRY, interpret=True)
        if entry.batched:
            return fn, (_sds((B, n, n), dt), _sds((B, n, MAXDEG), i32),
                        _sds((B, n, MAXDEG), dt))
        return fn, (_sds((n, n), dt), _sds((n, MAXDEG), i32),
                    _sds((n, MAXDEG), dt))

    # campaign wave bodies: the per-device program run under shard_map
    # by slice_sums_on_mesh/permanent_on_mesh, with a *traced* chunk
    # base -- one program for every device.
    from ..core import distributed
    if entry.engine == "jnp":
        fn = lambda A, fc: distributed._dyn_chunk_partials(
            A, fc, CPS, CHUNK, precision)
    elif entry.dtype == "f64":
        fn = lambda A, fc: distributed._pallas_device_partials(
            A, fc, CPS, CHUNK, precision)
    else:
        fn = lambda A, fc: distributed._pallas_device_partials_complex(
            A, fc, CPS, CHUNK, precision)
    return fn, (_sds((n, n), dt), _sds((), i32))


def trace_entry(entry: Entry, precision: str, B: int = CANON_B):
    """ClosedJaxpr of one entry at one precision/batch extent.  Abstract
    tracing only -- no device buffers, no compilation."""
    import jax
    fn, args = _build(entry, precision, B)
    return jax.make_jaxpr(fn)(*args)


# ---------------------------------------------------------------------------
# Canonical rendering
# ---------------------------------------------------------------------------

_DTYPE_SHORT = {
    "float16": "f16", "bfloat16": "bf16", "float32": "f32",
    "float64": "f64", "complex64": "c64", "complex128": "c128",
    "int8": "i8", "int16": "i16", "int32": "i32", "int64": "i64",
    "uint8": "u8", "uint16": "u16", "uint32": "u32", "uint64": "u64",
    "bool": "pred",
}

_ADDR = re.compile(r"0x[0-9a-fA-F]+")
# pallas NameAndSrcInfo embeds "at <abs path>:<line>" -- a source
# location whose spelling depends on sys.path/checkout and whose line
# shifts on unrelated edits; canonical text must carry neither.
_SRC_INFO = re.compile(r"\bat [^\s']+\.py:\d+")


def _short_dtype(dtype) -> str:
    import numpy as np
    name = np.dtype(dtype).name
    return _DTYPE_SHORT.get(name, name)


def _aval_str(aval) -> str:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return _ADDR.sub("<addr>", str(aval))
    dims = ",".join(str(d) for d in shape)
    return f"{_short_dtype(aval.dtype)}[{dims}]"


def _is_jaxpr(v) -> bool:
    import jax
    return isinstance(v, (jax.core.Jaxpr, jax.core.ClosedJaxpr))


def _sanitize(v, subs: list) -> str:
    """Deterministic, address-free rendering of one eqn param value.
    Sub-jaxprs are collected into ``subs`` and rendered beneath the
    eqn; callables render by name only."""
    import numpy as np
    if _is_jaxpr(v):
        subs.append(v)
        return f"jaxpr<{len(subs) - 1}>"
    if v is None or isinstance(v, (bool, np.bool_)):
        return str(v)
    if isinstance(v, (int, np.integer)):
        return str(int(v))
    if isinstance(v, (float, np.floating)):
        return repr(float(v))
    if isinstance(v, complex):
        return repr(v)
    if isinstance(v, str):
        return repr(_SRC_INFO.sub("at <src>", v))
    if isinstance(v, np.dtype):
        return _short_dtype(v)
    if isinstance(v, type) and issubclass(v, np.generic):
        return _short_dtype(v)
    if isinstance(v, np.ndarray):
        digest = hashlib.sha256(
            np.ascontiguousarray(v).tobytes()).hexdigest()[:12]
        return (f"ndarray({_short_dtype(v.dtype)}"
                f"[{','.join(map(str, v.shape))}] sha={digest})")
    if isinstance(v, (tuple, list)):
        body = ",".join(_sanitize(x, subs) for x in v)
        return f"({body})"
    if isinstance(v, dict):
        body = ",".join(f"{k}:{_sanitize(x, subs)}"
                        for k, x in sorted(v.items(), key=lambda kv:
                                           str(kv[0])))
        return "{" + body + "}"
    if isinstance(v, (set, frozenset)):
        body = ",".join(sorted(_sanitize(x, subs) for x in v))
        return "{" + body + "}"
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        body = ",".join(
            f"{f.name}={_sanitize(getattr(v, f.name), subs)}"
            for f in sorted(dataclasses.fields(v), key=lambda f: f.name))
        return f"{type(v).__name__}({body})"
    if callable(v):
        return f"fn:{getattr(v, '__name__', type(v).__name__)}"
    clean = _SRC_INFO.sub("at <src>", _ADDR.sub("<addr>", repr(v)))
    return f"<{type(v).__name__}:{clean}>"


class _Walk:
    """Accumulates canonical lines plus the contract records."""

    def __init__(self):
        self.lines: list[str] = []
        self.reduces: list[contracts.ReduceRecord] = []
        self.converts: list[contracts.ConvertRecord] = []
        self._eqn_index = 0


def _reduced_extents(eqn) -> tuple[int, ...]:
    """Extents of the contracted axes of a reduce/dot eqn."""
    name = eqn.primitive.name
    shape = tuple(eqn.invars[0].aval.shape)
    if name in ("reduce_sum", "reduce_prod", "reduce_max", "reduce_min"):
        axes = eqn.params.get("axes", ())
        return tuple(shape[a] for a in axes)
    if name == "dot_general":
        (lhs_c, _), _ = eqn.params["dimension_numbers"]
        return tuple(shape[a] for a in lhs_c)
    return ()


def _render_jaxpr(jaxpr, consts, walk: _Walk, depth: int):
    import jax
    import numpy as np
    Literal = jax.core.Literal
    pad = "  " * depth
    names: dict = {}

    def vname(v):
        if isinstance(v, Literal):
            return f"lit({_sanitize(np.asarray(v.val).item() if np.ndim(v.val) == 0 else np.asarray(v.val), [])}:{_aval_str(v.aval)})"
        if type(v).__name__ == "DropVar":
            return "_"
        if v not in names:
            names[v] = f"v{len(names)}"
        return names[v]

    for cv, cval in zip(jaxpr.constvars, consts):
        if cval is None:
            walk.lines.append(f"{pad}const {vname(cv)}:{_aval_str(cv.aval)}")
        else:
            arr = np.asarray(cval)
            digest = hashlib.sha256(
                np.ascontiguousarray(arr).tobytes()).hexdigest()[:12]
            walk.lines.append(
                f"{pad}const {vname(cv)}:{_aval_str(cv.aval)} sha={digest}")
    walk.lines.append(pad + "in " + " ".join(
        f"{vname(v)}:{_aval_str(v.aval)}" for v in jaxpr.invars))

    for eqn in jaxpr.eqns:
        subs: list = []
        params = ",".join(f"{k}={_sanitize(v, subs)}"
                          for k, v in sorted(eqn.params.items()))
        ins = " ".join(vname(v) for v in eqn.invars)
        outs = " ".join(f"{vname(v)}:{_aval_str(v.aval)}"
                        for v in eqn.outvars)
        name = eqn.primitive.name
        idx = walk._eqn_index
        walk._eqn_index += 1
        walk.lines.append(f"{pad}{outs} = {name}[{params}] {ins}")

        if eqn.invars and not isinstance(eqn.invars[0], Literal):
            in_aval = eqn.invars[0].aval
            short = _short_dtype(getattr(in_aval, "dtype", np.int32)) \
                if hasattr(in_aval, "dtype") else "?"
            ext = _reduced_extents(eqn)
            if ext and contracts._is_floatish(short):
                walk.reduces.append(contracts.ReduceRecord(
                    index=idx, primitive=name, dtype=short,
                    reduced_extents=ext))
            if name == "convert_element_type":
                walk.converts.append(contracts.ConvertRecord(
                    index=idx, src=short,
                    dst=_short_dtype(eqn.outvars[0].aval.dtype)))

        for sub in subs:
            if isinstance(sub, jax.core.ClosedJaxpr):
                _render_jaxpr(sub.jaxpr, sub.consts, walk, depth + 1)
            else:
                _render_jaxpr(sub, [None] * len(sub.constvars), walk,
                              depth + 1)

    walk.lines.append(pad + "out " + " ".join(
        vname(v) for v in jaxpr.outvars))


def canonical_walk(closed) -> _Walk:
    walk = _Walk()
    _render_jaxpr(closed.jaxpr, closed.consts, walk, 0)
    return walk


def canonical_lines(closed) -> list[str]:
    return canonical_walk(closed).lines


def fingerprint(lines: list[str]) -> str:
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Goldens
# ---------------------------------------------------------------------------

def golden_path(entry: Entry, golden_dir: str | None = None) -> str:
    return os.path.join(golden_dir or GOLDEN_DIR, entry.name + ".golden")


def _jax_version() -> str:
    import jax
    return jax.__version__


def render_golden(entry: Entry,
                  sections: dict[str, tuple[str, list[str] | None]]) -> str:
    """Golden file text: per-precision fingerprints, plus the canonical
    trace verbatim for TEXT_PRECISION (the diffable precision)."""
    head = [
        "# permprove golden -- machine-generated; regenerate with",
        "#   PYTHONPATH=src python -m repro.analysis.ir --bless",
        f"version: {VERSION}",
        f"jax: {_jax_version()}",
        f"entry: {entry.name}",
        f"n: {N} num_chunks: {NUM_CHUNKS} batch: {CANON_B} "
        f"maxdeg: {MAXDEG} wave: {CPS}x{CHUNK}",
    ]
    body = []
    for prec in PRECISIONS:
        fp, lines = sections[prec]
        body.append(f"== precision={prec} fingerprint={fp}")
        if lines is not None:
            body.extend(lines)
    return "\n".join(head + body) + "\n"


def parse_golden(text: str) -> dict:
    """-> {"jax": str, "sections": {prec: (fingerprint, lines|None)}}"""
    jax_ver = None
    sections: dict[str, tuple[str, list[str] | None]] = {}
    cur = None
    for line in text.splitlines():
        if line.startswith("jax: "):
            jax_ver = line[len("jax: "):].strip()
        m = re.match(r"== precision=(\S+) fingerprint=(\S+)", line)
        if m:
            cur = m.group(1)
            sections[cur] = (m.group(2), [])
            continue
        if cur is not None:
            fp, lines = sections[cur]
            lines.append(line)
    sections = {p: (fp, lines if lines else None)
                for p, (fp, lines) in sections.items()}
    return {"jax": jax_ver, "sections": sections}


# ---------------------------------------------------------------------------
# The prove pass
# ---------------------------------------------------------------------------

def _select(pattern: str | None) -> list[Entry]:
    if not pattern:
        return list(ENTRIES)
    return [e for e in ENTRIES if fnmatch.fnmatch(e.name, pattern)]


def _entry_walks(entry: Entry, log=None):
    """{precision: walk} at CANON_B plus {precision: walk} at ALT_B for
    batch entries (None otherwise)."""
    walks, alt_walks = {}, {}
    for prec in PRECISIONS:
        walks[prec] = canonical_walk(trace_entry(entry, prec, CANON_B))
        if entry.batched:
            alt_walks[prec] = canonical_walk(
                trace_entry(entry, prec, ALT_B))
    if log:
        log(f"  traced {entry.name} ({len(walks[TEXT_PRECISION].lines)} "
            f"canonical lines)")
    return walks, (alt_walks if entry.batched else None)


def _contract_findings(entry: Entry, walks, alt_walks) -> list[Finding]:
    found: list[Finding] = []
    for prec, w in walks.items():
        found += contracts.pli102_dtype_flow(entry.name, w.converts, prec)
        if alt_walks is not None:
            aw = alt_walks[prec]
            found += contracts.pli103_batch_invariance(
                entry.name, prec, w.lines, aw.lines, CANON_B, ALT_B)
            found += contracts.pli101_reductions(
                entry.name, prec, w.reduces, aw.reduces, CANON_B, ALT_B)
    return found


def _mesh_programs(log=None):
    """Compiled HLO of every sharded program + its sanctioned collective
    budget, or None (-> PLI104 skipped) when <2 devices are visible.

    Abstract ``.lower().compile()`` only -- no data touches a device.
    """
    import numpy as np
    import jax
    devs = jax.devices()
    if len(devs) < 2:
        return None
    from jax.sharding import Mesh
    from ..core import distributed
    from ..core.ryser import chunk_geometry

    D = len(devs[:8])
    mesh = Mesh(np.asarray(devs[:8]), ("d",))
    T, C, _ = chunk_geometry(N, NUM_CHUNKS)
    f64, i32 = np.float64, np.int32
    A = _sds((N, N), f64)
    Ac = _sds((N, N), np.complex128)
    sl = _sds((D, 1), i32)
    stack = _sds((D, N, N), f64)

    progs = []

    def lower(name, fn, args, sanctioned):
        if log:
            log(f"  compiling mesh program {name}")
        txt = fn.lower(*args).compile().as_text()
        progs.append((name, txt, sanctioned))

    ONE_PSUM = {"all-reduce": 2}      # one (hi, lo) twofloat psum pair
    NONE = {}
    lower("mesh.wave_jnp",
          distributed._wave_fn(mesh, CPS, CHUNK, "dq_acc", "jnp", None),
          (A, sl), NONE)
    lower("mesh.wave_pallas",
          distributed._wave_fn(mesh, CPS, CHUNK, "dq_acc", "pallas", None),
          (A, sl), NONE)
    lower("mesh.oneshot_jnp",
          distributed._oneshot_mesh_fn(mesh, 1, CPS, CHUNK, "dq_acc",
                                       "jnp"),
          (A, sl, _sds((D, 1), f64)), ONE_PSUM)
    lower("mesh.oneshot_pallas",
          distributed._oneshot_mesh_fn(mesh, 1, CPS, CHUNK, "dq_acc",
                                       "pallas"),
          (Ac, sl, _sds((D, 1), f64)), ONE_PSUM)
    lower("mesh.dense_batch",
          distributed._dense_batch_mesh_fn(mesh, T, C, "dq_acc"),
          (stack,), NONE)
    lower("mesh.sparse_batch",
          distributed._sparse_batch_mesh_fn(mesh, T, C, "dq_acc"),
          (stack, _sds((D, N, MAXDEG), i32), _sds((D, N, MAXDEG), f64)),
          NONE)
    return progs


def run_check(entries_pattern: str | None = None,
              golden_dir: str | None = None, bless_mode: bool = False,
              with_mesh: bool = True, log=None) -> dict:
    """Trace, check contracts, and gate (or bless) goldens.

    Returns the report dict (``version``/``entries``/``findings``/
    ``suppressions``/``goldens``/``mesh``).
    """
    import jax
    jax.config.update("jax_enable_x64", True)

    gdir = golden_dir or GOLDEN_DIR
    selected = _select(entries_pattern)
    findings: list[Finding] = []
    drifted: list[dict] = []
    missing: list[str] = []
    blessed: list[str] = []
    golden_skip = None

    for entry in selected:
        walks, alt_walks = _entry_walks(entry, log)
        findings += _contract_findings(entry, walks, alt_walks)

        sections = {
            p: (fingerprint(w.lines),
                w.lines if p == TEXT_PRECISION else None)
            for p, w in walks.items()}
        gpath = golden_path(entry, gdir)
        if bless_mode:
            os.makedirs(gdir, exist_ok=True)
            with open(gpath, "w", encoding="utf-8") as f:
                f.write(render_golden(entry, sections))
            blessed.append(entry.name)
            continue
        if not os.path.exists(gpath):
            missing.append(entry.name)
            continue
        with open(gpath, encoding="utf-8") as f:
            gold = parse_golden(f.read())
        if gold["jax"] != _jax_version():
            golden_skip = (f"goldens blessed under jax {gold['jax']} "
                           f"but running {_jax_version()}; fingerprint "
                           f"gate skipped (contract rules still ran)")
            continue
        for prec in PRECISIONS:
            got_fp, got_lines = sections[prec]
            want_fp, want_lines = gold["sections"].get(prec, (None, None))
            if want_fp == got_fp:
                continue
            diff = None
            if want_lines is not None and got_lines is not None:
                diff = "\n".join(difflib.unified_diff(
                    want_lines, got_lines, fromfile=f"golden/{prec}",
                    tofile=f"traced/{prec}", lineterm="", n=2))
            drifted.append({"entry": entry.name, "precision": prec,
                            "want": want_fp, "got": got_fp,
                            "diff": diff})

    mesh_report: dict = {"checked": 0, "skipped": None}
    if with_mesh and not bless_mode:
        progs = _mesh_programs(log)
        if progs is None:
            mesh_report["skipped"] = ("single visible device; run via "
                                      "the CLI (forces 8 host devices) "
                                      "for the PLI104 collective audit")
        else:
            for name, txt, sanctioned in progs:
                findings += contracts.pli104_collectives(
                    name, txt, sanctioned)
            mesh_report["checked"] = len(progs)

    pre_suppressed = [f for f in findings if f.suppressed]
    active, suppressed = contracts.apply_sanctions(
        [f for f in findings if not f.suppressed])
    suppressed += pre_suppressed
    return {
        "version": VERSION,
        "entries": [e.name for e in selected],
        "findings": active,
        "suppressions": suppressed,
        "goldens": {"dir": gdir, "drifted": drifted, "missing": missing,
                    "blessed": blessed, "skipped": golden_skip},
        "mesh": mesh_report,
    }


def bless(entries_pattern: str | None = None,
          golden_dir: str | None = None, log=None) -> dict:
    return run_check(entries_pattern, golden_dir, bless_mode=True,
                     with_mesh=False, log=log)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _report_json(report: dict) -> dict:
    out = dict(report)
    out["findings"] = [f.to_json() for f in report["findings"]]
    out["suppressions"] = [f.to_json() for f in report["suppressions"]]
    return out


def _print_report(report: dict) -> None:
    for f in report["findings"]:
        print(f.render())
    g = report["goldens"]
    for d in g["drifted"]:
        print(f"GOLDEN DRIFT {d['entry']} precision={d['precision']}: "
              f"fingerprint {d['want']} -> {d['got']}")
        if d["diff"]:
            print(d["diff"])
        else:
            print(f"  (canonical text stored for "
                  f"precision={TEXT_PRECISION} only; re-run with "
                  f"--bless in a scratch tree to inspect)")
    for name in g["missing"]:
        print(f"GOLDEN MISSING {name}: no {golden_path_name(name)} -- "
              f"run --bless and commit the result")
    if g["skipped"]:
        print(f"note: {g['skipped']}")
    if report["mesh"]["skipped"]:
        print(f"note: PLI104 {report['mesh']['skipped']}")
    n_f, n_s = len(report["findings"]), len(report["suppressions"])
    n_d = len(g["drifted"]) + len(g["missing"])
    print(f"permprove: {len(report['entries'])} entries x "
          f"{len(PRECISIONS)} precisions, {n_f} finding(s), "
          f"{n_s} sanctioned suppression(s), {n_d} golden problem(s), "
          f"{report['mesh']['checked']} mesh program(s) audited")


def golden_path_name(entry_name: str) -> str:
    return os.path.join("tests", "ir_goldens", entry_name + ".golden")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.ir", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--check", action="store_true",
                    help="trace all entries, check PLI contracts and "
                         "golden fingerprints")
    ap.add_argument("--bless", action="store_true",
                    help="regenerate the goldens from the current tree")
    ap.add_argument("--entries", default=None, metavar="PATTERN",
                    help="fnmatch filter over entry names "
                         "(e.g. 'dense_jnp.*')")
    ap.add_argument("--goldens", default=None, metavar="DIR",
                    help=f"golden directory (default {GOLDEN_DIR})")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the PLI104 compiled-mesh collective audit")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON on stdout")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the JSON report to PATH")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if not (args.check or args.bless):
        ap.print_usage()
        return 2
    if args.entries and not _select(args.entries):
        print(f"no entries match {args.entries!r}", file=sys.stderr)
        return 2

    log = None if (args.quiet or args.json) else print
    if args.bless:
        report = bless(args.entries, args.goldens, log=log)
    else:
        report = run_check(args.entries, args.goldens,
                           with_mesh=not args.no_mesh, log=log)

    payload = _report_json(report)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2)
    if args.json:
        json.dump(payload, sys.stdout, indent=2)
        print()
    else:
        if args.bless:
            for name in report["goldens"]["blessed"]:
                print(f"blessed {golden_path_name(name)}")
        else:
            _print_report(report)

    bad = (report["findings"] or report["goldens"]["drifted"]
           or report["goldens"]["missing"])
    return 1 if bad else 0


if __name__ == "__main__":
    # Force a multi-device host platform BEFORE jax loads so the PLI104
    # collective audit compiles against a real mesh on CPU.
    if "jax" not in sys.modules \
            and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8").strip()
    sys.exit(main())
