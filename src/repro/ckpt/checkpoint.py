"""Pytree checkpointing (training state + permanent-job state).

Single-file ``.npz`` per step with path-keyed leaves; atomic rename;
keeps the last N checkpoints.  Restores into an existing tree template
(shape/dtype checked), so resharding on restore is just device_put with the
current mesh's NamedShardings -- elastic restarts across different meshes
work because the on-disk format is sharding-agnostic.
"""

from __future__ import annotations

import os
import re
import tempfile

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "save_train_state",
           "restore_train_state", "latest_step"]

_SEP = "|"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree, extra: dict | None = None) -> None:
    flat = _flatten(tree)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__{k}"] = np.asarray(v)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, template):
    """Restore into the template's structure; returns (tree, extra)."""
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    with np.load(path, allow_pickle=False) as z:
        extra = {k[len("__extra__"):]: z[k] for k in z.files
                 if k.startswith("__extra__")}
        out = []
        for path_k, leaf in leaves_t:
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path_k)
            arr = z[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            # dtype is part of the template contract too: silently
            # restoring a float32 leaf into a float64 template (or a real
            # array into a complex slot) corrupts numerics downstream.
            # Extension dtypes (bfloat16, float8) come back from .npz as
            # raw void of the same width -- view them through the template.
            want = np.dtype(leaf.dtype)
            if arr.dtype != want:
                if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
                    arr = arr.view(want)
                else:
                    raise ValueError(f"dtype mismatch for {key}: "
                                     f"{arr.dtype} vs {leaf.dtype}")
            out.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, extra


def save_train_state(ckpt_dir: str, step: int, params, opt_state,
                     keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    save_pytree(path, {"params": params, "opt": opt_state},
                extra={"step": step})
    # prune old checkpoints
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep]:
        try:
            os.unlink(os.path.join(ckpt_dir, f"step_{s:08d}.npz"))
        except OSError:
            pass
    return path


def _all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    return [int(m.group(1)) for f in os.listdir(ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", f))]


def latest_step(ckpt_dir: str) -> int | None:
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_train_state(ckpt_dir: str, params_template, opt_template,
                        step: int | None = None):
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tree, extra = load_pytree(path, {"params": params_template,
                                     "opt": opt_template})
    return tree["params"], tree["opt"], int(extra["step"])
