"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE, gelu MLP with bias.  [arXiv:2402.19173; hf]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab=49152, norm="layernorm", mlp="gelu", attn_bias=True,
    rope_theta=100000.0, source="arXiv:2402.19173; hf")
