"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
16 experts top-2, vocab=32064.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, n_experts=16, top_k=2, norm="layernorm", mlp="swiglu",
    rope_theta=10000.0, source="hf:microsoft/Phi-3.5-MoE-instruct; hf")
