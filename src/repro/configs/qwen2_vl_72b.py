"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]
Vision frontend is a stub: the backbone consumes precomputed patch
embeddings + 3D (t, h, w) position ids (assignment rule)."""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, norm="rmsnorm", mlp="swiglu", rope_theta=1e6,
    mrope_sections=(16, 24, 24), attn_bias=True,
    source="arXiv:2409.12191; hf", notes="M-RoPE; vision frontend stubbed")
