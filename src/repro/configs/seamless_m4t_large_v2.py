"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.

24L encoder + 24L decoder, d_model=1024, 16H (GQA kv=16), d_ff=8192,
vocab=256206.  [arXiv:2308.11596; hf]  Audio frontend is a stub: the
encoder consumes precomputed frame embeddings (assignment rule).
"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="seamless-m4t-large-v2", family="audio-encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, norm="layernorm", mlp="gelu",
    rope_theta=10000.0, attn_bias=True,
    source="arXiv:2308.11596; hf", notes="enc-dec; audio frontend stubbed")
