"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; llama-arch code model.  [arXiv:2405.04324; hf]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, norm="layernorm", mlp="gelu", attn_bias=True,
    rope_theta=10000.0, source="arXiv:2405.04324; hf",
    notes="deep-narrow MQA; non-gated gelu MLP (gpt-bigcode style): "
          "gated swiglu would give 47B, the published model is 34B")
