"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384,
8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, n_experts=8, top_k=2, norm="rmsnorm", mlp="swiglu",
    swa_window=4096, rope_theta=1e6,
    source="arXiv:2401.04088; hf", notes="SWA per assignment")
