"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, vocab=50280,
ssm_state=128 (SSD).  [arXiv:2405.21060; unverified]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_conv=4, ssm_chunk=128,
    source="arXiv:2405.21060; unverified",
    notes="attn-free SSD; runs long_500k")
