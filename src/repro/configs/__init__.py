"""Assigned-architecture registry (--arch <id> selectable everywhere)."""
from __future__ import annotations

from importlib import import_module

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "stablelm-3b": "stablelm_3b",
    "starcoder2-3b": "starcoder2_3b",
    "command-r-35b": "command_r_35b",
    "granite-34b": "granite_34b",
    "mamba2-370m": "mamba2_370m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-1.2b": "zamba2_1p2b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ARCH_IDS = tuple(_MODULES)

# archs whose attention is fully quadratic: long_500k is skipped for these
# (assignment rule; see DESIGN.md Sec. 5)
FULL_ATTENTION_ARCHS = tuple(a for a in ARCH_IDS
                             if a not in ("mamba2-370m", "zamba2-1.2b"))


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return import_module(f".{_MODULES[arch_id]}", __package__).CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
