"""zamba2-1.2b [hybrid]: 38L mamba2 backbone, d_model=2048, shared
attention block (32H kv=32, d_ff=8192) applied every 6 layers,
vocab=32000, ssm_state=64.  [arXiv:2411.15242; hf]"""
from ..models.common import ModelCfg

CONFIG = ModelCfg(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    ssm_conv=4, ssm_chunk=128, shared_attn_period=6,
    norm="rmsnorm", mlp="swiglu",
    source="arXiv:2411.15242; hf",
    notes="mamba2 + weight-shared attn blocks; runs long_500k")
