"""Serving driver: LM decode loop + batched permanent serving.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --prompt-len 64 --gen 32 --batch 4 [--reduced]
    PYTHONPATH=src python -m repro.launch.serve --mode permanent \
        --perm-n 10 --batch 32 --requests 256

LM mode builds the serve bundle (KV sharding policy chosen per arch/mesh),
prefills a synthetic prompt batch, then decodes greedily.  Permanent mode
drains a synthetic request stream through ``engine.permanent_batch`` in
batches, so compilation and dispatch are amortized across requests -- the
throughput shape (perms/sec) the SUperman paper headlines.  Runnable on
CPU with ``--reduced``; on a real pod the same code paths serve the full
configs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models.model import ShapeCell, build
from ..train.train_step import build_serve_steps
from .mesh import make_local_mesh

__all__ = ["serve_main", "run_serving", "run_permanent_serving"]


def run_serving(arch: str, *, prompt_len: int = 64, gen: int = 32,
                batch: int = 4, reduced: bool = True, mesh=None,
                seed: int = 0, greedy: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = mesh or make_local_mesh()
    max_seq = prompt_len + gen
    rng = np.random.default_rng(seed)

    prefill_cell = ShapeCell("serve", "prefill", prompt_len, batch)
    decode_cell = ShapeCell("serve", "decode", max_seq, batch)
    prefill_fn, _, _, _ = build_serve_steps(model, mesh, prefill_cell)
    decode_fn, _, _, policy = build_serve_steps(model, mesh, decode_cell)

    params = model.init_params(jax.random.PRNGKey(seed))
    # serving weights are bf16 + resident (cf. build_serve_steps)
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 else p, params)

    if cfg.family == "vlm":
        pos = np.broadcast_to(np.arange(prompt_len)[None, None],
                              (3, batch, prompt_len)).copy()
        inputs = {"embeds": jnp.asarray(
            rng.normal(0, 0.02, (batch, prompt_len, cfg.d_model)),
            cfg.dtype), "positions": jnp.asarray(pos, jnp.int32)}
    elif cfg.family == "audio-encdec":
        inputs = {"enc_embeds": jnp.asarray(
            rng.normal(0, 0.02, (batch, prompt_len, cfg.d_model)),
            cfg.dtype)}
    else:
        inputs = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}

    t0 = time.time()
    h, cache = prefill_fn(params, inputs)
    # pad the prefill cache out to max_seq (cache was built at prompt_len)
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == prompt_len and cfg.family != "ssm":
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, gen)
            return jnp.pad(x, pad)
        return x
    if cfg.family in ("dense", "moe", "vlm", "audio-encdec"):
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    elif cfg.family == "hybrid":
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    t_prefill = time.time() - t0

    tok = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    out_tokens = []
    t0 = time.time()
    for i in range(gen):
        step_inputs = {"token": tok, "pos": jnp.int32(prompt_len + i)}
        if cfg.family == "vlm":
            step_inputs["positions"] = jnp.full((3, batch, 1),
                                                prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, step_inputs, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) \
            if greedy else tok
        out_tokens.append(np.asarray(tok)[:, 0])
    t_decode = time.time() - t0
    toks = np.stack(out_tokens, axis=1)
    return {"tokens": toks, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": batch * gen / max(t_decode, 1e-9),
            "kv_policy": policy}


def run_permanent_serving(*, n: int = 10, batch: int = 32,
                          requests: int = 128, density: float = 1.0,
                          precision: str = "dq_acc", backend: str = "jnp",
                          seed: int = 0):
    """Drain a synthetic permanent-request stream through the batch engine.

    ``requests`` random n x n matrices (dense, or sparse when
    ``density < 1``) are served in batches of ``batch`` via
    ``engine.permanent_batch`` -- one compiled device program per bucket,
    reused across batches, so steady-state cost is dispatch + compute
    instead of per-request tracing.  Returns perms/sec and per-batch
    latency stats; the first batch (compile) is reported separately.
    """
    from ..core import engine

    if batch < 1 or requests < 1:
        raise ValueError(f"need batch >= 1 and requests >= 1, got "
                         f"batch={batch} requests={requests}")
    rng = np.random.default_rng(seed)
    if density < 1.0:
        mats = [rng.uniform(0.5, 1.5, (n, n))
                * (rng.uniform(0, 1, (n, n)) < density)
                for _ in range(requests)]
    else:
        mats = [rng.uniform(-1, 1, (n, n)) for _ in range(requests)]

    values = np.zeros(requests, dtype=np.complex128)
    lat = []                     # (seconds, served requests) per batch
    t_all = time.time()
    for b0 in range(0, requests, batch):
        chunk = mats[b0:b0 + batch]
        nreq = len(chunk)
        if nreq < batch:
            # pad the ragged tail to the compiled batch shape -- a smaller
            # stack would trace a fresh program for one final dispatch
            chunk = chunk + [chunk[-1]] * (batch - nreq)
        t0 = time.time()
        vals = engine.permanent_batch(chunk, precision=precision,
                                      backend=backend)
        values[b0:b0 + nreq] = vals[:nreq]
        lat.append((time.time() - t0, nreq))
    total_s = time.time() - t_all
    steady = lat[1:] if len(lat) > 1 else lat
    steady_s = sum(s for s, _ in steady)
    steady_n = sum(c for _, c in steady)
    return {"values": np.real(values), "total_s": total_s,
            "compile_batch_s": lat[0][0],
            "steady_batch_s": steady_s / len(steady),
            "perms_per_s": steady_n / steady_s if steady_s else 0.0,
            "batches": len(lat)}


def serve_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "permanent"), default="lm")
    ap.add_argument("--arch", choices=ARCH_IDS, default="starcoder2-3b")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--perm-n", type=int, default=10,
                    help="permanent mode: matrix size")
    ap.add_argument("--requests", type=int, default=128,
                    help="permanent mode: request stream length")
    ap.add_argument("--density", type=float, default=1.0,
                    help="permanent mode: nnz density of request matrices")
    ap.add_argument("--precision", default="dq_acc")
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    args = ap.parse_args(argv)
    if args.mode == "permanent":
        jax.config.update("jax_enable_x64", True)
        out = run_permanent_serving(
            n=args.perm_n, batch=args.batch, requests=args.requests,
            density=args.density, precision=args.precision,
            backend=args.backend)
        print(f"[serve] permanents: {args.requests} reqs x n={args.perm_n} "
              f"batch={args.batch} backend={args.backend}")
        print(f"[serve] compile batch {out['compile_batch_s']:.3f}s, steady "
              f"{out['steady_batch_s'] * 1e3:.1f}ms/batch -> "
              f"{out['perms_per_s']:.0f} perms/s")
        return 0
    out = run_serving(args.arch, prompt_len=args.prompt_len, gen=args.gen,
                      batch=args.batch, reduced=args.reduced)
    print(f"[serve] kv_policy={out['kv_policy']} "
          f"prefill {out['prefill_s']:.2f}s decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(serve_main())
