"""Serving driver: batched permanent serving.

    PYTHONPATH=src python -m repro.launch.serve \
        --perm-n 10 --batch 32 --requests 256
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve \
        --perm-n 12 --batch 64 --requests 256 --mesh 8
    PYTHONPATH=src python -m repro.launch.serve --soak \
        --perm-n 12 --batch 8 --rate 50 --compile-cache .xla-cache \
        --metrics-port 0 --metrics-json soak.json

Drains a synthetic request stream through a ``PermanentSolver``'s async
request queue: submissions accumulate in size buckets and flush on
size/deadline triggers, repeated submatrices resolve from the solver's
result cache, and compilation/dispatch are amortized across requests --
the throughput shape (perms/sec) the SUperman paper headlines.  The LM
decode loop that shared this driver was seed scaffolding; it retired
with the rest of the LM tree (ISSUE 10), so permanent serving is the
only mode.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

__all__ = ["serve_main", "run_permanent_serving", "run_permanent_soak"]


def run_permanent_serving(*, n: int = 10, batch: int = 32,
                          requests: int = 128, density: float = 1.0,
                          precision: str = "dq_acc", backend: str = "jnp",
                          repeat_pool: int = 0, deadline_s: float = 0.05,
                          cache: bool = True, mesh=None,
                          complex_entries: bool = False, seed: int = 0,
                          campaign_matrix=None, campaign_mesh=None,
                          campaign_waves: int = 1,
                          campaign_checkpoint: str | None = None,
                          campaign_slices: int = 64,
                          campaign_lanes: int = 1024):
    """Drain a synthetic permanent-request stream through the solver queue.

    ``requests`` random n x n matrices (dense, or sparse when
    ``density < 1``; complex when ``complex_entries`` -- the
    boson-sampling amplitude shape; drawn from a pool of ``repeat_pool``
    distinct matrices when > 0, the resampling shape) are submitted one
    by one to a ``PermanentSolver``'s async queue.  Size-bucketed
    accumulation flushes each bucket at depth ``batch`` (or after
    ``deadline_s``), so batches fill from the arrival stream instead of
    being hand-rolled; repeated submatrices resolve from the solver's
    content-hash result cache without touching the device.  With ``mesh``
    set (and ``backend="distributed"``), flushed buckets -- complex ones
    included, as split re/im planes -- are batch-axis sharded over the
    mesh's devices instead of running on one.  Returns perms/sec and
    per-flush latency stats; the first flush (compile) is reported
    separately.

    With ``campaign_matrix`` set, a long-running step-space campaign for
    that single huge matrix (checkpointed via ``campaign_checkpoint``)
    advances ``campaign_waves`` waves on ``campaign_mesh`` after every
    bucket flush -- the 2D batch x step picture: the batch axis keeps
    serving the request stream while the step axis grinds through one
    n >= 40 permanent -- then runs to completion once the stream drains.
    The result dict gains ``campaign_fraction`` / ``campaign_value``.

    Since PR 7 this is a thin wrapper over
    :class:`repro.serve.PermanentService` in ``fill_first`` mode (bucket
    quantization off), which reproduces the PR 6 solver-queue flush
    composition exactly: each bucket reaches ``plan_batch`` with the
    same matrices in the same order, so results are bitwise identical to
    the old direct-queue implementation.  The open-loop continuous-
    batching path is :func:`run_permanent_soak`.
    """
    from ..core.solver import SolverConfig
    from ..serve import (CampaignSpec, LaneSpec, PermanentService,
                         ServiceConfig)

    if batch < 1 or requests < 1:
        raise ValueError(f"need batch >= 1 and requests >= 1, got "
                         f"batch={batch} requests={requests}")
    if mesh is not None and backend not in ("distributed",
                                            "distributed_batch"):
        backend = "distributed"      # a mesh implies the sharded bucket path
    rng = np.random.default_rng(seed)

    def draw():
        if density < 1.0:
            M = rng.uniform(0.5, 1.5, (n, n))
            if complex_entries:
                M = M + 1j * rng.uniform(0.5, 1.5, (n, n))
            return M * (rng.uniform(0, 1, (n, n)) < density)
        M = rng.uniform(-1, 1, (n, n))
        if complex_entries:
            M = M + 1j * rng.uniform(-1, 1, (n, n))
        return M

    if repeat_pool > 0:
        pool = [draw() for _ in range(repeat_pool)]
        mats = [pool[i] for i in rng.integers(0, repeat_pool, requests)]
    else:
        mats = [draw() for _ in range(requests)]

    campaign = None
    if campaign_matrix is not None:
        campaign = CampaignSpec(matrix=campaign_matrix, mesh=campaign_mesh,
                                waves=campaign_waves,
                                checkpoint=campaign_checkpoint,
                                slices=campaign_slices,
                                lanes=campaign_lanes)
    svc = PermanentService(
        SolverConfig(precision=precision, backend=backend, cache=cache,
                     queue_max_batch=batch, queue_max_delay_s=deadline_s),
        ServiceConfig(max_batch=batch, fill_first=True,
                      quantize_buckets=False, deadline_s=deadline_s,
                      lanes=(LaneSpec("default", 0, slo_s=None),),
                      max_queue_depth=2 ** 62, log_every_s=float("inf")),
        distributed_ctx=mesh, campaign=campaign, log=None)

    tickets = []
    t_all = time.time()
    for M in mats:
        tickets.append(svc.submit(M, deadline_s=None))
        # one tick per arrival: in fill_first mode this dispatches only
        # full or deadline-aged buckets -- the PR 6 flush triggers; the
        # campaign's step axis advances after each dispatch
        svc.step()
    tail = svc.pending
    tail_s = 0.0
    if tail:
        t0 = time.time()
        svc.drain(finish_campaign=False)
        tail_s = time.time() - t0
    svc._advance_campaign(None)  # stream drained: finish the campaign
    total_s = time.time() - t_all
    values = np.array([t.result() for t in tickets], dtype=np.complex128)
    # steady state excludes the first dispatch (compile) and the ragged
    # tail (a never-before-seen bucket width pays a one-off retrace)
    lat = [(dt, served) for _, served, dt, trig in svc.dispatch_log
           if trig in ("size", "age")]
    steady = lat[1:] if len(lat) > 1 else lat
    steady_s = sum(s for s, _ in steady)
    steady_n = sum(c for _, c in steady)
    stats = svc.solver.stats()
    return {"values": values if complex_entries else np.real(values),
            "campaign_value": svc.campaign_value,
            "campaign_fraction": svc.campaign_fraction,
            "total_s": total_s,
            "compile_batch_s": lat[0][0] if lat else tail_s,
            "steady_batch_s": steady_s / max(1, len(steady)),
            "tail_s": tail_s,
            "perms_per_s": steady_n / steady_s if steady_s else 0.0,
            "batches": len(svc.dispatch_log),
            "cache": stats["cache"],
            "downgrades": stats["downgrades"],
            "device_dispatches": stats["device_dispatches"],
            "snapshot": svc.snapshot()}


def run_permanent_soak(*, n: int = 12, batch: int = 8, requests: int = 64,
                       rate_hz: float = 50.0, density: float = 1.0,
                       precision: str = "dq_acc", backend: str = "jnp",
                       repeat_pool: int = 8, complex_entries: bool = False,
                       seed: int = 0, mesh=None, slo_ms: float | None = None,
                       compile_cache: str | None = None,
                       warmup: bool = True, expire_every: int = 0,
                       metrics_port: int | None = None,
                       metrics_json: str | None = None,
                       campaign_matrix=None, campaign_mesh=None,
                       campaign_waves: int = 1,
                       campaign_checkpoint: str | None = None,
                       log=print):
    """Open-loop soak of the continuous-batching service (``--soak``).

    Unlike :func:`run_permanent_serving` (closed-loop, PR 6 flush
    semantics), this drives :class:`repro.serve.PermanentService` in
    continuous mode under Poisson arrivals at ``rate_hz``: partial
    buckets dispatch whenever the device is free, padded up the
    power-of-two ladder; lane SLOs shed late work with typed reasons;
    ``compile_cache``/``warmup`` give a cold process a compile-free
    first bucket.  ``metrics_port`` serves the snapshot as JSON over
    HTTP while the soak runs; ``metrics_json`` writes the final snapshot
    to a file.  Returns the ``run_soak`` dict (snapshot + tickets).
    """
    import json as _json

    from ..core.solver import SolverConfig
    from ..serve import (CampaignSpec, PermanentService, ServiceConfig,
                         run_soak, start_metrics_server)

    from ..serve import DEFAULT_LANES, LaneSpec

    if mesh is not None and backend not in ("distributed",
                                            "distributed_batch"):
        backend = "distributed"
    if slo_ms is None:
        lanes = DEFAULT_LANES
    else:
        # one knob scales both lanes; bulk keeps its 15x-looser ratio
        lanes = (LaneSpec("interactive", 0, slo_s=slo_ms / 1e3),
                 LaneSpec("bulk", 1, slo_s=15 * slo_ms / 1e3))
    campaign = None
    if campaign_matrix is not None:
        campaign = CampaignSpec(matrix=campaign_matrix, mesh=campaign_mesh,
                                waves=campaign_waves,
                                checkpoint=campaign_checkpoint)
    svc = PermanentService(
        SolverConfig(precision=precision, backend=backend),
        ServiceConfig(max_batch=batch, lanes=lanes,
                      compile_cache_dir=compile_cache,
                      warmup_ns=(n,) if warmup else (),
                      warmup_complex=complex_entries, log_every_s=5.0),
        distributed_ctx=mesh, campaign=campaign, log=log)
    if svc.warmup_report and log:
        wr = svc.warmup_report
        log(f"[serve] warmup: {wr['geometries']} geometries in "
            f"{wr['seconds']:.1f}s, compile cache {wr['compile']}")
    server = None
    if metrics_port is not None:
        server = start_metrics_server(svc.snapshot, port=metrics_port)
        if log:
            log(f"[serve] metrics on http://127.0.0.1:"
                f"{server.server_address[1]}/metrics")
    try:
        out = run_soak(svc, requests=requests, rate_hz=rate_hz, n=n,
                       density=density, complex_entries=complex_entries,
                       repeat_pool=repeat_pool, seed=seed,
                       expire_every=expire_every)
    finally:
        if server is not None:
            server.shutdown()
    if metrics_json:
        with open(metrics_json, "w") as f:
            _json.dump(out["snapshot"], f, indent=1)
    return out


def serve_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    # --mode is kept for CLI compatibility (docs/CI invoke
    # "--mode permanent"); permanent is the only mode since the LM seed
    # scaffolding retired.
    ap.add_argument("--mode", choices=("permanent",), default="permanent")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--perm-n", type=int, default=10,
                    help="permanent mode: matrix size")
    ap.add_argument("--requests", type=int, default=128,
                    help="permanent mode: request stream length")
    ap.add_argument("--density", type=float, default=1.0,
                    help="permanent mode: nnz density of request matrices")
    ap.add_argument("--repeat-pool", type=int, default=0,
                    help="permanent mode: draw requests from this many "
                         "distinct matrices (0 = all distinct)")
    ap.add_argument("--complex", dest="complex_entries", action="store_true",
                    help="permanent mode: complex request matrices "
                         "(boson-sampling amplitudes); sharded as split "
                         "re/im planes under --mesh")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="permanent mode: queue flush deadline")
    ap.add_argument("--no-cache", dest="cache", action="store_false",
                    help="permanent mode: disable the result cache")
    ap.add_argument("--precision", default="dq_acc")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas", "distributed"))
    ap.add_argument("--mesh", nargs="?", const="auto", default=None,
                    metavar="N|BxS",
                    help="permanent mode: shard flushed buckets over a "
                         "N-device ('data',) mesh (default: all devices; "
                         "implies --backend distributed).  BxS (e.g. 2x4) "
                         "builds a 2D (batch x step) CampaignMesh: the "
                         "batch column serves buckets, the step row runs "
                         "--campaign waves.  Force host devices with "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--campaign", metavar="NPY|N", default=None,
                    help="permanent mode: advance a step-space campaign "
                         "for this matrix (.npy path, or an integer for a "
                         "random NxN) between bucket flushes")
    ap.add_argument("--campaign-checkpoint", default=None,
                    help="JobState .npz for the --campaign job")
    ap.add_argument("--campaign-waves", type=int, default=1,
                    help="campaign waves to run per bucket flush")
    ap.add_argument("--soak", action="store_true",
                    help="permanent mode: open-loop Poisson soak of the "
                         "continuous-batching service instead of the "
                         "closed-loop queue drain")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="soak: Poisson arrival rate (requests/s)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="soak: interactive-lane SLO/deadline (default: "
                         "lane defaults, 2s interactive / 30s bulk)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="soak: persistent XLA compilation cache dir")
    ap.add_argument("--no-warmup", dest="warmup", action="store_false",
                    help="soak: skip the kernel-geometry warm-up pass")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="soak: serve the metrics snapshot as JSON on "
                         "this port (0 = ephemeral) while running")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="soak: write the final metrics snapshot here")
    args = ap.parse_args(argv)
    jax.config.update("jax_enable_x64", True)
    mesh = None
    campaign_mesh = None
    if args.mesh is not None and "x" in str(args.mesh):
        from .mesh import make_campaign_mesh
        b, s = (int(v) for v in str(args.mesh).lower().split("x"))
        cm = make_campaign_mesh(b, s)
        mesh, campaign_mesh = cm.batch_mesh, cm.step_mesh
        print(f"[serve] 2D campaign mesh {b}x{s}: buckets on the "
              f"{b}-device batch column, campaign waves on the "
              f"{s}-device step row")
    elif args.mesh is not None:
        from .mesh import make_batch_mesh
        mesh = make_batch_mesh(
            None if args.mesh == "auto" else int(args.mesh))
        print(f"[serve] batch-sharding buckets over "
              f"{mesh.devices.size}-device mesh {mesh.axis_names}")
    campaign_matrix = None
    if args.campaign is not None:
        if args.campaign.isdigit():
            cn = int(args.campaign)
            campaign_matrix = np.random.default_rng(7).uniform(
                0.2, 1.2, (cn, cn))
        else:
            campaign_matrix = np.load(args.campaign)
        print(f"[serve] campaign: n={campaign_matrix.shape[0]} "
              f"ckpt={args.campaign_checkpoint} "
              f"waves/flush={args.campaign_waves}")
    if args.soak:
        out = run_permanent_soak(
            n=args.perm_n, batch=args.batch, requests=args.requests,
            rate_hz=args.rate, density=args.density,
            precision=args.precision, backend=args.backend,
            repeat_pool=args.repeat_pool or 8,
            complex_entries=args.complex_entries, mesh=mesh,
            slo_ms=args.slo_ms, compile_cache=args.compile_cache,
            warmup=args.warmup, metrics_port=args.metrics_port,
            metrics_json=args.metrics_json,
            campaign_matrix=campaign_matrix,
            campaign_mesh=campaign_mesh,
            campaign_waves=args.campaign_waves,
            campaign_checkpoint=args.campaign_checkpoint)
        snap = out["snapshot"]
        req = snap["requests"]
        lat = snap["latency_s"]["overall"]
        print(f"[serve] soak: {req['admitted']} reqs @ "
              f"{args.rate:.0f}/s -> {req['completed']} done, "
              f"{req['shed_total']} shed {dict(req['shed'])}, "
              f"p50 {lat['p50'] * 1e3:.0f}ms p99 "
              f"{lat['p99'] * 1e3:.0f}ms, "
              f"{snap['dispatches']} dispatches (mean occupancy "
              f"{snap['bucket_occupancy']['mean']:.2f})")
        if snap["campaign_fraction"] is not None:
            print(f"[serve] campaign: "
                  f"{snap['campaign_fraction']:.1%} done")
        return 0
    out = run_permanent_serving(
        n=args.perm_n, batch=args.batch, requests=args.requests,
        density=args.density, precision=args.precision,
        backend=args.backend, repeat_pool=args.repeat_pool,
        deadline_s=args.deadline_ms / 1e3, cache=args.cache, mesh=mesh,
        complex_entries=args.complex_entries,
        campaign_matrix=campaign_matrix, campaign_mesh=campaign_mesh,
        campaign_waves=args.campaign_waves,
        campaign_checkpoint=args.campaign_checkpoint)
    print(f"[serve] permanents: {args.requests} "
          f"{'complex ' if args.complex_entries else ''}reqs "
          f"x n={args.perm_n} batch={args.batch} backend="
          f"{'distributed' if mesh is not None else args.backend}")
    if out["downgrades"]:
        print(f"[serve] downgrades: {len(out['downgrades'])} "
              f"(e.g. {out['downgrades'][0]})")
    print(f"[serve] compile batch {out['compile_batch_s']:.3f}s, steady "
          f"{out['steady_batch_s'] * 1e3:.1f}ms/batch -> "
          f"{out['perms_per_s']:.0f} perms/s")
    if out["cache"]:
        print(f"[serve] cache: {out['cache']['hits']} hits / "
              f"{out['cache']['misses']} misses "
              f"(hit rate {out['cache']['hit_rate']:.1%}), "
              f"{out['device_dispatches']} device dispatches")
    if out["campaign_fraction"] is not None:
        cv = out["campaign_value"]
        vtxt = "pending" if cv is None else f"{cv:+.17e}"
        print(f"[serve] campaign: {out['campaign_fraction']:.1%} done, "
              f"perm = {vtxt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(serve_main())
