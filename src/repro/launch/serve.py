"""Serving driver: LM decode loop + batched permanent serving.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --prompt-len 64 --gen 32 --batch 4 [--reduced]
    PYTHONPATH=src python -m repro.launch.serve --mode permanent \
        --perm-n 10 --batch 32 --requests 256
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --mode permanent \
        --perm-n 12 --batch 64 --requests 256 --mesh 8

LM mode builds the serve bundle (KV sharding policy chosen per arch/mesh),
prefills a synthetic prompt batch, then decodes greedily.  Permanent mode
drains a synthetic request stream through a ``PermanentSolver``'s async
request queue: submissions accumulate in size buckets and flush on
size/deadline triggers, repeated submatrices resolve from the solver's
result cache, and compilation/dispatch are amortized across requests --
the throughput shape (perms/sec) the SUperman paper headlines.  Runnable
on CPU with ``--reduced``; on a real pod the same code paths serve the
full configs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models.model import ShapeCell, build
from ..train.train_step import build_serve_steps
from .mesh import make_local_mesh

__all__ = ["serve_main", "run_serving", "run_permanent_serving"]


def run_serving(arch: str, *, prompt_len: int = 64, gen: int = 32,
                batch: int = 4, reduced: bool = True, mesh=None,
                seed: int = 0, greedy: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = mesh or make_local_mesh()
    max_seq = prompt_len + gen
    rng = np.random.default_rng(seed)

    prefill_cell = ShapeCell("serve", "prefill", prompt_len, batch)
    decode_cell = ShapeCell("serve", "decode", max_seq, batch)
    prefill_fn, _, _, _ = build_serve_steps(model, mesh, prefill_cell)
    decode_fn, _, _, policy = build_serve_steps(model, mesh, decode_cell)

    params = model.init_params(jax.random.PRNGKey(seed))
    # serving weights are bf16 + resident (cf. build_serve_steps)
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 else p, params)

    if cfg.family == "vlm":
        pos = np.broadcast_to(np.arange(prompt_len)[None, None],
                              (3, batch, prompt_len)).copy()
        inputs = {"embeds": jnp.asarray(
            rng.normal(0, 0.02, (batch, prompt_len, cfg.d_model)),
            cfg.dtype), "positions": jnp.asarray(pos, jnp.int32)}
    elif cfg.family == "audio-encdec":
        inputs = {"enc_embeds": jnp.asarray(
            rng.normal(0, 0.02, (batch, prompt_len, cfg.d_model)),
            cfg.dtype)}
    else:
        inputs = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}

    t0 = time.time()
    h, cache = prefill_fn(params, inputs)
    # pad the prefill cache out to max_seq (cache was built at prompt_len)
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == prompt_len and cfg.family != "ssm":
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, gen)
            return jnp.pad(x, pad)
        return x
    if cfg.family in ("dense", "moe", "vlm", "audio-encdec"):
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    elif cfg.family == "hybrid":
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    t_prefill = time.time() - t0

    tok = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    out_tokens = []
    t0 = time.time()
    for i in range(gen):
        step_inputs = {"token": tok, "pos": jnp.int32(prompt_len + i)}
        if cfg.family == "vlm":
            step_inputs["positions"] = jnp.full((3, batch, 1),
                                                prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, step_inputs, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) \
            if greedy else tok
        out_tokens.append(np.asarray(tok)[:, 0])
    t_decode = time.time() - t0
    toks = np.stack(out_tokens, axis=1)
    return {"tokens": toks, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": batch * gen / max(t_decode, 1e-9),
            "kv_policy": policy}


def run_permanent_serving(*, n: int = 10, batch: int = 32,
                          requests: int = 128, density: float = 1.0,
                          precision: str = "dq_acc", backend: str = "jnp",
                          repeat_pool: int = 0, deadline_s: float = 0.05,
                          cache: bool = True, mesh=None,
                          complex_entries: bool = False, seed: int = 0,
                          campaign_matrix=None, campaign_mesh=None,
                          campaign_waves: int = 1,
                          campaign_checkpoint: str | None = None,
                          campaign_slices: int = 64,
                          campaign_lanes: int = 1024):
    """Drain a synthetic permanent-request stream through the solver queue.

    ``requests`` random n x n matrices (dense, or sparse when
    ``density < 1``; complex when ``complex_entries`` -- the
    boson-sampling amplitude shape; drawn from a pool of ``repeat_pool``
    distinct matrices when > 0, the resampling shape) are submitted one
    by one to a ``PermanentSolver``'s async queue.  Size-bucketed
    accumulation flushes each bucket at depth ``batch`` (or after
    ``deadline_s``), so batches fill from the arrival stream instead of
    being hand-rolled; repeated submatrices resolve from the solver's
    content-hash result cache without touching the device.  With ``mesh``
    set (and ``backend="distributed"``), flushed buckets -- complex ones
    included, as split re/im planes -- are batch-axis sharded over the
    mesh's devices instead of running on one.  Returns perms/sec and
    per-flush latency stats; the first flush (compile) is reported
    separately.

    With ``campaign_matrix`` set, a long-running step-space campaign for
    that single huge matrix (checkpointed via ``campaign_checkpoint``)
    advances ``campaign_waves`` waves on ``campaign_mesh`` after every
    bucket flush -- the 2D batch x step picture: the batch axis keeps
    serving the request stream while the step axis grinds through one
    n >= 40 permanent -- then runs to completion once the stream drains.
    The result dict gains ``campaign_fraction`` / ``campaign_value``.
    """
    from ..core.solver import PermanentSolver, SolverConfig

    if batch < 1 or requests < 1:
        raise ValueError(f"need batch >= 1 and requests >= 1, got "
                         f"batch={batch} requests={requests}")
    if mesh is not None and backend not in ("distributed",
                                            "distributed_batch"):
        backend = "distributed"      # a mesh implies the sharded bucket path
    rng = np.random.default_rng(seed)

    def draw():
        if density < 1.0:
            M = rng.uniform(0.5, 1.5, (n, n))
            if complex_entries:
                M = M + 1j * rng.uniform(0.5, 1.5, (n, n))
            return M * (rng.uniform(0, 1, (n, n)) < density)
        M = rng.uniform(-1, 1, (n, n))
        if complex_entries:
            M = M + 1j * rng.uniform(-1, 1, (n, n))
        return M

    if repeat_pool > 0:
        pool = [draw() for _ in range(repeat_pool)]
        mats = [pool[i] for i in rng.integers(0, repeat_pool, requests)]
    else:
        mats = [draw() for _ in range(requests)]

    solver = PermanentSolver(SolverConfig(
        precision=precision, backend=backend, cache=cache,
        queue_max_batch=batch, queue_max_delay_s=deadline_s),
        distributed_ctx=mesh)

    # -- interleaved step-space campaign (2D batch x step sharding) -----
    camp = {"state": None, "value": None}
    if campaign_matrix is not None:
        from ..core.distributed import run_campaign
        from ..core.stepspace import plan_slices
        cmat = np.asarray(campaign_matrix)
        if campaign_mesh is None:
            from jax.sharding import Mesh
            campaign_mesh = Mesh(np.array(jax.devices()), ("step",))
        ts, cps, C = plan_slices(cmat.shape[0], campaign_slices, 1,
                                 campaign_lanes)

        def _advance_campaign(waves):
            """Run up to ``waves`` campaign waves (None = to completion);
            state threads across calls so each flush resumes in place."""
            if campaign_state_done():
                return
            val, st = run_campaign(
                cmat, campaign_mesh, total_slices=ts,
                chunks_per_slice=cps, chunk_size=C, precision=precision,
                checkpoint_path=campaign_checkpoint,
                state=camp["state"], max_waves=waves)
            camp["state"], camp["value"] = st, val

        def campaign_state_done():
            return camp["value"] is not None
    else:
        def _advance_campaign(waves):
            return

    lat = []                     # (seconds, served requests) per flush
    reqs = []
    t_all = time.time()
    for M in mats:
        served_before = solver.flushes
        t0 = time.time()
        reqs.append(solver.submit(M))
        if solver.flushes > served_before:   # this submit triggered a flush
            lat.append((time.time() - t0, batch))
            # the step axis advances while the batch axis is between
            # flushes -- the big job progresses without stalling serving
            _advance_campaign(campaign_waves)
    tail = solver.pending
    tail_s = 0.0
    if tail:
        t0 = time.time()
        solver.flush()
        tail_s = time.time() - t0
    _advance_campaign(None)      # stream drained: finish the campaign
    total_s = time.time() - t_all
    values = np.array([r.result() for r in reqs], dtype=np.complex128)
    # steady state excludes the first flush (compile) and the ragged tail
    # (a never-before-seen bucket width pays a one-off retrace)
    steady = lat[1:] if len(lat) > 1 else lat
    steady_s = sum(s for s, _ in steady)
    steady_n = sum(c for _, c in steady)
    stats = solver.stats()
    camp_frac = camp["state"].fraction_done() if camp["state"] else None
    return {"values": values if complex_entries else np.real(values),
            "campaign_value": camp["value"],
            "campaign_fraction": camp_frac,
            "total_s": total_s,
            "compile_batch_s": lat[0][0] if lat else tail_s,
            "steady_batch_s": steady_s / max(1, len(steady)),
            "tail_s": tail_s,
            "perms_per_s": steady_n / steady_s if steady_s else 0.0,
            "batches": len(lat) + (1 if tail else 0),
            "cache": stats["cache"],
            "downgrades": stats["downgrades"],
            "device_dispatches": stats["device_dispatches"]}


def serve_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "permanent"), default="lm")
    ap.add_argument("--arch", choices=ARCH_IDS, default="starcoder2-3b")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--perm-n", type=int, default=10,
                    help="permanent mode: matrix size")
    ap.add_argument("--requests", type=int, default=128,
                    help="permanent mode: request stream length")
    ap.add_argument("--density", type=float, default=1.0,
                    help="permanent mode: nnz density of request matrices")
    ap.add_argument("--repeat-pool", type=int, default=0,
                    help="permanent mode: draw requests from this many "
                         "distinct matrices (0 = all distinct)")
    ap.add_argument("--complex", dest="complex_entries", action="store_true",
                    help="permanent mode: complex request matrices "
                         "(boson-sampling amplitudes); sharded as split "
                         "re/im planes under --mesh")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="permanent mode: queue flush deadline")
    ap.add_argument("--no-cache", dest="cache", action="store_false",
                    help="permanent mode: disable the result cache")
    ap.add_argument("--precision", default="dq_acc")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas", "distributed"))
    ap.add_argument("--mesh", nargs="?", const="auto", default=None,
                    metavar="N|BxS",
                    help="permanent mode: shard flushed buckets over a "
                         "N-device ('data',) mesh (default: all devices; "
                         "implies --backend distributed).  BxS (e.g. 2x4) "
                         "builds a 2D (batch x step) CampaignMesh: the "
                         "batch column serves buckets, the step row runs "
                         "--campaign waves.  Force host devices with "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--campaign", metavar="NPY|N", default=None,
                    help="permanent mode: advance a step-space campaign "
                         "for this matrix (.npy path, or an integer for a "
                         "random NxN) between bucket flushes")
    ap.add_argument("--campaign-checkpoint", default=None,
                    help="JobState .npz for the --campaign job")
    ap.add_argument("--campaign-waves", type=int, default=1,
                    help="campaign waves to run per bucket flush")
    args = ap.parse_args(argv)
    if args.mode == "permanent":
        jax.config.update("jax_enable_x64", True)
        mesh = None
        campaign_mesh = None
        if args.mesh is not None and "x" in str(args.mesh):
            from .mesh import make_campaign_mesh
            b, s = (int(v) for v in str(args.mesh).lower().split("x"))
            cm = make_campaign_mesh(b, s)
            mesh, campaign_mesh = cm.batch_mesh, cm.step_mesh
            print(f"[serve] 2D campaign mesh {b}x{s}: buckets on the "
                  f"{b}-device batch column, campaign waves on the "
                  f"{s}-device step row")
        elif args.mesh is not None:
            from .mesh import make_batch_mesh
            mesh = make_batch_mesh(
                None if args.mesh == "auto" else int(args.mesh))
            print(f"[serve] batch-sharding buckets over "
                  f"{mesh.devices.size}-device mesh {mesh.axis_names}")
        campaign_matrix = None
        if args.campaign is not None:
            if args.campaign.isdigit():
                cn = int(args.campaign)
                campaign_matrix = np.random.default_rng(7).uniform(
                    0.2, 1.2, (cn, cn))
            else:
                campaign_matrix = np.load(args.campaign)
            print(f"[serve] campaign: n={campaign_matrix.shape[0]} "
                  f"ckpt={args.campaign_checkpoint} "
                  f"waves/flush={args.campaign_waves}")
        out = run_permanent_serving(
            n=args.perm_n, batch=args.batch, requests=args.requests,
            density=args.density, precision=args.precision,
            backend=args.backend, repeat_pool=args.repeat_pool,
            deadline_s=args.deadline_ms / 1e3, cache=args.cache, mesh=mesh,
            complex_entries=args.complex_entries,
            campaign_matrix=campaign_matrix, campaign_mesh=campaign_mesh,
            campaign_waves=args.campaign_waves,
            campaign_checkpoint=args.campaign_checkpoint)
        print(f"[serve] permanents: {args.requests} "
              f"{'complex ' if args.complex_entries else ''}reqs "
              f"x n={args.perm_n} batch={args.batch} backend="
              f"{'distributed' if mesh is not None else args.backend}")
        if out["downgrades"]:
            print(f"[serve] downgrades: {len(out['downgrades'])} "
                  f"(e.g. {out['downgrades'][0]})")
        print(f"[serve] compile batch {out['compile_batch_s']:.3f}s, steady "
              f"{out['steady_batch_s'] * 1e3:.1f}ms/batch -> "
              f"{out['perms_per_s']:.0f} perms/s")
        if out["cache"]:
            print(f"[serve] cache: {out['cache']['hits']} hits / "
                  f"{out['cache']['misses']} misses "
                  f"(hit rate {out['cache']['hit_rate']:.1%}), "
                  f"{out['device_dispatches']} device dispatches")
        if out["campaign_fraction"] is not None:
            cv = out["campaign_value"]
            vtxt = "pending" if cv is None else f"{cv:+.17e}"
            print(f"[serve] campaign: {out['campaign_fraction']:.1%} done, "
                  f"perm = {vtxt}")
        return 0
    out = run_serving(args.arch, prompt_len=args.prompt_len, gen=args.gen,
                      batch=args.batch, reduced=args.reduced)
    print(f"[serve] kv_policy={out['kv_policy']} "
          f"prefill {out['prefill_s']:.2f}s decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(serve_main())
