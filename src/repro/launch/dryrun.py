import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (the ONLY entry point that fakes 512 devices).

For every (architecture x input-shape x mesh) cell this lowers + compiles
the real sharded step (train_step / prefill / decode), proving the
distribution config is coherent, then records:

  * memory_analysis()          -- fits-per-device evidence
  * cost_analysis()            -- FLOPs / bytes for the roofline
  * collective bytes           -- parsed from the optimized HLO
  * the three roofline terms   -- utils/roofline.py

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all [--mesh both] [--jobs ...]
      (runs every cell in its own subprocess; failures isolated)
"""

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, FULL_ATTENTION_ARCHS, get_config  # noqa: E402
from ..models.model import SHAPES, build                          # noqa: E402
from ..train.optimizer import AdamWConfig, AdamWState             # noqa: E402
from ..train.train_step import build_serve_steps, build_train_step  # noqa: E402
from ..utils.hlo import collective_bytes, count_ops               # noqa: E402
from ..utils.hlo_cost import analyze_hlo                           # noqa: E402
from ..utils.roofline import roofline_from_analysis               # noqa: E402
from .mesh import make_production_mesh, mesh_device_count         # noqa: E402

SKIP = "SKIP(full-attention)"


def cell_is_skipped(arch: str, shape: str) -> bool:
    return shape == "long_500k" and arch in FULL_ATTENTION_ARCHS


def _abstract_opt(params_abs):
    m = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                     params_abs)
    return AdamWState(m=m, v=m, count=jax.ShapeDtypeStruct((), jnp.int32))


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    model = build(cfg)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_device_count(mesh)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "chips": chips,
           "status": "error"}

    params_abs = model.init_params(abstract=True)

    if cell.kind == "train":
        bundle = build_train_step(model, mesh, AdamWConfig())
        opt_abs = _abstract_opt(params_abs)
        batch_abs = model.input_specs(cell)
        lowered = bundle.step_fn.lower(params_abs, opt_abs, batch_abs)
    else:
        step_fn, in_shards, c_shard, policy = build_serve_steps(
            model, mesh, cell)
        rec["kv_policy"] = policy
        # serving weights are bf16 and resident (no FSDP gathers)
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            params_abs)
        in_abs = model.input_specs(cell)
        if cell.kind == "prefill":
            lowered = step_fn.lower(params_abs, in_abs)
        else:
            cache_abs = model.cache_specs(cell)
            lowered = step_fn.lower(params_abs, in_abs, cache_abs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = dict(compiled.cost_analysis() or {})
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(
                mem, "peak_memory_in_bytes",
                getattr(mem, "temp_size_in_bytes", None)),
            "repr": str(mem)[:2000],
        }
    except Exception as e:  # CPU backend may not implement it
        mem_rec = {"error": f"{type(e).__name__}: {e}"}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)          # trip-count-UNAWARE (raw HLO)
    ops = count_ops(hlo)
    tc = analyze_hlo(hlo)                 # trip-count-aware per-device costs

    model_fl = model.model_flops(cell)
    # analyze_hlo returns per-device totals; the roofline helper divides
    # whole-program numbers by chips, so scale back up
    cost_tc = {"flops": tc.dot_flops * chips,
               "bytes accessed": tc.bytes_accessed * chips}
    bytes_min = float((mem_rec.get("argument_bytes") or 0)
                      + (mem_rec.get("output_bytes") or 0))
    rl = roofline_from_analysis(cost_tc, tc.collective_bytes, chips,
                                model_fl, bytes_min=bytes_min)

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        cost={k: cost[k] for k in sorted(cost) if isinstance(
            cost[k], (int, float))},
        cost_trip_aware={
            "dot_flops_per_device": tc.dot_flops,
            "bytes_per_device": tc.bytes_accessed,
            "collective_bytes_per_device": tc.collective_bytes,
            "coll_by_kind": tc.coll_by_kind,
            "dot_count": tc.dot_count,
            "while_count": tc.while_count,
        },
        memory=mem_rec,
        collectives=coll,
        ops=ops,
        hlo_bytes=len(hlo),
        n_params=model.n_params(),
        n_active_params=model.n_active_params(),
        model_flops=model_fl,
        roofline=rl.to_dict(),
    )
    return rec


def out_path(out_dir: str, arch: str, shape: str, mesh_kind: str) -> str:
    safe = arch.replace("/", "_")
    return os.path.join(out_dir, f"{safe}__{shape}__{mesh_kind}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses (cached)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mk in meshes:
                    path = out_path(args.out, arch, shape, mk)
                    if cell_is_skipped(arch, shape):
                        with open(path, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "mesh": mk, "status": SKIP}, f)
                        continue
                    if os.path.exists(path) and not args.force:
                        with open(path) as f:
                            if json.load(f).get("status") == "ok":
                                continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk,
                           "--out", args.out]
                    print(f"[dryrun] {arch} x {shape} x {mk} ...",
                          flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append((arch, shape, mk))
                        with open(path, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "mesh": mk, "status": "error",
                                       "stderr": r.stderr[-4000:]}, f)
                        print(f"  FAILED: {r.stderr[-500:]}", flush=True)
                    else:
                        print(f"  ok ({r.stdout.strip().splitlines()[-1] if r.stdout.strip() else ''})",
                              flush=True)
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape
    mesh_kinds = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
    for mk in mesh_kinds:
        if cell_is_skipped(args.arch, args.shape):
            print(f"{args.arch} x {args.shape}: {SKIP}")
            continue
        try:
            rec = run_cell(args.arch, args.shape, mk)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                   "status": "error", "traceback": traceback.format_exc()}
        with open(out_path(args.out, args.arch, args.shape, mk), "w") as f:
            json.dump(rec, f, indent=1, default=str)
        if rec["status"] != "ok":
            print(rec.get("traceback", "error"), file=sys.stderr)
            return 1
        rl = rec["roofline"]
        print(f"{args.arch} x {args.shape} x {mk}: ok "
              f"compile={rec['compile_s']}s "
              f"flops={rec['cost'].get('flops', 0):.3e} "
              f"coll={rec['collectives']['total_bytes']:.3e}B "
              f"dominant={rl['dominant']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
