"""SUperman CLI: compute matrix permanents (the paper's tool, JAX-native).

    PYTHONPATH=src python -m repro.launch.permanent --n 20            # random dense
    PYTHONPATH=src python -m repro.launch.permanent --matrix m.npy \
        --precision kahan --backend pallas
    PYTHONPATH=src python -m repro.launch.permanent --n 24 --distributed \
        --checkpoint job.npz     # resumable multi-device job

Matrix sources: --matrix <.npy>, --n <random dense>, --sparse-n/--density
(random sparse), --family allones|fibonacci (known-permanent families).

EVERY backend -- distributed included -- goes through the plan/execute
API: the CLI prints the ``ExecutionPlan`` summary (leaves, routes,
buckets, step estimate) before dispatching, and ``--plan-json`` dumps the
whole serialized plan.  ``--checkpoint`` turns the run into a resumable
step-space campaign (forces the ``step_sharded`` route unless
``--campaign-threshold`` overrides it); dedicated campaign driving lives
in ``repro.launch.campaign``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.oracle import all_ones_permanent
from ..core.solver import PermanentSolver, SolverConfig
from .mesh import make_local_mesh

__all__ = ["permanent_main"]


def _load_matrix(args) -> np.ndarray:
    rng = np.random.default_rng(args.seed)
    if args.matrix:
        return np.load(args.matrix)
    if args.family == "allones":
        return np.full((args.n, args.n), args.value)
    if args.family == "fibonacci":
        # tridiagonal 0/1 matrix: perm = Fibonacci(n+1)  (Kilic & Tasci)
        A = np.zeros((args.n, args.n))
        for i in range(args.n):
            for j in range(args.n):
                if abs(i - j) <= 1:
                    A[i, j] = 1.0
        return A
    if args.sparse_n:
        n = args.sparse_n
        A = rng.uniform(0.5, 1.5, (n, n)) \
            * (rng.uniform(0, 1, (n, n)) < args.density)
        return A
    return rng.uniform(-1, 1, (args.n, args.n))


def permanent_main(argv=None) -> int:
    # f64 is required for the engines' precision semantics: without it
    # jnp.asarray silently downcasts the planner's float64 leaves to f32
    # and every precision mode reports f32-level error
    import jax
    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", help=".npy file with a square matrix")
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--sparse-n", type=int, default=0)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--family", choices=("allones", "fibonacci"))
    ap.add_argument("--value", type=float, default=1.0)
    ap.add_argument("--precision", default="dq_acc",
                    choices=("dd", "dq_fast", "dq_acc", "qq", "kahan"))
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas", "distributed"))
    ap.add_argument("--no-preprocess", action="store_true")
    ap.add_argument("--checkpoint", help="resumable job state (.npz); "
                    "forces the step_sharded campaign route")
    ap.add_argument("--campaign-threshold", type=float, default=None,
                    help="step-cost estimate above which a leaf becomes a "
                         "resumable campaign (default: forced with "
                         "--checkpoint, 2^34 otherwise)")
    ap.add_argument("--slices", type=int, default=64,
                    help="campaign slice-count target (plan_slices)")
    ap.add_argument("--lanes", type=int, default=1024,
                    help="campaign chunk-count target (plan_slices)")
    ap.add_argument("--chunks", type=int, default=4096)
    ap.add_argument("--plan-json", action="store_true",
                    help="dump the full ExecutionPlan as JSON before "
                         "executing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    A = _load_matrix(args)
    n = A.shape[0]
    print(f"[superman] n={n} nnz={int((A != 0).sum())} "
          f"density={(A != 0).mean():.2%} precision={args.precision} "
          f"backend={args.backend}")

    t0 = time.time()
    threshold = args.campaign_threshold
    if threshold is None:
        # --checkpoint means "this run must be resumable" -> campaign
        threshold = -1.0 if args.checkpoint \
            else SolverConfig().campaign_threshold
    ctx = make_local_mesh() if args.backend == "distributed" else None
    solver = PermanentSolver(SolverConfig(
        precision=args.precision, backend=args.backend,
        preprocess=not args.no_preprocess, num_chunks=args.chunks,
        campaign_threshold=threshold, campaign_slices=args.slices,
        campaign_lanes=args.lanes, campaign_checkpoint=args.checkpoint),
        distributed_ctx=ctx)
    solver.campaign_progress = lambda s: print(
        f"[superman] {s.fraction_done():6.1%} done", flush=True)
    plan = solver.plan(A)
    print(f"[superman] {plan.summary()}")
    if args.plan_json:
        print(plan.json(indent=2))
    val, report = solver.execute(plan, return_report=True)
    dt = time.time() - t0

    if isinstance(val, complex):
        print(f"[superman] perm(A) = {val.real:+.17e} {val.imag:+.17e}j"
              f"   ({dt:.2f}s)")
    else:
        print(f"[superman] perm(A) = {val:+.17e}   ({dt:.2f}s)")
    if report:
        print(f"[superman] dm_removed={report.dm_removed} "
              f"fm_leaves={report.fm_leaves} dispatch={report.dispatch[:6]}")
    if args.family == "allones":
        exact = all_ones_permanent(n, args.value)
        rel = abs(val - exact) / abs(exact)
        print(f"[superman] exact = {exact:+.17e}  rel.err = {rel:.2e}")
    if args.family == "fibonacci":
        fib = [1, 1]  # fib[k] == F(k+1)
        for _ in range(n):
            fib.append(fib[-1] + fib[-2])
        status = "OK" if round(val) == fib[n] else "MISMATCH"
        print(f"[superman] Fibonacci({n + 1}) = {fib[n]}  "
              f"(got {val:.1f})  {status}")
    return 0


if __name__ == "__main__":
    raise SystemExit(permanent_main())
