"""Production mesh construction.

IMPORTANT: functions only -- importing this module never touches jax device
state.  The dry-run entry point (launch/dryrun.py) force-creates 512 host
devices via XLA_FLAGS *before* importing jax; everything else sees the real
device count.
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_batch_mesh",
           "mesh_device_count"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int | None = None):
    """Best-effort mesh over whatever devices exist (examples/tests)."""
    n = jax.device_count()
    if model_axis is None:
        model_axis = 1
        while model_axis * 2 <= int(math.sqrt(n)):
            model_axis *= 2
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_batch_mesh(num_devices: int | None = None):
    """One-axis ("data",) mesh for batch-axis sharding (permanent serving).

    ``num_devices=None`` takes every visible device; an explicit count
    takes the first ``num_devices`` (must not exceed the host's devices).
    """
    import numpy as np
    from jax.sharding import Mesh

    avail = jax.devices()
    n = len(avail) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(avail):
        raise ValueError(f"need 1 <= num_devices <= {len(avail)}, got {n}")
    return Mesh(np.array(avail[:n]), ("data",))


def mesh_device_count(mesh) -> int:
    return math.prod(mesh.devices.shape)
