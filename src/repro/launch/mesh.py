"""Production mesh construction.

IMPORTANT: functions only -- importing this module never touches jax device
state.  Entry points that want many host devices (e.g. permprove's PLI104
mesh audit, multi-device CI) set XLA_FLAGS *before* importing jax;
everything else sees the real device count.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_batch_mesh",
           "CampaignMesh", "make_campaign_mesh", "mesh_device_count"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int | None = None):
    """Best-effort mesh over whatever devices exist (examples/tests)."""
    n = jax.device_count()
    if model_axis is None:
        model_axis = 1
        while model_axis * 2 <= int(math.sqrt(n)):
            model_axis *= 2
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_batch_mesh(num_devices: int | None = None):
    """One-axis ("data",) mesh for batch-axis sharding (permanent serving).

    ``num_devices=None`` takes every visible device; an explicit count
    takes the first ``num_devices`` (must not exceed the host's devices).
    """
    import numpy as np
    from jax.sharding import Mesh

    avail = jax.devices()
    n = len(avail) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(avail):
        raise ValueError(f"need 1 <= num_devices <= {len(avail)}, got {n}")
    return Mesh(np.array(avail[:n]), ("data",))


@dataclass(frozen=True)
class CampaignMesh:
    """2D (batch x step) device grid for mixed serving + campaign traffic.

    ``mesh`` is the full ``("batch", "step")`` grid; ``batch_mesh`` (the
    grid's first column) serves ``distributed_batch`` bucket flushes and
    ``step_mesh`` (the grid's first row) runs step-space campaign waves.
    The two sub-meshes overlap only at grid[0, 0] -- on this
    host-reproduction setup that corner device time-slices between the
    two roles, which is exactly the contention the serve loop's
    wave-between-flushes interleaving amortizes.  On real hardware the
    step extent dwarfs the batch extent (one big matrix, many devices).
    """
    mesh: Any          # jax.sharding.Mesh, ("batch", "step")
    batch_mesh: Any    # ("batch",) sub-mesh: bucket traffic
    step_mesh: Any     # ("step",) sub-mesh: campaign waves


def make_campaign_mesh(batch: int, step: int) -> CampaignMesh:
    """Carve the first ``batch * step`` visible devices into a 2D grid
    whose step axis runs a resumable campaign while the batch axis keeps
    serving bucket flushes (ROADMAP: 2D batch x step sharding)."""
    import numpy as np
    from jax.sharding import Mesh

    avail = jax.devices()
    if batch < 1 or step < 1:
        raise ValueError(f"need batch >= 1 and step >= 1, got "
                         f"{batch}x{step}")
    if batch * step > len(avail):
        raise ValueError(f"mesh {batch}x{step} needs {batch * step} "
                         f"devices, only {len(avail)} visible")
    grid = np.array(avail[:batch * step]).reshape(batch, step)
    return CampaignMesh(
        mesh=Mesh(grid, ("batch", "step")),
        batch_mesh=Mesh(grid[:, 0], ("batch",)),
        step_mesh=Mesh(grid[0, :], ("step",)))


def mesh_device_count(mesh) -> int:
    return math.prod(mesh.devices.shape)
