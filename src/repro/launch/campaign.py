"""Campaign CLI: drive one huge permanent as a resumable step-space job.

    PYTHONPATH=src python -m repro.launch.campaign --n 40 \
        --checkpoint job.npz                  # run until done (or killed)
    PYTHONPATH=src python -m repro.launch.campaign --n 40 \
        --checkpoint job.npz                  # ... rerun: resumes
    PYTHONPATH=src python -m repro.launch.campaign --n 40 \
        --checkpoint job.npz --max-waves 4    # budgeted: exit 3 if pending

The run goes through the plan/execute stack: the planner routes the
matrix to the ``step_sharded`` campaign route (``--threshold`` is forced
negative by default so even small test matrices campaign), the executor's
``CampaignBackend`` runs waves of ``slice_sums_on_mesh`` over a flat
("step",) mesh and checkpoints after every wave.  One ``[campaign] wave``
line is printed per wave, AFTER the checkpoint is durable -- a SIGKILL
any time after the first such line loses at most the in-flight wave, and
the resumed run is bitwise-identical to an uninterrupted one at any
device count (tests/test_campaign.py kills this CLI mid-wave to prove
it).

Exit codes: 0 value printed, 3 paused by --max-waves with slices pending.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

__all__ = ["campaign_main"]


def _load_matrix(args) -> np.ndarray:
    rng = np.random.default_rng(args.seed)
    if args.matrix:
        return np.load(args.matrix)
    if args.family == "allones":
        return np.full((args.n, args.n), 1.0)
    if args.family == "fibonacci":
        A = np.zeros((args.n, args.n))
        for i in range(args.n):
            for j in range(args.n):
                if abs(i - j) <= 1:
                    A[i, j] = 1.0
        return A
    A = rng.uniform(0.2, 1.2, (args.n, args.n))
    if args.complex:
        A = A + 1j * rng.uniform(0.2, 1.2, (args.n, args.n))
    return A


def campaign_main(argv=None) -> int:
    import jax
    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", help=".npy file with a square matrix")
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--family", choices=("allones", "fibonacci"))
    ap.add_argument("--complex", action="store_true",
                    help="random complex matrix (with --n)")
    ap.add_argument("--checkpoint", required=True,
                    help="JobState .npz (created, appended, resumed)")
    ap.add_argument("--precision", default="dq_acc",
                    choices=("dd", "dq_fast", "dq_acc", "qq", "kahan"))
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"),
                    help="per-device wave body")
    ap.add_argument("--slices", type=int, default=64,
                    help="slice-count target (plan_slices)")
    ap.add_argument("--lanes", type=int, default=1024,
                    help="chunk-count target (plan_slices)")
    ap.add_argument("--devices", type=int, default=None,
                    help="use only the first N visible devices")
    ap.add_argument("--max-waves", type=int, default=None,
                    help="pause (exit 3) after this many waves")
    ap.add_argument("--threshold", type=float, default=-1.0,
                    help="campaign_threshold (default -1: always campaign)")
    ap.add_argument("--preprocess", action="store_true",
                    help="enable DM/FM (default off: campaign the matrix "
                         "as-is so the checkpoint geometry is the whole "
                         "step space)")
    ap.add_argument("--plan-json", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from jax.sharding import Mesh

    from ..core.distributed import CampaignPaused
    from ..core.solver import PermanentSolver, SolverConfig

    A = _load_matrix(args)
    n = A.shape[0]
    avail = jax.devices()
    D = len(avail) if args.devices is None else int(args.devices)
    if not 1 <= D <= len(avail):
        raise SystemExit(f"need 1 <= --devices <= {len(avail)}, got {D}")
    mesh = Mesh(np.array(avail[:D]), ("step",))

    solver = PermanentSolver(SolverConfig(
        precision=args.precision,
        backend=args.backend if args.backend == "pallas" else "jnp",
        preprocess=args.preprocess,
        campaign_threshold=args.threshold,
        campaign_slices=args.slices, campaign_lanes=args.lanes,
        campaign_checkpoint=args.checkpoint,
        campaign_max_waves=args.max_waves), distributed_ctx=mesh)
    t0 = time.time()

    def progress(state):
        # printed AFTER the wave's checkpoint hit disk: the kill/resume
        # harness SIGKILLs on the first of these lines knowing the
        # recorded progress is durable
        print(f"[campaign] wave done={state.fraction_done():.4f} "
              f"pending={len(state.pending_slices())} "
              f"t={time.time() - t0:.2f}s", flush=True)

    solver.campaign_progress = progress
    plan = solver.plan(A)
    print(f"[campaign] n={n} devices={D} {plan.summary()}", flush=True)
    if args.plan_json:
        print(plan.json(indent=2), flush=True)

    try:
        val = solver.execute(plan)
    except CampaignPaused as e:
        print(f"[campaign] paused: {e}", flush=True)
        return 3
    dt = time.time() - t0
    # %.17e round-trips float64 exactly: the kill/resume tests compare
    # these printed values bitwise
    if isinstance(val, complex):
        print(f"[campaign] perm(A) = {val.real:+.17e} {val.imag:+.17e}j"
              f"   ({dt:.2f}s)", flush=True)
    else:
        print(f"[campaign] perm(A) = {val:+.17e}   ({dt:.2f}s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(campaign_main())
