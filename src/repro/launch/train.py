"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --steps 200 --seq 256 --batch 8 [--reduced] [--ckpt-dir ckpt/]

Runs on whatever devices exist (CPU smoke -> full pod): builds the mesh,
shards params/optimizer with the production rules, streams synthetic data,
checkpoints every ``--ckpt-every`` steps and auto-resumes from the latest
checkpoint.  ``--reduced`` swaps in the small same-family config so the
driver is runnable end-to-end on one CPU (examples/train_lm.py uses it).
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCH_IDS, get_config
from ..ckpt.checkpoint import restore_train_state, save_train_state
from ..models.model import ShapeCell, build
from ..train.data import SyntheticLM, make_global_batch
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.train_step import build_train_step
from .mesh import make_local_mesh

__all__ = ["train_main", "run_training"]


def run_training(arch: str, *, steps: int = 100, seq: int = 256,
                 global_batch: int = 8, reduced: bool = True,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 microbatch: int = 0, log_every: int = 10,
                 mesh=None, seed: int = 0, lr: float = 3e-4):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = mesh or make_local_mesh()
    cell = ShapeCell("cli", "train", seq, global_batch)

    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(1, steps // 20))
    bundle = build_train_step(model, mesh, opt_cfg, microbatch=microbatch)

    params = model.init_params(jax.random.PRNGKey(seed))
    params = jax.device_put(params, bundle.param_sharding)
    opt_state = adamw_init(params)
    opt_state = jax.device_put(opt_state, bundle.opt_sharding)
    start_step = 0
    if ckpt_dir:
        restored = restore_train_state(ckpt_dir, params, opt_state)
        if restored:
            params, opt_state, start_step = restored
            params = jax.device_put(params, bundle.param_sharding)
            opt_state = jax.device_put(opt_state, bundle.opt_sharding)
            print(f"[train] resumed from step {start_step}")

    stream = SyntheticLM(cfg, cell, seed=seed)
    history = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch = make_global_batch(stream, step, mesh, bundle.batch_sharding)
        params, opt_state, metrics = bundle.step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_train_state(ckpt_dir, step + 1, jax.device_get(params),
                             jax.device_get(opt_state))
    return params, opt_state, history


def train_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)
    _, _, history = run_training(
        args.arch, steps=args.steps, seq=args.seq, global_batch=args.batch,
        reduced=args.reduced, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatch=args.microbatch, lr=args.lr)
    first, last = history[0][1], history[-1][1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(train_main())
