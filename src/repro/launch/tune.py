"""Autotuner CLI: fill the on-disk kernel-geometry tuning table.

    PYTHONPATH=src python -m repro.launch.tune \
        --routes dense,sparse,complex --n 8..16 --out table.json
    PYTHONPATH=src python -m repro.launch.tune \
        --routes dense --n 8,10,12 --out table.json --interpret  # CPU CI

One line prints per tuned key (winner geometry, speedup over the
default, predicted-vs-measured ratio); the table lands at ``--out`` in
the versioned, kernel-source-hashed format of ``repro.tune.table`` and
is picked up by the planner via ``SolverConfig.tuning_table`` (or the
``REPRO_TUNING_TABLE`` audit hook).  ``--report`` additionally writes
the per-candidate mispredict rows as JSON for
``benchmarks/roofline_report.py``.

The ``campaign`` route tunes the per-device wave body of
``slice_sums_on_mesh`` and needs more than one visible device to be
meaningful -- combine with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
on CPU.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

__all__ = ["parse_ns", "tune_main"]


def parse_ns(spec: str) -> list[int]:
    """``"8..16"`` (inclusive range) or ``"8,10,12"`` (list) -> sizes."""
    spec = spec.strip()
    if ".." in spec:
        lo, hi = spec.split("..", 1)
        lo, hi = int(lo), int(hi)
        if lo > hi:
            raise ValueError(f"empty size range {spec!r}")
        return list(range(lo, hi + 1))
    return [int(tok) for tok in spec.split(",") if tok]


def tune_main(argv=None) -> int:
    import jax
    jax.config.update("jax_enable_x64", True)
    from ..tune.search import ROUTES, tune_table
    from ..utils.roofline import detect_hw

    ap = argparse.ArgumentParser()
    ap.add_argument("--routes", default="dense",
                    help=f"comma list of {','.join(ROUTES)}")
    ap.add_argument("--n", default="8..12", dest="sizes",
                    help='matrix sizes: "8..16" or "8,10,12"')
    ap.add_argument("--out", required=True, help="tuning table JSON path")
    ap.add_argument("--report", default=None,
                    help="also write per-candidate mispredict rows (JSON)")
    ap.add_argument("--precision", default="dq_acc",
                    choices=("dd", "dq_fast", "dq_acc", "qq", "kahan"))
    ap.add_argument("--density", type=float, default=0.5,
                    help="sparse-route density (bucketed in the table)")
    ap.add_argument("--batch", type=int, default=16,
                    help="measurement batch size")
    ap.add_argument("--top-k", type=int, default=3,
                    help="model-ranked candidates to measure per key")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats per candidate (median kept)")
    ap.add_argument("--interpret", action="store_true",
                    help="interpret-mode kernels (CPU CI; no accelerator)")
    ap.add_argument("--hw", default=None,
                    help="override the hardware spec (utils/roofline.py "
                         "registry name; default: autodetect)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    routes = [r for r in args.routes.split(",") if r]
    for r in routes:
        if r not in ROUTES:
            raise SystemExit(f"unknown route {r!r}; choose from {ROUTES}")
    ns = parse_ns(args.sizes)

    mesh = None
    if "campaign" in routes:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()), ("step",))

    hw = detect_hw(args.hw) if args.hw else detect_hw()
    print(f"[tune] routes={','.join(routes)} n={ns} hw={hw.name} "
          f"interpret={args.interpret}", flush=True)
    t0 = time.time()

    def progress(entry):
        print(f"[tune] {entry.key()} -> {entry.geometry.tag()} "
              f"speedup={entry.speedup:.2f}x "
              f"pred/meas={entry.mispredict_ratio:.2f} "
              f"({entry.measured_s * 1e3:.2f}ms)", flush=True)

    table, report = tune_table(
        routes, ns, density=args.density, precision=args.precision,
        batch=args.batch, top_k=args.top_k, repeats=args.repeats,
        interpret=args.interpret, seed=args.seed, mesh=mesh,
        progress=progress)
    table.save(args.out)
    print(f"[tune] {len(table.entries)} entr(ies) -> {args.out} "
          f"({time.time() - t0:.1f}s)", flush=True)
    if args.report:
        with open(args.report, "w") as f:
            json.dump({"hw": hw.name, "rows": report}, f, indent=1)
        print(f"[tune] mispredict report -> {args.report}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(tune_main())
