"""Synthetic sharded data pipeline.

Deterministic, seed-addressable token streams (no dataset downloads in this
environment).  ``make_global_batch`` materializes a step's batch directly
into the mesh sharding via ``jax.make_array_from_callback`` -- each device
generates only its own shard, the multi-host-friendly pattern (no global
array ever exists on one host).
"""

from __future__ import annotations

import numpy as np
import jax

from ..models.common import ModelCfg
from ..models.model import ShapeCell

__all__ = ["SyntheticLM", "make_global_batch"]


class SyntheticLM:
    """Deterministic LM stream: tokens[step, b, s] = hash(seed, step, b, s).

    A cheap stand-in with real-data plumbing: per-shard generation,
    epoch/step addressing, and label shifting.
    """

    def __init__(self, cfg: ModelCfg, cell: ShapeCell, seed: int = 0):
        self.cfg = cfg
        self.cell = cell
        self.seed = seed

    def _tokens(self, step: int, lo_b: int, hi_b: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, lo_b]))
        return rng.integers(0, self.cfg.vocab, (hi_b - lo_b, seq + 1),
                            dtype=np.int32)

    def host_batch(self, step: int) -> dict:
        """Full global batch on the host (single-process path)."""
        c, cell = self.cfg, self.cell
        toks = self._tokens(step, 0, cell.global_batch, cell.seq)
        return self._pack(toks, step)

    def _pack(self, toks: np.ndarray, step: int) -> dict:
        c, cell = self.cfg, self.cell
        B, S = toks.shape[0], toks.shape[1] - 1
        inp, lab = toks[:, :-1], toks[:, 1:]
        if c.family == "vlm":
            rng = np.random.default_rng((self.seed, step, 7))
            emb = rng.normal(0, 0.02, (B, S, c.d_model)).astype(np.float32)
            pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S)).copy()
            return {"embeds": emb.astype(np.float32),
                    "positions": pos.astype(np.int32), "labels": lab}
        if c.family == "audio-encdec":
            rng = np.random.default_rng((self.seed, step, 8))
            emb = rng.normal(0, 0.02, (B, S, c.d_model)).astype(np.float32)
            return {"enc_embeds": emb, "dec_tokens": inp, "labels": lab}
        return {"tokens": inp, "labels": lab}


def make_global_batch(stream: SyntheticLM, step: int, mesh, batch_sharding):
    """Build the sharded global batch; each device's shard is generated
    locally by the callback (multi-host safe)."""
    host = stream.host_batch(step)

    def place(name, arr, sh):
        arr = np.asarray(arr)

        def cb(index):
            return arr[index]

        return jax.make_array_from_callback(arr.shape, sh, cb)

    return {k: place(k, v, batch_sharding[k]) for k, v in host.items()}
