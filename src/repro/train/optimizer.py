"""AdamW optimizer (pure JAX, ZeRO-sharded by construction).

No optax in this environment, so a minimal-but-production AdamW:
f32 moments, decoupled weight decay, global-norm clipping, linear warmup +
cosine decay schedule.  Optimizer state mirrors the parameter tree, so the
ZeRO sharding story is simply "state inherits the param PartitionSpecs"
(params are already FSDP+TP sharded; see models/shardings.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay for norms / biases / 1-d params."""
    name = str(getattr(path[-1], "key", path[-1]))
    return name not in ("w", "b", "bi", "bo", "bq", "bk", "bv", "conv_b",
                        "dt_bias", "A_log", "Dskip", "norm_w")


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * (g * g)
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(new_m, new_v, count), metrics
