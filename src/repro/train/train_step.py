"""Sharded train/serve step builders (the units the dry-run lowers).

``build_train_step``: value_and_grad of the model loss + AdamW update, jit'd
with NamedShardings: params/opt FSDP+TP (ZeRO), batch over the data axes.
Optional gradient accumulation runs microbatches under ``lax.scan`` (the
compiled HLO stays one fused step).

``build_serve_steps``: prefill and decode steps with KV-cache shardings;
decode uses the sequence-sharded flash-decoding path when the arch's
kv-heads don't divide the model axis (DESIGN.md Sec. 6).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import actx
from ..models import shardings as SH
from ..models.common import ModelCfg
from ..models.model import Model, ShapeCell
from ..models.transformer import SeqShardCtx
from .optimizer import AdamWConfig, AdamWState, adamw_update

__all__ = ["MeshAxes", "mesh_axes_of", "build_train_step",
           "build_serve_steps", "named", "TrainStepBundle"]


@dataclass(frozen=True)
class MeshAxes:
    dp: tuple       # data-parallel axes, e.g. ("pod", "data")
    model: str      # tensor/expert axis


def mesh_axes_of(mesh: Mesh) -> MeshAxes:
    names = tuple(mesh.axis_names)
    dp = tuple(n for n in names if n != "model")
    return MeshAxes(dp=dp, model="model" if "model" in names else names[-1])


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclass
class TrainStepBundle:
    step_fn: Any            # jitted (params, opt, batch) -> (params, opt, metrics)
    param_sharding: Any
    opt_sharding: Any
    batch_sharding: Any
    param_specs: Any


def build_train_step(model: Model, mesh: Mesh, opt_cfg: AdamWConfig,
                     *, microbatch: int = 0, donate: bool = True,
                     seq_parallel: bool = False, strategy: str = "tp"):
    """Returns a TrainStepBundle; step_fn is jit-compiled but not yet
    lowered (the dry-run lowers it with ShapeDtypeStructs).

    strategy:
      "tp"    -- width dims over the model axis (TP/EP) + FSDP over data
                 (the default; right for models that need model parallelism)
      "fsdp"  -- no tensor parallelism: params sharded over ALL mesh axes
                 (ZeRO-3); batch over the data axes.  Eliminates the
                 per-layer TP boundary all-reduces -- the right choice for
                 small models (see EXPERIMENTS.md Perf H2)."""
    axes = mesh_axes_of(mesh)
    cfg = model.cfg
    shapes = model.param_shapes()
    SH.set_mesh_sizes({a: mesh.shape[a] for a in mesh.axis_names})
    if strategy == "fsdp":
        all_axes = axes.dp + (axes.model,)
        pspecs = SH.param_specs(cfg, shapes, fsdp=all_axes, mdl=None,
                                mdl_size=1)
    else:
        pspecs = SH.param_specs(cfg, shapes, fsdp=axes.dp, mdl=axes.model,
                                mdl_size=mesh.shape[axes.model])
    p_shard = named(mesh, pspecs)
    opt_specs = AdamWState(m=pspecs, v=pspecs, count=P())
    o_shard = named(mesh, opt_specs)
    loss_fn = model.loss_fn()

    def loss_and_grad(params, batch):
        if not microbatch:
            return jax.value_and_grad(loss_fn)(params, batch)

        # gradient accumulation: split the local batch into microbatches
        def micro(carry, mb):
            tot, acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (tot + l, jax.tree.map(jnp.add, acc, g)), None

        def split(x):
            b = x.shape[0]
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])

        mbatch = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tot, acc), _ = jax.lax.scan(micro, (jnp.float32(0), zero), mbatch)
        n = jnp.float32(microbatch)
        return tot / n, jax.tree.map(lambda g: g / n, acc)

    act_dp = axes.dp + (axes.model,) if strategy == "fsdp" else axes.dp

    def step(params, opt_state, batch):
        with actx.use(mesh, act_dp, axes.model,
                      seq_parallel=seq_parallel):
            loss, grads = loss_and_grad(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    batch_tree = model.input_specs(ShapeCell("x", "train", 8, 8))
    bspecs = SH.batch_specs(cfg, batch_tree, dp=act_dp, mdl=axes.model)
    b_shard = named(mesh, bspecs)

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1) if donate else ())
    return TrainStepBundle(jitted, p_shard, o_shard, b_shard, pspecs)


def decode_kv_policy(cfg: ModelCfg, mesh: Mesh) -> str:
    """'heads' when kv-heads divide the model axis, else 'seq'
    (sequence-sharded cache + flash-decoding combine)."""
    msize = mesh.shape[mesh_axes_of(mesh).model]
    if cfg.family == "ssm":
        return "state"
    if cfg.n_kv_heads % msize == 0:
        return "heads"
    return "seq"


def _effective_dp(mesh: Mesh, axes: MeshAxes, global_batch: int):
    """Batch-dim axes: the data axes if they divide the batch, else
    replicated (e.g. long_500k with global_batch=1)."""
    dp_size = math.prod(mesh.shape[a] for a in axes.dp)
    return axes.dp if global_batch % dp_size == 0 else None


def build_serve_steps(model: Model, mesh: Mesh, cell: ShapeCell):
    """jit bundle for one serve cell.  Returns (step_fn, in_shardings) where
    step_fn is the prefill step (cell.kind == 'prefill') or the one-token
    decode step (cell.kind == 'decode').

    Serving uses RESIDENT (TP-only) weights -- typically cast to bf16 by
    the caller; FSDP weight gathers per token are a latency disaster
    (EXPERIMENTS.md Perf H4).  MoE expert tables stay data-sharded."""
    axes = mesh_axes_of(mesh)
    cfg = model.cfg
    shapes = model.param_shapes()
    SH.set_mesh_sizes({a: mesh.shape[a] for a in mesh.axis_names})
    pspecs = SH.param_specs(cfg, shapes, fsdp=axes.dp, mdl=axes.model,
                            mdl_size=mesh.shape[axes.model], serve=True)
    p_shard = named(mesh, pspecs)
    dp = _effective_dp(mesh, axes, cell.global_batch)

    in_tree = model.input_specs(cell)
    ispecs = SH.batch_specs(cfg, in_tree, dp=dp, mdl=axes.model)
    i_shard = named(mesh, ispecs)

    policy = decode_kv_policy(cfg, mesh)
    cache_tree = model.cache_specs(cell)
    cspecs = SH.cache_specs_sharding(cfg, cache_tree, dp=dp, mdl=axes.model,
                                     seq_sharded=(policy == "seq"))
    c_shard = named(mesh, cspecs)

    if cell.kind == "prefill":
        prefill_raw = model.prefill_fn(cell.seq)

        def prefill_ctx(params, inputs):
            with actx.use(mesh, dp, axes.model):
                return prefill_raw(params, inputs)

        prefill_jit = jax.jit(prefill_ctx,
                              in_shardings=(p_shard, i_shard),
                              out_shardings=(None, c_shard))
        return prefill_jit, (p_shard, i_shard), c_shard, policy

    seq_ctx = None
    if policy == "seq":
        seq_ctx = SeqShardCtx(mesh=mesh, axis=axes.model,
                              dp_axes=dp if dp else ())
    decode_raw = model.decode_fn(seq_ctx)

    def decode_ctx(params, inputs, cache):
        with actx.use(mesh, dp, axes.model):
            return decode_raw(params, inputs, cache)

    dp_axis = None if dp is None else (dp if len(dp) > 1 else dp[0])
    logits_spec = NamedSharding(mesh, P(dp_axis, None, axes.model))
    decode_jit = jax.jit(decode_ctx,
                         in_shardings=(p_shard, i_shard, c_shard),
                         out_shardings=(logits_spec, c_shard),
                         donate_argnums=(2,))
    return decode_jit, (p_shard, i_shard, c_shard), c_shard, policy
