"""Sparse-matrix permanent: CRS/CCS storage and SpaRyser (paper Alg. 2).

The matrix is stored in the paper's dual CRS + CCS formats (Fig. 1).  The
Gray-code loop updates the row-sum vector ``x`` using only the nonzeros of
the changed column -- O(nnz_j) instead of O(n) per step.

TPU adaptation (DESIGN.md Sec. 2): lockstep lanes cannot skip work, so the
per-column nonzero lists are *padded to the max column degree* and the
padded entries point at a dummy row (index n) with value 0 -- the scatter
stays shape-static and vectorizes, while the arithmetic still touches only
``maxdeg`` rows.  The sparsity pattern is a trace-time constant: the jitted
engine is specialized per pattern, the analogue of the paper's per-matrix
kernel generation ([22], Sec. 3.3).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import precision as P
from .ryser import (chain_prod, chain_prod_complex, chunk_geometry,
                    complex_precision, nw_base_vector, rank1_chunk_init,
                    tf_tree_sum, _CEGSchedules, _final_factor)

__all__ = ["SparseMatrix", "perm_sparyser_chunked", "perm_sparyser_batched",
           "sparse_batched_values", "sparse_batched_values_complex",
           "sparse_chunked_value", "pack_padded_ccs",
           "sparse_chunk_partial_sums"]


@dataclass(frozen=True)
class SparseMatrix:
    """CRS + CCS dual storage (paper Fig. 1). Host-side numpy arrays."""
    n: int
    rptrs: np.ndarray   # (n+1,)
    cids: np.ndarray    # (nnz,) column ids, row-major order
    rvals: np.ndarray   # (nnz,)
    cptrs: np.ndarray   # (n+1,)
    rids: np.ndarray    # (nnz,) row ids, column-major order
    cvals: np.ndarray   # (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.cids.shape[0])

    @property
    def density(self) -> float:
        return self.nnz / float(self.n * self.n)

    @staticmethod
    def from_dense(A: np.ndarray, tol: float = 0.0) -> "SparseMatrix":
        A = np.asarray(A)
        n = A.shape[0]
        mask = np.abs(A) > tol
        rptrs = np.zeros(n + 1, dtype=np.int32)
        cids, rvals = [], []
        for i in range(n):
            js = np.nonzero(mask[i])[0]
            cids.append(js)
            rvals.append(A[i, js])
            rptrs[i + 1] = rptrs[i] + len(js)
        cptrs = np.zeros(n + 1, dtype=np.int32)
        rids, cvals = [], []
        for j in range(n):
            is_ = np.nonzero(mask[:, j])[0]
            rids.append(is_)
            cvals.append(A[is_, j])
            cptrs[j + 1] = cptrs[j] + len(is_)
        cat = lambda xs, dt: (np.concatenate(xs).astype(dt) if xs else
                              np.zeros(0, dtype=dt))
        return SparseMatrix(
            n=n,
            rptrs=rptrs, cids=cat(cids, np.int32), rvals=cat(rvals, A.dtype),
            cptrs=cptrs, rids=cat(rids, np.int32), cvals=cat(cvals, A.dtype))

    def to_dense(self) -> np.ndarray:
        A = np.zeros((self.n, self.n), dtype=self.rvals.dtype)
        for i in range(self.n):
            sl = slice(self.rptrs[i], self.rptrs[i + 1])
            A[i, self.cids[sl]] = self.rvals[sl]
        return A

    def padded_columns(self):
        """(rows, vals) of shape (n, maxdeg): column-j nonzeros, padded with
        (row=n, val=0) -- the shape-static scatter form."""
        n = self.n
        maxdeg = max(1, int(np.max(self.cptrs[1:] - self.cptrs[:-1])))
        rows = np.full((n, maxdeg), n, dtype=np.int32)
        vals = np.zeros((n, maxdeg), dtype=self.cvals.dtype)
        for j in range(n):
            sl = slice(self.cptrs[j], self.cptrs[j + 1])
            deg = sl.stop - sl.start
            rows[j, :deg] = self.rids[sl]
            vals[j, :deg] = self.cvals[sl]
        return rows, vals

    def min_degree(self):
        """(which, index, deg): minimum nonzero count over rows and columns.

        which is 'row' or 'col'.  Used by the Alg. 4 dispatcher.
        """
        rdeg = self.rptrs[1:] - self.rptrs[:-1]
        cdeg = self.cptrs[1:] - self.cptrs[:-1]
        ri = int(np.argmin(rdeg))
        ci = int(np.argmin(cdeg))
        if rdeg[ri] <= cdeg[ci]:
            return "row", ri, int(rdeg[ri])
        return "col", ci, int(cdeg[ci])


def sparse_chunk_partial_sums(sp: SparseMatrix, T: int, C: int,
                              precision: str = "dq_acc",
                              chunk_offset: int = 0,
                              total_chunks: int | None = None) -> P.TwoFloat:
    """SpaRyser (Alg. 2) partial sums for a chunk range; mirrors
    ``ryser.chunk_partial_sums`` but updates x through the padded CCS."""
    A = jnp.asarray(sp.to_dense())       # used only for init matmul (n x n)
    rows_pad, vals_pad = sp.padded_columns()
    return _sparse_partials_traced(A, jnp.asarray(rows_pad),
                                   jnp.asarray(vals_pad), T, C, precision,
                                   chunk_offset, total_chunks)


def _sparse_partials_traced(A, rows_pad, vals_pad, T: int, C: int,
                            precision: str, chunk_offset: int = 0,
                            total_chunks: int | None = None) -> P.TwoFloat:
    """Traced-core SpaRyser partials: the matrix enters only through the
    (traced) dense ``A`` (init matmul), ``rows_pad`` and ``vals_pad``
    (n, maxdeg) padded CCS arrays -- so the same program vmaps over a
    stack of same-shape sparse matrices (``perm_sparyser_batched``)."""
    if total_chunks is None:
        total_chunks = T
    n = A.shape[0]
    dtype = A.dtype
    S = _CEGSchedules(n, T, C, chunk_offset, total_chunks)
    # fixed-order rank-1 init, not ``A @ Gbits`` (see ryser.chain_prod:
    # XLA's contraction split is batch-shape-dependent), extended with
    # dummy row n for padded scatters
    X0 = rank1_chunk_init(A, nw_base_vector(A), S.gray_bits(n, dtype))
    X0 = jnp.concatenate([X0, jnp.zeros((1, T), dtype=dtype)], axis=0)

    sched_j, base_bits, mid_flags, w_parity = S.scan_inputs
    lane_bitk = S.lane_bitk
    tail_j, tail_sign, tail_live = S.tail_j, S.tail_sign, S.tail_live

    def accum(acc, term):
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision in ("dq_acc", "qq"):
            t = P.tf_add_acc(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term)
        return (acc[0] + term, acc[1])

    def scan_body(carry, inputs):
        X, acc = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)
        s = (2 * sign_bits - 1).astype(dtype)              # (T,)
        r = rows_pad[col_j]                                # (maxdeg,)
        v = vals_pad[col_j]                                # (maxdeg,)
        X = X.at[r, :].add(v[:, None] * s[None, :])
        prod = chain_prod(X[:n])
        term = jnp.where(par == 1, -prod, prod)
        acc = accum(acc, term)
        return (X, acc), None

    z = jnp.zeros((T,), dtype=dtype)
    (X, acc), _ = jax.lax.scan(scan_body, (X0, (z, z)),
                               (sched_j, base_bits, mid_flags, w_parity))

    # tail step
    r = rows_pad[jnp.asarray(tail_j)]                      # (T, maxdeg)
    v = vals_pad[jnp.asarray(tail_j)]                      # (T, maxdeg)
    sgn = jnp.asarray((tail_sign * tail_live).astype(np.float64)).astype(dtype)
    upd = (v * sgn[:, None]).T                             # (maxdeg, T)
    X = X.at[r.T, jnp.arange(T)[None, :]].add(upd)
    prod = chain_prod(X[:n])
    live = jnp.asarray(tail_live)
    neg = (C & 1) == 1
    term = jnp.where(live, -prod if neg else prod, jnp.zeros_like(prod))
    acc = accum(acc, term)

    if precision in ("kahan", "dd"):
        return P.TwoFloat(acc[0], jnp.zeros_like(acc[0]))
    return P.TwoFloat(acc[0], acc[1])


def _sparse_partials_traced_complex(Ar, Ai, rows_pad, vals_r, vals_i,
                                    T: int, C: int, precision: str,
                                    chunk_offset: int = 0,
                                    total_chunks: int | None = None):
    """Split-plane complex SpaRyser partials; mirrors
    ``_sparse_partials_traced`` with the matrix carried as (re, im) float
    planes (see ``ryser.chunk_partial_sums_complex`` for the
    representation contract).  Returns ``(re, im, base)`` -- (T,)
    TwoFloats per component plus the scalar base-term pair read off lane
    0's initial state (valid at ``chunk_offset == 0``)."""
    precision = complex_precision(precision)
    if total_chunks is None:
        total_chunks = T
    n = Ar.shape[0]
    dtype = Ar.dtype
    S = _CEGSchedules(n, T, C, chunk_offset, total_chunks)
    Gbits = S.gray_bits(n, dtype)
    Xr = rank1_chunk_init(Ar, nw_base_vector(Ar), Gbits)
    Xi = rank1_chunk_init(Ai, nw_base_vector(Ai), Gbits)
    # base product from the lane products' (n, T) vector pattern (a
    # standalone (B,)-shaped chain compiles batch-shape-dependently)
    b0r, b0i = chain_prod_complex(Xr, Xi)
    base = (b0r[0], b0i[0])
    zrow = jnp.zeros((1, T), dtype=dtype)
    Xr = jnp.concatenate([Xr, zrow], axis=0)     # dummy row n for scatters
    Xi = jnp.concatenate([Xi, zrow], axis=0)

    lane_bitk = S.lane_bitk
    tail_j, tail_sign, tail_live = S.tail_j, S.tail_sign, S.tail_live

    def accum(acc, term):
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "dq_acc":
            t = P.tf_add_acc(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term)
        return (acc[0] + term, acc[1])  # dd

    def scan_body(carry, inputs):
        Xr, Xi, acc_r, acc_i = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)
        s = (2 * sign_bits - 1).astype(dtype)              # (T,)
        r = rows_pad[col_j]                                # (maxdeg,)
        Xr = Xr.at[r, :].add(vals_r[col_j][:, None] * s[None, :])
        Xi = Xi.at[r, :].add(vals_i[col_j][:, None] * s[None, :])
        pr, pi = chain_prod_complex(Xr[:n], Xi[:n])
        acc_r = accum(acc_r, jnp.where(par == 1, -pr, pr))
        acc_i = accum(acc_i, jnp.where(par == 1, -pi, pi))
        return (Xr, Xi, acc_r, acc_i), None

    z = jnp.zeros((T,), dtype=dtype)
    (Xr, Xi, acc_r, acc_i), _ = jax.lax.scan(
        scan_body, (Xr, Xi, (z, z), (z, z)), S.scan_inputs)

    # tail step
    r = rows_pad[jnp.asarray(tail_j)]                      # (T, maxdeg)
    sgn = jnp.asarray((tail_sign * tail_live).astype(np.float64)).astype(dtype)
    cols = jnp.arange(T)[None, :]
    Xr = Xr.at[r.T, cols].add((vals_r[jnp.asarray(tail_j)] * sgn[:, None]).T)
    Xi = Xi.at[r.T, cols].add((vals_i[jnp.asarray(tail_j)] * sgn[:, None]).T)
    pr, pi = chain_prod_complex(Xr[:n], Xi[:n])
    live = jnp.asarray(tail_live)
    neg = (C & 1) == 1
    zero = jnp.zeros_like(pr)
    acc_r = accum(acc_r, jnp.where(live, -pr if neg else pr, zero))
    acc_i = accum(acc_i, jnp.where(live, -pi if neg else pi, zero))

    if precision in ("kahan", "dd"):
        return (P.TwoFloat(acc_r[0], jnp.zeros_like(acc_r[0])),
                P.TwoFloat(acc_i[0], jnp.zeros_like(acc_i[0])), base)
    return (P.TwoFloat(acc_r[0], acc_r[1]),
            P.TwoFloat(acc_i[0], acc_i[1]), base)


def _sparse_key(sp: SparseMatrix):
    return (sp.n, sp.cids.tobytes(), sp.rptrs.tobytes())


def sparse_chunked_value(A, rows_pad, vals_pad, T: int, C: int,
                         precision: str):
    """Traced scalar SpaRyser permanent from (dense, padded-CCS) arrays.

    The scalar composition behind ``perm_sparyser_chunked`` as one
    traceable function of traced arrays -- the same fixed-order
    reductions as ``sparse_batched_values``'s per-element epilogue
    (bit-identity between a scalar straggler and a bucket member), and
    the entry permprove's IR verifier traces for the sparse jnp scalar
    route.
    """
    n = A.shape[0]
    partials = _sparse_partials_traced(A, rows_pad, vals_pad, T, C,
                                       precision)
    p_hi, p_lo = jax.lax.optimization_barrier((partials.hi, partials.lo))
    hi, e1 = tf_tree_sum(p_hi, p_lo)
    p0 = chain_prod(nw_base_vector(A))
    total = P.tf_add_acc(P.TwoFloat(hi, e1), p0)
    return P.tf_value(total) * _final_factor(n)


def perm_sparyser_chunked(sp: SparseMatrix, num_chunks: int = 4096,
                          precision: str = "dq_acc"):
    """Permanent of a sparse matrix via chunked SpaRyser.

    Complex matrices run the split-plane engine as a B=1 batch program
    (``perm_sparyser_batched``), so scalar stragglers are bit-identical to
    the same leaf served inside a bucket.
    """
    n = sp.n
    if n == 1:
        return np.asarray(sp.to_dense()).item()
    A = jnp.asarray(sp.to_dense())
    if n == 2:
        return np.asarray(A[0, 0] * A[1, 1] + A[0, 1] * A[1, 0]).item()
    if np.iscomplexobj(sp.cvals):
        return perm_sparyser_batched([sp], num_chunks=num_chunks,
                                     precision=precision)[0].item()
    T, C, _ = chunk_geometry(n, num_chunks)
    rows_pad, vals_pad = sp.padded_columns()
    val = sparse_chunked_value(A, jnp.asarray(rows_pad),
                               jnp.asarray(vals_pad), T, C, precision)
    return np.asarray(val).item()


def sparse_batched_values(A_stack, rows_stack, vals_stack, T: int, C: int,
                          precision: str):
    """Traced (B,) sparse permanents of a packed same-size stack.

    Shared by the jitted single-device program (``_sparse_batched_jit``)
    and the per-device body of the mesh-sharded sparse batch path
    (``distributed.sparse_batch_permanents_on_mesh``) -- one trace (and
    ``ryser.tf_tree_sum``'s fixed-order cross-chunk reduction), so sharded
    and local values are bit-identical for any shard shape.
    """
    n = A_stack.shape[1]
    parts = jax.vmap(
        lambda A, r, v: _sparse_partials_traced(A, r, v, T, C, precision)
    )(A_stack, rows_stack, vals_stack)
    # see ryser.batched_values: fusion across this boundary is
    # batch-shape-dependent and would break shard/local bit-identity
    p_hi, p_lo = jax.lax.optimization_barrier((parts.hi, parts.lo))

    def reduce_one(A, hi_t, lo_t):
        hi, e1 = tf_tree_sum(hi_t, lo_t)
        p0 = chain_prod(nw_base_vector(A))
        total = P.tf_add_acc(P.TwoFloat(hi, e1), p0)
        return P.tf_value(total) * _final_factor(n)

    return jax.vmap(reduce_one)(A_stack, p_hi, p_lo)


@partial(jax.jit, static_argnames=("T", "C", "precision"))
def _sparse_batched_jit(A_stack, rows_stack, vals_stack, T: int, C: int,
                        precision: str):
    return sparse_batched_values(A_stack, rows_stack, vals_stack, T, C,
                                 precision)


def sparse_batched_values_complex(Ar_stack, Ai_stack, rows_stack,
                                  vals_r_stack, vals_i_stack,
                                  T: int, C: int, precision: str):
    """Traced (re, im) pair for a packed split-plane complex sparse stack.

    The complex analogue of ``sparse_batched_values``: one body shared by
    the jitted single-device program and the per-device body of
    ``distributed.sparse_batch_permanents_on_mesh``.  Batched with
    ``lax.map`` rather than vmap for the same reason as
    ``ryser.batched_values_complex``: one body program regardless of the
    batch/shard extent makes per-element values shape-independent by
    construction.
    """
    precision = complex_precision(precision)
    n = Ar_stack.shape[1]

    def one(packed):
        ar, ai, rows, vr, vi = packed
        parts_r, parts_i, (p0r, p0i) = _sparse_partials_traced_complex(
            ar, ai, rows, vr, vi, T, C, precision)
        rh, rl, ih, il, p0r, p0i = jax.lax.optimization_barrier(
            (parts_r.hi, parts_r.lo, parts_i.hi, parts_i.lo, p0r, p0i))
        hr, er = tf_tree_sum(rh, rl)
        hi_, ei = tf_tree_sum(ih, il)
        tot_r = P.tf_add_acc(P.TwoFloat(hr, er), p0r)
        tot_i = P.tf_add_acc(P.TwoFloat(hi_, ei), p0i)
        f = _final_factor(n)
        return P.tf_value(tot_r) * f, P.tf_value(tot_i) * f

    return jax.lax.map(
        one, (Ar_stack, Ai_stack, rows_stack, vals_r_stack, vals_i_stack))


@partial(jax.jit, static_argnames=("T", "C", "precision"))
def _sparse_batched_complex_jit(Ar_stack, Ai_stack, rows_stack,
                                vals_r_stack, vals_i_stack,
                                T: int, C: int, precision: str):
    return sparse_batched_values_complex(
        Ar_stack, Ai_stack, rows_stack, vals_r_stack, vals_i_stack,
        T, C, precision)


def pack_padded_ccs(sps: list[SparseMatrix]):
    """Pack a same-size bucket into batch-stacked dense + padded-CCS arrays.

    Returns host-side ``(A_stack, rows_stack, vals_stack)`` with shapes
    (B, n, n), (B, n, maxdeg), (B, n, maxdeg); the per-matrix columns are
    padded to the bucket-wide max column degree with (row=n, val=0)
    entries, which scatter into the dummy row and are arithmetically
    inert -- per-element numerics do not depend on the bucket's maxdeg.
    """
    assert sps, "empty bucket"
    n = sps[0].n
    assert all(sp.n == n for sp in sps), "bucket must be same-size"
    padded = [sp.padded_columns() for sp in sps]
    maxdeg = max(r.shape[1] for r, _ in padded)
    B = len(sps)
    dtype = np.result_type(*(v.dtype for _, v in padded))
    rows_stack = np.full((B, n, maxdeg), n, dtype=np.int32)
    vals_stack = np.zeros((B, n, maxdeg), dtype=dtype)
    for b, (r, v) in enumerate(padded):
        rows_stack[b, :, :r.shape[1]] = r
        vals_stack[b, :, :v.shape[1]] = v
    A_stack = np.stack([sp.to_dense().astype(dtype) for sp in sps])
    return A_stack, rows_stack, vals_stack


def perm_sparyser_batched(sps: list[SparseMatrix], num_chunks: int = 4096,
                          precision: str = "dq_acc") -> np.ndarray:
    """Permanents of a bucket of same-size sparse matrices, one dispatch.

    All matrices must share ``n``; their padded CCS columns are padded
    further to the bucket-wide max column degree (padding points at the
    dummy row, so it is arithmetically inert) and the SpaRyser body is
    vmapped over the stack.  The jitted program is specialized per
    (n, maxdeg, T, C) -- the batched analogue of the per-pattern kernel
    specialization, amortized over the whole bucket.
    """
    assert sps, "empty bucket"
    n = sps[0].n
    assert all(sp.n == n for sp in sps), "bucket must be same-size"
    if n <= 2:
        # pass the caller's precision/num_chunks through to the scalar
        # path -- dropping them silently would serve tiny buckets at the
        # default config whatever the plan asked for
        return np.array([perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                               precision=precision)
                         for sp in sps])
    T, C, _ = chunk_geometry(n, num_chunks)
    A_stack, rows_stack, vals_stack = pack_padded_ccs(sps)
    if np.iscomplexobj(vals_stack):
        vr, vi = _sparse_batched_complex_jit(
            jnp.asarray(np.ascontiguousarray(A_stack.real)),
            jnp.asarray(np.ascontiguousarray(A_stack.imag)),
            jnp.asarray(rows_stack),
            jnp.asarray(np.ascontiguousarray(vals_stack.real)),
            jnp.asarray(np.ascontiguousarray(vals_stack.imag)),
            T, C, precision)
        return np.asarray(vr) + 1j * np.asarray(vi)
    out = _sparse_batched_jit(jnp.asarray(A_stack), jnp.asarray(rows_stack),
                              jnp.asarray(vals_stack), T, C, precision)
    return np.asarray(out)
