"""Dense Gray-code Ryser permanent engines (paper Alg. 1 / Alg. 3) in JAX.

Three engines, all returning ``perm(A)``:

* ``perm_ryser_seq``     -- faithful sequential Alg. 1 (one ``lax.scan`` over
  the 2^{n-1}-1 Gray steps).  Reference semantics; O(n 2^{n-1}).
* ``perm_ryser_chunked`` -- faithful Alg. 3: the iteration space is split in
  ``T`` chunks; each chunk rebuilds its private row-sum vector from
  ``Gray(start-1)`` (here: one matmul ``A @ G``) and iterates locally.
  Chunks are *power-of-2, window-aligned* (the paper's CEG load
  distribution, Sec. 3.2.1) so the changed bit is chunk-uniform at every
  local step except each window's last -- in vectorized form the column
  update is a broadcast, not a gather.
* the same chunked body is reused per-device by ``core.distributed`` and in
  matmul ("window-batched") form by the Pallas kernel.

Precision modes (paper Table 3): ``dd`` (plain), ``dq_fast`` (Dekker add,
[30]), ``dq_acc`` (accurate add, [31]), ``qq`` (twofloat inner product too),
``kahan`` ([29]).  The outer cross-chunk reduction is always twofloat
("quad for the outer sum", Sec. 5).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import gray as G
from . import precision as P

__all__ = [
    "nw_base_vector",
    "perm_ryser_seq",
    "perm_ryser_chunked",
    "perm_ryser_batched",
    "batched_values",
    "batched_values_complex",
    "tf_tree_sum",
    "chain_prod",
    "chain_prod_complex",
    "chunk_partial_sums",
    "chunk_partial_sums_complex",
    "chunk_geometry",
    "complex_precision",
    "ryser_flops",
]


def nw_base_vector(A):
    """Nijenhuis-Wilf start vector  x[i] = a[i, n-1] - rowsum_i / 2.

    The row sum is a fixed-order sequential chain, not ``jnp.sum``: XLA
    reassociates axis reductions depending on the surrounding program
    shape, and the batch-sharded path needs every contraction in the
    engine to be batch-shape-independent (see ``batched_values``).
    """
    n = A.shape[1]
    rowsum = A[:, 0]
    for j in range(1, n):
        rowsum = rowsum + A[:, j]
    return A[:, -1] - rowsum / 2


def _final_factor(n: int) -> int:
    """(4 * (n mod 2) - 2) == 2 * (-1)^{n-1}."""
    return 4 * (n % 2) - 2


def ryser_flops(n: int) -> float:
    """Model FLOPs of the chunked engine: ~2n per Gray step (n adds for the
    row-sum update + n mults for the product) over 2^{n-1} steps."""
    return 2.0 * n * 2.0 ** (n - 1)


# ---------------------------------------------------------------------------
# Sequential (faithful Alg. 1)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n",))
def _ryser_seq_jit(A, n: int):
    idx_dtype = jnp.int64 if n > 31 else jnp.int32
    x0 = nw_base_vector(A)
    p0 = jnp.prod(x0)  # permlint: disable=PL001  # length-n product, Alg. 1 reference

    def body(carry, g):
        x, acc_hi, acc_lo = carry
        low = g & -g
        j = jax.lax.population_count(low - 1)
        gray_g = g ^ (g >> 1)
        s = jnp.where((gray_g & low) != 0, 1.0, -1.0).astype(A.dtype)
        x = x + s * A[:, j]
        prod = jnp.prod(x)  # permlint: disable=PL001  # length-n product, Alg. 1 reference
        term = jnp.where((g & 1) != 0, -prod, prod)
        acc = P.tf_add_acc(P.TwoFloat(acc_hi, acc_lo), term)
        return (x, acc.hi, acc.lo), None

    gs = jnp.arange(1, 2 ** (n - 1), dtype=idx_dtype)
    (x, hi, lo), _ = jax.lax.scan(body, (x0, p0, jnp.zeros_like(p0)), gs)
    return (hi + lo) * _final_factor(n)


def perm_ryser_seq(A):
    """Faithful Algorithm 1 with twofloat accumulation. n <= ~26 advised."""
    A = jnp.asarray(A)
    n = A.shape[0]
    if n == 1:
        return A[0, 0]
    return _ryser_seq_jit(A, n)


# ---------------------------------------------------------------------------
# Chunked / vectorized (faithful Alg. 3 + CEG chunking)
# ---------------------------------------------------------------------------

# chunk_geometry lives in core.stepspace (pure host math shared with the
# jax-free planner); re-exported here for the engines and their callers.
from .stepspace import chunk_geometry  # noqa: E402


class _CEGSchedules:
    """Host-constant CEG schedules for chunks [offset, offset + T).

    Everything here depends only on (n, T, C, chunk_offset) -- never on the
    matrix -- so the real engine, the split-plane complex engine and the
    sparse engine all share one computation (and, transitively, one
    definition of the iteration order).
    """

    def __init__(self, n: int, T: int, C: int, chunk_offset: int = 0,
                 total_chunks: int | None = None):
        if total_chunks is None:
            total_chunks = T
        k = int(math.log2(C))
        assert C == 1 << k and k >= 1, "chunks must be power-of-2 sized, C >= 2"
        space = 1 << (n - 1)
        assert total_chunks * C == space, (total_chunks, C, space)
        self.k = k
        starts = (np.arange(T, dtype=np.uint64)
                  + np.uint64(chunk_offset)) * np.uint64(C)
        self.starts = starts

        # --- trace-time schedules (the "matrix-specific rebuild" analogue) ---
        sched = G.changed_bit_schedule(k)        # (C-1,) uniform changed bits
        # per-step signs need bits j and j+1 of g = start + w.  For w < C
        # these depend only on w, except bit k of the start enters at w = C/2.
        w_arr = np.arange(1, C, dtype=np.uint64)
        jj = sched.astype(np.uint64)
        bit_j = ((w_arr >> jj) ^ (w_arr >> (jj + np.uint64(1)))) & np.uint64(1)
        mid_mask = (jj + 1 == k)                           # only at w = C/2
        start_bit_k = ((starts >> np.uint64(k)) & np.uint64(1)).astype(np.int32)

        self.sched_j = jnp.asarray(sched)                  # (C-1,)
        self.base_bits = jnp.asarray(bit_j.astype(np.int32))    # (C-1,)
        self.mid_flags = jnp.asarray(mid_mask.astype(np.int32))  # (C-1,)
        self.w_parity = jnp.asarray((w_arr & np.uint64(1)).astype(np.int32))
        self.lane_bitk = jnp.asarray(start_bit_k)          # (T,)

        # tail step (w = C): per-chunk column and sign, host constants.
        g_tail = starts + np.uint64(C)
        tail_j = np.array([G.ctz(int(gt)) for gt in g_tail], dtype=np.int32)
        tail_sign = np.array([G.step_sign(int(gt)) for gt in g_tail],
                             dtype=np.int64)
        tail_live = g_tail <= np.uint64(space - 1)
        self.tail_j = np.where(tail_live, tail_j, 0)
        self.tail_sign = tail_sign
        self.tail_live = tail_live

    @property
    def scan_inputs(self):
        return (self.sched_j, self.base_bits, self.mid_flags, self.w_parity)

    def gray_bits(self, n: int, dtype):
        """(n, T) Gray-code bits of the chunk start steps."""
        return jnp.asarray(G.gray_bits_matrix(self.starts, n), dtype=dtype)

    def tail_columns(self, A):
        """Signed, liveness-masked tail column matrix A[:, tail_j] (n, T)."""
        return A[:, jnp.asarray(self.tail_j)] * jnp.asarray(
            (self.tail_sign * self.tail_live).astype(np.float64)
        ).astype(A.dtype)[None, :]


def rank1_chunk_init(A, x_base, Gbits):
    """Chunk state init (Alg. 3 lines 10-13) as fixed-order rank-1
    accumulation: a plain ``A @ Gbits`` matmul lets XLA pick the
    contraction split per program shape, which breaks the sharded/local
    bit-identity contract (see ``batched_values``)."""
    X0 = x_base[:, None]
    for j in range(A.shape[0]):
        X0 = X0 + A[:, j:j + 1] * Gbits[j:j + 1, :]                   # (n, T)
    return X0


def chunk_partial_sums(A, T: int, C: int, precision: str = "dq_acc",
                       chunk_offset: int = 0, total_chunks: int | None = None):
    """Per-chunk partial sums for chunks [chunk_offset, chunk_offset + T).

    This is the device-level workhorse reused by ``core.distributed``: each
    device calls it on its own chunk range.  Returns a TwoFloat of shape (T,)
    with ``partial[t] = sum_{w=1..C} (-1)^{g} prod_i x_{t,w}[i]`` -- the base
    (g == 0) term is NOT included (added once by the caller).  Requires
    C == 2^k with k >= 1 and chunk starts aligned to C.
    """
    n = A.shape[0]
    dtype = A.dtype
    S = _CEGSchedules(n, T, C, chunk_offset, total_chunks)
    X0 = rank1_chunk_init(A, nw_base_vector(A), S.gray_bits(n, dtype))
    sched_j, base_bits, mid_flags, w_parity = S.scan_inputs
    lane_bitk = S.lane_bitk
    Atail = S.tail_columns(A)
    tail_live = S.tail_live

    use_qq = precision == "qq"

    def tf_update(Xhi, Xlo, d):
        shi, slo = P.two_sum(Xhi, d)
        return P.fast_two_sum(shi, slo + Xlo)

    def product(Xhi, Xlo):
        if not use_qq:
            # sequential chain, not jnp.prod: fixed association order
            # regardless of the surrounding batch shape
            t = Xhi[0]
            for i in range(1, n):
                t = t * Xhi[i]
            return P.tf_from(t)
        t = P.TwoFloat(Xhi[0], Xlo[0])
        for i in range(1, n):
            t = P.tf_mul_tf(t, P.TwoFloat(Xhi[i], Xlo[i]))
        return t

    def init_acc():
        z = jnp.zeros((T,), dtype=dtype)
        return (z, z)

    def accum(acc, term: P.TwoFloat):
        """Fold a product term into the per-chunk partial accumulator."""
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term.hi)
            return (t.hi, t.lo)
        if precision == "dq_acc":
            t = P.tf_add_acc(P.TwoFloat(*acc), term.hi)
            return (t.hi, t.lo)
        if precision == "qq":
            t = P.tf_add_tf(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term.hi)
        return (acc[0] + term.hi, acc[1])  # dd

    def scan_body(carry, inputs):
        Xhi, Xlo, acc = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)               # (T,) in {0,1}
        s = (2 * sign_bits - 1).astype(dtype)              # (T,)
        d = A[:, col_j][:, None] * s[None, :]              # broadcast column
        if use_qq:
            Xhi, Xlo = tf_update(Xhi, Xlo, d)
        else:
            Xhi = Xhi + d
        prod = product(Xhi, Xlo)
        term = P.TwoFloat(jnp.where(par == 1, -prod.hi, prod.hi),
                          jnp.where(par == 1, -prod.lo, prod.lo))
        acc = accum(acc, term)
        return (Xhi, Xlo, acc), None

    Xlo0 = jnp.zeros_like(X0)
    carry = (X0, Xlo0, init_acc())
    carry, _ = jax.lax.scan(scan_body, carry,
                            (sched_j, base_bits, mid_flags, w_parity))
    Xhi, Xlo, acc = carry

    # tail step w = C (per-chunk column; sign/mask folded into Atail)
    if use_qq:
        Xhi, Xlo = tf_update(Xhi, Xlo, Atail)
    else:
        Xhi = Xhi + Atail
    prod = product(Xhi, Xlo)
    live = jnp.asarray(tail_live)
    neg = (C & 1) == 1  # (-1)^{g = start + C} == (-1)^C, chunk-uniform
    hi = jnp.where(live, -prod.hi if neg else prod.hi, jnp.zeros_like(prod.hi))
    lo = jnp.where(live, -prod.lo if neg else prod.lo, jnp.zeros_like(prod.lo))
    acc = accum(acc, P.TwoFloat(hi, lo))

    if precision == "kahan":
        return P.TwoFloat(acc[0], jnp.zeros_like(acc[0]))
    if precision == "dd":
        return P.TwoFloat(acc[0], jnp.zeros_like(acc[0]))
    return P.TwoFloat(acc[0], acc[1])


@partial(jax.jit, static_argnames=("num_chunks", "precision"))
def _chunked_jit(A, num_chunks: int, precision: str):
    n = A.shape[0]
    T, C, _ = chunk_geometry(n, num_chunks)
    partials = chunk_partial_sums(A, T, C, precision)
    # outer reduction always in twofloat (paper: quad outer sum), with the
    # same fixed-order tree/chain reductions as ``batched_values`` so the
    # scalar and batched engines stay bit-identical
    p_hi, p_lo = jax.lax.optimization_barrier((partials.hi, partials.lo))
    hi, e1 = tf_tree_sum(p_hi, p_lo)
    x_base = nw_base_vector(A)
    p0 = chain_prod(x_base)
    total = P.tf_add_acc(P.TwoFloat(hi, e1), p0)
    return P.tf_value(total) * _final_factor(n)


def perm_ryser_chunked(A, num_chunks: int = 4096, precision: str = "dq_acc"):
    """Faithful Alg. 3 (chunked parallel Ryser) with CEG-aligned chunks.

    Complex matrices run the split-plane engine as a B=1 batch program, so
    the scalar and batched complex paths share one trace (and one set of
    numerics) -- a ragged straggler served scalar is bit-identical to the
    same leaf served inside a bucket.
    """
    A = jnp.asarray(A)
    n = A.shape[0]
    if n == 1:
        return A[0, 0]
    if n == 2:
        return A[0, 0] * A[1, 1] + A[0, 1] * A[1, 0]
    if jnp.iscomplexobj(A):
        vr, vi = _batched_complex_jit(jnp.real(A)[None], jnp.imag(A)[None],
                                      num_chunks, precision)
        return (vr + 1j * vi)[0]
    return _chunked_jit(A, num_chunks, precision)


# ---------------------------------------------------------------------------
# Batched (vmapped Alg. 3): one device program for a stack of matrices
# ---------------------------------------------------------------------------

def chain_prod(X):
    """Fixed-order product over axis 0 (see ``tf_tree_sum``: ``jnp.prod``'s
    association is an XLA scheduling choice, not a contract)."""
    t = X[0]
    for i in range(1, X.shape[0]):
        t = t * X[i]
    return t


def chain_prod_complex(Xr, Xi):
    """Fixed-order complex product over axis 0 of split (re, im) planes.

    The explicit 4-mult/2-add recurrence -- the same one the Pallas complex
    kernel unrolls -- instead of complex-dtype ``*``: XLA's complex multiply
    lowering is free to fuse/reassociate per program shape, and the
    split-plane engines promise shard-shape-independent values.
    """
    pr, pi = Xr[0], Xi[0]
    for i in range(1, Xr.shape[0]):
        pr, pi = pr * Xr[i] - pi * Xi[i], pr * Xi[i] + pi * Xr[i]
    return pr, pi


def complex_precision(precision: str) -> str:
    """Effective precision mode for the complex engines.

    ``qq``'s twofloat inner product relies on Dekker splitting, which is
    real-only; complex runs it as ``kahan`` (the planner surfaces this as a
    ``qq->kahan`` downgrade tag).  Every split-plane entry point routes its
    precision through here so the jnp / distributed traces agree.
    """
    return "kahan" if precision == "qq" else precision


def tf_tree_sum(hi, lo):
    """Pairwise twofloat tree reduction with a FIXED association order.

    ``jnp.sum``'s reduction split is an XLA scheduling decision that
    depends on the surrounding program shape -- the same (T,) sum inside
    a (4, T) program and a (32, T) program can associate differently and
    diverge at the ulp level, and the batch-sharded path promises values
    bit-identical to the single-device batched engine for ANY shard
    shape.  So the cross-chunk reduction fixes its own order: halve and
    merge (hi, lo) pairs with the compensated ``tf_add_tf`` until one
    element is left (elementwise ops are order-free; the odd tail
    element is peeled per level, so any length works).  Each merge keeps
    its rounding error in the lo limb, which is also more accurate on
    cancellation-heavy inputs than summing hi and lo separately in plain
    f64 (the pre-PR outer reduction).  Returns scalar ``(hi, lo)``.
    """
    L = hi.shape[0]
    while L > 1:
        half = L // 2
        t = P.tf_add_tf(P.TwoFloat(hi[:half], lo[:half]),
                        P.TwoFloat(hi[half:2 * half], lo[half:2 * half]))
        if L == 2 * half:
            hi, lo = t.hi, t.lo
        else:
            hi = jnp.concatenate([t.hi, hi[2 * half:]], axis=0)
            lo = jnp.concatenate([t.lo, lo[2 * half:]], axis=0)
        L = (L + 1) // 2
    return hi[0], lo[0]


def batched_values(As, T: int, C: int, precision: str):
    """Traced (B,) permanents of a same-size stack, chunk geometry fixed.

    The single traced body shared by the jitted single-device program
    (``_batched_jit``) and the per-device body of the mesh-sharded batch
    path (``distributed.batch_permanents_on_mesh``) -- sharing the trace
    (plus ``tf_tree_sum``'s fixed-order cross-chunk reduction) is what makes
    the sharded values bit-identical to the local ones.
    """
    n = As.shape[1]
    parts = jax.vmap(lambda A: chunk_partial_sums(A, T, C, precision))(As)
    # pin the scan -> outer-reduction boundary: without the barrier XLA
    # fuses the reduction epilogue into the scan differently at different
    # batch shapes (fma/reassociation), breaking the bit-identity
    # contract between sharded and local execution.  (Applied outside the
    # vmap -- optimization_barrier has no batching rule on JAX 0.4.x.)
    p_hi, p_lo = jax.lax.optimization_barrier((parts.hi, parts.lo))

    def reduce_one(A, hi_t, lo_t):
        hi, e1 = tf_tree_sum(hi_t, lo_t)
        p0 = chain_prod(nw_base_vector(A))
        total = P.tf_add_acc(P.TwoFloat(hi, e1), p0)
        return P.tf_value(total) * _final_factor(n)

    return jax.vmap(reduce_one)(As, p_hi, p_lo)


@partial(jax.jit, static_argnames=("num_chunks", "precision"))
def _batched_jit(As, num_chunks: int, precision: str):
    n = As.shape[1]
    T, C, _ = chunk_geometry(n, num_chunks)
    return batched_values(As, T, C, precision)


# ---------------------------------------------------------------------------
# Split-plane complex engine: the matrix travels as explicit (re, im) planes
# ---------------------------------------------------------------------------

def chunk_partial_sums_complex(Ar, Ai, T: int, C: int,
                               precision: str = "dq_acc",
                               chunk_offset: int = 0,
                               total_chunks: int | None = None):
    """Split-plane complex Alg.-3 chunk partials; mirrors
    ``chunk_partial_sums`` with the matrix carried as (re, im) float planes.

    TPU VPUs have no complex dtype, so the whole stack shares the kernel's
    representation: the row-sum state is a plane pair (Xr, Xi), column
    updates are two real broadcasts, the product is the explicit complex
    chain recurrence (``chain_prod_complex``), and the partial sums are
    accumulated *per component* with the same compensated strategies as the
    real engine.  Returns ``(re, im, base)`` where ``re``/``im`` are
    TwoFloats of shape (T,) NOT including the base (g == 0) term, and
    ``base`` is the ``(p0_re, p0_im)`` scalar pair of that base term, read
    off lane 0's initial state (valid when ``chunk_offset == 0``; callers
    at nonzero offsets ignore it).  The base product deliberately shares
    the lane products' (n, T) vector pattern: a standalone (B,)-shaped
    complex chain compiles batch-shape-dependently (ulp drift between B=1
    and B=2 programs, observed on CPU), this pattern does not.  ``qq``
    runs as ``kahan`` (``complex_precision``).
    """
    precision = complex_precision(precision)
    n = Ar.shape[0]
    dtype = Ar.dtype
    S = _CEGSchedules(n, T, C, chunk_offset, total_chunks)
    Gbits = S.gray_bits(n, dtype)
    xr = nw_base_vector(Ar)
    xi = nw_base_vector(Ai)
    Xr = rank1_chunk_init(Ar, xr, Gbits)
    Xi = rank1_chunk_init(Ai, xi, Gbits)
    # lane 0 of chunk 0 starts at g = 0 (all Gray bits zero), so its
    # initial state IS the NW base vector and its product the base term
    b0r, b0i = chain_prod_complex(Xr, Xi)
    base = (b0r[0], b0i[0])
    lane_bitk = S.lane_bitk
    Atail_r = S.tail_columns(Ar)
    Atail_i = S.tail_columns(Ai)

    def accum(acc, term):
        """Per-component compensated accumulate (one real plane)."""
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "dq_acc":
            t = P.tf_add_acc(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term)
        return (acc[0] + term, acc[1])  # dd

    def fold(acc_r, acc_i, pr, pi, negate):
        tr = jnp.where(negate, -pr, pr)
        ti = jnp.where(negate, -pi, pi)
        return accum(acc_r, tr), accum(acc_i, ti)

    def scan_body(carry, inputs):
        Xr, Xi, acc_r, acc_i = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)               # (T,) in {0,1}
        s = (2 * sign_bits - 1).astype(dtype)              # (T,)
        Xr = Xr + Ar[:, col_j][:, None] * s[None, :]       # broadcast column
        Xi = Xi + Ai[:, col_j][:, None] * s[None, :]
        pr, pi = chain_prod_complex(Xr, Xi)
        acc_r, acc_i = fold(acc_r, acc_i, pr, pi, par == 1)
        return (Xr, Xi, acc_r, acc_i), None

    z = jnp.zeros((T,), dtype=dtype)
    carry = (Xr, Xi, (z, z), (z, z))
    carry, _ = jax.lax.scan(scan_body, carry, S.scan_inputs)
    Xr, Xi, acc_r, acc_i = carry

    # tail step w = C (per-chunk column; sign/mask folded into Atail)
    Xr = Xr + Atail_r
    Xi = Xi + Atail_i
    pr, pi = chain_prod_complex(Xr, Xi)
    live = jnp.asarray(S.tail_live)
    neg = (C & 1) == 1  # (-1)^{g = start + C} == (-1)^C, chunk-uniform
    zero = jnp.zeros_like(pr)
    pr = jnp.where(live, -pr if neg else pr, zero)
    pi = jnp.where(live, -pi if neg else pi, zero)
    acc_r = accum(acc_r, pr)
    acc_i = accum(acc_i, pi)

    if precision in ("kahan", "dd"):
        return (P.TwoFloat(acc_r[0], jnp.zeros_like(acc_r[0])),
                P.TwoFloat(acc_i[0], jnp.zeros_like(acc_i[0])), base)
    return (P.TwoFloat(acc_r[0], acc_r[1]),
            P.TwoFloat(acc_i[0], acc_i[1]), base)


def batched_values_complex(Ars, Ais, T: int, C: int, precision: str):
    """Traced (re, im) value pair for a (B, n, n) split-plane complex stack.

    The complex analogue of ``batched_values``: the single traced body
    shared by the jitted single-device program (``_batched_complex_jit``)
    and the per-device body of the mesh-sharded complex batch path
    (``distributed.batch_permanents_on_mesh``) -- one trace plus
    ``tf_tree_sum``'s fixed-order per-component reductions is what makes
    sharded complex values bit-identical to local ones, mirroring the real
    path's guarantee.  Returns ``(values_re, values_im)`` of shape (B,).
    """
    precision = complex_precision(precision)
    n = Ars.shape[1]

    def one(planes):
        ar, ai = planes
        parts_r, parts_i, (p0r, p0i) = chunk_partial_sums_complex(
            ar, ai, T, C, precision)
        # pin the scan -> outer-reduction boundary (see ``batched_values``;
        # legal here -- the body is not under vmap)
        rh, rl, ih, il, p0r, p0i = jax.lax.optimization_barrier(
            (parts_r.hi, parts_r.lo, parts_i.hi, parts_i.lo, p0r, p0i))
        hr, er = tf_tree_sum(rh, rl)
        hi_, ei = tf_tree_sum(ih, il)
        tot_r = P.tf_add_acc(P.TwoFloat(hr, er), p0r)
        tot_i = P.tf_add_acc(P.TwoFloat(hi_, ei), p0i)
        f = _final_factor(n)
        return P.tf_value(tot_r) * f, P.tf_value(tot_i) * f

    # lax.map, NOT vmap: vmap fuses across the batch axis and XLA's
    # fusion/contraction choices for the complex product chains vary with
    # the batch extent (ulp drift between B=1/B=2/B=5 programs, observed
    # on CPU) -- a scan-over-batch compiles ONE body program whatever B
    # is, so per-element values cannot depend on the batch or shard shape.
    # Per-matrix SIMD parallelism (the T chunk lanes) is unaffected; what
    # batching amortizes here is dispatch + compilation, as in PR 1.
    return jax.lax.map(one, (Ars, Ais))


@partial(jax.jit, static_argnames=("num_chunks", "precision"))
def _batched_complex_jit(Ars, Ais, num_chunks: int, precision: str):
    n = Ars.shape[1]
    T, C, _ = chunk_geometry(n, num_chunks)
    return batched_values_complex(Ars, Ais, T, C, precision)


def perm_ryser_batched(As, num_chunks: int = 4096, precision: str = "dq_acc"):
    """Permanents of a stack of same-size matrices in ONE device program.

    ``As`` is (B, n, n); returns (B,).  The chunked Alg.-3 body (all its
    host-side CEG schedules are batch-invariant: they depend only on
    (n, T, C)) is vmapped over the leading batch axis under a single jit,
    so a whole stack costs one dispatch and one compilation per (B, n)
    instead of B host round-trips -- the substrate for ``permanent_batch``
    and the batched serving loop.  Matches ``perm_ryser_chunked`` per
    element (identical chunk geometry and twofloat outer reduction).
    """
    As = jnp.asarray(As)
    if As.ndim != 3 or As.shape[1] != As.shape[2]:
        raise ValueError(f"(B, n, n) stack required, got {As.shape}")
    n = As.shape[1]
    if n == 1:
        return As[:, 0, 0]
    if n == 2:
        return (As[:, 0, 0] * As[:, 1, 1] + As[:, 0, 1] * As[:, 1, 0])
    if jnp.iscomplexobj(As):
        vr, vi = _batched_complex_jit(jnp.real(As), jnp.imag(As),
                                      num_chunks, precision)
        return vr + 1j * vi
    return _batched_jit(As, num_chunks, precision)
