"""Sparse preprocessing for permanents (paper Sec. 4): Dulmage-Mendelsohn
redundant-entry elimination and the Forbert-Marx compression recursion.

All host-side NumPy / pure Python (preprocessing cost is polynomial and
negligible next to the exponential kernel; paper: < 5s for every test
matrix).

* ``dm_eliminate``    -- Sec. 4.1: find a perfect matching (Hopcroft-Karp),
  orient matched edges row->col and the rest col->row, compute SCCs
  (iterative Tarjan), and zero every entry whose edge crosses SCCs -- such
  entries are in no perfect matching, hence contribute nothing.
* ``fm_decompose``    -- Sec. 4.2 / Alg. 4: while some row/column has
  ``minNnz <= 4``, apply D1 / D2 / D34 compression (Eq. 6), producing a
  list of (coefficient, matrix) leaves whose permanents sum to perm(A).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "hopcroft_karp",
    "strongly_connected_components",
    "dm_eliminate",
    "fm_decompose",
    "Leaf",
]


# ---------------------------------------------------------------------------
# Bipartite maximum matching.  Permanent matrices are tiny (n <= ~64), so
# Kuhn's augmenting-path algorithm (O(V * E)) is exact and more than fast
# enough; the paper's O(E sqrt(V)) Hopcroft-Karp bound is irrelevant at this
# scale (preprocessing < 5s even in the paper's own experiments).
# ---------------------------------------------------------------------------

def hopcroft_karp(adj: list[list[int]], n_left: int, n_right: int):
    """Maximum matching of a bipartite graph (Kuhn's algorithm).

    ``adj[u]`` lists right-vertices adjacent to left-vertex ``u``.
    Returns (match_l, match_r) with -1 for unmatched.
    """
    match_l = [-1] * n_left
    match_r = [-1] * n_right

    def try_augment(u: int, seen: list[bool]) -> bool:
        for v in adj[u]:
            if seen[v]:
                continue
            seen[v] = True
            if match_r[v] == -1 or try_augment(match_r[v], seen):
                match_l[u] = v
                match_r[v] = u
                return True
        return False

    # greedy warm start
    for u in range(n_left):
        for v in adj[u]:
            if match_r[v] == -1:
                match_l[u] = v
                match_r[v] = u
                break
    for u in range(n_left):
        if match_l[u] == -1:
            try_augment(u, [False] * n_right)
    return match_l, match_r


# ---------------------------------------------------------------------------
# Strongly connected components (iterative Tarjan), O(V + E)
# ---------------------------------------------------------------------------

def strongly_connected_components(adj: list[list[int]]) -> list[int]:
    """Returns comp[v] = SCC id for a directed graph given as adjacency lists."""
    n = len(adj)
    UNVISITED = -1
    index = [UNVISITED] * n
    low = [0] * n
    on_stack = [False] * n
    comp = [UNVISITED] * n
    stack: list[int] = []
    next_index = 0
    next_comp = 0

    for root in range(n):
        if index[root] != UNVISITED:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = next_index
                next_index += 1
                stack.append(v)
                on_stack[v] = True
            advanced = False
            while pi < len(adj[v]):
                w = adj[v][pi]
                pi += 1
                if index[w] == UNVISITED:
                    work[-1] = (v, pi)
                    work.append((w, 0))
                    advanced = True
                    break
                elif on_stack[w]:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            if low[v] == index[v]:
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp[w] = next_comp
                    if w == v:
                        break
                next_comp += 1
            work.pop()
            if work:
                u, _ = work[-1]
                low[u] = min(low[u], low[v])
    return comp


# ---------------------------------------------------------------------------
# Dulmage-Mendelsohn redundant-entry elimination (Sec. 4.1)
# ---------------------------------------------------------------------------

def dm_eliminate(A: np.ndarray):
    """Zero out entries that appear in no perfect matching.

    Returns (A', removed_count).  If the matrix has no perfect matching the
    permanent is 0 and A' is the zero matrix.
    """
    A = np.asarray(A)
    n = A.shape[0]
    mask = A != 0
    adj = [list(np.nonzero(mask[i])[0]) for i in range(n)]
    match_l, match_r = hopcroft_karp(adj, n, n)
    if any(m == -1 for m in match_l):
        return np.zeros_like(A), int(mask.sum())

    # directed bipartite graph: rows 0..n-1, cols n..2n-1
    # matched edges row -> col; unmatched col -> row
    dadj: list[list[int]] = [[] for _ in range(2 * n)]
    for i in range(n):
        for j in adj[i]:
            if match_l[i] == j:
                dadj[i].append(n + j)
            else:
                dadj[n + j].append(i)
    comp = strongly_connected_components(dadj)

    # an edge is in some perfect matching iff it is matched or lies on an
    # alternating cycle (endpoints in one SCC).  Matched edges always stay --
    # the paper's phrasing omits this, but e.g. for a triangular matrix every
    # matched (diagonal) edge is its own SCC pair yet obviously survives.
    out = A.copy()
    removed = 0
    for i in range(n):
        for j in adj[i]:
            if match_l[i] != j and comp[i] != comp[n + j]:
                out[i, j] = 0
                removed += 1
    return out, removed


# ---------------------------------------------------------------------------
# Forbert-Marx compression (Sec. 4.2 / Alg. 4)
# ---------------------------------------------------------------------------

@dataclass
class Leaf:
    """coef * perm(matrix) is one additive contribution to perm(A)."""
    coef: complex | float
    matrix: np.ndarray


def _min_degree(A: np.ndarray):
    mask = A != 0
    rdeg = mask.sum(axis=1)
    cdeg = mask.sum(axis=0)
    ri = int(np.argmin(rdeg))
    ci = int(np.argmin(cdeg))
    if rdeg[ri] <= cdeg[ci]:
        return "row", ri, int(rdeg[ri])
    return "col", ci, int(cdeg[ci])


def _compress_row(A: np.ndarray, i: int):
    """Apply Eq. 6 on row i (which must have 2..4 nonzeros, or 1 for D1).

    Returns list of (coef, matrix) children; each child is (n-1)x(n-1) or
    n x n per Alg. 4.
    """
    n = A.shape[0]
    js = np.nonzero(A[i] != 0)[0]
    deg = len(js)
    others = np.array([r for r in range(n) if r != i])
    if deg == 0:
        return []  # permanent contribution is zero
    if deg == 1:
        # D1: perm(A) = alpha * perm(A minus row i, col j)
        j = int(js[0])
        alpha = A[i, j]
        keep = np.array([c for c in range(n) if c != j])
        return [(alpha, A[np.ix_(others, keep)])]
    # pick the two first nonzeros as (alpha, beta)
    j1, j2 = int(js[0]), int(js[1])
    alpha, beta = A[i, j1], A[i, j2]
    keep = np.array([c for c in range(n) if c not in (j1, j2)])
    d = A[others][:, j1]          # column under alpha
    e = A[others][:, j2]          # column under beta
    B = A[np.ix_(others, keep)]
    merged = np.concatenate([(alpha * e + beta * d)[:, None], B], axis=1)
    if deg == 2:
        # D2: only the merged child survives (c == 0 in Eq. 6)
        return [(1.0, merged)]
    # D34: A' = A with alpha,beta zeroed (n x n) + merged ((n-1) x (n-1))
    Ap = A.copy()
    Ap[i, j1] = 0
    Ap[i, j2] = 0
    return [(1.0, Ap), (1.0, merged)]


def fm_decompose(A: np.ndarray, max_min_nnz: int = 4,
                 size_floor: int = 3) -> list[Leaf]:
    """Recursively compress A until every row/column has more than
    ``max_min_nnz`` nonzeros (paper: 4) or the matrix is tiny.

    Returns leaves [(coef, matrix)] with perm(A) = sum coef * perm(matrix).
    Matrices smaller than ``size_floor`` are folded into the coefficient
    directly (1x1 / 2x2 closed forms).
    """
    leaves: list[Leaf] = []
    stack: list[tuple[complex | float, np.ndarray]] = [(1.0, np.asarray(A))]
    while stack:
        coef, M = stack.pop()
        n = M.shape[0]
        if n == 0:
            leaves.append(Leaf(coef, np.ones((1, 1), dtype=M.dtype)))
            continue
        if n == 1:
            leaves.append(Leaf(coef * M[0, 0], np.ones((1, 1), dtype=M.dtype)))
            continue
        if n == 2:
            val = M[0, 0] * M[1, 1] + M[0, 1] * M[1, 0]
            leaves.append(Leaf(coef * val, np.ones((1, 1), dtype=M.dtype)))
            continue
        which, idx, deg = _min_degree(M)
        if deg == 0:
            continue  # zero row/col -> zero contribution
        if deg > max_min_nnz:
            leaves.append(Leaf(coef, M))
            continue
        W = M if which == "row" else M.T.copy()
        for ccoef, child in _compress_row(W, idx):
            child = child if which == "row" else child.T.copy()
            stack.append((coef * ccoef, child))
    return leaves
