"""Plan side of the SUperman plan/execute split (Alg. 4 as data).

The paper's dispatch pipeline -- type sniff -> DM elimination -> Forbert-
Marx compression -> dense/sparse routing -> size bucketing -- used to be
re-derived inside every ``permanent`` call.  This module runs it ONCE and
reifies the result as an :class:`ExecutionPlan`: an inspectable,
JSON-serializable description of exactly what the executor will do (which
leaves exist, how they route, which buckets share a device program, what
the Ryser-step cost estimate is) before any device work happens.

* :class:`SolverConfig` -- one frozen dataclass replacing the engine's
  kwarg sprawl (precision, backend, preprocessing, chunking, cache and
  queue policy).
* :class:`LeafTask` -- one post-DM/FM leaf: owner matrix index, additive
  coefficient, the leaf matrix, its dense/sparse route and a lazy
  content hash (the result-cache key material).
* :class:`ExecutionPlan` -- leaves + per-matrix summaries + size buckets
  + cost estimate.  ``plan == plan`` compares content fingerprints, so
  planning is checkably deterministic; ``to_json()`` serializes the
  dispatch decisions for logging or offline inspection.
* :func:`build_plan` -- the only constructor; ``PermanentSolver.plan`` /
  ``plan_batch`` and the legacy ``engine.permanent*`` wrappers all call
  it.

Planning is pure host-side NumPy: no jit, no device, no state.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, replace
from typing import Any

import numpy as np

from . import decompose as D
from .stepspace import Geometry, plan_slices

__all__ = [
    "DENSITY_SWITCH",
    "SolverConfig",
    "PermanentReport",
    "CampaignSpec",
    "LeafTask",
    "MatrixPlan",
    "ExecutionPlan",
    "build_plan",
]

# Alg. 4: dense kernel when nonzero density >= 30%
DENSITY_SWITCH = 0.30

ROUTE_DENSE = "dense"
ROUTE_SPARSE = "sparse"
ROUTE_INLINE = "inline"        # n <= 2 closed form, no device program
ROUTE_CAMPAIGN = "step_sharded"  # 2^{n-1} step space sliced across waves


@dataclass(frozen=True)
class SolverConfig:
    """Everything that used to be seven keyword arguments.

    Dispatch knobs (``precision``/``backend``/``preprocess``/``dm``/``fm``/
    ``num_chunks``) mirror the legacy ``permanent`` kwargs exactly; the
    remaining fields configure the stateful solver layers (result cache,
    async request queue).
    """
    precision: str = "dq_acc"        # dd | dq_fast | dq_acc | qq | kahan
    backend: str = "jnp"             # jnp|pallas|distributed|distributed_batch
    preprocess: bool = True          # master switch for DM + FM (Sec. 4)
    dm: bool | None = None           # override DM elimination
    fm: bool | None = None           # override Forbert-Marx compression
    num_chunks: int = 4096           # Alg. 3 tau (rounded to power of two)
    # Pallas kernel geometry resolution (config override > tuning-table
    # hit > kernel defaults).  ``geometry`` pins one explicit Geometry
    # for every kernel leaf; ``tuning_table`` points at an on-disk
    # ``repro.tune`` TuningTable resolved per (route, n, density, dtype,
    # precision) at plan time.  The *resolved* per-leaf geometry is part
    # of numeric identity (fingerprints, cache keys, checkpoints).
    geometry: Geometry | None = None
    tuning_table: str | None = None
    # Step-space campaign routing: a single leaf whose Ryser-step estimate
    # exceeds campaign_threshold re-routes to ROUTE_CAMPAIGN -- its step
    # space is cut into resumable slices (geometry recorded in the plan as
    # a CampaignSpec) and the executor's CampaignBackend runs them in
    # checkpointed waves.  None disables the route; negative forces it.
    campaign_threshold: float | None = float(2 ** 34)
    campaign_slices: int = 64        # plan_slices() slice-count target
    campaign_lanes: int = 1024       # plan_slices() chunk-count target
    campaign_checkpoint: str | None = None   # JobState .npz path
    campaign_max_waves: int | None = None    # pause (CampaignPaused) after
    cache: bool = True               # content-hash result cache on leaves
    cache_entries: int = 4096        # LRU capacity of the result cache
    queue_max_batch: int = 32        # flush a size bucket at this depth
    queue_max_delay_s: float = 0.05  # ... or when its oldest request ages out
    # Injected time source for the queue's deadline triggers (None =
    # time.monotonic).  Queue policy only -- it decides WHEN buckets
    # flush, never what is computed -- so it is excluded from plan
    # fingerprints, equality, and to_json (callables aren't JSON).
    clock: Any = field(default=None, compare=False, repr=False)

    def replace(self, **kw) -> "SolverConfig":
        return replace(self, **kw)

    def effective_precision(self, is_complex: bool) -> str:
        # qq's Dekker-split inner product is real-only; complex falls back
        # to kahan (engine contract since the scalar pipeline).  The plan
        # surfaces this as a ``qq->kahan`` precision_downgrade tag in the
        # dispatch tags and --plan-json, like backend downgrades.
        if is_complex and self.precision == "qq":
            return "kahan"
        return self.precision


@dataclass
class PermanentReport:
    """Everything the engine did for one matrix, for logging."""
    value: complex | float = 0.0
    n: int = 0
    nnz: int = 0
    density: float = 1.0
    dm_removed: int = 0
    fm_leaves: int = 0
    leaf_sizes: list[int] = field(default_factory=list)
    dispatch: list[str] = field(default_factory=list)
    precision: str = "dq_acc"
    backend: str = "jnp"


@dataclass(frozen=True)
class CampaignSpec:
    """The resumable step-space decomposition of one ROUTE_CAMPAIGN leaf.

    Fixed at plan time from the campaign knobs alone (never the runtime
    device count), so the same plan -- and any checkpoint it wrote -- can
    be executed or resumed under any mesh size.  ``total_slices *
    chunks_per_slice * chunk_size == 2^{n-1}``.
    """
    total_slices: int
    chunks_per_slice: int
    chunk_size: int
    precision: str                   # effective precision of the wave body
    backend: str                     # per-device slice body: jnp | pallas
    geometry: Geometry | None = None   # pallas wave-body kernel geometry

    def as_tuple(self) -> tuple:
        return (self.total_slices, self.chunks_per_slice, self.chunk_size,
                self.precision, self.backend,
                self.geometry.tag() if self.geometry else None)


@dataclass
class LeafTask:
    """coef * perm(matrix) is one additive contribution to owner's result."""
    owner: int                       # index into the planned matrix list
    coef: complex | float
    matrix: np.ndarray               # post-DM/FM leaf (float64 / complex128)
    route: str                       # dense | sparse | inline | step_sharded
    campaign: CampaignSpec | None = None   # set iff route == step_sharded
    # Resolved kernel geometry; set iff a Pallas kernel will produce this
    # leaf's value (config.backend == "pallas", n above the kernel floor).
    # None = the producing backend runs without geometry (jnp et al.), so
    # jnp-plan fingerprints and cache keys are untouched by tuning.
    geometry: Geometry | None = None
    _key: str | None = None

    @property
    def n(self) -> int:
        return self.matrix.shape[0]

    @property
    def key(self) -> str:
        """Content hash of the leaf matrix (result-cache key material)."""
        if self._key is None:
            h = hashlib.sha1()
            h.update(self.matrix.dtype.str.encode())
            h.update(str(self.matrix.shape).encode())
            h.update(np.ascontiguousarray(self.matrix).tobytes())
            self._key = h.hexdigest()
        return self._key


@dataclass
class MatrixPlan:
    """Per-input-matrix planning summary (feeds PermanentReport)."""
    index: int
    n: int
    nnz: int
    density: float
    dm_removed: int = 0
    fm_leaves: int = 0
    leaf_sizes: list[int] = field(default_factory=list)
    const: complex | float = 0.0     # folded 1x1/2x2 contributions


@dataclass
class ExecutionPlan:
    """The reified Alg.-4 dispatch for one matrix or one batch.

    ``leaves`` hold the device work; ``buckets`` group leaf indices by
    (route, n) -- in batched plans each multi-leaf bucket becomes ONE
    vmapped device program.  ``estimated_steps`` is the summed Ryser
    step-space size (n * 2^(n-1) per dense leaf, density-scaled for
    sparse), a dispatch-free cost proxy.
    """
    config: SolverConfig
    batched: bool                    # bucketed batch dispatch vs per-leaf
    is_complex: bool
    precision: str                   # effective (qq->kahan on complex)
    entries: list[MatrixPlan]
    leaves: list[LeafTask]
    buckets: dict[tuple[str, int], list[int]]
    estimated_steps: float
    # "qq->kahan" when the effective precision differs from the configured
    # one (complex qq); None otherwise.  Executor mirrors it into every
    # report's dispatch tags.
    precision_downgrade: str | None = None

    @property
    def num_matrices(self) -> int:
        return len(self.entries)

    # Every SolverConfig field is classified exactly once below, and
    # permlint rule PL005 rejects any new field that isn't: a field in
    # _NUMERIC_FIELDS perturbs what is computed (it participates in
    # ``fingerprint()``); a field in _POLICY_FIELDS only changes WHEN or
    # WHERE work is dispatched -- two plans differing only there execute
    # identically.  See docs/INVARIANTS.md (PL005).
    _NUMERIC_FIELDS = ("precision", "backend", "preprocess", "dm", "fm",
                       "num_chunks")
    # The campaign_* knobs steer routing and slice geometry; their effect
    # on numerics is already captured in the fingerprint body via each
    # leaf's route and ``CampaignSpec.as_tuple()``, so hashing the raw
    # knobs would only split identical executions.  cache/queue knobs and
    # the injected clock never touch device work at all.  geometry /
    # tuning_table follow the campaign precedent: they steer *which*
    # kernel geometry each leaf resolves to, and the resolved value is
    # hashed per leaf in the fingerprint body (LeafTask.geometry /
    # CampaignSpec.geometry) -- hashing the raw knobs (a table *path*)
    # would split plans whose resolved execution is identical.
    _POLICY_FIELDS = ("campaign_threshold", "campaign_slices",
                      "campaign_lanes", "campaign_checkpoint",
                      "campaign_max_waves", "geometry", "tuning_table",
                      "cache", "cache_entries",
                      "queue_max_batch", "queue_max_delay_s", "clock")

    def fingerprint(self) -> tuple:
        """Content identity: equal fingerprints -> identical execution.

        Only the numerics-affecting config fields participate; queue /
        cache policy knobs are deliberately excluded (see
        ``_NUMERIC_FIELDS``).
        """
        cfg = tuple((f, getattr(self.config, f))
                    for f in self._NUMERIC_FIELDS)
        return (
            cfg, self.batched, self.is_complex, self.precision,
            tuple((l.owner, complex(l.coef), l.route, l.key,
                   l.campaign.as_tuple() if l.campaign else None,
                   l.geometry.as_tuple() if l.geometry else None)
                  for l in self.leaves),
            tuple(sorted((r, n, tuple(idx))
                         for (r, n), idx in self.buckets.items())),
            tuple((e.index, e.n, e.nnz, e.dm_removed, e.fm_leaves,
                   complex(e.const)) for e in self.entries),
        )

    def __eq__(self, other) -> bool:
        if not isinstance(other, ExecutionPlan):
            return NotImplemented
        return self.fingerprint() == other.fingerprint()

    def to_json(self) -> dict:
        """JSON-serializable dispatch description (no matrix payloads)."""
        def _num(x):
            x = complex(x)
            return x.real if x.imag == 0 else [x.real, x.imag]
        cfg = asdict(self.config)
        cfg.pop("clock", None)       # queue-policy callable, not JSON
        return {
            "config": cfg,
            "batched": self.batched,
            "is_complex": self.is_complex,
            "precision": self.precision,
            "precision_downgrade": self.precision_downgrade,
            "matrices": [
                {"index": e.index, "n": e.n, "nnz": e.nnz,
                 "density": e.density, "dm_removed": e.dm_removed,
                 "fm_leaves": e.fm_leaves, "leaf_sizes": e.leaf_sizes,
                 "const": _num(e.const)}
                for e in self.entries],
            "leaves": [
                {"owner": l.owner, "n": l.n, "route": l.route,
                 "coef": _num(l.coef), "key": l.key,
                 "campaign": asdict(l.campaign) if l.campaign else None,
                 "geometry": l.geometry.tag() if l.geometry else None}
                for l in self.leaves],
            "buckets": [
                {"route": r, "n": n, "size": len(idx), "leaves": list(idx)}
                for (r, n), idx in sorted(self.buckets.items())],
            "estimated_steps": self.estimated_steps,
        }

    def json(self, **kw) -> str:
        return json.dumps(self.to_json(), **kw)

    def summary(self) -> str:
        """One-line human summary for CLIs and logs."""
        b = len(self.entries)
        routes = {}
        for l in self.leaves:
            routes[l.route] = routes.get(l.route, 0) + 1
        rtxt = " ".join(f"{r}={c}" for r, c in sorted(routes.items())) \
            or "const-only"
        ptxt = self.precision if self.precision_downgrade is None \
            else f"{self.precision}({self.precision_downgrade})"
        return (f"plan[{'batch' if self.batched else 'scalar'}] "
                f"matrices={b} leaves={len(self.leaves)} ({rtxt}) "
                f"buckets={len(self.buckets)} "
                f"est_steps={self.estimated_steps:.3g} "
                f"precision={ptxt} backend={self.config.backend}")


def _preprocess_leaves(work: np.ndarray, mplan: MatrixPlan,
                       do_dm: bool, do_fm: bool) -> list[D.Leaf]:
    """DM elimination + Forbert-Marx on one matrix (Sec. 4).

    Returns the leaf list; [] when DM zeroed the matrix (perm == 0).
    """
    n = work.shape[0]
    if do_dm and mplan.density < 0.5 and n >= 3:
        work, removed = D.dm_eliminate(work)
        mplan.dm_removed = removed
        if not work.any():
            mplan.fm_leaves = 0
            return []
    if do_fm and n >= 3:
        leaves = D.fm_decompose(work)
    else:
        leaves = [D.Leaf(1.0, work)]
    mplan.fm_leaves = len(leaves)
    mplan.leaf_sizes = [l.matrix.shape[0] for l in leaves]
    return leaves


def _density_of(m: np.ndarray) -> float:
    n = m.shape[0]
    return float((m != 0).sum()) / max(1, n * n)


def _route(m: np.ndarray, batched: bool) -> str:
    n = m.shape[0]
    if batched and n <= 2:
        return ROUTE_INLINE          # closed form, folded at execute time
    if n <= 2 or _density_of(m) >= DENSITY_SWITCH:
        return ROUTE_DENSE
    return ROUTE_SPARSE


# Below this n the pallas backend's _kernel_ok falls back to jnp (the
# kernel floor in core/executor.py) -- no kernel, no geometry identity.
_KERNEL_FLOOR_N = 4


def _resolve_geometry(config: SolverConfig, route: str, n: int,
                      density: float, dtype_str: str,
                      precision: str) -> Geometry | None:
    """config override > tuning-table hit > None (kernel defaults).

    The table import is lazy and only happens when a table is configured:
    the default planning path stays jax-free and file-I/O-free.
    """
    if config.geometry is not None:
        return config.geometry
    if config.tuning_table is None:
        return None
    from ..tune.table import resolve_geometry
    g = resolve_geometry(config.tuning_table, route, n, density,
                         dtype_str, precision)
    if g is None and route == ROUTE_CAMPAIGN:
        # campaign wave bodies fall back to the dense-route entry
        g = resolve_geometry(config.tuning_table, ROUTE_DENSE, n, density,
                             dtype_str, precision)
    return g


def _leaf_cost(m: np.ndarray, route: str) -> float:
    n = m.shape[0]
    if route == ROUTE_INLINE or n <= 2:
        return float(n)
    steps = n * float(2 ** (n - 1))
    if route == ROUTE_SPARSE:
        steps *= float((m != 0).sum()) / (n * n)
    return steps


def build_plan(mats: list[np.ndarray], config: SolverConfig, *,
               batched: bool) -> ExecutionPlan:
    """Run type sniff + DM/FM + routing + bucketing over ``mats``.

    ``batched=False`` preserves the scalar engine's per-leaf dispatch
    order exactly (every leaf is its own unit of work); ``batched=True``
    is the bucketed dispatcher shape (n <= 2 leaves fold inline, same-size
    same-route leaves share a bucket).
    """
    mats = [np.asarray(M) for M in mats]
    for M in mats:
        if M.ndim != 2 or M.shape[0] != M.shape[1]:
            raise ValueError(f"square matrices required, got {M.shape}")
    is_complex = any(np.iscomplexobj(M) for M in mats)
    precision = config.effective_precision(is_complex)
    dtype = np.complex128 if is_complex else np.float64
    do_dm = config.preprocess if config.dm is None else config.dm
    do_fm = config.preprocess if config.fm is None else config.fm

    entries: list[MatrixPlan] = []
    leaves: list[LeafTask] = []
    for i, M in enumerate(mats):
        n = M.shape[0]
        work = M.astype(dtype)
        nnz = int((work != 0).sum())
        mplan = MatrixPlan(index=i, n=n, nnz=nnz,
                           density=nnz / max(1, n * n))
        entries.append(mplan)
        for leaf in _preprocess_leaves(work, mplan, do_dm, do_fm):
            m = leaf.matrix
            if m.shape == (1, 1) and m[0, 0] == 1:
                mplan.const += leaf.coef
                continue
            leaves.append(LeafTask(owner=i, coef=leaf.coef, matrix=m,
                                   route=_route(m, batched)))

    # Campaign re-route: any dense/sparse leaf whose step-cost estimate
    # exceeds the threshold becomes a step_sharded leaf with a resumable
    # slice decomposition recorded in the plan.  The geometry depends only
    # on the plan knobs (never the runtime device count) -- that is what
    # makes the checkpoint elastic.
    thr = config.campaign_threshold
    if thr is not None:
        for leaf in leaves:
            if leaf.route in (ROUTE_DENSE, ROUTE_SPARSE) and \
                    _leaf_cost(leaf.matrix, leaf.route) > thr:
                ts, cps, C = plan_slices(
                    leaf.n, config.campaign_slices, 1,
                    config.campaign_lanes)
                leaf.route = ROUTE_CAMPAIGN
                cbackend = "pallas" if config.backend == "pallas" else "jnp"
                leaf.campaign = CampaignSpec(
                    total_slices=ts, chunks_per_slice=cps, chunk_size=C,
                    precision=precision,
                    backend=cbackend,
                    geometry=_resolve_geometry(
                        config, ROUTE_CAMPAIGN, leaf.n,
                        _density_of(leaf.matrix), leaf.matrix.dtype.str,
                        precision) if cbackend == "pallas" else None)
                leaf.geometry = None   # identity lives on the CampaignSpec

    # Kernel geometry resolution: only leaves a Pallas kernel will
    # actually produce carry one -- jnp/distributed plans (and tiny-n
    # fallback leaves) keep geometry out of their identity entirely.
    if config.backend == "pallas":
        for leaf in leaves:
            if leaf.route in (ROUTE_DENSE, ROUTE_SPARSE) and \
                    leaf.n >= _KERNEL_FLOOR_N:
                leaf.geometry = _resolve_geometry(
                    config, leaf.route, leaf.n, _density_of(leaf.matrix),
                    leaf.matrix.dtype.str, precision)

    buckets: dict[tuple[str, int], list[int]] = {}
    for j, leaf in enumerate(leaves):
        buckets.setdefault((leaf.route, leaf.n), []).append(j)
    cost = sum(_leaf_cost(l.matrix, l.route) for l in leaves)
    downgrade = None if precision == config.precision \
        else f"{config.precision}->{precision}"
    return ExecutionPlan(config=config, batched=batched,
                         is_complex=is_complex, precision=precision,
                         entries=entries, leaves=leaves, buckets=buckets,
                         estimated_steps=cost,
                         precision_downgrade=downgrade)
