"""Compensated floating-point arithmetic (paper Sec. 5).

The paper's precision ladder -- DD / DQ[30] / DQ[31] / QQ / Kahan[29] --
emulates quad precision with pairs of doubles on GPUs.  TPUs have no f64
hardware, so the framework makes the ladder *dtype-generic*: a ``twofloat``
``(hi, lo)`` pair doubles the mantissa of any base dtype:

    base f32  -> df32 (~49-bit mantissa)  -- the on-TPU "quad"
    base f64  -> df64 (~106-bit mantissa) -- the paper's emulated quad (CPU)

All primitives are branch-free jnp expressions usable inside Pallas kernels,
``lax.scan`` bodies, and ``shard_map`` regions.

References: Dekker 1971 [30] (fast/sloppy add, split, two_prod),
Knuth TwoSum (accurate add, the NVIDIA-forum variant [31]), Kahan 1965 [29].
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = [
    "two_sum",
    "fast_two_sum",
    "split",
    "two_prod",
    "TwoFloat",
    "tf_zero",
    "tf_from",
    "tf_add_fast",
    "tf_add_acc",
    "tf_add_tf",
    "tf_mul",
    "tf_mul_tf",
    "tf_neg",
    "tf_value",
    "kahan_init",
    "kahan_add",
    "PRECISION_MODES",
]


# ---------------------------------------------------------------------------
# Error-free transformations
# ---------------------------------------------------------------------------

def two_sum(a, b):
    """Knuth TwoSum: s + e == a + b exactly (6 flops, branch-free)."""
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e


def fast_two_sum(a, b):
    """Dekker FastTwoSum: requires |a| >= |b| (3 flops)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _split_const(dtype) -> float:
    """Dekker splitting constant 2^ceil(p/2) + 1 for p-bit mantissa."""
    p = jnp.finfo(dtype).nmant + 1  # mantissa bits incl. implicit
    return float((1 << ((p + 1) // 2)) + 1)


def split(a):
    """Dekker split: a == hi + lo with hi, lo having ~p/2 mantissa bits."""
    c = _split_const(a.dtype) * a
    hi = c - (c - a)
    lo = a - hi
    return hi, lo


def two_prod(a, b):
    """Dekker TwoProd via splitting (no FMA assumed): p + e == a * b."""
    p = a * b
    ah, al = split(a)
    bh, bl = split(b)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


# ---------------------------------------------------------------------------
# TwoFloat ("emulated quad" for any base dtype)
# ---------------------------------------------------------------------------

class TwoFloat(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray


def tf_zero(dtype=jnp.float64, shape=()) -> TwoFloat:
    z = jnp.zeros(shape, dtype=dtype)
    return TwoFloat(z, z)


def tf_from(x) -> TwoFloat:
    return TwoFloat(x, jnp.zeros_like(x))


def tf_add_fast(t: TwoFloat, b) -> TwoFloat:
    """t + b, Dekker-style sloppy add (the paper's DQ[30]; 10-flop class).

    Accurate when no catastrophic cancellation between hi parts; cheapest.
    """
    s, e = two_sum(t.hi, b)
    return TwoFloat(*fast_two_sum(s, e + t.lo))


def tf_add_acc(t: TwoFloat, b) -> TwoFloat:
    """t + b, accurate two_sum-based add (the paper's DQ[31]; 18-flop class)."""
    s, e = two_sum(t.hi, b)
    lo, e2 = two_sum(t.lo, e)
    hi, lo = fast_two_sum(s, lo)
    return TwoFloat(*fast_two_sum(hi, lo + e2))


def tf_add_tf(a: TwoFloat, b: TwoFloat) -> TwoFloat:
    """Full twofloat + twofloat add (used for the outer/global reduction)."""
    s, e = two_sum(a.hi, b.hi)
    e = e + a.lo + b.lo
    return TwoFloat(*fast_two_sum(s, e))


def tf_mul(t: TwoFloat, b) -> TwoFloat:
    """t * scalar b."""
    p, e = two_prod(t.hi, b)
    return TwoFloat(*fast_two_sum(p, e + t.lo * b))


def tf_mul_tf(a: TwoFloat, b: TwoFloat) -> TwoFloat:
    p, e = two_prod(a.hi, b.hi)
    e = e + (a.hi * b.lo + a.lo * b.hi)
    return TwoFloat(*fast_two_sum(p, e))


def tf_neg(t: TwoFloat) -> TwoFloat:
    return TwoFloat(-t.hi, -t.lo)


def tf_value(t: TwoFloat):
    return t.hi + t.lo


# ---------------------------------------------------------------------------
# Kahan compensated accumulation
# ---------------------------------------------------------------------------

def kahan_init(dtype=jnp.float64, shape=()):
    z = jnp.zeros(shape, dtype=dtype)
    return (z, z)


def kahan_add(acc, x):
    """acc = (sum, c); returns updated (sum, c) with compensation c."""
    s, c = acc
    y = x - c
    t = s + y
    c = (t - s) - y
    return (t, c)


# The engine-level precision modes mirroring the paper's Table 3 columns.
# inner-product dtype x partial-sum accumulation strategy.
PRECISION_MODES = ("dd", "dq_fast", "dq_acc", "qq", "kahan")
