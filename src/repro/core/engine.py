"""SUperman engine: legacy free-function facade over the plan/execute API.

The Alg.-4 pipeline (type sniff -> DM -> FM -> dense/sparse dispatch ->
precision/backend) now lives in the plan/execute split:

* ``core.planner``  -- ``SolverConfig`` + ``build_plan`` reify dispatch
  decisions as an inspectable, serializable ``ExecutionPlan``;
* ``core.executor`` -- backend strategy registry (``jnp`` / ``pallas`` /
  ``distributed``) + the bucket dispatcher;
* ``core.cache``    -- content-hash result cache on post-DM/FM leaves;
* ``core.solver``   -- the stateful ``PermanentSolver`` session (plan /
  execute / submit / flush).

``permanent(A, ...)`` and ``permanent_batch(As, ...)`` remain the
drop-in, stateless entry points: each call builds a one-shot plan and
executes it uncached, preserving the historical kwargs, return types,
report tags and numerics exactly.  New code that wants plan inspection,
cached re-execution, or the async request queue should hold a
``PermanentSolver`` instead.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from .executor import execute_plan
from .planner import (DENSITY_SWITCH, PermanentReport, SolverConfig,
                      build_plan)
from .solver import PermanentSolver

__all__ = ["permanent", "permanent_batch", "PermanentReport",
           "PermanentSolver", "SolverConfig", "DENSITY_SWITCH"]


def _config(precision: str, preprocess: bool, dm: bool | None,
            fm: bool | None, num_chunks: int, backend: str) -> SolverConfig:
    return SolverConfig(precision=precision, backend=backend,
                        preprocess=preprocess, dm=dm, fm=fm,
                        num_chunks=num_chunks, cache=False)


def permanent(A, *, precision: str = "dq_acc", preprocess: bool = True,
              dm: bool | None = None, fm: bool | None = None,
              num_chunks: int = 4096, backend: str = "jnp",
              distributed_ctx: Any | None = None,
              return_report: bool = False):
    """Compute perm(A) the SUperman way.

    Args:
      A: (n, n) array-like; real, complex or integer entries.
      precision: one of ``dd | dq_fast | dq_acc | qq | kahan`` (Table 3).
      preprocess: master switch for DM + FM preprocessing (Sec. 4).
      dm / fm: override the individual preprocessing stages.
      num_chunks: parallel chunk count (Alg. 3's tau); rounded to a power
        of two per the CEG load distribution.
      backend: ``jnp`` (chunked engines), ``pallas`` (TPU kernel,
        interpret-mode on CPU), ``distributed`` (mesh-wide shard_map; pass
        ``distributed_ctx`` from ``core.distributed.DistributedPermanent``).
      return_report: also return a PermanentReport.
    """
    A = np.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"square matrix required, got {A.shape}")
    cfg = _config(precision, preprocess, dm, fm, num_chunks, backend)
    plan = build_plan([A], cfg, batched=False)
    totals, reports, _ = execute_plan(plan, distributed_ctx=distributed_ctx)
    report = reports[0]
    report.value = complex(totals[0]) if plan.is_complex \
        else float(np.real(totals[0]))
    return (report.value, report) if return_report else report.value


def permanent_batch(As, *, precision: str = "dq_acc", preprocess: bool = True,
                    dm: bool | None = None, fm: bool | None = None,
                    num_chunks: int = 4096, backend: str = "jnp",
                    distributed_ctx: Any | None = None,
                    return_report: bool = False) -> np.ndarray:
    """Compute perm(A) for a whole stack of matrices in bucketed batches.

    The batched Alg.-4 dispatcher: one plan over the full request stack,
    every group of same-size leaves ONE vmapped device program instead of
    a host round-trip per matrix:

      * dtype is sniffed once for the whole batch (any complex entry
        promotes the batch to complex128; ``qq`` then falls back to kahan
        exactly like the scalar engine);
      * each matrix is DM/FM-preprocessed individually; the resulting
        leaves are tagged with their owner and *bucketed by size* (and
        dense/sparse route, same DENSITY_SWITCH rule as ``permanent``);
      * dense buckets run ``ryser.perm_ryser_batched`` (backend="jnp") or
        the batch-grid Pallas kernel (backend="pallas"); complex buckets
        are first-class on both -- split-plane engine / split-plane
        kernel -- with no downgrade;
      * sparse buckets run ``sparyser.perm_sparyser_batched`` (padded-CCS
        stacks, one jit per (n, maxdeg) bucket) or, under
        ``backend="pallas"`` with n >= 4, the batch-grid SpaRyser kernel
        (``kernels.ops.permanent_pallas_sparse_batched``) -- no more
        ``pallas->jnp`` sparse downgrade;
      * ragged stragglers -- buckets holding a single leaf -- fall back to
        the scalar per-leaf path, so mixed-size inputs still work.

    Args:
      As: (B, n, n) array-like, or a sequence of square matrices (sizes
        may differ -- bucketing handles ragged inputs).
      precision / preprocess / dm / fm / num_chunks: as in ``permanent``.
      backend: ``jnp``, ``pallas``, or ``distributed``/
        ``distributed_batch``: buckets (real or complex) are
        batch-axis-sharded over ``distributed_ctx``'s mesh, and downgrade
        to ``jnp`` with a ``distributed->jnp`` tag when no ctx is
        attached.
      distributed_ctx: a ``jax.sharding.Mesh`` (or an object with a
        ``.mesh``) for the distributed backends.
      return_report: also return a list of per-matrix PermanentReport.

    Returns:
      (B,) float64 array (complex128 when the batch is complex); with
      ``return_report`` a ``(values, reports)`` tuple.
    """
    mats = [np.asarray(M) for M in As]
    for M in mats:
        if M.ndim != 2 or M.shape[0] != M.shape[1]:
            raise ValueError(f"square matrices required, got {M.shape}")
    cfg = _config(precision, preprocess, dm, fm, num_chunks, backend)
    plan = build_plan(mats, cfg, batched=True)
    totals, reports, _ = execute_plan(plan, distributed_ctx=distributed_ctx)
    out = totals if plan.is_complex else np.real(totals)
    for i, r in enumerate(reports):
        r.value = complex(out[i]) if plan.is_complex else float(out[i])
    return (out, reports) if return_report else out
