"""SUperman engine: the paper's end-to-end dispatch (Alg. 4) as a library.

``permanent(A, ...)`` is the public entry point.  Pipeline:

  1. type sniffing        real / complex / binary-integer
  2. DM elimination       (Sec. 4.1, optional)   -- may zero the matrix
  3. Forbert-Marx         (Sec. 4.2, optional)   -- leaves with minNnz > 4
  4. per-leaf dispatch    density >= 30% -> dense ParRyser;
                          sparsity > 70% -> ParSpaRyser     (Alg. 4 l.12-15)
  5. precision mode       dd / dq_fast / dq_acc / qq / kahan (Sec. 5)
  6. backend              "jnp" chunked engines, "pallas" kernel, or
                          "distributed" (mesh shard_map, core.distributed)

Complex matrices run the dense path with native complex dtype (twofloat
compensation is applied per real/imaginary component by the complex-safe
accumulators; `qq` is unsupported for complex and falls back to kahan).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from . import decompose as D
from . import ryser as R
from . import sparyser as S

__all__ = ["permanent", "PermanentReport", "DENSITY_SWITCH"]

# Alg. 4: dense kernel when nonzero density >= 30%
DENSITY_SWITCH = 0.30


@dataclass
class PermanentReport:
    """Everything the engine did, for logging / EXPERIMENTS.md."""
    value: complex | float = 0.0
    n: int = 0
    nnz: int = 0
    density: float = 1.0
    dm_removed: int = 0
    fm_leaves: int = 0
    leaf_sizes: list[int] = field(default_factory=list)
    dispatch: list[str] = field(default_factory=list)
    precision: str = "dq_acc"
    backend: str = "jnp"


def _leaf_value(M: np.ndarray, precision: str, num_chunks: int,
                backend: str, report: PermanentReport,
                distributed_ctx: Any | None):
    n = M.shape[0]
    density = float((M != 0).sum()) / max(1, n * n)
    if n <= 2 or density >= DENSITY_SWITCH:
        report.dispatch.append(f"dense(n={n})")
        if backend == "pallas" and n >= 4 and not np.iscomplexobj(M):
            from ..kernels import ops as K
            return complex(K.permanent_pallas(M, precision=precision)).real
        if backend == "distributed" and distributed_ctx is not None:
            return distributed_ctx.permanent(M, precision=precision)
        val = R.perm_ryser_chunked(M, num_chunks=num_chunks,
                                   precision=precision)
        return np.asarray(val).item()
    report.dispatch.append(f"sparse(n={n})")
    sp = S.SparseMatrix.from_dense(M)
    return S.perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                   precision=precision)


def permanent(A, *, precision: str = "dq_acc", preprocess: bool = True,
              dm: bool | None = None, fm: bool | None = None,
              num_chunks: int = 4096, backend: str = "jnp",
              distributed_ctx: Any | None = None,
              return_report: bool = False):
    """Compute perm(A) the SUperman way.

    Args:
      A: (n, n) array-like; real, complex or integer entries.
      precision: one of ``dd | dq_fast | dq_acc | qq | kahan`` (Table 3).
      preprocess: master switch for DM + FM preprocessing (Sec. 4).
      dm / fm: override the individual preprocessing stages.
      num_chunks: parallel chunk count (Alg. 3's tau); rounded to a power
        of two per the CEG load distribution.
      backend: ``jnp`` (chunked engines), ``pallas`` (TPU kernel,
        interpret-mode on CPU), ``distributed`` (mesh-wide shard_map; pass
        ``distributed_ctx`` from ``core.distributed.DistributedPermanent``).
      return_report: also return a PermanentReport.
    """
    A = np.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"square matrix required, got {A.shape}")
    n = A.shape[0]
    is_complex = np.iscomplexobj(A)
    if is_complex and precision == "qq":
        precision = "kahan"
    work = A.astype(np.complex128 if is_complex else np.float64)

    report = PermanentReport(n=n, nnz=int((work != 0).sum()),
                             precision=precision, backend=backend)
    report.density = report.nnz / max(1, n * n)

    do_dm = preprocess if dm is None else dm
    do_fm = preprocess if fm is None else fm

    if do_dm and report.density < 0.5 and n >= 3:
        work, removed = D.dm_eliminate(work)
        report.dm_removed = removed
        if not work.any():
            report.value = 0.0 + 0.0j if is_complex else 0.0
            return (report.value, report) if return_report else report.value

    if do_fm and n >= 3:
        leaves = D.fm_decompose(work)
    else:
        leaves = [D.Leaf(1.0, work)]
    report.fm_leaves = len(leaves)
    report.leaf_sizes = [l.matrix.shape[0] for l in leaves]

    total = 0.0 + 0.0j if is_complex else 0.0
    for leaf in leaves:
        if leaf.matrix.shape == (1, 1) and leaf.matrix[0, 0] == 1:
            total += leaf.coef
            continue
        total += leaf.coef * _leaf_value(leaf.matrix, precision, num_chunks,
                                         backend, report, distributed_ctx)
    report.value = total if is_complex else float(np.real(total))
    return (report.value, report) if return_report else report.value
