"""SUperman engine: the paper's end-to-end dispatch (Alg. 4) as a library.

``permanent(A, ...)`` is the public scalar entry point.  Pipeline:

  1. type sniffing        real / complex / binary-integer
  2. DM elimination       (Sec. 4.1, optional)   -- may zero the matrix
  3. Forbert-Marx         (Sec. 4.2, optional)   -- leaves with minNnz > 4
  4. per-leaf dispatch    density >= 30% -> dense ParRyser;
                          sparsity > 70% -> ParSpaRyser     (Alg. 4 l.12-15)
  5. precision mode       dd / dq_fast / dq_acc / qq / kahan (Sec. 5)
  6. backend              "jnp" chunked engines, "pallas" kernel, or
                          "distributed" (mesh shard_map, core.distributed)

``permanent_batch(As, ...)`` is the batched entry point: it runs the same
Alg.-4 pipeline over a whole request stack, but instead of one host
round-trip per matrix it sniffs the dtype once, preprocesses every matrix,
*buckets the resulting leaves by size*, and dispatches each bucket through
one vmapped device program (``ryser.perm_ryser_batched`` /
``sparyser.perm_sparyser_batched`` / the batch-grid Pallas kernel) --
ragged stragglers (singleton buckets) fall back to the scalar path.  This
is the throughput shape serving needs: boson-sampling pipelines ask for
permanents of thousands of submatrices, and the paper's headline number is
perms/sec, not per-call latency.

Complex matrices run the dense path with native complex dtype (twofloat
compensation is applied per real/imaginary component by the complex-safe
accumulators; `qq` is unsupported for complex and falls back to kahan).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from . import decompose as D
from . import ryser as R
from . import sparyser as S

__all__ = ["permanent", "permanent_batch", "PermanentReport",
           "DENSITY_SWITCH"]

# Alg. 4: dense kernel when nonzero density >= 30%
DENSITY_SWITCH = 0.30


@dataclass
class PermanentReport:
    """Everything the engine did, for logging / EXPERIMENTS.md."""
    value: complex | float = 0.0
    n: int = 0
    nnz: int = 0
    density: float = 1.0
    dm_removed: int = 0
    fm_leaves: int = 0
    leaf_sizes: list[int] = field(default_factory=list)
    dispatch: list[str] = field(default_factory=list)
    precision: str = "dq_acc"
    backend: str = "jnp"


def _leaf_value(M: np.ndarray, precision: str, num_chunks: int,
                backend: str, report: PermanentReport,
                distributed_ctx: Any | None):
    n = M.shape[0]
    density = float((M != 0).sum()) / max(1, n * n)
    if n <= 2 or density >= DENSITY_SWITCH:
        report.dispatch.append(f"dense(n={n})")
        if backend == "pallas" and n >= 4 and not np.iscomplexobj(M):
            from ..kernels import ops as K
            return complex(K.permanent_pallas(M, precision=precision)).real
        if backend == "distributed" and distributed_ctx is not None:
            return distributed_ctx.permanent(M, precision=precision)
        val = R.perm_ryser_chunked(M, num_chunks=num_chunks,
                                   precision=precision)
        return np.asarray(val).item()
    report.dispatch.append(f"sparse(n={n})")
    sp = S.SparseMatrix.from_dense(M)
    return S.perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                   precision=precision)


def _preprocess_leaves(work: np.ndarray, report: PermanentReport,
                       do_dm: bool, do_fm: bool):
    """DM elimination + Forbert-Marx on one matrix (Sec. 4).

    Returns the leaf list; [] when DM zeroed the matrix (perm == 0).
    """
    n = work.shape[0]
    if do_dm and report.density < 0.5 and n >= 3:
        work, removed = D.dm_eliminate(work)
        report.dm_removed = removed
        if not work.any():
            report.fm_leaves = 0
            return []
    if do_fm and n >= 3:
        leaves = D.fm_decompose(work)
    else:
        leaves = [D.Leaf(1.0, work)]
    report.fm_leaves = len(leaves)
    report.leaf_sizes = [l.matrix.shape[0] for l in leaves]
    return leaves


def permanent(A, *, precision: str = "dq_acc", preprocess: bool = True,
              dm: bool | None = None, fm: bool | None = None,
              num_chunks: int = 4096, backend: str = "jnp",
              distributed_ctx: Any | None = None,
              return_report: bool = False):
    """Compute perm(A) the SUperman way.

    Args:
      A: (n, n) array-like; real, complex or integer entries.
      precision: one of ``dd | dq_fast | dq_acc | qq | kahan`` (Table 3).
      preprocess: master switch for DM + FM preprocessing (Sec. 4).
      dm / fm: override the individual preprocessing stages.
      num_chunks: parallel chunk count (Alg. 3's tau); rounded to a power
        of two per the CEG load distribution.
      backend: ``jnp`` (chunked engines), ``pallas`` (TPU kernel,
        interpret-mode on CPU), ``distributed`` (mesh-wide shard_map; pass
        ``distributed_ctx`` from ``core.distributed.DistributedPermanent``).
      return_report: also return a PermanentReport.
    """
    A = np.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"square matrix required, got {A.shape}")
    n = A.shape[0]
    is_complex = np.iscomplexobj(A)
    if is_complex and precision == "qq":
        precision = "kahan"
    work = A.astype(np.complex128 if is_complex else np.float64)

    report = PermanentReport(n=n, nnz=int((work != 0).sum()),
                             precision=precision, backend=backend)
    report.density = report.nnz / max(1, n * n)

    do_dm = preprocess if dm is None else dm
    do_fm = preprocess if fm is None else fm

    leaves = _preprocess_leaves(work, report, do_dm, do_fm)
    if not leaves:
        report.value = 0.0 + 0.0j if is_complex else 0.0
        return (report.value, report) if return_report else report.value

    total = 0.0 + 0.0j if is_complex else 0.0
    for leaf in leaves:
        if leaf.matrix.shape == (1, 1) and leaf.matrix[0, 0] == 1:
            total += leaf.coef
            continue
        total += leaf.coef * _leaf_value(leaf.matrix, precision, num_chunks,
                                         backend, report, distributed_ctx)
    report.value = total if is_complex else float(np.real(total))
    return (report.value, report) if return_report else report.value


def permanent_batch(As, *, precision: str = "dq_acc", preprocess: bool = True,
                    dm: bool | None = None, fm: bool | None = None,
                    num_chunks: int = 4096, backend: str = "jnp",
                    return_report: bool = False) -> np.ndarray:
    """Compute perm(A) for a whole stack of matrices in bucketed batches.

    The batched Alg.-4 dispatcher: the paper's pipeline (type sniff -> DM ->
    FM -> dense/sparse dispatch) runs once over the full request stack, and
    every group of same-size leaves becomes ONE vmapped device program
    instead of a host round-trip per matrix:

      * dtype is sniffed once for the whole batch (any complex entry
        promotes the batch to complex128; ``qq`` then falls back to kahan
        exactly like the scalar engine);
      * each matrix is DM/FM-preprocessed individually; the resulting
        leaves are tagged with their owner and *bucketed by size* (and
        dense/sparse route, same DENSITY_SWITCH rule as ``permanent``);
      * dense buckets run ``ryser.perm_ryser_batched`` (backend="jnp") or
        the batch-grid Pallas kernel (backend="pallas", real only --
        complex buckets always take the vmapped jnp path);
      * sparse buckets run ``sparyser.perm_sparyser_batched`` (padded-CCS
        stacks, one jit per (n, maxdeg) bucket);
      * ragged stragglers -- buckets holding a single leaf -- fall back to
        the scalar per-leaf path, so mixed-size inputs still work.

    Args:
      As: (B, n, n) array-like, or a sequence of square matrices (sizes
        may differ -- bucketing handles ragged inputs).
      precision / preprocess / dm / fm / num_chunks: as in ``permanent``.
      backend: ``jnp`` or ``pallas`` (``distributed`` is scalar-only; use
        ``core.distributed`` directly for mesh-wide single permanents).
      return_report: also return a list of per-matrix PermanentReport.

    Returns:
      (B,) float64 array (complex128 when the batch is complex); with
      ``return_report`` a ``(values, reports)`` tuple.
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"permanent_batch supports jnp|pallas, got {backend}")
    mats = [np.asarray(M) for M in As]
    for M in mats:
        if M.ndim != 2 or M.shape[0] != M.shape[1]:
            raise ValueError(f"square matrices required, got {M.shape}")
    B = len(mats)
    is_complex = any(np.iscomplexobj(M) for M in mats)
    if is_complex and precision == "qq":
        precision = "kahan"
    dtype = np.complex128 if is_complex else np.float64
    do_dm = preprocess if dm is None else dm
    do_fm = preprocess if fm is None else fm

    totals = np.zeros(B, dtype=np.complex128)
    reports: list[PermanentReport] = []
    dense_buckets: dict[int, list] = {}   # n -> [(owner, coef, matrix)]
    sparse_buckets: dict[int, list] = {}

    for i, M in enumerate(mats):
        n = M.shape[0]
        work = M.astype(dtype)
        report = PermanentReport(n=n, nnz=int((work != 0).sum()),
                                 precision=precision, backend=backend)
        report.density = report.nnz / max(1, n * n)
        reports.append(report)
        for leaf in _preprocess_leaves(work, report, do_dm, do_fm):
            m = leaf.matrix
            ln = m.shape[0]
            if m.shape == (1, 1) and m[0, 0] == 1:
                totals[i] += leaf.coef
                continue
            if ln <= 2:
                report.dispatch.append(f"dense(n={ln})")
                v = m[0, 0] if ln == 1 else \
                    m[0, 0] * m[1, 1] + m[0, 1] * m[1, 0]
                totals[i] += leaf.coef * v
                continue
            density = float((m != 0).sum()) / (ln * ln)
            bucket = dense_buckets if density >= DENSITY_SWITCH \
                else sparse_buckets
            bucket.setdefault(ln, []).append((i, leaf.coef, m))

    for ln, items in sorted(dense_buckets.items()):
        if len(items) == 1:                      # ragged straggler: scalar
            i, coef, m = items[0]
            totals[i] += coef * complex(_leaf_value(
                m, precision, num_chunks, backend, reports[i], None))
            continue
        tag = f"dense_batch(n={ln},b={len(items)})"
        stack = np.stack([m for _, _, m in items])
        if backend == "pallas" and not is_complex and ln >= 4:
            from ..kernels import ops as K
            vals = np.asarray(K.permanent_pallas_batched(
                stack, precision=precision))
        else:
            vals = np.asarray(R.perm_ryser_batched(
                stack, num_chunks=num_chunks, precision=precision))
        for (i, coef, _), v in zip(items, vals):
            reports[i].dispatch.append(tag)
            totals[i] += coef * v

    for ln, items in sorted(sparse_buckets.items()):
        if len(items) == 1:
            i, coef, m = items[0]
            totals[i] += coef * complex(_leaf_value(
                m, precision, num_chunks, backend, reports[i], None))
            continue
        tag = f"sparse_batch(n={ln},b={len(items)})"
        sps = [S.SparseMatrix.from_dense(m) for _, _, m in items]
        vals = S.perm_sparyser_batched(sps, num_chunks=num_chunks,
                                       precision=precision)
        for (i, coef, _), v in zip(items, vals):
            reports[i].dispatch.append(tag)
            totals[i] += coef * v

    out = totals if is_complex else np.real(totals)
    for i in range(B):
        reports[i].value = complex(out[i]) if is_complex else float(out[i])
    return (out, reports) if return_report else out
