"""Content-hash result cache for permanent leaves (ROADMAP: result caching).

Permanents are pure functions of the matrix, and boson-sampling pipelines
resample overlapping submatrices -- after DM/FM preprocessing the same
leaf shows up over and over.  :class:`ResultCache` memoizes leaf results
keyed on (content hash, route, precision, backend, num_chunks), so a
repeated leaf skips the device entirely.

The cache is a bounded LRU (``OrderedDict`` move-to-end on hit) with
hit/miss accounting surfaced through :meth:`stats`; ``PermanentSolver``
owns one instance per session and the executor consults it per leaf.
"""

from __future__ import annotations

from collections import OrderedDict

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU mapping leaf cache keys to Python scalar results."""

    def __init__(self, max_entries: int = 4096):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._data: OrderedDict[tuple, complex | float] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key(leaf_key: str, route: str, precision: str, backend: str,
            num_chunks: int, dtype: str = "<f8",
            geometry: str = "-") -> tuple:
        """Full cache key: content hash + every numerics-affecting knob.

        Precision mode, backend and chunk geometry all perturb the
        floating-point result at the ulp level, so they are part of the
        identity -- a ``dd`` result must never satisfy a ``qq`` lookup.
        ``dtype`` (the leaf's numpy dtype string) is carried explicitly as
        well: the content hash already mixes it in, but the key must stay
        collision-free even if a future leaf hash drops the dtype -- a
        float64 leaf and a complex128 leaf whose imaginary part is all
        zeros are different computations (real engine vs split-plane
        engine) and must never share an entry.  ``precision`` is the
        plan's *effective* precision, so a complex ``qq`` plan stores and
        finds its values under ``kahan``.  ``geometry`` is the resolved
        Pallas kernel geometry tag (``Geometry.tag()``) when a kernel
        produced the value -- geometry changes the fixed-order reduction
        shape, so two geometries must never share an entry -- and the
        ``"-"`` sentinel for geometry-free producers (jnp et al.), so
        tuning never splits or contaminates jnp-produced values.
        """
        return (leaf_key, route, precision, backend, num_chunks, dtype,
                geometry)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: tuple) -> bool:
        return key in self._data

    def get(self, key: tuple):
        """Return the cached scalar or None (and count the hit/miss)."""
        try:
            val = self._data[key]
        except KeyError:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key: tuple, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._data.clear()

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0}
