"""Exact oracles for matrix permanents (host-side, NumPy / Python bigints).

These are the ground truth every other layer (jnp engines, Pallas kernels,
distributed runtime) is validated against:

* ``perm_definition``   -- O(n * n!) permutation expansion, n <= 11.
* ``perm_ryser_exact``  -- O(n * 2^n) Ryser over Python scalars; exact for
  integer matrices (bigints), high-accuracy (math.fsum) for floats.
* ``perm_bigint``       -- exact integer permanent for integer matrices.
* ``all_ones_permanent``-- closed form n! * a^n for constant matrices
  (the paper's Sec. 5 precision-test family).
"""

from __future__ import annotations

import math
from fractions import Fraction
from itertools import permutations

import numpy as np

__all__ = [
    "perm_definition",
    "perm_bigint",
    "perm_ryser_exact",
    "all_ones_permanent",
]


def perm_definition(A) -> complex | float:
    """Permanent via the definition. Exact control for small n (<= ~11)."""
    A = np.asarray(A)
    n = A.shape[0]
    assert A.shape == (n, n)
    total = 0
    for sigma in permutations(range(n)):
        p = 1
        for i in range(n):
            p = p * A[i, sigma[i]].item()
        total += p
    return total


def perm_bigint(A) -> int:
    """Exact permanent of an integer matrix via Ryser over Python bigints.

    Uses the plain inclusion-exclusion form (Eq. 2) with Gray-code updates;
    no floating point anywhere, so the result is exact for any magnitude.
    """
    A = np.asarray(A)
    n = A.shape[0]
    ai = [[int(A[i, j]) for j in range(n)] for i in range(n)]
    # Gray iteration over non-empty subsets of all n columns (Eq. 2).
    x = [0] * n
    total = 0
    for g in range(1, 1 << n):
        low = g & -g
        j = low.bit_length() - 1
        s = 1 if (g ^ (g >> 1)) & low else -1
        for i in range(n):
            x[i] += s * ai[i][j]
        prod = 1
        for i in range(n):
            prod *= x[i]
        total += (-1 if (g & 1) else 1) * prod
    return ((-1) ** n) * total


def perm_ryser_exact(A):
    """High-accuracy Ryser for real/complex floats using Fraction arithmetic
    when the input is exactly representable, falling back to float with
    math.fsum-style compensated accumulation.

    For float inputs the entries are lifted to Fractions (floats are exact
    binary rationals), so the returned value is the *exact* permanent of the
    stored matrix, rounded once at the end.
    """
    A = np.asarray(A)
    n = A.shape[0]
    if np.iscomplexobj(A):
        # complex permanent is not separable; do full complex Fraction math
        ar = [[Fraction(float(A[i, j].real)) for j in range(n)] for i in range(n)]
        ai = [[Fraction(float(A[i, j].imag)) for j in range(n)] for i in range(n)]
        xr = [Fraction(0)] * n
        xi = [Fraction(0)] * n
        tr, ti = Fraction(0), Fraction(0)
        for g in range(1, 1 << n):
            low = g & -g
            j = low.bit_length() - 1
            s = 1 if (g ^ (g >> 1)) & low else -1
            for i in range(n):
                xr[i] += s * ar[i][j]
                xi[i] += s * ai[i][j]
            pr, pi = Fraction(1), Fraction(0)
            for i in range(n):
                pr, pi = pr * xr[i] - pi * xi[i], pr * xi[i] + pi * xr[i]
            sign = -1 if (g & 1) else 1
            tr += sign * pr
            ti += sign * pi
        sgn = (-1) ** n
        return complex(float(sgn * tr), float(sgn * ti))

    af = [[Fraction(float(A[i, j])) for j in range(n)] for i in range(n)]
    x = [Fraction(0)] * n
    total = Fraction(0)
    for g in range(1, 1 << n):
        low = g & -g
        j = low.bit_length() - 1
        s = 1 if (g ^ (g >> 1)) & low else -1
        for i in range(n):
            x[i] += s * af[i][j]
        prod = Fraction(1)
        for i in range(n):
            prod *= x[i]
        total += (-1 if (g & 1) else 1) * prod
    return float((-1) ** n * total)


def all_ones_permanent(n: int, a: float = 1.0):
    """perm of the n x n constant matrix with entries a: n! * a^n.

    Returned as a Python float via exact integer/Fraction math (may overflow
    to inf for very large n; callers compare in log space then).
    """
    return float(math.factorial(n) * Fraction(float(a)) ** n)
