"""`PermanentSolver`: the stateful plan/execute session object.

The paper's Alg. 4 is a pipeline; this module exposes it as a lifecycle
instead of a free function:

    config = SolverConfig(precision="dq_acc", backend="jnp")
    solver = PermanentSolver(config)

    plan = solver.plan(A)            # type sniff + DM/FM + routing; no
    print(plan.summary())            # device work -- inspect or serialize
    value = solver.execute(plan)     # dispatch through the backend registry

    plans = solver.plan_batch(As)    # bucketed batch plan ...
    values = solver.execute(plans)   # ... one device program per bucket

**Plan** (`plan` / `plan_batch`) is pure and deterministic: equal inputs
produce ``==`` plans, and ``plan.to_json()`` serializes every dispatch
decision (leaves, routes, buckets, cost estimate) for offline inspection.
**Execute** walks the plan through ``core.executor``'s backend registry
and the solver's content-hash :class:`~repro.core.cache.ResultCache` --
repeated post-DM/FM leaves (boson-sampling pipelines resample overlapping
submatrices) skip the device entirely; ``solver.stats()`` reports the
hit/miss and dispatch accounting.

**Queue** (`submit` / `flush` / `poll`) decouples request arrival from
batch dispatch: submitted matrices accumulate in size-keyed buckets and
are flushed through a bucketed batch plan when a bucket reaches
``config.queue_max_batch`` (size trigger) or its oldest request ages past
``config.queue_max_delay_s`` (deadline trigger, checked on ``submit``/
``poll``).  ``submit`` returns a :class:`PermanentRequest` future whose
``result()`` forces a flush if needed -- mixed traffic fills batches
instead of fragmenting them (ROADMAP: async request queue).

The legacy ``engine.permanent`` / ``engine.permanent_batch`` free
functions are thin stateless wrappers over this machinery.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Sequence

import numpy as np

from .cache import ResultCache
from .executor import ExecStats, LeafTiming, execute_plan
from .planner import ExecutionPlan, PermanentReport, SolverConfig, build_plan

__all__ = ["PermanentSolver", "PermanentRequest", "SolverConfig",
           "SolverError"]


class SolverError(RuntimeError):
    """Typed failure from the solver's queue/flush machinery.

    Raised (instead of a bare ``assert``, which vanishes under
    ``python -O``) when a bucket flush fails to resolve every queued
    request -- the message names the bucket and the pending count so an
    always-on service can log and shed instead of dying opaquely.
    """


class PermanentRequest:
    """Future for one queued permanent; resolved by a solver flush."""

    def __init__(self, solver: "PermanentSolver", matrix: np.ndarray):
        self._solver = solver
        self.matrix = matrix
        self.n = matrix.shape[0]
        self.done = False
        self.value: complex | float | None = None
        self.report: PermanentReport | None = None

    def result(self) -> complex | float:
        """The permanent; flushes this request's size bucket if pending.

        Only the owning bucket is flushed -- a planning failure in an
        unrelated size bucket must not raise out of ``result()`` and
        strand a perfectly resolvable future.
        """
        if not self.done:
            self._solver._flush_bucket(self.n)
        if not self.done:
            _, reqs = self._solver._queue.get(self.n, (0.0, []))
            raise SolverError(
                f"flush of size bucket n={self.n} left "
                f"{len(reqs)} request(s) unresolved (this future among "
                f"them) -- bucket flush must resolve every queued request")
        return self.value

    def _resolve(self, value, report) -> None:
        self.value = value
        self.report = report
        self.done = True


class PermanentSolver:
    """Stateful plan/execute session: backend dispatch + cache + queue."""

    def __init__(self, config: SolverConfig | None = None, *,
                 distributed_ctx: Any | None = None,
                 clock: Callable[[], float] | None = None,
                 **overrides):
        config = config or SolverConfig()
        if overrides:
            config = config.replace(**overrides)
        self.config = config
        self.distributed_ctx = distributed_ctx
        self.cache = ResultCache(config.cache_entries) if config.cache \
            else None
        # clock precedence: explicit kwarg > SolverConfig.clock > monotonic
        # (injectable so deadline behavior is deterministic under test)
        self._clock = clock if clock is not None \
            else (config.clock or time.monotonic)  # permlint: disable=PL004  # sanctioned injectable-clock default
        # size-keyed request queue: n -> (first-enqueue time, requests)
        self._queue: dict[int, tuple[float, list[PermanentRequest]]] = {}
        self._stats = ExecStats()
        self.flushes = 0
        # optional JobState -> None callback fired after every
        # checkpointed wave of a step_sharded (campaign) leaf
        self.campaign_progress: Callable | None = None
        # admission/flush observability hooks (serve/metrics.py installs
        # these): on_submit(request) fires after a request is enqueued
        # (before any flush it triggers); on_flush(n, served, seconds)
        # fires after a bucket flush resolves its futures
        self.on_submit: Callable[[PermanentRequest], None] | None = None
        self.on_flush: Callable[[int, int, float], None] | None = None

    # -- plan ---------------------------------------------------------------

    def plan(self, A) -> ExecutionPlan:
        """Scalar plan for one matrix (per-leaf dispatch order)."""
        A = np.asarray(A)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"square matrix required, got {A.shape}")
        return build_plan([A], self.config, batched=False)

    def plan_batch(self, As: Sequence) -> ExecutionPlan:
        """Bucketed batch plan: same-size same-route leaves share one
        device program (vmapped locally, or batch-axis-sharded over the
        mesh when the solver holds a ``distributed_ctx`` and the backend
        is ``distributed``/``distributed_batch``)."""
        return build_plan(list(As), self.config, batched=True)

    # -- execute ------------------------------------------------------------

    def execute(self, plan: ExecutionPlan, *, return_report: bool = False):
        """Dispatch a plan; scalar plans return a Python scalar, batch
        plans a (B,) ndarray (complex128 when the plan is complex)."""
        totals, reports, stats = execute_plan(
            plan, cache=self.cache, distributed_ctx=self.distributed_ctx,
            campaign_progress=self.campaign_progress)
        self._merge_stats(stats)
        out = totals if plan.is_complex else np.real(totals)
        for i, r in enumerate(reports):
            r.value = complex(out[i]) if plan.is_complex else float(out[i])
        if not plan.batched and plan.num_matrices == 1:
            value = reports[0].value
            return (value, reports[0]) if return_report else value
        return (out, reports) if return_report else out

    # -- async request queue ------------------------------------------------

    def submit(self, A) -> PermanentRequest:
        """Queue one matrix; returns a future resolved at the next flush.

        Triggers an immediate flush of the matrix's size bucket when it
        reaches ``queue_max_batch``; also polls deadline triggers for
        every bucket (oldest request older than ``queue_max_delay_s``).
        """
        A = np.asarray(A)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"square matrix required, got {A.shape}")
        req = PermanentRequest(self, A)
        t0, reqs = self._queue.setdefault(A.shape[0],
                                          (self._clock(), []))
        reqs.append(req)
        if self.on_submit is not None:
            self.on_submit(req)
        if len(reqs) >= self.config.queue_max_batch:
            self._flush_bucket(A.shape[0])
        self.poll()
        return req

    @property
    def pending(self) -> int:
        return sum(len(reqs) for _, reqs in self._queue.values())

    def poll(self) -> int:
        """Flush every bucket whose deadline has passed; returns the
        number of requests flushed."""
        now = self._clock()
        due = [n for n, (t0, reqs) in self._queue.items()
               if reqs and now - t0 >= self.config.queue_max_delay_s]
        return sum(self._flush_bucket(n) for n in due)

    def flush(self) -> int:
        """Flush every queued bucket regardless of triggers; returns the
        number of requests flushed."""
        return sum(self._flush_bucket(n) for n in list(self._queue))

    def _flush_bucket(self, n: int) -> int:
        _, reqs = self._queue.get(n, (0.0, []))
        if not reqs:
            self._queue.pop(n, None)
            return 0
        # plan + execute BEFORE dequeuing: if either raises, the bucket
        # stays queued and the pending futures remain resolvable
        t0 = time.perf_counter()
        plan = self.plan_batch([r.matrix for r in reqs])
        _, reports = self.execute(plan, return_report=True)
        self._queue.pop(n, None)
        for req, report in zip(reqs, reports):
            req._resolve(report.value, report)
        self.flushes += 1
        if self.on_flush is not None:
            self.on_flush(n, len(reqs), time.perf_counter() - t0)
        return len(reqs)

    # -- accounting ---------------------------------------------------------

    def _merge_stats(self, s: ExecStats) -> None:
        t = self._stats
        t.device_dispatches += s.device_dispatches
        t.batched_leaves += s.batched_leaves
        t.scalar_leaves += s.scalar_leaves
        t.inline_leaves += s.inline_leaves
        t.cache_hits += s.cache_hits
        t.cache_misses += s.cache_misses
        t.downgrades.extend(s.downgrades)
        for key, lt in s.timings.items():
            t.timings.setdefault(key, LeafTiming()).merge(lt)

    def stats(self) -> dict:
        """Dispatch + cache + queue accounting for the session.

        ``leaf_timings`` aggregates the executor's per-leaf device timing
        by dispatch-site key (``dense_batch(n=12,jnp)`` -> count / leaves
        / total_s / max_s) -- the same shape ``serve.metrics`` exports in
        its snapshot schema, so benchmarks and the service log line read
        identical counters.
        """
        out = {"device_dispatches": self._stats.device_dispatches,
               "batched_leaves": self._stats.batched_leaves,
               "scalar_leaves": self._stats.scalar_leaves,
               "inline_leaves": self._stats.inline_leaves,
               "downgrades": list(self._stats.downgrades),
               "flushes": self.flushes,
               "pending": self.pending,
               "leaf_timings": {k: t.to_json()
                                for k, t in sorted(
                                    self._stats.timings.items())}}
        out["cache"] = self.cache.stats() if self.cache else None
        return out
