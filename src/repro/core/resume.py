"""Checkpoint / restart state for distributed permanent jobs.

A permanent job's durable state is tiny: the matrix fingerprint, the slice
decomposition, and per-slice twofloat partial sums.  Slices are independent
addends, so:

* a crashed job resumes from the last snapshot, losing at most one wave;
* a resumed job may use a different device count (elastic) -- waves are
  re-formed from the pending slice set;
* stragglers only delay their own wave; completed slices are never redone.

The file format is a single ``.npz`` (atomic rename on save).
"""

from __future__ import annotations

import hashlib
import os
import tempfile
from dataclasses import dataclass, field

import numpy as np

from . import precision as P

__all__ = ["JobState"]


def matrix_fingerprint(A: np.ndarray) -> str:
    A = np.ascontiguousarray(A)
    h = hashlib.sha256()
    h.update(str(A.shape).encode())
    h.update(str(A.dtype).encode())
    h.update(A.tobytes())
    return h.hexdigest()[:32]


@dataclass
class JobState:
    fingerprint: str
    total_slices: int
    done: np.ndarray          # (total_slices,) bool
    hi: np.ndarray            # (total_slices,) f64/c128 partial sums
    lo: np.ndarray            # (total_slices,) f64/c128 compensation terms

    # ------------------------------------------------------------------
    @staticmethod
    def create(matrix: np.ndarray, total_slices: int) -> "JobState":
        # complex jobs checkpoint complex slice sums: the twofloat
        # reduction below is add/sub only, which is componentwise-exact
        # under complex arithmetic
        dtype = np.complex128 if np.iscomplexobj(matrix) else np.float64
        return JobState(
            fingerprint=matrix_fingerprint(matrix),
            total_slices=total_slices,
            done=np.zeros(total_slices, dtype=bool),
            hi=np.zeros(total_slices, dtype=dtype),
            lo=np.zeros(total_slices, dtype=dtype))

    @staticmethod
    def load(path: str) -> "JobState":
        with np.load(path, allow_pickle=False) as z:
            return JobState(
                fingerprint=str(z["fingerprint"]),
                total_slices=int(z["total_slices"]),
                done=z["done"], hi=z["hi"], lo=z["lo"])

    @staticmethod
    def load_or_create(path: str | None, matrix: np.ndarray,
                       total_slices: int) -> "JobState":
        if path and os.path.exists(path):
            state = JobState.load(path)
            if state.fingerprint != matrix_fingerprint(matrix):
                raise ValueError(
                    "checkpoint belongs to a different matrix "
                    f"({state.fingerprint})")
            if state.total_slices != total_slices:
                raise ValueError(
                    f"checkpoint has {state.total_slices} slices, plan has "
                    f"{total_slices}; re-plan with matching slices_per_device"
                    " x devices or finish with the original decomposition")
            return state
        return JobState.create(matrix, total_slices)

    # ------------------------------------------------------------------
    def pending_slices(self) -> list[int]:
        return [int(i) for i in np.nonzero(~self.done)[0]]

    def record_wave(self, slice_ids, his, los) -> None:
        for sid, h, l in zip(slice_ids, his, los):
            self.done[sid] = True
            self.hi[sid] = h           # dtype fixed at create()
            self.lo[sid] = l

    def fraction_done(self) -> float:
        return float(self.done.mean())

    def reduce(self):
        """Twofloat sum of all completed slice partials (deterministic)."""
        hi, lo = 0.0, 0.0
        for i in np.nonzero(self.done)[0]:
            s, e = _two_sum_host(hi, self.hi[i])
            lo = lo + e + self.lo[i]
            hi = s
        # renormalize
        s, e = _two_sum_host(hi, lo)
        return s, e

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        os.close(fd)
        try:
            np.savez(tmp, fingerprint=self.fingerprint,
                     total_slices=self.total_slices,
                     done=self.done, hi=self.hi, lo=self.lo)
            # np.savez appends .npz to names without it
            produced = tmp if tmp.endswith(".npz") else tmp + ".npz"
            if os.path.exists(produced) and produced != tmp:
                os.replace(produced, tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


def _two_sum_host(a: float, b: float):
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e
