"""Checkpoint / restart state for step-space campaign jobs.

A permanent campaign's durable state is tiny: the matrix fingerprint, the
slice decomposition *and the configuration that produced it*, plus
per-slice twofloat partial sums.  Slices are independent addends, so:

* a crashed job resumes from the last snapshot, losing at most one wave;
* a resumed job may use a different device count (elastic) -- waves are
  re-formed from the pending slice set;
* stragglers only delay their own wave; completed slices are never redone.

Config safety: partial sums are only meaningful under the exact
(precision, backend, chunk geometry) that computed them -- merging a
``dd`` wave into a ``qq`` reduction, or slices cut at a different
``chunk_size``, silently corrupts the result at the ulp level.  The
``.npz`` therefore persists ``precision`` / ``backend`` /
``chunks_per_slice`` / ``chunk_size`` plus a format version, and
``load_or_create`` fails loudly on any mismatch (including checkpoints
written by the pre-versioned seed format).

The file format is a single ``.npz`` (atomic rename on save).
"""

from __future__ import annotations

import hashlib
import os
import tempfile
from dataclasses import dataclass

import numpy as np

__all__ = ["JobState", "FORMAT_VERSION"]

# v2: config-safety fields (precision/backend/chunk geometry) added; v1
# (the unversioned seed format) checkpoints are rejected at load.
# v3: kernel ``geometry`` tag joins the config-safety set -- pallas wave
# partials reduce in a fixed order set by the kernel geometry, so a
# campaign checkpointed under one tuned geometry must not resume under
# another ("-" = no kernel geometry, i.e. jnp wave bodies).
FORMAT_VERSION = 3

_CONFIG_KEYS = ("precision", "backend", "chunks_per_slice", "chunk_size",
                "geometry")


def matrix_fingerprint(A: np.ndarray) -> str:
    A = np.ascontiguousarray(A)
    h = hashlib.sha256()
    h.update(str(A.shape).encode())
    h.update(str(A.dtype).encode())
    h.update(A.tobytes())
    return h.hexdigest()[:32]


@dataclass
class JobState:
    fingerprint: str
    total_slices: int
    done: np.ndarray          # (total_slices,) bool
    hi: np.ndarray            # (total_slices,) f64/c128 partial sums
    lo: np.ndarray            # (total_slices,) f64/c128 compensation terms
    precision: str = "dq_acc"
    backend: str = "jnp"      # per-device slice body: jnp | pallas
    chunks_per_slice: int = 0
    chunk_size: int = 0
    geometry: str = "-"       # kernel Geometry.tag(), "-" = none (jnp)
    version: int = FORMAT_VERSION

    # ------------------------------------------------------------------
    @staticmethod
    def create(matrix: np.ndarray, total_slices: int, *,
               precision: str = "dq_acc", backend: str = "jnp",
               chunks_per_slice: int = 0,
               chunk_size: int = 0, geometry: str = "-") -> "JobState":
        # complex jobs checkpoint complex slice sums: the twofloat
        # reduction below is add/sub only, which is componentwise-exact
        # under complex arithmetic
        dtype = np.complex128 if np.iscomplexobj(matrix) else np.float64
        return JobState(
            fingerprint=matrix_fingerprint(matrix),
            total_slices=total_slices,
            done=np.zeros(total_slices, dtype=bool),
            hi=np.zeros(total_slices, dtype=dtype),
            lo=np.zeros(total_slices, dtype=dtype),
            precision=precision, backend=backend,
            chunks_per_slice=chunks_per_slice, chunk_size=chunk_size,
            geometry=geometry)

    @staticmethod
    def load(path: str) -> "JobState":
        with np.load(path, allow_pickle=False) as z:
            if "version" not in z.files:
                raise ValueError(
                    f"checkpoint {path!r} predates the config-safety "
                    f"format (v{FORMAT_VERSION}): it does not record the "
                    "precision/backend/chunk geometry its partial sums "
                    "were computed under and cannot be resumed safely")
            version = int(z["version"])
            if version != FORMAT_VERSION:
                raise ValueError(
                    f"checkpoint {path!r} has format v{version}, this "
                    f"code reads v{FORMAT_VERSION}")
            return JobState(
                fingerprint=str(z["fingerprint"]),
                total_slices=int(z["total_slices"]),
                done=z["done"], hi=z["hi"], lo=z["lo"],
                precision=str(z["precision"]),
                backend=str(z["backend"]),
                chunks_per_slice=int(z["chunks_per_slice"]),
                chunk_size=int(z["chunk_size"]),
                geometry=str(z["geometry"]),
                version=version)

    @staticmethod
    def load_or_create(path: str | None, matrix: np.ndarray,
                       total_slices: int, *,
                       precision: str = "dq_acc", backend: str = "jnp",
                       chunks_per_slice: int = 0,
                       chunk_size: int = 0,
                       geometry: str = "-") -> "JobState":
        if path and os.path.exists(path):
            state = JobState.load(path)
            if state.fingerprint != matrix_fingerprint(matrix):
                raise ValueError(
                    "checkpoint belongs to a different matrix "
                    f"({state.fingerprint})")
            if state.total_slices != total_slices:
                raise ValueError(
                    f"checkpoint has {state.total_slices} slices, plan has "
                    f"{total_slices}; re-plan with the original slice "
                    "decomposition or finish with the code that wrote it")
            want = {"precision": precision, "backend": backend,
                    "chunks_per_slice": chunks_per_slice,
                    "chunk_size": chunk_size, "geometry": geometry}
            bad = [k for k in _CONFIG_KEYS
                   if getattr(state, k) != want[k]]
            if bad:
                detail = ", ".join(
                    f"{k}: checkpoint={getattr(state, k)!r} "
                    f"plan={want[k]!r}" for k in bad)
                raise ValueError(
                    "checkpoint config mismatch -- partial sums computed "
                    "under a different configuration cannot be merged "
                    f"({detail}); resume with the original config or "
                    "restart from scratch")
            return state
        return JobState.create(matrix, total_slices, precision=precision,
                               backend=backend,
                               chunks_per_slice=chunks_per_slice,
                               chunk_size=chunk_size, geometry=geometry)

    # ------------------------------------------------------------------
    def pending_slices(self) -> list[int]:
        return [int(i) for i in np.nonzero(~self.done)[0]]

    def record_wave(self, slice_ids, his, los) -> None:
        for sid, h, l in zip(slice_ids, his, los):
            self.done[sid] = True
            self.hi[sid] = h           # dtype fixed at create()
            self.lo[sid] = l

    def fraction_done(self) -> float:
        return float(self.done.mean())

    def reduce(self):
        """Twofloat sum of all completed slice partials (deterministic).

        Fixed slice-id order, independent of wave composition and device
        count -- the reduction a killed-and-resumed campaign replays
        bitwise-identically.
        """
        hi, lo = 0.0, 0.0
        for i in np.nonzero(self.done)[0]:
            s, e = _two_sum_host(hi, self.hi[i])
            lo = lo + e + self.lo[i]
            hi = s
        # renormalize
        s, e = _two_sum_host(hi, lo)
        return s, e

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        os.close(fd)
        try:
            np.savez(tmp, fingerprint=self.fingerprint,
                     total_slices=self.total_slices,
                     done=self.done, hi=self.hi, lo=self.lo,
                     precision=self.precision, backend=self.backend,
                     chunks_per_slice=self.chunks_per_slice,
                     chunk_size=self.chunk_size, geometry=self.geometry,
                     version=self.version)
            # np.savez appends .npz to names without it
            produced = tmp if tmp.endswith(".npz") else tmp + ".npz"
            if os.path.exists(produced) and produced != tmp:
                os.replace(produced, tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


def _two_sum_host(a: float, b: float):
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e
