"""Pure step-space decomposition math (no jax, no devices).

The 2^{n-1}-step Gray iteration space is split twice:

* :func:`chunk_geometry` -- chunks: the intra-device parallelism unit
  (Alg. 3's tau lanes; every chunk is a power-of-two, window-aligned run
  of Gray steps so the CEG schedules are chunk-uniform).
* :func:`plan_slices` -- slices: the campaign / fault-tolerance unit (a
  contiguous block of chunks).  Slice sums are independent addends, so a
  killed-and-resumed job recomputes only unfinished slices and the final
  fixed-order reduction is identical no matter how slices were grouped
  into waves or how many devices ran them.

Both functions are pure host math: ``core.planner`` calls them while
building an :class:`~repro.core.planner.ExecutionPlan` (planning must not
import jax), and ``core.ryser`` / ``core.distributed`` re-export them for
the device engines.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["Geometry", "DEFAULT_GEOMETRY", "chunk_geometry", "kernel_geometry",
           "plan_slices"]


@dataclass(frozen=True)
class Geometry:
    """Requested Pallas kernel geometry: one frozen, hashable value.

    ``lanes`` / ``steps_per_chunk`` / ``window`` are the *requested* knobs;
    :func:`kernel_geometry` clamps them to the 2^{n-1} step space per
    matrix size.  A ``Geometry`` is a single jit static argument (one
    retrace axis instead of three) and -- because it changes the
    fixed-order reduction shape -- part of a value's numeric identity:
    it is hashed into plan fingerprints, appended to ``ResultCache``
    keys, and persisted in campaign checkpoints (see docs/INVARIANTS.md).
    """

    lanes: int = 128
    steps_per_chunk: int = 64
    window: int = 16
    max_blocks: int | None = None

    def as_tuple(self):
        return (self.lanes, self.steps_per_chunk, self.window,
                self.max_blocks)

    def tag(self) -> str:
        """Short stable string for cache keys / checkpoints / reports."""
        base = f"{self.lanes}x{self.steps_per_chunk}x{self.window}"
        return base if self.max_blocks is None else f"{base}b{self.max_blocks}"

    @staticmethod
    def from_tag(tag: str) -> "Geometry":
        body, _, mb = tag.partition("b")
        lanes, spc, window = (int(p) for p in body.split("x"))
        return Geometry(lanes, spc, window, int(mb) if mb else None)

    def kernel_geometry(self, n: int):
        """Clamp this geometry to n's step space -> (TB, C, Wu, num_blocks)."""
        return kernel_geometry(n, lanes=self.lanes,
                               steps_per_chunk=self.steps_per_chunk,
                               window=self.window, max_blocks=self.max_blocks)


DEFAULT_GEOMETRY = Geometry()


def kernel_geometry(n: int, *, lanes: int = 128, steps_per_chunk: int = 64,
                    window: int = 16, max_blocks: int | None = None):
    """Pick (TB, C, Wu, num_blocks) covering the 2^{n-1} step space.

    All power-of-two; TB * C * num_blocks == 2^{n-1}.  For small test
    matrices the requested sizes are clamped down.  Pure host math --
    the Pallas wrappers in ``kernels/ryser_pallas.py`` re-export it.
    """
    space = 1 << (n - 1)
    TB = min(lanes, max(2, space // 4))
    TB = 1 << int(math.floor(math.log2(TB)))
    C = min(steps_per_chunk, space // TB)
    C = max(2, 1 << int(math.floor(math.log2(C))))
    Wu = max(2, min(window, C))
    num_blocks = space // (TB * C)
    if max_blocks is not None:
        num_blocks = min(num_blocks, max_blocks)
    return TB, C, Wu, num_blocks


def chunk_geometry(n: int, num_chunks: int):
    """Power-of-2, window-aligned chunking of the 2^{n-1}-step space.

    Returns (T, C, k): T chunks of C = 2^k local steps; T * C == 2^{n-1},
    k >= 1 (so chunk starts are even and the accumulation sign is
    chunk-uniform).  Step ``w`` of chunk ``t`` is global step ``g = t*C + w``.
    """
    space = 1 << (n - 1)
    T = max(1, min(num_chunks, space // 2))
    T = 1 << int(math.floor(math.log2(T)))  # power of two
    C = space // T
    return T, C, int(math.log2(C))


def plan_slices(n: int, num_devices: int, slices_per_device: int = 8,
                lanes_per_device: int = 1024):
    """Static decomposition of the 2^{n-1} step space.

    Returns (total_slices, chunks_per_slice, chunk_size) such that
    ``total_slices * chunks_per_slice * chunk_size == 2^{n-1}`` with
    power-of-two chunk_size >= 2 (CEG alignment) and total_slices a
    power-of-two multiple of num_devices when possible.

    The decomposition depends only on its arguments -- never on the
    runtime device count -- which is what makes campaign checkpoints
    portable across elastic restarts: the planner fixes
    (total_slices, chunks_per_slice, chunk_size) once and any mesh can
    execute the pending slice set in waves of its own size.
    """
    want_chunks = num_devices * slices_per_device * lanes_per_device
    T, C, _ = chunk_geometry(n, want_chunks)
    ts = num_devices * slices_per_device
    ts = 1 << int(math.ceil(math.log2(ts)))
    while ts > 1 and (T % ts != 0 or T // ts < 1):
        ts //= 2
    return ts, T // ts, C
