"""Pure step-space decomposition math (no jax, no devices).

The 2^{n-1}-step Gray iteration space is split twice:

* :func:`chunk_geometry` -- chunks: the intra-device parallelism unit
  (Alg. 3's tau lanes; every chunk is a power-of-two, window-aligned run
  of Gray steps so the CEG schedules are chunk-uniform).
* :func:`plan_slices` -- slices: the campaign / fault-tolerance unit (a
  contiguous block of chunks).  Slice sums are independent addends, so a
  killed-and-resumed job recomputes only unfinished slices and the final
  fixed-order reduction is identical no matter how slices were grouped
  into waves or how many devices ran them.

Both functions are pure host math: ``core.planner`` calls them while
building an :class:`~repro.core.planner.ExecutionPlan` (planning must not
import jax), and ``core.ryser`` / ``core.distributed`` re-export them for
the device engines.
"""

from __future__ import annotations

import math

__all__ = ["chunk_geometry", "plan_slices"]


def chunk_geometry(n: int, num_chunks: int):
    """Power-of-2, window-aligned chunking of the 2^{n-1}-step space.

    Returns (T, C, k): T chunks of C = 2^k local steps; T * C == 2^{n-1},
    k >= 1 (so chunk starts are even and the accumulation sign is
    chunk-uniform).  Step ``w`` of chunk ``t`` is global step ``g = t*C + w``.
    """
    space = 1 << (n - 1)
    T = max(1, min(num_chunks, space // 2))
    T = 1 << int(math.floor(math.log2(T)))  # power of two
    C = space // T
    return T, C, int(math.log2(C))


def plan_slices(n: int, num_devices: int, slices_per_device: int = 8,
                lanes_per_device: int = 1024):
    """Static decomposition of the 2^{n-1} step space.

    Returns (total_slices, chunks_per_slice, chunk_size) such that
    ``total_slices * chunks_per_slice * chunk_size == 2^{n-1}`` with
    power-of-two chunk_size >= 2 (CEG alignment) and total_slices a
    power-of-two multiple of num_devices when possible.

    The decomposition depends only on its arguments -- never on the
    runtime device count -- which is what makes campaign checkpoints
    portable across elastic restarts: the planner fixes
    (total_slices, chunks_per_slice, chunk_size) once and any mesh can
    execute the pending slice set in waves of its own size.
    """
    want_chunks = num_devices * slices_per_device * lanes_per_device
    T, C, _ = chunk_geometry(n, want_chunks)
    ts = num_devices * slices_per_device
    ts = 1 << int(math.ceil(math.log2(ts)))
    while ts > 1 and (T % ts != 0 or T // ts < 1):
        ts //= 2
    return ts, T // ts, C
