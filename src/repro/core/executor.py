"""Execute side of the plan/execute split: backend registry + dispatcher.

``engine._leaf_value``'s if/elif backend chain is replaced by strategy
objects: each :class:`Backend` knows how to run one dense/sparse leaf and
(optionally) a whole same-size bucket; ``register_backend`` adds new
strategies without touching the dispatcher (the ``jnp`` / ``pallas`` /
``distributed`` trio registers itself at import).

:func:`execute_plan` walks an :class:`~repro.core.planner.ExecutionPlan`:

* scalar plans dispatch leaf by leaf in plan order (bit-identical to the
  legacy ``engine.permanent`` loop);
* batched plans fold n <= 2 leaves inline, consult the result cache per
  leaf, then run every multi-leaf (route, n) bucket as ONE vmapped device
  program -- cache hits and ragged singletons never enter a bucket;
* every leaf result is normalized to a Python scalar before accumulation
  (both dense and sparse routes -- no 0-d array surprises downstream),
  and backend downgrades are recorded in the dispatch tags (a complex
  bucket under ``backend="pallas"`` reports ``dense_batch(...,pallas->jnp)``
  instead of silently borrowing jnp numbers).

Returns per-matrix totals plus :class:`PermanentReport`s and an
:class:`ExecStats` with device-dispatch / cache accounting.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from . import ryser as R
from . import sparyser as S
from .cache import ResultCache
from .planner import (ROUTE_DENSE, ROUTE_INLINE, ROUTE_SPARSE, ExecutionPlan,
                      LeafTask, PermanentReport)

__all__ = ["Backend", "JnpBackend", "PallasBackend", "DistributedBackend",
           "register_backend", "get_backend", "available_backends",
           "ExecStats", "execute_plan"]


def _scalar(v) -> complex | float:
    """Normalize any engine return (0-d jax/numpy array, numpy scalar,
    Python number) to a Python scalar so downstream ``complex(...)``
    coercions never see 0-d array surprises."""
    return np.asarray(v).item()


@dataclass
class ExecStats:
    """What one execute_plan call actually did (for tests/benchmarks)."""
    device_dispatches: int = 0       # scalar leaf calls + bucket programs
    batched_leaves: int = 0          # leaves served by bucket programs
    scalar_leaves: int = 0           # leaves served one at a time
    inline_leaves: int = 0           # n <= 2 closed forms
    cache_hits: int = 0
    cache_misses: int = 0
    downgrades: list[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Backend strategy registry
# ---------------------------------------------------------------------------

class Backend:
    """One execution strategy for permanent leaves.

    ``dense``/``sparse`` run a single leaf and must return a Python
    scalar.  ``dense_batch``/``sparse_batch`` run a same-size bucket in
    one device program and return a (B,) ndarray, or ``None`` to signal
    "unsupported for this bucket" -- the dispatcher then falls back to
    the ``jnp`` strategy and tags the downgrade.
    """

    name = "?"

    def dense(self, M: np.ndarray, *, precision: str, num_chunks: int,
              ctx: Any | None = None) -> complex | float:
        raise NotImplementedError

    def sparse(self, sp, *, precision: str, num_chunks: int,
               ctx: Any | None = None) -> complex | float:
        # Alg. 4's SpaRyser has no kernel/mesh variant yet: every backend
        # shares the chunked jnp path (normalized to a Python scalar).
        return _scalar(S.perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                               precision=precision))

    def dense_batch(self, stack: np.ndarray, *, precision: str,
                    num_chunks: int) -> np.ndarray | None:
        return None

    def sparse_batch(self, sps: list, *, precision: str,
                     num_chunks: int) -> np.ndarray | None:
        return None


class JnpBackend(Backend):
    """Chunked / vmapped XLA engines (the default)."""

    name = "jnp"

    def dense(self, M, *, precision, num_chunks, ctx=None):
        return _scalar(R.perm_ryser_chunked(M, num_chunks=num_chunks,
                                            precision=precision))

    def dense_batch(self, stack, *, precision, num_chunks):
        return np.asarray(R.perm_ryser_batched(stack, num_chunks=num_chunks,
                                               precision=precision))

    def sparse_batch(self, sps, *, precision, num_chunks):
        return np.asarray(S.perm_sparyser_batched(sps, num_chunks=num_chunks,
                                                  precision=precision))


class PallasBackend(JnpBackend):
    """TPU kernel (interpret-mode on CPU); real matrices with n >= 4.

    Complex leaves and tiny matrices fall back to the jnp engines --
    scalar falls back silently (legacy contract), batched falls back with
    a ``pallas->jnp`` downgrade tag emitted by the dispatcher.
    """

    name = "pallas"

    def _supported(self, M_or_stack) -> bool:
        n = M_or_stack.shape[-1]
        return n >= 4 and not np.iscomplexobj(M_or_stack)

    def dense(self, M, *, precision, num_chunks, ctx=None):
        if self._supported(M):
            from ..kernels import ops as K
            return complex(K.permanent_pallas(M, precision=precision)).real
        return super().dense(M, precision=precision, num_chunks=num_chunks)

    def dense_batch(self, stack, *, precision, num_chunks):
        if self._supported(stack):
            from ..kernels import ops as K
            return np.asarray(K.permanent_pallas_batched(
                stack, precision=precision))
        return None                  # dispatcher falls back + tags downgrade

    def sparse_batch(self, sps, *, precision, num_chunks):
        return None                  # no sparse kernel: jnp fallback, tagged


class DistributedBackend(JnpBackend):
    """Mesh-wide shard_map (core.distributed); scalar dense only.

    Needs a ``DistributedPermanent`` context passed through
    ``execute_plan(..., distributed_ctx=...)``; without one it behaves
    like ``jnp`` (legacy contract).  Bucket programs are not supported --
    batch entry points reject this backend up front.
    """

    name = "distributed"

    def dense(self, M, *, precision, num_chunks, ctx=None):
        if ctx is not None:
            return _scalar(ctx.permanent(M, precision=precision))
        return super().dense(M, precision=precision, num_chunks=num_chunks)

    def dense_batch(self, stack, *, precision, num_chunks):
        return None

    def sparse_batch(self, sps, *, precision, num_chunks):
        return None


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend, name: str | None = None) -> Backend:
    """Register a strategy object under ``name`` (default: backend.name)."""
    _BACKENDS[name or backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{sorted(_BACKENDS)}") from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


register_backend(JnpBackend())
register_backend(PallasBackend())
register_backend(DistributedBackend())

_FALLBACK = "jnp"


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

def _cache_key(leaf: LeafTask, plan: ExecutionPlan) -> tuple:
    return ResultCache.key(leaf.key, leaf.route, plan.precision,
                           plan.config.backend, plan.config.num_chunks)


def _run_leaf(leaf: LeafTask, plan: ExecutionPlan, backend: Backend,
              report: PermanentReport, stats: ExecStats,
              ctx: Any | None) -> complex | float:
    """One leaf through the scalar strategy path (plan-order dispatch)."""
    n = leaf.n
    cfg = plan.config
    if leaf.route == ROUTE_SPARSE:
        report.dispatch.append(f"sparse(n={n})")
        sp = S.SparseMatrix.from_dense(leaf.matrix)
        val = backend.sparse(sp, precision=plan.precision,
                             num_chunks=cfg.num_chunks, ctx=ctx)
    else:
        report.dispatch.append(f"dense(n={n})")
        val = backend.dense(leaf.matrix, precision=plan.precision,
                            num_chunks=cfg.num_chunks, ctx=ctx)
    stats.device_dispatches += 1
    stats.scalar_leaves += 1
    return val


def _inline_value(m: np.ndarray) -> complex | float:
    return m[0, 0] if m.shape[0] == 1 else \
        m[0, 0] * m[1, 1] + m[0, 1] * m[1, 0]


def execute_plan(plan: ExecutionPlan, *, cache: ResultCache | None = None,
                 distributed_ctx: Any | None = None):
    """Dispatch every leaf of ``plan`` and accumulate per-matrix totals.

    Returns ``(totals, reports, stats)`` where ``totals`` is a (B,)
    complex128 array (callers extract the real part for real plans),
    ``reports`` one PermanentReport per planned matrix, and ``stats`` the
    dispatch/cache accounting.
    """
    cfg = plan.config
    backend = get_backend(cfg.backend)
    fallback = get_backend(_FALLBACK)
    stats = ExecStats()
    B = plan.num_matrices
    totals = np.zeros(B, dtype=np.complex128)
    reports = [PermanentReport(n=e.n, nnz=e.nnz, density=e.density,
                               dm_removed=e.dm_removed,
                               fm_leaves=e.fm_leaves,
                               leaf_sizes=list(e.leaf_sizes),
                               precision=plan.precision, backend=cfg.backend)
               for e in plan.entries]
    for e in plan.entries:
        totals[e.index] += e.const

    def lookup(leaf: LeafTask):
        if cache is None:
            return None, None
        key = _cache_key(leaf, plan)
        val = cache.get(key)
        if val is None:
            stats.cache_misses += 1
        else:
            stats.cache_hits += 1
        return key, val

    if not plan.batched:
        # scalar mode: strict plan-order per-leaf dispatch (legacy
        # ``permanent`` numerics, tag for tag)
        for leaf in plan.leaves:
            key, val = lookup(leaf)
            if val is not None:
                reports[leaf.owner].dispatch.append(
                    f"cache({leaf.route},n={leaf.n})")
            else:
                val = _run_leaf(leaf, plan, backend, reports[leaf.owner],
                                stats, distributed_ctx)
                if key is not None:
                    cache.put(key, val)
            totals[leaf.owner] += leaf.coef * val
        return totals, reports, stats

    # batched mode: inline folds, cache probe, then bucket programs.
    # With a cache attached, duplicate leaves inside one cold batch are
    # scheduled once: followers resolve from the cache after their
    # bucket runs (boson-sampling streams repeat submatrices *within* a
    # request batch, not just across calls).
    pending: dict[tuple[str, int], list[int]] = {}
    computed: dict[tuple, complex | float] = {}   # this call's results
    followers: list[LeafTask] = []
    for (route, n), idxs in plan.buckets.items():
        for j in idxs:
            leaf = plan.leaves[j]
            if route == ROUTE_INLINE:
                reports[leaf.owner].dispatch.append(f"dense(n={n})")
                totals[leaf.owner] += leaf.coef * _inline_value(leaf.matrix)
                stats.inline_leaves += 1
                continue
            if cache is not None:
                key = _cache_key(leaf, plan)
                if key in computed:
                    followers.append(leaf)
                    continue
                val = cache.get(key)
                if val is not None:
                    stats.cache_hits += 1
                    reports[leaf.owner].dispatch.append(
                        f"cache({route},n={n})")
                    totals[leaf.owner] += leaf.coef * val
                    continue
                stats.cache_misses += 1
                computed[key] = None      # scheduled; filled after its bucket
            pending.setdefault((route, n), []).append(j)

    for (route, n), idxs in sorted(pending.items()):
        leaves = [plan.leaves[j] for j in idxs]
        if len(leaves) == 1:         # ragged straggler: scalar path
            leaf = leaves[0]
            val = _run_leaf(leaf, plan, backend, reports[leaf.owner],
                            stats, distributed_ctx)
            if cache is not None:
                key = _cache_key(leaf, plan)
                cache.put(key, val)
                computed[key] = val
            totals[leaf.owner] += leaf.coef * complex(val)
            continue
        tag = f"{route}_batch(n={n},b={len(leaves)})"
        if route == ROUTE_DENSE:
            stack = np.stack([l.matrix for l in leaves])
            vals = backend.dense_batch(stack, precision=plan.precision,
                                       num_chunks=cfg.num_chunks)
            if vals is None:         # e.g. complex bucket under pallas
                vals = fallback.dense_batch(stack, precision=plan.precision,
                                            num_chunks=cfg.num_chunks)
                tag = f"{route}_batch(n={n},b={len(leaves)}," \
                      f"{cfg.backend}->{_FALLBACK})"
                stats.downgrades.append(tag)
        else:
            sps = [S.SparseMatrix.from_dense(l.matrix) for l in leaves]
            vals = backend.sparse_batch(sps, precision=plan.precision,
                                        num_chunks=cfg.num_chunks)
            if vals is None:
                vals = fallback.sparse_batch(sps, precision=plan.precision,
                                             num_chunks=cfg.num_chunks)
                tag = f"{route}_batch(n={n},b={len(leaves)}," \
                      f"{cfg.backend}->{_FALLBACK})"
                stats.downgrades.append(tag)
        stats.device_dispatches += 1
        stats.batched_leaves += len(leaves)
        vals = np.asarray(vals)
        for leaf, v in zip(leaves, vals):
            v = _scalar(v)
            reports[leaf.owner].dispatch.append(tag)
            if cache is not None:
                key = _cache_key(leaf, plan)
                cache.put(key, v)
                computed[key] = v
            totals[leaf.owner] += leaf.coef * v

    for leaf in followers:                 # duplicates of scheduled leaves
        # resolve from this call's own results, not the shared cache -- an
        # LRU smaller than the batch may already have evicted the entry
        val = computed[_cache_key(leaf, plan)]
        assert val is not None, "scheduled leaf must have been computed"
        cache.hits += 1                    # in-flight dedup is still a hit
        stats.cache_hits += 1
        reports[leaf.owner].dispatch.append(
            f"cache({leaf.route},n={leaf.n})")
        totals[leaf.owner] += leaf.coef * val
    return totals, reports, stats
