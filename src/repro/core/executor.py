"""Execute side of the plan/execute split: backend registry + dispatcher.

``engine._leaf_value``'s if/elif backend chain is replaced by strategy
objects: each :class:`Backend` knows how to run one dense/sparse leaf and
(optionally) a whole same-size bucket; ``register_backend`` adds new
strategies without touching the dispatcher (the ``jnp`` / ``pallas`` /
``distributed`` / ``distributed_batch`` / ``campaign`` strategies
register themselves at import).  ``campaign`` is special: it is never
selected by ``SolverConfig.backend`` -- the planner routes individual
oversized leaves to it (``route == "step_sharded"``) and it executes
them as checkpointed step-space waves (see :class:`CampaignBackend`).

**Batch contract.**  ``dense_batch(stack, *, precision, num_chunks, ctx)``
and ``sparse_batch(sps, *, precision, num_chunks, ctx)`` run one
same-size bucket as a single device program and return a (B,) ndarray of
values in bucket order, or ``None`` to signal "unsupported for this
bucket" -- the dispatcher then re-runs the bucket on the ``jnp``
strategy and tags the downgrade as ``{route}_batch(...,<cfg>->jnp)``
(e.g. ``distributed->jnp`` when no mesh/ctx is attached; complex stacks
are first-class on every strategy and no longer downgrade).  ``ctx`` is
the ``distributed_ctx`` threaded
through :func:`execute_plan`: a ``jax.sharding.Mesh`` or any object with
a ``.mesh`` attribute (``core.distributed.DistributedPermanent``);
non-distributed strategies ignore it.  Every strategy must also answer
:meth:`Backend.value_backend` -- the registry name of the strategy whose
numerics will actually produce a leaf's value.  The result cache stores
values under THAT name, never the configured one, so a jnp-computed
downgrade can never satisfy a genuine pallas/distributed lookup whose
kernel numerics differ at the ulp level.

:func:`execute_plan` walks an :class:`~repro.core.planner.ExecutionPlan`:

* scalar plans dispatch leaf by leaf in plan order (bit-identical to the
  legacy ``engine.permanent`` loop);
* batched plans fold n <= 2 leaves inline, consult the result cache per
  leaf, then run every multi-leaf (route, n) bucket as ONE device
  program (vmapped locally, or batch-axis-sharded over the mesh under
  ``distributed``) -- cache hits and ragged singletons never enter a
  bucket;
* every leaf result is normalized to a Python scalar before accumulation
  (both dense and sparse routes -- no 0-d array surprises downstream),
  and backend downgrades are recorded in the dispatch tags, as is the
  planner's ``qq->kahan`` complex precision downgrade
  (``precision(qq->kahan)`` on every report, mirroring ``--plan-json``).

Returns per-matrix totals plus :class:`PermanentReport`s and an
:class:`ExecStats` with device-dispatch / cache accounting.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from . import ryser as R
from . import sparyser as S
from .cache import ResultCache
from .planner import (ROUTE_CAMPAIGN, ROUTE_DENSE, ROUTE_INLINE,
                      ROUTE_SPARSE, CampaignSpec, ExecutionPlan,
                      LeafTask, PermanentReport)

__all__ = ["Backend", "JnpBackend", "PallasBackend", "DistributedBackend",
           "DistributedBatchBackend", "CampaignBackend",
           "register_backend", "get_backend", "available_backends",
           "ExecStats", "LeafTiming", "execute_plan"]


def _ctx_mesh(ctx):
    """Extract a usable Mesh from a distributed ctx (Mesh or runner)."""
    if ctx is None:
        return None
    from jax.sharding import Mesh
    mesh = getattr(ctx, "mesh", ctx)
    return mesh if isinstance(mesh, Mesh) else None


def _scalar(v) -> complex | float:
    """Normalize any engine return (0-d jax/numpy array, numpy scalar,
    Python number) to a Python scalar so downstream ``complex(...)``
    coercions never see 0-d array surprises."""
    return np.asarray(v).item()


@dataclass
class LeafTiming:
    """Wall-clock accounting for one dispatch-site key.

    One key is one (route, n, producing-backend) device program family,
    e.g. ``dense_batch(n=12,jnp)`` or ``sparse(n=9,pallas)``; ``count``
    is device dispatches, ``leaves`` the leaf results they produced
    (a bucket dispatch serves many leaves).
    """
    count: int = 0
    leaves: int = 0
    total_s: float = 0.0
    max_s: float = 0.0

    def add(self, seconds: float, leaves: int = 1) -> None:
        self.count += 1
        self.leaves += leaves
        self.total_s += seconds
        self.max_s = max(self.max_s, seconds)

    def merge(self, other: "LeafTiming") -> None:
        self.count += other.count
        self.leaves += other.leaves
        self.total_s += other.total_s
        self.max_s = max(self.max_s, other.max_s)

    def to_json(self) -> dict:
        return {"count": self.count, "leaves": self.leaves,
                "total_s": self.total_s, "max_s": self.max_s,
                "mean_s": self.total_s / self.count if self.count else 0.0}


@dataclass
class ExecStats:
    """What one execute_plan call actually did (for tests/benchmarks)."""
    device_dispatches: int = 0       # scalar leaf calls + bucket programs
    batched_leaves: int = 0          # leaves served by bucket programs
    scalar_leaves: int = 0           # leaves served one at a time
    inline_leaves: int = 0           # n <= 2 closed forms
    cache_hits: int = 0
    cache_misses: int = 0
    downgrades: list[str] = field(default_factory=list)
    # per-dispatch-site wall-clock timing (serve/metrics.py exports these
    # through the one snapshot schema; PermanentSolver.stats() aggregates
    # them across calls as ``leaf_timings``)
    timings: dict[str, LeafTiming] = field(default_factory=dict)

    def record_time(self, key: str, seconds: float,
                    leaves: int = 1) -> None:
        self.timings.setdefault(key, LeafTiming()).add(seconds, leaves)


# ---------------------------------------------------------------------------
# Backend strategy registry
# ---------------------------------------------------------------------------

class Backend:
    """One execution strategy for permanent leaves.

    ``dense``/``sparse`` run a single leaf and must return a Python
    scalar.  ``dense_batch``/``sparse_batch`` follow the batch contract
    in the module docstring: one bucket -> (B,) ndarray, or ``None`` to
    downgrade to ``jnp``.  ``value_backend`` names the strategy whose
    numerics actually serve a leaf -- the result-cache identity.
    ``geometry`` is the leaf's resolved kernel geometry (config override
    or tuning-table hit); only kernel-backed strategies honor it, the
    jnp engines ignore it (their numerics have no kernel geometry).
    """

    name = "?"

    def dense(self, M: np.ndarray, *, precision: str, num_chunks: int,
              geometry=None, ctx: Any | None = None) -> complex | float:
        raise NotImplementedError

    def sparse(self, sp, *, precision: str, num_chunks: int,
               geometry=None, ctx: Any | None = None) -> complex | float:
        raise NotImplementedError

    def dense_batch(self, stack: np.ndarray, *, precision: str,
                    num_chunks: int, geometry=None,
                    ctx: Any | None = None) -> np.ndarray | None:
        return None

    def sparse_batch(self, sps: list, *, precision: str, num_chunks: int,
                     geometry=None,
                     ctx: Any | None = None) -> np.ndarray | None:
        return None

    def value_backend(self, route: str, n: int, *, batched: bool,
                      ctx: Any | None = None) -> str:
        """Registry name of the strategy whose numerics produce this
        leaf's value.  Cache keys use THIS name, not the configured
        backend, so downgraded (jnp-computed) values are stored -- and
        found -- under ``jnp``.  Produced-by logic is uniform across the
        dense and sparse routes (no sparse hardcode since the SpaRyser
        kernel landed: sparse leaves are kernel-served too); strategies
        that fall back for some shapes override this accordingly."""
        return self.name


class JnpBackend(Backend):
    """Chunked / vmapped XLA engines (the default)."""

    name = "jnp"

    def dense(self, M, *, precision, num_chunks, geometry=None, ctx=None):
        return _scalar(R.perm_ryser_chunked(M, num_chunks=num_chunks,
                                            precision=precision))

    def sparse(self, sp, *, precision, num_chunks, geometry=None, ctx=None):
        return _scalar(S.perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                               precision=precision))

    def dense_batch(self, stack, *, precision, num_chunks, geometry=None,
                    ctx=None):
        return np.asarray(R.perm_ryser_batched(stack, num_chunks=num_chunks,
                                               precision=precision))

    def sparse_batch(self, sps, *, precision, num_chunks, geometry=None,
                     ctx=None):
        return np.asarray(S.perm_sparyser_batched(sps, num_chunks=num_chunks,
                                                  precision=precision))


class PallasBackend(JnpBackend):
    """TPU kernel (interpret-mode on CPU); real OR complex, n >= 4.

    Dense AND sparse leaves run the kernels (sparse: the padded-CCS
    SpaRyser kernels in ``kernels.ryser_sparse``, same batch grid and
    window schedule); complex leaves run the split re/im plane variants.
    Only tiny matrices fall back to the jnp engines -- scalar dense falls
    back silently (legacy contract), scalar sparse and every batch with a
    ``pallas->jnp`` downgrade tag emitted by the dispatcher.
    """

    name = "pallas"

    @staticmethod
    def _kernel_ok(n: int) -> bool:
        return n >= 4

    def _supported(self, M_or_stack) -> bool:
        return self._kernel_ok(M_or_stack.shape[-1])

    def dense(self, M, *, precision, num_chunks, geometry=None, ctx=None):
        if self._supported(M):
            from ..kernels import ops as K
            return _scalar(K.permanent_pallas(M, precision=precision,
                                              geometry=geometry))
        return super().dense(M, precision=precision, num_chunks=num_chunks)

    def sparse(self, sp, *, precision, num_chunks, geometry=None, ctx=None):
        if self._kernel_ok(sp.n):
            from ..kernels import ops as K
            return _scalar(K.permanent_pallas_sparse(sp, precision=precision,
                                                     geometry=geometry))
        return super().sparse(sp, precision=precision,
                              num_chunks=num_chunks)

    def dense_batch(self, stack, *, precision, num_chunks, geometry=None,
                    ctx=None):
        if self._supported(stack):
            from ..kernels import ops as K
            return np.asarray(K.permanent_pallas_batched(
                stack, precision=precision, geometry=geometry))
        return None                  # dispatcher falls back + tags downgrade

    def sparse_batch(self, sps, *, precision, num_chunks, geometry=None,
                     ctx=None):
        if self._kernel_ok(sps[0].n):
            from ..kernels import ops as K
            return np.asarray(K.permanent_pallas_sparse_batched(
                sps, precision=precision, geometry=geometry))
        return None                  # tiny bucket: jnp fallback, tagged

    def value_backend(self, route, n, *, batched, ctx=None):
        if self._kernel_ok(n):       # dense and sparse kernels alike
            return self.name
        return "jnp"                 # tiny-n fallback to the jnp engines


class DistributedBatchBackend(JnpBackend):
    """Batch-axis sharding over ``core.distributed``'s mesh (ROADMAP:
    batch sharding over the device mesh).

    ``dense_batch``/``sparse_batch`` shard a same-size bucket's leading
    axis over the mesh -- matrices replicated per shard (each device owns
    whole matrices, no psum), ragged tails padded to the device count and
    masked on the host; complex buckets shard their split (re, im)
    planes through the same shard_map bodies.  Needs a mesh through
    ``ctx``; without one every bucket downgrades to ``jnp`` with a tag.
    Scalar leaves (ragged singletons) use the plain jnp engines -- a
    one-matrix bucket has nothing to shard.
    """

    name = "distributed_batch"

    def dense_batch(self, stack, *, precision, num_chunks, geometry=None,
                    ctx=None):
        mesh = _ctx_mesh(ctx)
        if mesh is None:
            return None              # no mesh attached: tagged jnp downgrade
        from . import distributed as Dm
        return Dm.batch_permanents_on_mesh(stack, mesh, precision=precision,
                                           num_chunks=num_chunks)

    def sparse_batch(self, sps, *, precision, num_chunks, geometry=None,
                     ctx=None):
        mesh = _ctx_mesh(ctx)
        if mesh is None:
            return None
        from . import distributed as Dm
        return Dm.sparse_batch_permanents_on_mesh(
            sps, mesh, precision=precision, num_chunks=num_chunks)

    def value_backend(self, route, n, *, batched, ctx=None):
        if batched and _ctx_mesh(ctx) is not None:
            return self.name
        return "jnp"


class DistributedBackend(JnpBackend):
    """Mesh-wide shard_map (core.distributed).

    Scalar dense leaves split the Gray-step space over the mesh (the
    paper's Sec. 6.3 shape, for the occasional huge matrix); batched
    plans delegate whole buckets to the ``distributed_batch`` strategy
    (data parallelism over matrices).  Needs a ctx passed through
    ``execute_plan(..., distributed_ctx=...)`` -- either a
    ``DistributedPermanent`` runner or a bare ``jax.sharding.Mesh``;
    without one it behaves like ``jnp`` (legacy contract), batched with a
    ``distributed->jnp`` downgrade tag.
    """

    name = "distributed"

    def dense(self, M, *, precision, num_chunks, geometry=None, ctx=None):
        if ctx is not None:
            # a DistributedPermanent runner computes at ITS OWN precision
            # (ctx.permanent takes none) -- only honor it when that agrees
            # with the plan, else the value would be reported and cached
            # under a precision it was never computed at
            if hasattr(ctx, "permanent") and \
                    getattr(ctx, "precision", precision) == precision:
                return _scalar(ctx.permanent(M))
            from . import distributed as Dm
            return _scalar(Dm.permanent_on_mesh(M, _ctx_mesh(ctx),
                                                precision=precision))
        return super().dense(M, precision=precision, num_chunks=num_chunks)

    def dense_batch(self, stack, *, precision, num_chunks, geometry=None,
                    ctx=None):
        return get_backend("distributed_batch").dense_batch(
            stack, precision=precision, num_chunks=num_chunks,
            geometry=geometry, ctx=ctx)

    def sparse_batch(self, sps, *, precision, num_chunks, geometry=None,
                     ctx=None):
        return get_backend("distributed_batch").sparse_batch(
            sps, precision=precision, num_chunks=num_chunks,
            geometry=geometry, ctx=ctx)

    def value_backend(self, route, n, *, batched, ctx=None):
        if batched:
            return get_backend("distributed_batch").value_backend(
                route, n, batched=batched, ctx=ctx)
        if route == ROUTE_DENSE and ctx is not None:
            return self.name
        return "jnp"


class CampaignBackend(Backend):
    """Checkpointed step-space waves for ROUTE_CAMPAIGN leaves.

    Not selected through ``SolverConfig.backend`` -- the planner routes a
    leaf here when its step-cost estimate crosses
    ``campaign_threshold``, and the :class:`CampaignSpec` it records
    (slice geometry + wave-body backend + precision) fully determines the
    numerics.  Execution is ``core.distributed.run_campaign``: waves of
    :func:`~repro.core.distributed.slice_sums_on_mesh` over the ctx mesh
    (or a flat 1D mesh over every visible device when no ctx is
    attached), twofloat partials checkpointed to
    ``SolverConfig.campaign_checkpoint`` after each wave, fixed-order
    final reduce.  A ``campaign_max_waves`` budget that expires with
    slices pending raises :class:`~repro.core.distributed.CampaignPaused`
    through ``execute_plan`` (the checkpoint holds the progress).
    """

    name = "campaign"

    def campaign(self, M: np.ndarray, spec: CampaignSpec, *,
                 ctx: Any | None = None, checkpoint_path: str | None = None,
                 progress_cb=None,
                 max_waves: int | None = None) -> complex | float:
        from . import distributed as Dm
        mesh = _ctx_mesh(ctx)
        if mesh is None:
            import jax
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()), ("step",))
        value, state = Dm.run_campaign(
            M, mesh, total_slices=spec.total_slices,
            chunks_per_slice=spec.chunks_per_slice,
            chunk_size=spec.chunk_size, precision=spec.precision,
            backend=spec.backend, geometry=spec.geometry,
            checkpoint_path=checkpoint_path,
            progress_cb=progress_cb, max_waves=max_waves)
        if value is None:
            raise Dm.CampaignPaused(state)
        return _scalar(value)


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend, name: str | None = None) -> Backend:
    """Register a strategy object under ``name`` (default: backend.name)."""
    _BACKENDS[name or backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{sorted(_BACKENDS)}") from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


register_backend(JnpBackend())
register_backend(PallasBackend())
register_backend(DistributedBackend())
register_backend(DistributedBatchBackend())
register_backend(CampaignBackend())

_FALLBACK = "jnp"


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

def _geometry_tag(leaf: LeafTask, produced_by: str) -> str:
    """Geometry component of the cache key for ``leaf``.

    A geometry tag enters the key only when kernel numerics actually
    depend on it: campaign leaves carry theirs on the spec (the wave
    body is the kernel), plain leaves only when a Pallas kernel serves
    them.  Values produced by the jnp engines -- including pallas->jnp
    downgrades -- key under the ``"-"`` sentinel so tuning never splits
    or contaminates geometry-free results.
    """
    if leaf.route == ROUTE_CAMPAIGN:
        g = leaf.campaign.geometry if leaf.campaign is not None else None
    elif produced_by == "pallas":
        g = leaf.geometry
    else:
        g = None
    return g.tag() if g is not None else "-"


def _cache_key(leaf: LeafTask, plan: ExecutionPlan, produced_by: str) -> tuple:
    """Result-cache key for ``leaf``.

    ``produced_by`` is the *value-producing* backend name (see
    ``Backend.value_backend``), NOT ``plan.config.backend`` -- a
    pallas/distributed bucket that downgrades to jnp stores (and finds)
    its numbers under ``jnp``, so a jnp-computed value can never satisfy
    a genuine kernel lookup whose numerics differ at the ulp level.
    The leaf dtype is part of the identity too (belt and braces over the
    content hash): a float64 leaf and a complex128 leaf with zero
    imaginary part must never collide, and ``plan.precision`` is the
    *effective* precision, so a complex ``qq`` plan keys under ``kahan``.
    Resolved kernel geometry joins the key the same way (see
    :func:`_geometry_tag`): two geometries reduce in different fixed
    orders and must never share an entry.
    """
    return ResultCache.key(leaf.key, leaf.route, plan.precision,
                           produced_by, plan.config.num_chunks,
                           dtype=leaf.matrix.dtype.str,
                           geometry=_geometry_tag(leaf, produced_by))


def _run_leaf(leaf: LeafTask, plan: ExecutionPlan, backend: Backend,
              report: PermanentReport, stats: ExecStats,
              ctx: Any | None) -> complex | float:
    """One leaf through the scalar strategy path (plan-order dispatch)."""
    n = leaf.n
    cfg = plan.config
    if leaf.route == ROUTE_SPARSE:
        # scalar sparse tags carry backend attribution like every batch
        # tag: ``sparse(n=..,<backend>)``, with a ``cfg->produced``
        # downgrade suffix when another strategy's numerics serve the
        # leaf -- so --plan-json reports where sparse values came from
        produced = backend.value_backend(ROUTE_SPARSE, n, batched=False,
                                         ctx=ctx)
        if produced == cfg.backend:
            tag = f"sparse(n={n},{produced})"
        else:
            tag = f"sparse(n={n},{cfg.backend}->{produced})"
            stats.downgrades.append(tag)
        report.dispatch.append(tag)
        sp = S.SparseMatrix.from_dense(leaf.matrix)
        t0 = time.perf_counter()
        val = backend.sparse(sp, precision=plan.precision,
                             num_chunks=cfg.num_chunks,
                             geometry=leaf.geometry, ctx=ctx)
        stats.record_time(f"sparse(n={n},{produced})",
                          time.perf_counter() - t0)
    else:
        produced = backend.value_backend(ROUTE_DENSE, n, batched=False,
                                         ctx=ctx)
        report.dispatch.append(f"dense(n={n})")
        t0 = time.perf_counter()
        val = backend.dense(leaf.matrix, precision=plan.precision,
                            num_chunks=cfg.num_chunks,
                            geometry=leaf.geometry, ctx=ctx)
        stats.record_time(f"dense(n={n},{produced})",
                          time.perf_counter() - t0)
    stats.device_dispatches += 1
    stats.scalar_leaves += 1
    return val


def _inline_value(m: np.ndarray) -> complex | float:
    return m[0, 0] if m.shape[0] == 1 else \
        m[0, 0] * m[1, 1] + m[0, 1] * m[1, 0]


def execute_plan(plan: ExecutionPlan, *, cache: ResultCache | None = None,
                 distributed_ctx: Any | None = None,
                 campaign_progress=None):
    """Dispatch every leaf of ``plan`` and accumulate per-matrix totals.

    Returns ``(totals, reports, stats)`` where ``totals`` is a (B,)
    complex128 array (callers extract the real part for real plans),
    ``reports`` one PermanentReport per planned matrix, and ``stats`` the
    dispatch/cache accounting.  ``campaign_progress`` is an optional
    ``JobState -> None`` callback fired after every checkpointed wave of
    a ROUTE_CAMPAIGN leaf.
    """
    cfg = plan.config
    backend = get_backend(cfg.backend)
    fallback = get_backend(_FALLBACK)
    stats = ExecStats()
    B = plan.num_matrices
    totals = np.zeros(B, dtype=np.complex128)
    reports = [PermanentReport(n=e.n, nnz=e.nnz, density=e.density,
                               dm_removed=e.dm_removed,
                               fm_leaves=e.fm_leaves,
                               leaf_sizes=list(e.leaf_sizes),
                               precision=plan.precision, backend=cfg.backend)
               for e in plan.entries]
    for e in plan.entries:
        totals[e.index] += e.const
    if plan.precision_downgrade:
        # surface the planner's silent complex precision fallback the same
        # way backend downgrades are surfaced (satellite: qq->kahan tag)
        ptag = f"precision({plan.precision_downgrade})"
        stats.downgrades.append(ptag)
        for r in reports:
            r.dispatch.append(ptag)

    def produced_by(leaf: LeafTask, batched: bool) -> str:
        """Name of the strategy whose numerics will serve this leaf.

        Campaign leaves key under the full wave-body identity recorded in
        their spec -- backend AND slice geometry -- because the twofloat
        wave partials depend on the decomposition, not just the engine."""
        if leaf.route == ROUTE_CAMPAIGN:
            s = leaf.campaign
            return (f"campaign[{s.backend},{s.total_slices}x"
                    f"{s.chunks_per_slice}x{s.chunk_size}]")
        return backend.value_backend(leaf.route, leaf.n, batched=batched,
                                     ctx=distributed_ctx)

    def lookup(leaf: LeafTask, batched: bool):
        if cache is None:
            return None, None
        key = _cache_key(leaf, plan, produced_by(leaf, batched))
        val = cache.get(key)
        if val is None:
            stats.cache_misses += 1
        else:
            stats.cache_hits += 1
        return key, val

    campaign_leaves = [l for l in plan.leaves if l.route == ROUTE_CAMPAIGN]

    def campaign_ckpt(leaf: LeafTask) -> str | None:
        """Checkpoint path for a campaign leaf: the configured path
        verbatim for a single-campaign plan, leaf-key-suffixed when
        several leaves campaign (their JobStates must not collide)."""
        base = cfg.campaign_checkpoint
        if base is None:
            return None
        if len(campaign_leaves) == 1:
            return base
        return f"{base}.{leaf.key[:12]}.npz"

    def run_campaign_leaf(leaf: LeafTask) -> complex | float:
        spec = leaf.campaign
        reports[leaf.owner].dispatch.append(
            f"step_sharded(n={leaf.n},slices={spec.total_slices},"
            f"{spec.backend})")
        t0 = time.perf_counter()
        val = get_backend("campaign").campaign(
            leaf.matrix, spec, ctx=distributed_ctx,
            checkpoint_path=campaign_ckpt(leaf),
            progress_cb=campaign_progress,
            max_waves=cfg.campaign_max_waves)
        stats.record_time(f"step_sharded(n={leaf.n},{spec.backend})",
                          time.perf_counter() - t0)
        stats.device_dispatches += 1
        stats.scalar_leaves += 1
        return val

    if not plan.batched:
        # scalar mode: strict plan-order per-leaf dispatch (legacy
        # ``permanent`` numerics, tag for tag)
        for leaf in plan.leaves:
            key, val = lookup(leaf, False)
            if val is not None:
                reports[leaf.owner].dispatch.append(
                    f"cache({leaf.route},n={leaf.n})")
            elif leaf.route == ROUTE_CAMPAIGN:
                val = run_campaign_leaf(leaf)
                if key is not None:
                    cache.put(key, val)
            else:
                val = _run_leaf(leaf, plan, backend, reports[leaf.owner],
                                stats, distributed_ctx)
                if key is not None:
                    cache.put(key, val)
            totals[leaf.owner] += leaf.coef * val
        return totals, reports, stats

    # batched mode: inline folds, cache probe, then bucket programs.
    # With a cache attached, duplicate leaves inside one cold batch are
    # scheduled once: followers resolve from the cache after their
    # bucket runs (boson-sampling streams repeat submatrices *within* a
    # request batch, not just across calls).  ``computed`` is keyed by
    # the PROBE key (batched producing-backend prediction); the store key
    # may differ when a bucket downgrades or a singleton takes the scalar
    # path -- followers always resolve through the probe key.
    pending: dict[tuple[str, int], list[int]] = {}
    computed: dict[tuple, complex | float] = {}   # this call's results
    followers: list[LeafTask] = []
    for (route, n), idxs in plan.buckets.items():
        for j in idxs:
            leaf = plan.leaves[j]
            if route == ROUTE_INLINE:
                reports[leaf.owner].dispatch.append(f"dense(n={n})")
                totals[leaf.owner] += leaf.coef * _inline_value(leaf.matrix)
                stats.inline_leaves += 1
                continue
            if cache is not None:
                key = _cache_key(leaf, plan, produced_by(leaf, True))
                if key in computed:
                    followers.append(leaf)
                    continue
                val = cache.get(key)
                if val is not None:
                    stats.cache_hits += 1
                    reports[leaf.owner].dispatch.append(
                        f"cache({route},n={n})")
                    totals[leaf.owner] += leaf.coef * val
                    continue
                stats.cache_misses += 1
                computed[key] = None      # scheduled; filled after its bucket
            pending.setdefault((route, n), []).append(j)

    for (route, n), idxs in sorted(pending.items()):
        bucket_leaves = [plan.leaves[j] for j in idxs]
        if route == ROUTE_CAMPAIGN:
            # campaign leaves never share a device program: each is its
            # own checkpointed wave sequence (probe key == store key --
            # the campaign identity is batched-independent)
            bname = produced_by(bucket_leaves[0], True)
            for leaf in bucket_leaves:
                val = run_campaign_leaf(leaf)
                if cache is not None:
                    k = _cache_key(leaf, plan, bname)
                    cache.put(k, val)
                    computed[k] = val
                totals[leaf.owner] += leaf.coef * complex(val)
            continue
        # one device program per resolved kernel geometry: a (route, n)
        # bucket can mix densities whose tuning-table hits differ, and
        # geometry is a static jit argument AND numeric identity -- such
        # leaves must never share a dispatch
        groups: dict[str, list[LeafTask]] = {}
        for leaf in bucket_leaves:
            gtag = leaf.geometry.tag() if leaf.geometry is not None else "-"
            groups.setdefault(gtag, []).append(leaf)
        for _gtag, leaves in sorted(groups.items()):
            bname = produced_by(leaves[0], True)
            geometry = leaves[0].geometry
            # ragged straggler: scalar path -- but only while the scalar
            # strategy produces the same numerics family as the bucket
            # one (under distributed+mesh the scalar path is the
            # step-space split, which is NOT bit-identical to the batch
            # engines and would be stored under a key the batched probes
            # never use)
            if len(leaves) == 1 and bname == produced_by(leaves[0], False):
                leaf = leaves[0]
                val = _run_leaf(leaf, plan, backend, reports[leaf.owner],
                                stats, distributed_ctx)
                if cache is not None:
                    cache.put(_cache_key(leaf, plan, bname), val)
                    computed[_cache_key(leaf, plan, bname)] = val
                totals[leaf.owner] += leaf.coef * complex(val)
                continue
            tag = f"{route}_batch(n={n},b={len(leaves)})"
            t_bucket = time.perf_counter()
            if route == ROUTE_DENSE:
                stack = np.stack([l.matrix for l in leaves])
                vals = backend.dense_batch(stack, precision=plan.precision,
                                           num_chunks=cfg.num_chunks,
                                           geometry=geometry,
                                           ctx=distributed_ctx)
                if vals is None:     # e.g. tiny bucket under pallas
                    vals = fallback.dense_batch(stack,
                                                precision=plan.precision,
                                                num_chunks=cfg.num_chunks)
                    tag = f"{route}_batch(n={n},b={len(leaves)}," \
                          f"{cfg.backend}->{_FALLBACK})"
                    stats.downgrades.append(tag)
                    bname = _FALLBACK   # the fallback produced these values
            else:
                sps = [S.SparseMatrix.from_dense(l.matrix) for l in leaves]
                vals = backend.sparse_batch(sps, precision=plan.precision,
                                            num_chunks=cfg.num_chunks,
                                            geometry=geometry,
                                            ctx=distributed_ctx)
                if vals is None:
                    vals = fallback.sparse_batch(sps,
                                                 precision=plan.precision,
                                                 num_chunks=cfg.num_chunks)
                    tag = f"{route}_batch(n={n},b={len(leaves)}," \
                          f"{cfg.backend}->{_FALLBACK})"
                    stats.downgrades.append(tag)
                    bname = _FALLBACK
            stats.device_dispatches += 1
            stats.batched_leaves += len(leaves)
            stats.record_time(f"{route}_batch(n={n},{bname})",
                              time.perf_counter() - t_bucket,
                              leaves=len(leaves))
            vals = np.asarray(vals)
            for leaf, v in zip(leaves, vals):
                v = _scalar(v)
                reports[leaf.owner].dispatch.append(tag)
                if cache is not None:
                    cache.put(_cache_key(leaf, plan, bname), v)
                    computed[_cache_key(leaf, plan,
                                        produced_by(leaf, True))] = v
                totals[leaf.owner] += leaf.coef * v

    for leaf in followers:                 # duplicates of scheduled leaves
        # resolve from this call's own results, not the shared cache -- an
        # LRU smaller than the batch may already have evicted the entry
        val = computed[_cache_key(leaf, plan, produced_by(leaf, True))]
        assert val is not None, "scheduled leaf must have been computed"
        cache.hits += 1                    # in-flight dedup is still a hit
        stats.cache_hits += 1
        reports[leaf.owner].dispatch.append(
            f"cache({leaf.route},n={leaf.n})")
        totals[leaf.owner] += leaf.coef * val
    return totals, reports, stats
