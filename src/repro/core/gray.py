"""Gray-code machinery for Ryser/Nijenhuis-Wilf permanent computation.

The Nijenhuis-Wilf variant iterates column subsets S of {0..n-2} in binary
reflected Gray-code order: at global step ``g`` (1-based) the changed bit is
``j = ctz(g)`` and its new value is bit ``j`` of ``gray(g) = g ^ (g >> 1)``.

Window/alignment properties used throughout the framework (the TPU analogue
of the paper's CEG optimization, Sec. 3.2.1):

* ``CBL_n`` (changed-bit-location sequence) is a palindrome and satisfies
  ``CBL_n = CBL_{n-1} + [n-1] + CBL_{n-1}``, hence for chunks of size
  ``2^k`` starting at multiples of ``2^k``, the changed bit at local step
  ``w`` is ``ctz(w)`` -- identical for every chunk -- for all ``w < 2^k``.
  Only the final local step (``w = 2^k``) has a chunk-dependent bit.
* The accumulation sign ``(-1)^g`` equals ``(-1)^w`` for aligned power-of-2
  chunks (the chunk base ``t * 2^k`` is even for ``k >= 1``).

All helpers are dual: Python-int versions for trace-time constant folding
(the analogue of the paper's matrix-specific rebuild) and jnp versions for
in-kernel vectorized evaluation over lanes.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "gray",
    "ctz",
    "gray_bit",
    "step_sign",
    "changed_bit_schedule",
    "gray_bits_matrix",
    "gray_code_jnp",
    "step_sign_jnp",
    "accum_sign",
]


# ---------------------------------------------------------------------------
# Python-int versions (trace-time constants; exact for any n via bigints)
# ---------------------------------------------------------------------------

def gray(g: int) -> int:
    """The g-th binary reflected Gray code."""
    return g ^ (g >> 1)


def ctz(g: int) -> int:
    """Count trailing zeros == index of the bit changed at step g (g >= 1)."""
    if g <= 0:
        raise ValueError("ctz requires g >= 1")
    return (g & -g).bit_length() - 1


def gray_bit(g: int, j: int) -> int:
    """Bit j of gray(g)."""
    return (gray(g) >> j) & 1


def step_sign(g: int) -> int:
    """+1 if the changed bit at step g turned on, else -1.

    The changed bit is ``j = ctz(g)``; its new value is ``gray_bit(g, j)``.
    """
    return 2 * gray_bit(g, ctz(g)) - 1


def accum_sign(g: int) -> int:
    """(-1)^g factor applied to the step-g product term."""
    return -1 if (g & 1) else 1


def changed_bit_schedule(chunk_log2: int) -> np.ndarray:
    """Changed-bit index for local steps ``w = 1 .. 2^k - 1`` of an aligned
    power-of-2 chunk (identical for every chunk; the last step ``w = 2^k``
    is chunk-dependent and excluded).  Length ``2^k - 1``.
    """
    k = chunk_log2
    return np.array([ctz(w) for w in range(1, 1 << k)], dtype=np.int32)


def gray_bits_matrix(starts: np.ndarray, nbits: int) -> np.ndarray:
    """(nbits, T) 0/1 matrix: column t holds the bits of gray(starts[t]).

    Used to initialize per-chunk row-sum vectors with one matmul:
    ``X0 = x_base[:, None] + A @ G`` (the MXU analogue of Alg. 3 lines 10-13).
    """
    starts = np.asarray(starts, dtype=np.uint64)
    g = starts ^ (starts >> np.uint64(1))
    j = np.arange(nbits, dtype=np.uint64)[:, None]
    return ((g[None, :] >> j) & np.uint64(1)).astype(np.int32)


# ---------------------------------------------------------------------------
# jnp versions (vectorized over lanes inside kernels / shard_map bodies)
# ---------------------------------------------------------------------------

def gray_code_jnp(g):
    """gray(g) for integer arrays (uint32/uint64)."""
    return g ^ (g >> 1)


def step_sign_jnp(g, j):
    """Vectorized step sign: +1 if bit j of gray(g) is 1 else -1 (float32).

    ``bit_j(gray(g)) = (g >> j ^ g >> (j+1)) & 1`` avoids computing gray(g)
    for wide integer types.
    """
    one = jnp.ones((), dtype=g.dtype)
    b = ((g >> j) ^ (g >> (j + one))) & one
    return (2 * b.astype(jnp.int32) - 1)
