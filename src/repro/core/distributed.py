"""Distributed permanent execution bodies (paper Sec. 6.3, scaled to pods).

Post-campaign-refactor layering -- this module owns every *mesh program*
(shard_map bodies and their compiled-fn caches); policy lives above it:

* **step-space split** (one huge matrix over the Gray-step space):
  ``permanent_on_mesh`` is the one-shot psum path (the paper's MPI
  reduce); ``slice_sums_on_mesh`` is the wave primitive underneath the
  campaign -- one slice per device, no reduction, sentinel-padded lanes
  masked out.  ``run_campaign`` drives waves of pending slices through
  it with checkpointed twofloat partials (``core.resume.JobState``):
  deterministic slice decomposition (``core.stepspace.plan_slices``),
  elastic device count, failed waves re-queued, and a fixed-order final
  reduction -- a killed-and-resumed campaign is bitwise-identical to an
  uninterrupted one.
* **batch-axis split** (many moderate matrices): ``batch_permanents_on_mesh``
  / ``sparse_batch_permanents_on_mesh`` shard a same-size bucket's
  leading axis; each device owns whole matrices, ragged tails are padded
  and masked, and the per-device body shares the single-device engines'
  trace, so sharded values are bit-identical to the ``jnp`` backend per
  precision mode.
* **dispatch** happens one layer up: ``core.planner`` routes a leaf to
  ``step_sharded`` (campaign) when its step-cost estimate exceeds
  ``SolverConfig.campaign_threshold``, and ``core.executor``'s
  ``CampaignBackend`` / ``DistributedBackend`` / ``DistributedBatchBackend``
  strategies call down into this module.  ``DistributedPermanent`` remains
  as a thin pre-plan-era wrapper over ``run_campaign``.

Complex input is first-class everywhere: the batch-axis entry points
shard the matrices' split (re, im) planes through the same shard_map body
as the jnp backend; the step-space split carries complex through its
twofloat sums (TwoSum is componentwise-exact under complex addition)
and, under ``backend="pallas"``, runs the split-plane kernel per device.

Every accumulation in this module is governed by the fixed-order
reduction invariant (permlint rule PL001, ``docs/INVARIANTS.md``): raw
``jnp`` reductions appear only where the reduced shape is fixed by the
matrix or the ``CampaignSpec`` geometry -- never by the device count --
and each such site carries an inline ``# permlint: disable=PL001``
justification that the linter inventories on every run.

APIs:
  ``permanent_on_mesh``     one-shot step-space split (psum reduction)
  ``slice_sums_on_mesh``    per-device slice sums, no reduction (wave mode)
  ``run_campaign``          checkpointed, elastic, resumable wave driver
  ``CampaignPaused``        control-flow signal for wave-budgeted runs
  ``batch_permanents_on_mesh``         batch-axis sharded dense bucket
  ``sparse_batch_permanents_on_mesh``  batch-axis sharded sparse bucket
  ``DistributedPermanent``  legacy wrapper over ``run_campaign``
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P_

from ..utils.compat import shard_map
from . import gray as G
from . import precision as P
from .resume import JobState
from .ryser import (batched_values, batched_values_complex, chunk_geometry,
                    complex_precision, nw_base_vector, _final_factor)
from .stepspace import DEFAULT_GEOMETRY, Geometry, plan_slices

__all__ = ["permanent_on_mesh", "slice_sums_on_mesh", "run_campaign",
           "CampaignPaused",
           "batch_permanents_on_mesh", "sparse_batch_permanents_on_mesh",
           "DistributedPermanent", "plan_slices"]


def _dyn_chunk_partials(A, first_chunk, T: int, C: int, precision: str):
    """Chunk partial sums with a *traced* starting chunk index.

    Mirrors ``ryser.chunk_partial_sums`` but computes the Gray-code init
    bits and the tail schedule with jnp uint64 bit math, so the chunk
    offset may be a device-varying traced value -- required under
    shard_map, where every device runs the same program on different
    slice ids.  Needs jax_enable_x64 for n > 31 (the Pallas kernel uses a
    32-bit pair encoding on real TPUs instead; see kernels/ryser_pallas).
    """
    n = A.shape[0]
    k = int(math.log2(C))
    assert C == 1 << k and k >= 1
    dtype = A.dtype
    space = jnp.uint64(1) << jnp.uint64(n - 1)

    x_base = nw_base_vector(A)
    starts = (first_chunk.astype(jnp.uint64)
              + jnp.arange(T, dtype=jnp.uint64)) * jnp.uint64(C)
    gray_s = starts ^ (starts >> jnp.uint64(1))
    jbits = jnp.arange(n, dtype=jnp.uint64)[:, None]
    Gbits = ((gray_s[None, :] >> jbits) & jnp.uint64(1)).astype(dtype)  # (n,T)
    X0 = x_base[:, None] + A @ Gbits

    # schedules for w = 1..C-1 (host constants -- identical for all chunks)
    sched = G.changed_bit_schedule(k)
    w_arr = np.arange(1, C, dtype=np.uint64)
    jj = sched.astype(np.uint64)
    bit_j = ((w_arr >> jj) ^ (w_arr >> (jj + np.uint64(1)))) & np.uint64(1)
    mid_mask = (jj + 1 == k)
    sched_j = jnp.asarray(sched)
    base_bits = jnp.asarray(bit_j.astype(np.int32))
    mid_flags = jnp.asarray(mid_mask.astype(np.int32))
    w_parity = jnp.asarray((w_arr & np.uint64(1)).astype(np.int32))
    lane_bitk = ((starts >> jnp.uint64(k)) & jnp.uint64(1)).astype(jnp.int32)

    # tail step (w = C): traced bit math
    g_tail = starts + jnp.uint64(C)
    low = g_tail & (~g_tail + jnp.uint64(1))
    tail_j = jax.lax.population_count(low - jnp.uint64(1)).astype(jnp.int32)
    gray_t = g_tail ^ (g_tail >> jnp.uint64(1))
    tail_sign = jnp.where((gray_t & low) != 0, 1.0, -1.0).astype(dtype)
    tail_live = g_tail <= (space - jnp.uint64(1))
    tail_j = jnp.where(tail_live, tail_j, 0)

    def accum(acc, term):
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision in ("dq_acc", "qq"):
            t = P.tf_add_acc(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term)
        return (acc[0] + term, acc[1])  # dd

    def scan_body(carry, inputs):
        X, acc = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)
        s = (2 * sign_bits - 1).astype(dtype)
        X = X + A[:, col_j][:, None] * s[None, :]
        # column product over the fixed axis n -- shape set by the matrix,
        # never by device count, so association is stable across meshes
        prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis column product
        term = jnp.where(par == 1, -prod, prod)
        return (X, accum(acc, term)), None

    # derive the zero accumulator from X0 so its varying-manual-axes match
    # under shard_map (JAX >= 0.8 vma typing)
    z = X0[0] * 0
    (X, acc), _ = jax.lax.scan(
        scan_body, (X0, (z, z)), (sched_j, base_bits, mid_flags, w_parity))

    # tail: per-lane column via one-hot matmul (gather-free; kernel-identical)
    onehot = (tail_j[None, :] == jnp.arange(n, dtype=jnp.int32)[:, None])
    X = X + (A @ onehot.astype(dtype)) \
        * (tail_sign * tail_live.astype(dtype))[None, :]
    prod = jnp.prod(X, axis=0)  # permlint: disable=PL001  # fixed-axis column product
    neg = (C & 1) == 1
    term = jnp.where(tail_live, -prod if neg else prod, jnp.zeros_like(prod))
    acc = accum(acc, term)
    if precision in ("kahan", "dd"):
        return P.TwoFloat(acc[0], jnp.zeros_like(acc[0]))
    return P.TwoFloat(acc[0], acc[1])


def _device_body(A_rep, slices_local, *, spd, chunks_per_slice, C, precision):
    """Sum the slices owned by one device; returns scalar twofloat."""
    acc = P.TwoFloat(jnp.zeros((), A_rep.dtype), jnp.zeros((), A_rep.dtype))
    for i in range(spd):
        first_chunk = slices_local[0, i] * chunks_per_slice
        parts = _dyn_chunk_partials(A_rep, first_chunk, chunks_per_slice, C,
                                    precision)
        # parts has shape (chunks_per_slice,) fixed by CampaignSpec geometry,
        # identical at every device count -- association is mesh-invariant
        h, l = P.two_sum(jnp.sum(parts.hi), jnp.sum(parts.lo))  # permlint: disable=PL001  # shape-stable by CampaignSpec
        acc = P.tf_add_tf(acc, P.TwoFloat(h, l))
    return acc


def permanent_on_mesh(A, mesh: Mesh, *, precision: str = "dq_acc",
                      slices_per_device: int = 1,
                      lanes_per_device: int = 1024,
                      backend: str = "jnp"):
    """One-shot distributed permanent over every device of ``mesh``.

    The iteration space is sharded over *all* mesh axes; ``A`` is replicated
    (it is tiny); the result is the psum of twofloat partials -- the same
    communication structure as the paper's MPI reduce.

    backend="pallas" runs the TPU kernel (interpret-mode on CPU) on each
    device's chunk range instead of the jnp engine -- the full production
    path: two-level split -> Pallas grid -> lanes -> one psum.

    Complex matrices work on both backends: the jnp chunk engine and the
    twofloat psum reduction are add/sub-componentwise (TwoSum is exact
    under complex addition), and the pallas backend launches the
    split-plane complex kernel per device.  Unlike the batch engines, no
    qq->kahan mapping is needed (or applied) here: the step-space family
    has no twofloat product path -- ``_dyn_chunk_partials`` accumulates
    qq as ``tf_add_acc`` for real and complex alike, so
    ``permanent_on_mesh``, ``slice_sums_on_mesh`` and
    ``DistributedPermanent`` agree at every precision mode.
    """
    A = jnp.asarray(A)
    n = A.shape[0]
    D = math.prod(mesh.devices.shape)
    total_slices, chunks_per_slice, C = plan_slices(
        n, D, slices_per_device, lanes_per_device)
    spd = max(1, total_slices // D)
    axes = tuple(mesh.axis_names)
    slice_table = np.arange(D * spd, dtype=np.int32).reshape(D, spd)
    # slices beyond total_slices would double-count; plan_slices pads the
    # slice count to a power of two <= D*spd, so clamp via masking
    live = (slice_table < total_slices)
    slice_table = np.where(live, slice_table, 0)

    dev_slices = jax.device_put(slice_table,
                                NamedSharding(mesh, P_(axes)))
    dev_live = jax.device_put(live.astype(np.float64),
                              NamedSharding(mesh, P_(axes)))

    hi, lo = _oneshot_mesh_fn(mesh, spd, chunks_per_slice, C, precision,
                              backend)(A, dev_slices, dev_live)
    p0 = jnp.prod(nw_base_vector(A))  # permlint: disable=PL001  # length-n product, shape set by the matrix
    total = P.tf_add_acc(P.TwoFloat(hi, lo), p0)
    return P.tf_value(total) * _final_factor(n)


@lru_cache(maxsize=None)
def _oneshot_mesh_fn(mesh: Mesh, spd: int, chunks_per_slice: int, C: int,
                     precision: str, backend: str):
    """Compiled one-shot mesh program for ``permanent_on_mesh``.

    Extracted from the former per-call closure so (a) repeated one-shot
    calls on the same (mesh, plan geometry, precision, backend) reuse
    one compiled program instead of retracing every call, and (b)
    permprove can ``.lower()`` the exact production program for the
    PLI104 collective audit: exactly one twofloat psum pair -- two
    ``all-reduce`` instructions per mesh axis at most -- may appear.
    Complex input needs no extra cache key: jit re-specializes on the
    operand dtype under the same program.
    """
    axes = tuple(mesh.axis_names)

    def device_partials(A_rep, first_chunk):
        if backend == "pallas":
            fn = _pallas_device_partials_complex \
                if jnp.iscomplexobj(A_rep) else _pallas_device_partials
            return fn(A_rep, first_chunk, chunks_per_slice, C, precision,
                      vma=frozenset(axes))
        return _dyn_chunk_partials(A_rep, first_chunk, chunks_per_slice, C,
                                   precision)

    def body(A_rep, slices_local, live_local):
        acc = P.TwoFloat(jnp.zeros((), A_rep.dtype),
                         jnp.zeros((), A_rep.dtype))
        for i in range(spd):
            first_chunk = slices_local[0, i] * chunks_per_slice
            parts = device_partials(A_rep, first_chunk)
            m = live_local[0, i].astype(A_rep.dtype)
            # permlint: disable=PL001  # parts shape fixed by chunks_per_slice, mesh-invariant
            h, l = P.two_sum(jnp.sum(parts.hi) * m, jnp.sum(parts.lo) * m)
            acc = P.tf_add_tf(acc, P.TwoFloat(h, l))
        hi, lo = acc
        for ax in axes:
            hi = jax.lax.psum(hi, ax)
            lo = jax.lax.psum(lo, ax)
        return hi, lo

    # check_vma=False: interpret-mode pallas inside shard_map trips
    # the vma typing on its internal grid dynamic_slices
    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P_(), P_(axes), P_(axes)),
                             out_specs=(P_(), P_()),
                             check_vma=False))


@lru_cache(maxsize=None)
def _wave_fn(mesh: Mesh, chunks_per_slice: int, chunk_size: int,
             precision: str, backend: str, geometry: Geometry | None = None):
    """Compiled per-wave mesh program for one (mesh, geometry, precision,
    backend) -- cached so a many-wave campaign compiles ONCE per
    configuration instead of once per wave (jit caches on function
    identity; a fresh closure per call would retrace every wave).
    ``geometry`` (the tuned kernel geometry, pallas backend only) is part
    of the cache key: two geometries are two different wave programs.

    The body masks sentinel lanes (slice id < 0): a padded device runs an
    arithmetically-discarded slice-0 program -- under SPMD every device
    executes the same wave program, so the masked work costs no wall
    clock -- and its (hi, lo) contribution is multiplied to exact zero.
    """
    axes = tuple(mesh.axis_names)

    def body(A_rep, slices_local):
        sid = slices_local[0, 0]
        first_chunk = jnp.maximum(sid, 0) * chunks_per_slice
        if backend == "pallas":
            fn = _pallas_device_partials_complex \
                if jnp.iscomplexobj(A_rep) else _pallas_device_partials
            parts = fn(A_rep, first_chunk, chunks_per_slice, chunk_size,
                       precision, geometry=geometry, vma=frozenset(axes))
        else:
            parts = _dyn_chunk_partials(A_rep, first_chunk,
                                        chunks_per_slice,
                                        chunk_size, precision)
        # sentinel mask: live lanes multiply by exactly 1.0 (identity
        # under IEEE-754), padded lanes by 0.0
        m = (sid >= 0).astype(A_rep.dtype)
        # permlint: disable=PL001  # parts shape fixed by chunks_per_slice, mesh-invariant
        h, l = P.two_sum(jnp.sum(parts.hi) * m, jnp.sum(parts.lo) * m)
        return h[None], l[None]

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P_(), P_(axes)),
                             out_specs=(P_(axes), P_(axes)),
                             check_vma=False))


def slice_sums_on_mesh(A, mesh: Mesh, slice_ids: np.ndarray, *,
                       chunks_per_slice: int, chunk_size: int,
                       precision: str = "dq_acc", backend: str = "jnp",
                       geometry: Geometry | None = None):
    """Per-slice twofloat sums for one wave of D slices (no reduction).

    slice_ids: (D,) int32, one slice per device.  Entries < 0 are
    sentinel padding for short waves: their lanes return exact zeros and
    callers must discard them explicitly (``run_campaign`` does) -- no
    already-done slice is ever re-recorded.  Returns (his, los) of shape
    (D,).  ``geometry`` tunes the per-device kernel launch (pallas
    backend only; the jnp body has no kernel geometry).
    """
    A = jnp.asarray(A)
    D = math.prod(mesh.devices.shape)
    slice_ids = np.asarray(slice_ids, dtype=np.int32)
    assert slice_ids.shape == (D,)
    axes = tuple(mesh.axis_names)
    dev_slices = jax.device_put(slice_ids.reshape(D, 1),
                                NamedSharding(mesh, P_(axes)))
    his, los = _wave_fn(mesh, chunks_per_slice, chunk_size,
                        precision, backend, geometry)(A, dev_slices)
    return np.asarray(his), np.asarray(los)


def _pallas_device_partials(A_rep, first_chunk, T: int, C: int,
                            precision: str, geometry: Geometry | None = None,
                            vma=None):
    """Per-device Pallas kernel over the chunk range [first_chunk,
    first_chunk+T); the kernel's u64 lane math consumes the traced base
    index, so the same program serves every device (shard_map-safe).
    ``geometry`` tunes lanes (block size within T) and the update window
    (within C); T and C themselves come from the CampaignSpec and are
    part of the campaign's numeric identity, not the tuner's."""
    from ..kernels.ops import pad_matrix, pad_base_vector
    from ..kernels.ryser_pallas import ryser_pallas_call
    from .ryser import nw_base_vector

    n = A_rep.shape[0]
    g = geometry or DEFAULT_GEOMETRY
    TB = min(g.lanes, T)
    num_blocks = T // TB
    Wu = min(g.window, C)
    A_pad = pad_matrix(A_rep)
    xb = pad_base_vector(nw_base_vector(A_rep), A_pad.shape[0]).reshape(-1, 1)
    prec = precision if precision in ("dd", "kahan", "dq_acc", "dq_fast") \
        else "dq_acc"
    out = ryser_pallas_call(
        A_pad, xb, first_chunk, n=n, TB=TB, C=C, Wu=Wu,
        num_blocks=num_blocks, precision=prec, mode="batched",
        interpret=True, vma=vma)
    return P.TwoFloat(out[:, 0], out[:, 1])


def _pallas_device_partials_complex(A_rep, first_chunk, T: int, C: int,
                                    precision: str,
                                    geometry: Geometry | None = None,
                                    vma=None):
    """Split-plane complex analogue of ``_pallas_device_partials``: per-
    device complex kernel over [first_chunk, first_chunk+T), partials
    re-packed as a complex TwoFloat so the caller's twofloat psum
    machinery (componentwise-exact under complex addition) is unchanged."""
    from ..kernels.ops import split_base_planes, split_matrix_planes
    from ..kernels.ryser_complex import ryser_pallas_call_complex
    from .ryser import nw_base_vector

    n = A_rep.shape[0]
    g = geometry or DEFAULT_GEOMETRY
    TB = min(g.lanes, T)
    num_blocks = T // TB
    Wu = min(g.window, C)
    Ar_pad, Ai_pad = split_matrix_planes(A_rep)
    xbr, xbi = split_base_planes(nw_base_vector(A_rep), Ar_pad.shape[0])
    prec = precision if precision in ("dd", "kahan", "dq_acc", "dq_fast") \
        else "dq_acc"
    out = ryser_pallas_call_complex(
        Ar_pad, Ai_pad, xbr, xbi, first_chunk, n=n, TB=TB, C=C, Wu=Wu,
        num_blocks=num_blocks, precision=prec, interpret=True, vma=vma)
    return P.TwoFloat(out[:, 0] + 1j * out[:, 2],
                      out[:, 1] + 1j * out[:, 3])


# ---------------------------------------------------------------------------
# Batch-axis sharding: data parallelism over matrices, not Gray steps
# ---------------------------------------------------------------------------

def _batch_pad(B: int, mesh: Mesh) -> int:
    """Rows of padding needed so the batch axis divides the device count."""
    D = math.prod(mesh.devices.shape)
    return (-B) % D


@lru_cache(maxsize=None)
def _dense_batch_mesh_fn(mesh: Mesh, T: int, C: int, precision: str):
    """Compiled mesh program for one (mesh, chunk geometry, precision).

    The shard_map body is ``ryser.batched_values`` verbatim over each
    device's local sub-stack -- chunk offsets are always 0 (devices own
    whole matrices), so the host-constant CEG schedules apply unchanged
    and no dynamic-offset (``_dyn_chunk_partials``) machinery is needed.
    """
    axes = tuple(mesh.axis_names)

    def body(local):                     # (B/D, n, n) per device
        return batched_values(local, T, C, precision)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=P_(axes),
                             out_specs=P_(axes), check_vma=False))


@lru_cache(maxsize=None)
def _dense_batch_mesh_fn_complex(mesh: Mesh, T: int, C: int, precision: str):
    """Split-plane complex analogue of ``_dense_batch_mesh_fn``: the body
    is ``ryser.batched_values_complex`` verbatim over each device's local
    (re, im) sub-stacks -- one trace shared with the jnp backend."""
    axes = tuple(mesh.axis_names)

    def body(local_r, local_i):          # (B/D, n, n) x2 per device
        return batched_values_complex(local_r, local_i, T, C, precision)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P_(axes), P_(axes)),
                             out_specs=(P_(axes), P_(axes)),
                             check_vma=False))


def batch_permanents_on_mesh(stack, mesh: Mesh, *,
                             precision: str = "dq_acc",
                             num_chunks: int = 4096) -> np.ndarray:
    """Permanents of a (B, n, n) stack, batch axis sharded over ``mesh``.

    Each device computes the full 2^{n-1} step space for the matrices it
    owns (data parallelism over the bucket), so there is no cross-device
    reduction at all; ragged tails (B not divisible by the device count)
    are padded with zero matrices whose results are discarded on the
    host.  Values are bit-identical to ``ryser.perm_ryser_batched`` for
    every precision mode -- the per-device body shares its trace.
    Complex stacks shard their split (re, im) planes through
    ``ryser.batched_values_complex`` under the same contract.
    """
    stack = np.asarray(stack)
    if stack.ndim != 3 or stack.shape[1] != stack.shape[2]:
        raise ValueError(f"(B, n, n) stack required, got {stack.shape}")
    B, n = stack.shape[0], stack.shape[1]
    if n == 1:
        return np.asarray(stack[:, 0, 0])
    if n == 2:
        return np.asarray(stack[:, 0, 0] * stack[:, 1, 1]
                          + stack[:, 0, 1] * stack[:, 1, 0])
    is_complex = np.iscomplexobj(stack)
    stack = stack.astype(np.complex128 if is_complex else np.float64)
    pad = _batch_pad(B, mesh)
    if pad:
        stack = np.concatenate(
            [stack, np.zeros((pad, n, n), stack.dtype)], axis=0)
    axes = tuple(mesh.axis_names)
    T, C, _ = chunk_geometry(n, num_chunks)
    shard = NamedSharding(mesh, P_(axes))
    if is_complex:
        vr, vi = _dense_batch_mesh_fn_complex(
            mesh, T, C, complex_precision(precision))(
            jax.device_put(np.ascontiguousarray(stack.real), shard),
            jax.device_put(np.ascontiguousarray(stack.imag), shard))
        return (np.asarray(vr) + 1j * np.asarray(vi))[:B]
    dev_stack = jax.device_put(stack, shard)
    vals = _dense_batch_mesh_fn(mesh, T, C, precision)(dev_stack)
    return np.asarray(vals)[:B]


@lru_cache(maxsize=None)
def _sparse_batch_mesh_fn(mesh: Mesh, T: int, C: int, precision: str):
    from .sparyser import sparse_batched_values
    axes = tuple(mesh.axis_names)

    def body(A_local, rows_local, vals_local):
        return sparse_batched_values(A_local, rows_local, vals_local,
                                     T, C, precision)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P_(axes), P_(axes), P_(axes)),
                             out_specs=P_(axes), check_vma=False))


@lru_cache(maxsize=None)
def _sparse_batch_mesh_fn_complex(mesh: Mesh, T: int, C: int,
                                  precision: str):
    from .sparyser import sparse_batched_values_complex
    axes = tuple(mesh.axis_names)

    def body(Ar_local, Ai_local, rows_local, vr_local, vi_local):
        return sparse_batched_values_complex(
            Ar_local, Ai_local, rows_local, vr_local, vi_local,
            T, C, precision)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P_(axes),) * 5,
                             out_specs=(P_(axes), P_(axes)),
                             check_vma=False))


@lru_cache(maxsize=None)
def _sparse_batch_mesh_fn_pallas(mesh: Mesh, precision: str):
    """Per-device SpaRyser *kernel* over the local sub-stack: the sparse
    analogue of ``permanent_on_mesh``'s ``backend="pallas"`` -- each
    device launches the (batch, block)-grid padded-CCS kernel on the
    matrices it owns (``kernels.ops.sparse_batched_values_pallas``; the
    traced body splits complex planes itself, so one mesh program serves
    real and complex buckets alike).  Kernel numerics, not the jnp trace:
    values match the single-device pallas backend, and the jnp path to
    the usual 1e-9 kernel tolerance rather than bitwise.
    """
    from ..kernels.ops import sparse_batched_values_pallas
    axes = tuple(mesh.axis_names)

    def body(A_local, rows_local, vals_local):
        return sparse_batched_values_pallas(A_local, rows_local,
                                            vals_local, precision=precision)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P_(axes),) * 3,
                             out_specs=P_(axes), check_vma=False))


def sparse_batch_permanents_on_mesh(sps: list, mesh: Mesh, *,
                                    precision: str = "dq_acc",
                                    num_chunks: int = 4096,
                                    backend: str = "jnp") -> np.ndarray:
    """Sparse-bucket analogue of :func:`batch_permanents_on_mesh`.

    The bucket is packed once on the host (``sparyser.pack_padded_ccs``,
    bucket-wide maxdeg -- padding scatters into the dummy row and never
    perturbs numerics), padded to the device count with inert all-dummy
    entries, and the padded-CCS SpaRyser body is sharded over the batch
    axis.  Bit-identical to ``sparyser.perm_sparyser_batched`` -- complex
    buckets included (split re/im planes through
    ``sparyser.sparse_batched_values_complex``).

    ``backend="pallas"`` runs the SpaRyser *kernel* per device instead of
    the jnp trace (real or complex, one body) -- the last ``--mesh``
    route that used to have no kernel option.  Kernel values agree with
    the jnp path to the established 1e-9 pallas tolerance (the bitwise
    contract is jnp<->distributed's, not the kernel's).
    """
    from .sparyser import pack_padded_ccs, perm_sparyser_chunked
    assert sps, "empty bucket"
    n = sps[0].n
    if n <= 2:
        return np.array([perm_sparyser_chunked(sp, num_chunks=num_chunks,
                                               precision=precision)
                         for sp in sps])
    A_stack, rows_stack, vals_stack = pack_padded_ccs(sps)
    B = A_stack.shape[0]
    pad = _batch_pad(B, mesh)
    if pad:
        maxdeg = rows_stack.shape[2]
        A_stack = np.concatenate(
            [A_stack, np.zeros((pad, n, n), A_stack.dtype)], axis=0)
        rows_stack = np.concatenate(
            [rows_stack, np.full((pad, n, maxdeg), n, np.int32)], axis=0)
        vals_stack = np.concatenate(
            [vals_stack, np.zeros((pad, n, maxdeg), vals_stack.dtype)],
            axis=0)
    axes = tuple(mesh.axis_names)
    T, C, _ = chunk_geometry(n, num_chunks)
    shard = NamedSharding(mesh, P_(axes))
    if backend == "pallas":
        vals = _sparse_batch_mesh_fn_pallas(mesh, precision)(
            jax.device_put(A_stack, shard),
            jax.device_put(rows_stack, shard),
            jax.device_put(vals_stack, shard))
        return np.asarray(vals)[:B]
    if np.iscomplexobj(vals_stack):
        vr, vi = _sparse_batch_mesh_fn_complex(
            mesh, T, C, complex_precision(precision))(
            jax.device_put(np.ascontiguousarray(A_stack.real), shard),
            jax.device_put(np.ascontiguousarray(A_stack.imag), shard),
            jax.device_put(rows_stack, shard),
            jax.device_put(np.ascontiguousarray(vals_stack.real), shard),
            jax.device_put(np.ascontiguousarray(vals_stack.imag), shard))
        return (np.asarray(vr) + 1j * np.asarray(vi))[:B]
    vals = _sparse_batch_mesh_fn(mesh, T, C, precision)(
        jax.device_put(A_stack, shard), jax.device_put(rows_stack, shard),
        jax.device_put(vals_stack, shard))
    return np.asarray(vals)[:B]


class CampaignPaused(Exception):
    """A wave-budgeted campaign ran out of ``max_waves`` with slices still
    pending.  Carries the in-memory :class:`JobState` so the caller can
    keep driving the same job (``run_campaign(..., state=exc.state)``)
    without re-reading the checkpoint."""

    def __init__(self, state: JobState):
        self.state = state
        super().__init__(
            f"campaign paused at {state.fraction_done():.1%} "
            f"({len(state.pending_slices())} of {state.total_slices} "
            "slices pending)")


def run_campaign(A, mesh: Mesh, *, total_slices: int, chunks_per_slice: int,
                 chunk_size: int, precision: str = "dq_acc",
                 backend: str = "jnp", geometry: Geometry | None = None,
                 checkpoint_path: str | None = None,
                 state: JobState | None = None, progress_cb=None,
                 max_waves: int | None = None, max_wave_retries: int = 2):
    """Execute a step-space campaign in device-count-sized waves.

    The unit of work is a *slice* (contiguous block of ``chunks_per_slice``
    chunks of ``chunk_size`` Gray steps); the decomposition comes from the
    caller (``core.stepspace.plan_slices`` via the planner's
    ``CampaignSpec``) and is independent of the runtime device count, so:

    * waves are re-formed from the pending slice set each iteration --
      a resumed job may use any mesh (elastic);
    * a failed/preempted wave records nothing; its slices stay pending
      and are re-queued into the next wave (straggler rebalance at wave
      granularity; after ``max_wave_retries`` consecutive failures the
      error propagates);
    * after each wave the twofloat per-slice partials are checkpointed
      (``JobState``, config-safe ``.npz``), losing at most one wave to a
      SIGKILL;
    * the final reduction is a fixed slice-id-order twofloat sum, so a
      killed-and-resumed run -- under any device count -- is
      bitwise-identical to an uninterrupted one.

    Returns ``(value, state)``; ``value`` is ``None`` when ``max_waves``
    paused the run with slices still pending (callers that need the
    pause as control flow raise :class:`CampaignPaused`, e.g. the
    executor's ``CampaignBackend``).
    """
    A = np.asarray(A)
    n = A.shape[0]
    D = math.prod(mesh.devices.shape)
    if state is None:
        state = JobState.load_or_create(
            checkpoint_path, A, total_slices, precision=precision,
            backend=backend, chunks_per_slice=chunks_per_slice,
            chunk_size=chunk_size,
            geometry=geometry.tag() if geometry is not None else "-")
    waves = 0
    retries = 0
    while True:
        pending = state.pending_slices()
        if not pending:
            break
        if max_waves is not None and waves >= max_waves:
            return None, state
        wave = pending[:D]
        ids = np.array(wave + [-1] * (D - len(wave)), dtype=np.int32)
        try:
            his, los = slice_sums_on_mesh(
                A, mesh, ids, chunks_per_slice=chunks_per_slice,
                chunk_size=chunk_size, precision=precision, backend=backend,
                geometry=geometry)
        except Exception:
            # preempted/straggling wave: nothing recorded, its slices
            # stay pending and the next iteration re-forms the wave
            retries += 1
            if retries > max_wave_retries:
                raise
            continue
        retries = 0
        # discard sentinel-padded lanes explicitly: only the wave's own
        # slice ids are recorded
        state.record_wave(wave, his[:len(wave)], los[:len(wave)])
        waves += 1
        if checkpoint_path:
            state.save(checkpoint_path)
        if progress_cb:
            progress_cb(state)

    hi, lo = state.reduce()
    p0 = np.prod(np.asarray(nw_base_vector(jnp.asarray(A)))).item()
    total = P.tf_add_acc(
        P.TwoFloat(jnp.asarray(hi), jnp.asarray(lo)), jnp.asarray(p0))
    # .item(): float for real jobs (the legacy return type), complex
    # for complex jobs
    value = np.asarray(P.tf_value(total)).item() * _final_factor(n)
    return value, state


@dataclass
class DistributedPermanent:
    """Checkpointable, elastic multi-slice permanent job (legacy wrapper).

    Pre-plan-era entry point kept for direct library use; the slice
    decomposition is derived from THIS mesh's device count, and the wave
    loop is :func:`run_campaign`.  New code should route through the
    planner (``SolverConfig.campaign_threshold``) so the decomposition is
    recorded in the ``ExecutionPlan`` and independent of the mesh.
    """
    mesh: Mesh
    precision: str = "dq_acc"
    slices_per_device: int = 8
    lanes_per_device: int = 1024
    checkpoint_path: str | None = None
    backend: str = "jnp"          # "pallas" -> per-device TPU kernel

    def permanent(self, A, progress_cb=None):
        A = np.asarray(A)
        n = A.shape[0]
        D = math.prod(self.mesh.devices.shape)
        total_slices, chunks_per_slice, C = plan_slices(
            n, D, self.slices_per_device, self.lanes_per_device)
        value, _ = run_campaign(
            A, self.mesh, total_slices=total_slices,
            chunks_per_slice=chunks_per_slice, chunk_size=C,
            precision=self.precision, backend=self.backend,
            checkpoint_path=self.checkpoint_path, progress_cb=progress_cb)
        return value
