"""Distributed permanent computation (paper Sec. 6.3, scaled to pods).

The paper's MPI layer statically splits the 2^{n-1} Gray-step space over
GPUs; communication is a single final reduction.  We generalize to a JAX
mesh with any number of axes (e.g. ("pod", "data", "model")):

* **two-level split** -- space -> per-device ranges (shard_map) -> per-device
  chunks (Alg. 3 / CEG inside the chunk engine).
* **over-decomposition** -- every device's range is further cut into
  ``slices_per_device`` slices; slice results are independent partial sums.
  This is the straggler-mitigation / fault-tolerance granularity: a
  restarted or re-scaled job only recomputes unfinished slices.
* **deterministic reduction** -- per-slice twofloat sums are psum'd over all
  mesh axes (one scalar pair; the paper's "communication is negligible").

APIs:
  ``permanent_on_mesh``     one-shot functional API (psum reduction)
  ``slice_sums_on_mesh``    per-device slice sums, no reduction (wave mode)
  ``DistributedPermanent``  checkpoint/restart + elastic runner (core.resume)
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P_

from ..utils.compat import shard_map
from . import gray as G
from . import precision as P
from .ryser import chunk_geometry, nw_base_vector, _final_factor

__all__ = ["permanent_on_mesh", "slice_sums_on_mesh", "DistributedPermanent",
           "plan_slices"]


def plan_slices(n: int, num_devices: int, slices_per_device: int = 8,
                lanes_per_device: int = 1024):
    """Static decomposition of the 2^{n-1} step space.

    Returns (total_slices, chunks_per_slice, chunk_size) such that
    ``total_slices * chunks_per_slice * chunk_size == 2^{n-1}`` with
    power-of-two chunk_size >= 2 (CEG alignment) and total_slices a
    power-of-two multiple of num_devices when possible.
    """
    want_chunks = num_devices * slices_per_device * lanes_per_device
    T, C, _ = chunk_geometry(n, want_chunks)
    ts = num_devices * slices_per_device
    ts = 1 << int(math.ceil(math.log2(ts)))
    while ts > 1 and (T % ts != 0 or T // ts < 1):
        ts //= 2
    return ts, T // ts, C


def _dyn_chunk_partials(A, first_chunk, T: int, C: int, precision: str):
    """Chunk partial sums with a *traced* starting chunk index.

    Mirrors ``ryser.chunk_partial_sums`` but computes the Gray-code init
    bits and the tail schedule with jnp uint64 bit math, so the chunk
    offset may be a device-varying traced value -- required under
    shard_map, where every device runs the same program on different
    slice ids.  Needs jax_enable_x64 for n > 31 (the Pallas kernel uses a
    32-bit pair encoding on real TPUs instead; see kernels/ryser_pallas).
    """
    n = A.shape[0]
    k = int(math.log2(C))
    assert C == 1 << k and k >= 1
    dtype = A.dtype
    space = jnp.uint64(1) << jnp.uint64(n - 1)

    x_base = nw_base_vector(A)
    starts = (first_chunk.astype(jnp.uint64)
              + jnp.arange(T, dtype=jnp.uint64)) * jnp.uint64(C)
    gray_s = starts ^ (starts >> jnp.uint64(1))
    jbits = jnp.arange(n, dtype=jnp.uint64)[:, None]
    Gbits = ((gray_s[None, :] >> jbits) & jnp.uint64(1)).astype(dtype)  # (n,T)
    X0 = x_base[:, None] + A @ Gbits

    # schedules for w = 1..C-1 (host constants -- identical for all chunks)
    sched = G.changed_bit_schedule(k)
    w_arr = np.arange(1, C, dtype=np.uint64)
    jj = sched.astype(np.uint64)
    bit_j = ((w_arr >> jj) ^ (w_arr >> (jj + np.uint64(1)))) & np.uint64(1)
    mid_mask = (jj + 1 == k)
    sched_j = jnp.asarray(sched)
    base_bits = jnp.asarray(bit_j.astype(np.int32))
    mid_flags = jnp.asarray(mid_mask.astype(np.int32))
    w_parity = jnp.asarray((w_arr & np.uint64(1)).astype(np.int32))
    lane_bitk = ((starts >> jnp.uint64(k)) & jnp.uint64(1)).astype(jnp.int32)

    # tail step (w = C): traced bit math
    g_tail = starts + jnp.uint64(C)
    low = g_tail & (~g_tail + jnp.uint64(1))
    tail_j = jax.lax.population_count(low - jnp.uint64(1)).astype(jnp.int32)
    gray_t = g_tail ^ (g_tail >> jnp.uint64(1))
    tail_sign = jnp.where((gray_t & low) != 0, 1.0, -1.0).astype(dtype)
    tail_live = g_tail <= (space - jnp.uint64(1))
    tail_j = jnp.where(tail_live, tail_j, 0)

    def accum(acc, term):
        if precision == "dq_fast":
            t = P.tf_add_fast(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision in ("dq_acc", "qq"):
            t = P.tf_add_acc(P.TwoFloat(*acc), term)
            return (t.hi, t.lo)
        if precision == "kahan":
            return P.kahan_add(acc, term)
        return (acc[0] + term, acc[1])  # dd

    def scan_body(carry, inputs):
        X, acc = carry
        col_j, bit, midf, par = inputs
        sign_bits = bit ^ (midf & lane_bitk)
        s = (2 * sign_bits - 1).astype(dtype)
        X = X + A[:, col_j][:, None] * s[None, :]
        prod = jnp.prod(X, axis=0)
        term = jnp.where(par == 1, -prod, prod)
        return (X, accum(acc, term)), None

    # derive the zero accumulator from X0 so its varying-manual-axes match
    # under shard_map (JAX >= 0.8 vma typing)
    z = X0[0] * 0
    (X, acc), _ = jax.lax.scan(
        scan_body, (X0, (z, z)), (sched_j, base_bits, mid_flags, w_parity))

    # tail: per-lane column via one-hot matmul (gather-free; kernel-identical)
    onehot = (tail_j[None, :] == jnp.arange(n, dtype=jnp.int32)[:, None])
    X = X + (A @ onehot.astype(dtype)) \
        * (tail_sign * tail_live.astype(dtype))[None, :]
    prod = jnp.prod(X, axis=0)
    neg = (C & 1) == 1
    term = jnp.where(tail_live, -prod if neg else prod, jnp.zeros_like(prod))
    acc = accum(acc, term)
    if precision in ("kahan", "dd"):
        return P.TwoFloat(acc[0], jnp.zeros_like(acc[0]))
    return P.TwoFloat(acc[0], acc[1])


def _device_body(A_rep, slices_local, *, spd, chunks_per_slice, C, precision):
    """Sum the slices owned by one device; returns scalar twofloat."""
    acc = P.TwoFloat(jnp.zeros((), A_rep.dtype), jnp.zeros((), A_rep.dtype))
    for i in range(spd):
        first_chunk = slices_local[0, i] * chunks_per_slice
        parts = _dyn_chunk_partials(A_rep, first_chunk, chunks_per_slice, C,
                                    precision)
        h, l = P.two_sum(jnp.sum(parts.hi), jnp.sum(parts.lo))
        acc = P.tf_add_tf(acc, P.TwoFloat(h, l))
    return acc


def permanent_on_mesh(A, mesh: Mesh, *, precision: str = "dq_acc",
                      slices_per_device: int = 1,
                      lanes_per_device: int = 1024,
                      backend: str = "jnp"):
    """One-shot distributed permanent over every device of ``mesh``.

    The iteration space is sharded over *all* mesh axes; ``A`` is replicated
    (it is tiny); the result is the psum of twofloat partials -- the same
    communication structure as the paper's MPI reduce.

    backend="pallas" runs the TPU kernel (interpret-mode on CPU) on each
    device's chunk range instead of the jnp engine -- the full production
    path: two-level split -> Pallas grid -> lanes -> one psum.
    """
    A = jnp.asarray(A)
    n = A.shape[0]
    D = math.prod(mesh.devices.shape)
    total_slices, chunks_per_slice, C = plan_slices(
        n, D, slices_per_device, lanes_per_device)
    spd = max(1, total_slices // D)
    axes = tuple(mesh.axis_names)
    slice_table = np.arange(D * spd, dtype=np.int32).reshape(D, spd)
    # slices beyond total_slices would double-count; plan_slices pads the
    # slice count to a power of two <= D*spd, so clamp via masking
    live = (slice_table < total_slices)
    slice_table = np.where(live, slice_table, 0)

    dev_slices = jax.device_put(slice_table,
                                NamedSharding(mesh, P_(axes)))
    dev_live = jax.device_put(live.astype(np.float64),
                              NamedSharding(mesh, P_(axes)))

    def device_partials(A_rep, first_chunk):
        if backend == "pallas":
            return _pallas_device_partials(A_rep, first_chunk,
                                           chunks_per_slice, C, precision,
                                           vma=frozenset(axes))
        return _dyn_chunk_partials(A_rep, first_chunk, chunks_per_slice, C,
                                   precision)

    @jax.jit
    def run(A, dev_slices, dev_live):
        def body(A_rep, slices_local, live_local):
            acc = P.TwoFloat(jnp.zeros((), A_rep.dtype),
                             jnp.zeros((), A_rep.dtype))
            for i in range(slices_local.shape[1]):
                first_chunk = slices_local[0, i] * chunks_per_slice
                parts = device_partials(A_rep, first_chunk)
                m = live_local[0, i].astype(A_rep.dtype)
                h, l = P.two_sum(jnp.sum(parts.hi) * m, jnp.sum(parts.lo) * m)
                acc = P.tf_add_tf(acc, P.TwoFloat(h, l))
            hi, lo = acc
            for ax in axes:
                hi = jax.lax.psum(hi, ax)
                lo = jax.lax.psum(lo, ax)
            return hi, lo

        # check_vma=False: interpret-mode pallas inside shard_map trips
        # the vma typing on its internal grid dynamic_slices
        return shard_map(body, mesh=mesh,
                         in_specs=(P_(), P_(axes), P_(axes)),
                         out_specs=(P_(), P_()),
                         check_vma=False)(A, dev_slices, dev_live)

    hi, lo = run(A, dev_slices, dev_live)
    p0 = jnp.prod(nw_base_vector(A))
    total = P.tf_add_acc(P.TwoFloat(hi, lo), p0)
    return P.tf_value(total) * _final_factor(n)


def slice_sums_on_mesh(A, mesh: Mesh, slice_ids: np.ndarray, *,
                       chunks_per_slice: int, chunk_size: int,
                       precision: str = "dq_acc", backend: str = "jnp"):
    """Per-slice twofloat sums for one wave of D slices (no reduction).

    slice_ids: (D,) int32, one slice per device (pad with any id; the host
    discards dead entries).  Returns (his, los) of shape (D,).
    """
    A = jnp.asarray(A)
    D = math.prod(mesh.devices.shape)
    assert slice_ids.shape == (D,)
    axes = tuple(mesh.axis_names)
    dev_slices = jax.device_put(slice_ids.reshape(D, 1),
                                NamedSharding(mesh, P_(axes)))

    @jax.jit
    def run(A, dev_slices):
        def body(A_rep, slices_local):
            first_chunk = slices_local[0, 0] * chunks_per_slice
            if backend == "pallas":
                parts = _pallas_device_partials(
                    A_rep, first_chunk, chunks_per_slice, chunk_size,
                    precision, vma=frozenset(axes))
            else:
                parts = _dyn_chunk_partials(A_rep, first_chunk,
                                            chunks_per_slice,
                                            chunk_size, precision)
            h, l = P.two_sum(jnp.sum(parts.hi), jnp.sum(parts.lo))
            return h[None], l[None]

        return shard_map(body, mesh=mesh,
                         in_specs=(P_(), P_(axes)),
                         out_specs=(P_(axes), P_(axes)),
                         check_vma=False)(A, dev_slices)

    his, los = run(A, dev_slices)
    return np.asarray(his), np.asarray(los)


def _pallas_device_partials(A_rep, first_chunk, T: int, C: int,
                            precision: str, vma=None):
    """Per-device Pallas kernel over the chunk range [first_chunk,
    first_chunk+T); the kernel's u64 lane math consumes the traced base
    index, so the same program serves every device (shard_map-safe)."""
    from ..kernels.ops import pad_matrix, pad_base_vector
    from ..kernels.ryser_pallas import ryser_pallas_call
    from .ryser import nw_base_vector

    n = A_rep.shape[0]
    TB = min(128, T)
    num_blocks = T // TB
    Wu = min(16, C)
    A_pad = pad_matrix(A_rep)
    xb = pad_base_vector(nw_base_vector(A_rep), A_pad.shape[0]).reshape(-1, 1)
    prec = precision if precision in ("dd", "kahan", "dq_acc") else "dq_acc"
    out = ryser_pallas_call(
        A_pad, xb, first_chunk, n=n, TB=TB, C=C, Wu=Wu,
        num_blocks=num_blocks, precision=prec, mode="batched",
        interpret=True, vma=vma)
    return P.TwoFloat(out[:, 0], out[:, 1])


@dataclass
class DistributedPermanent:
    """Checkpointable, elastic multi-slice permanent job.

    The unit of work is a *slice* (contiguous block of chunks).  ``run()``
    executes unfinished slices in device-count-sized waves, checkpointing
    after each wave; it can resume under a different mesh (elastic) because
    slice sums are position-independent addends.
    """
    mesh: Mesh
    precision: str = "dq_acc"
    slices_per_device: int = 8
    lanes_per_device: int = 1024
    checkpoint_path: str | None = None
    backend: str = "jnp"          # "pallas" -> per-device TPU kernel

    def permanent(self, A, progress_cb=None):
        from .resume import JobState  # local import to avoid cycle
        A = np.asarray(A)
        n = A.shape[0]
        D = math.prod(self.mesh.devices.shape)
        total_slices, chunks_per_slice, C = plan_slices(
            n, D, self.slices_per_device, self.lanes_per_device)
        state = JobState.load_or_create(self.checkpoint_path, matrix=A,
                                        total_slices=total_slices)
        pending = state.pending_slices()
        for w0 in range(0, len(pending), D):
            wave = pending[w0:w0 + D]
            ids = np.array(list(wave) + [0] * (D - len(wave)), dtype=np.int32)
            his, los = slice_sums_on_mesh(
                A, self.mesh, ids, chunks_per_slice=chunks_per_slice,
                chunk_size=C, precision=self.precision,
                backend=self.backend)
            state.record_wave(wave, his[:len(wave)], los[:len(wave)])
            if self.checkpoint_path:
                state.save(self.checkpoint_path)
            if progress_cb:
                progress_cb(state)

        hi, lo = state.reduce()
        p0 = float(np.prod(np.asarray(nw_base_vector(jnp.asarray(A)))))
        total = P.tf_add_acc(
            P.TwoFloat(jnp.asarray(hi), jnp.asarray(lo)), jnp.asarray(p0))
        return float(P.tf_value(total)) * _final_factor(n)
