"""JAX cross-version compatibility shims.

The repo targets the modern JAX API surface (``jax.shard_map``, varying
manual axes on ``ShapeDtypeStruct``), but must also run on JAX 0.4.x where
``shard_map`` lives in ``jax.experimental.shard_map`` and takes
``check_rep`` instead of ``check_vma`` (the kwarg was renamed when the
rep-typing system became vma-typing).  Every ``shard_map`` call site in
the repo goes through :func:`shard_map` below so the choice is made in
exactly one place.

Exports:

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
  -- dispatches to ``jax.shard_map`` when present, else to the legacy
  ``jax.experimental.shard_map.shard_map`` with ``check_vma`` translated
  to ``check_rep``.
* ``shape_dtype_struct(shape, dtype, vma=None)`` -- ``ShapeDtypeStruct``
  that forwards ``vma`` (varying manual axes) only on JAX versions whose
  constructor accepts it; older versions simply don't track vma, which is
  equivalent to running with ``check_vma=False``.
* ``HAS_NATIVE_SHARD_MAP`` -- True when ``jax.shard_map`` exists.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "shape_dtype_struct", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    Mirrors the modern ``jax.shard_map`` keyword API.  On JAX 0.4.x the
    call is routed to ``jax.experimental.shard_map.shard_map`` and
    ``check_vma`` becomes ``check_rep`` (same semantics: disable the
    per-output replication/vma typing check).
    """
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)


def shape_dtype_struct(shape, dtype, vma=None):
    """``jax.ShapeDtypeStruct`` forwarding ``vma`` only where supported."""
    if vma is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    try:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    except TypeError:  # JAX 0.4.x: no vma typing on avals
        return jax.ShapeDtypeStruct(shape, dtype)
