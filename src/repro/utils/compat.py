"""JAX cross-version compatibility shims.

The repo targets the modern JAX API surface (``jax.shard_map``, varying
manual axes on ``ShapeDtypeStruct``) and prefers the native symbols
whenever the installed JAX provides them; the shims below exist only as
fallbacks for older releases (ROADMAP upstream-facing item: the fallback
is self-contained and drops out once the minimum supported JAX has
``jax.shard_map``).  Every ``shard_map`` call site in the repo goes
through :func:`shard_map` so the choice is made in exactly one place --
and made ONCE, at import time, not per call.

Resolution order for ``shard_map``:

1. ``jax.shard_map`` (native, modern releases) -- used as-is;
2. ``jax.experimental.shard_map.shard_map`` (0.4.x era) -- the
   replication-check kwarg is adapted by *inspecting the signature*
   (``check_vma`` was named ``check_rep`` before the rep-typing system
   became vma-typing), so intermediate releases that renamed it under
   either module path all work.

Exports:

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
  -- version-portable shard_map mirroring the modern keyword API.
* ``shape_dtype_struct(shape, dtype, vma=None)`` -- ``ShapeDtypeStruct``
  that forwards ``vma`` (varying manual axes) only on JAX versions whose
  constructor accepts it; older versions simply don't track vma, which is
  equivalent to running with ``check_vma=False``.
* ``HAS_NATIVE_SHARD_MAP`` -- True when ``jax.shard_map`` exists.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "shape_dtype_struct", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def _resolve_shard_map():
    """Pick the shard_map implementation and its check-kwarg name once."""
    if HAS_NATIVE_SHARD_MAP:
        impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as impl
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):      # C-level / wrapped callables:
        params = None                    # assume the era's kwarg below
    if params is None:
        # signature unknown -- every call site here passes check_vma=False
        # and NEEDS the flag forwarded, so assume the name that matches
        # the resolved implementation's era rather than dropping it
        check_kw = "check_vma" if HAS_NATIVE_SHARD_MAP else "check_rep"
    elif "check_vma" in params:
        check_kw = "check_vma"
    elif "check_rep" in params:
        check_kw = "check_rep"
    else:                                # future JAX: flag dropped entirely
        check_kw = None
    return impl, check_kw


_SHARD_MAP_IMPL, _CHECK_KW = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    Mirrors the modern ``jax.shard_map`` keyword API; ``check_vma``
    travels under whatever name the resolved implementation accepts
    (``check_rep`` on 0.4.x -- same semantics: disable the per-output
    replication/vma typing check) and is dropped if it accepts neither.
    """
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_vma
    return _SHARD_MAP_IMPL(f, **kwargs)


def shape_dtype_struct(shape, dtype, vma=None):
    """``jax.ShapeDtypeStruct`` forwarding ``vma`` only where supported."""
    if vma is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    try:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    except TypeError:  # JAX 0.4.x: no vma typing on avals
        return jax.ShapeDtypeStruct(shape, dtype)
