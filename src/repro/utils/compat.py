"""JAX cross-version compatibility shims.

The repo targets the modern JAX API surface (``jax.shard_map``, varying
manual axes on ``ShapeDtypeStruct``) and resolves the native symbols ONCE
at import time, never per call.  The dual-path signature-sniffing layer
that used to probe ``check_vma``/``check_rep`` under every module path is
gone (ROADMAP upstream-facing item): resolution is a single two-way
branch -- ``jax.shard_map`` when it exists (one signature probe picks the
check kwarg: releases that promoted the symbol before the
``check_rep -> check_vma`` rename still take the old name), else
``jax.experimental.shard_map`` with its ``check_rep`` kwarg (same
semantics: disable the per-output replication/vma typing check), covering
the still-supported 0.4.x line.  That fallback CANNOT be dropped yet: the
CI floor pins ``jax>=0.4.30,<0.5``, and no 0.4.x release ever shipped the
native symbol -- delete the ``else`` branch (and this paragraph) when the
floor moves to a JAX with ``jax.shard_map``.

Exports:

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
  -- version-portable shard_map mirroring the modern keyword API.
* ``shape_dtype_struct(shape, dtype, vma=None)`` -- ``ShapeDtypeStruct``
  that forwards ``vma`` (varying manual axes) only on JAX versions whose
  constructor accepts it; older versions simply don't track vma, which is
  equivalent to running with ``check_vma=False``.
* ``HAS_NATIVE_SHARD_MAP`` -- True when ``jax.shard_map`` exists.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "shape_dtype_struct", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if HAS_NATIVE_SHARD_MAP:
    _SHARD_MAP_IMPL = jax.shard_map
    # the symbol went top-level before the check_rep -> check_vma rename:
    # probe the native signature once rather than assume the modern name
    try:
        _CHECK_KW = "check_vma" if "check_vma" in inspect.signature(
            _SHARD_MAP_IMPL).parameters else "check_rep"
    except (TypeError, ValueError):  # unsignaturable wrapper: modern kwarg
        _CHECK_KW = "check_vma"
else:  # JAX 0.4.x (the CI floor): pre-vma-typing era, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _SHARD_MAP_IMPL
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map`` mirroring the modern keyword API;
    ``check_vma`` travels as ``check_rep`` on the 0.4.x fallback."""
    return _SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KW: check_vma})


def shape_dtype_struct(shape, dtype, vma=None):
    """``jax.ShapeDtypeStruct`` forwarding ``vma`` only where supported."""
    if vma is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    try:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    except TypeError:  # JAX 0.4.x: no vma typing on avals
        return jax.ShapeDtypeStruct(shape, dtype)
