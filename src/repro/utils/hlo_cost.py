"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which massively
undercounts scanned-layer programs (our whole compile-time-economy design).
This module parses the optimized HLO text and accumulates

  * dot FLOPs                 (2 * prod(result) * prod(contracting dims))
  * bytes accessed            (operands + result per op, XLA-style)
  * collective bytes          (ring-model per participant:
                               all-gather/all-to-all/permute: result bytes;
                               reduce-scatter: operand bytes;
                               all-reduce: 2x operand bytes)

recursively through ``while`` ops, scaling by ``known_trip_count`` from the
backend_config (jax scans always carry it), and through fusion calls.
Values are per-device per-execution (the SPMD module is the per-device
program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_META_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "rng-bit-generator"}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(type_str: str):
    """First array shape in a type string -> (dtype, [dims]) or None."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum",
                "minimum", "negate", "exponential", "log", "rsqrt", "sqrt",
                "power", "tanh", "select", "compare", "and", "or", "xor",
                "shift-left", "shift-right-logical", "clamp"}


@dataclass
class HloCost:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    dot_count: float = 0.0
    while_count: int = 0
    elementwise_flops: float = 0.0   # result-element count of VPU-class ops

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.dot_flops * k, self.bytes_accessed * k,
                       self.collective_bytes * k,
                       {kk: v * k for kk, v in self.coll_by_kind.items()},
                       self.dot_count * k, self.while_count,
                       self.elementwise_flops * k)

    def add(self, o: "HloCost") -> None:
        self.dot_flops += o.dot_flops
        self.bytes_accessed += o.bytes_accessed
        self.collective_bytes += o.collective_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        self.dot_count += o.dot_count
        self.while_count += o.while_count
        self.elementwise_flops += o.elementwise_flops


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _split_computations(hlo: str) -> dict:
    """name -> list of instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{",
                         line)
            if m and ("->" in line or line.startswith("ENTRY")
                      or line.rstrip().endswith("{")):
                cur = m.group(1).lstrip("%")
                comps[cur] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
            continue
        if cur is not None and line.strip() != "}":
            comps[cur].append(line)
    return comps


def _operands(rest: str) -> list[str]:
    """Operand %names from the text after the opening paren."""
    depth = 1
    out = []
    cur = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    for m in re.finditer(r"%[\w.\-]+", args):
        out.append(m.group(0))
    return out


def _attr(line: str, name: str):
    m = re.search(name + r"=(%?[\w.\-]+)", line)
    return m.group(1).lstrip("%") if m else None


def _trip_count(line: str) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    return float(m.group(1)) if m else 1.0


def _dot_flops(line: str, result_type: str, symtab: dict,
               operands: list[str]) -> float:
    res = _shape_dims(result_type)
    if res is None or not operands:
        return 0.0
    lhs_type = symtab.get(operands[0])
    if lhs_type is None:
        return 0.0
    lhs = _shape_dims(lhs_type)
    if lhs is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contract *= lhs[1][int(d)]
    _, rdims = res
    n = 1
    for d in rdims:
        n *= d
    return 2.0 * n * contract


def _analyze_comp(name: str, comps: dict, cache: dict) -> HloCost:
    if name in cache:
        return cache[name]
    cost = HloCost()
    cache[name] = cost  # break cycles defensively
    for line in comps.get(name, ()):
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, rtype, op, rest = m.groups()
        if op in _META_OPS:
            continue
        operands = _operands(rest)
        symtab = _SYMTABS.get(name, {})
        rbytes = _type_bytes(rtype)
        obytes = sum(_type_bytes(symtab.get(o, "")) for o in operands)

        if op == "while":
            tc = _trip_count(line)
            body = _attr(line, "body")
            cond = _attr(line, "condition")
            if body:
                cost.add(_analyze_comp(body, comps, cache).scaled(tc))
            if cond:
                cost.add(_analyze_comp(cond, comps, cache).scaled(tc))
            cost.while_count += 1
            continue
        if op == "fusion":
            callee = _attr(line, "calls")
            if callee:
                sub = _analyze_comp(callee, comps, cache)
                # flops recurse; bytes counted at the fusion boundary
                cost.dot_flops += sub.dot_flops
                cost.collective_bytes += sub.collective_bytes
                cost.dot_count += sub.dot_count
            cost.bytes_accessed += rbytes + obytes
            cost.elementwise_flops += _analyze_comp(
                callee, comps, cache).elementwise_flops if callee else 0
            continue
        if op in ("call", "conditional"):
            callee = _attr(line, "to_apply") or _attr(line, "calls")
            if callee:
                cost.add(_analyze_comp(callee, comps, cache))
            continue

        kind = next((c for c in _COLL_KINDS
                     if op == c or op.startswith(c + "-")), None)
        if kind and not op.endswith("-done"):
            if kind == "all-reduce":
                nb = 2 * obytes
            elif kind == "reduce-scatter":
                nb = obytes
            else:
                nb = rbytes
            cost.collective_bytes += nb
            cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0) + nb
            cost.bytes_accessed += rbytes + obytes
            continue

        if op in ("dot", "convolution"):
            cost.dot_flops += _dot_flops(line, rtype, symtab, operands)
            cost.dot_count += 1
        if op in _ELEMENTWISE or op.startswith("reduce"):
            sd = _shape_dims(rtype)
            if sd:
                n_el = 1
                for d in sd[1]:
                    n_el *= d
                if op.startswith("reduce"):
                    # reduce flops ~= input elements
                    sin = _shape_dims(symtab.get(operands[0], "")) \
                        if operands else None
                    if sin:
                        n_el = 1
                        for d in sin[1]:
                            n_el *= d
                cost.elementwise_flops += n_el
        cost.bytes_accessed += rbytes + obytes
    cache[name] = cost
    return cost


_SYMTABS: dict = {}


def analyze_hlo(hlo_text: str) -> HloCost:
    global _SYMTABS
    comps = _split_computations(hlo_text)
    _SYMTABS = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        _SYMTABS[cname] = tab
    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    return _analyze_comp(entry, comps, {})


def top_contributors(hlo_text: str, top: int = 15):
    """(kind, shape-signature, flops-or-bytes, trip-scaled count) ranked:
    per-dot flops and per-collective bytes, trip-count aware.  The debugging
    lens for 'where do the FLOPs/collective bytes actually go'."""
    comps = _split_computations(hlo_text)
    symtabs = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        symtabs[cname] = tab
    # compute trip multiplier per computation by walking from entry
    mult = {}

    def walk(name, k):
        mult[name] = mult.get(name, 0.0) + k
        for line in comps.get(name, ()):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            op = m.group(3)
            if op == "while":
                tc = _trip_count(line)
                for attr in ("body", "condition"):
                    c = _attr(line, attr)
                    if c:
                        walk(c, k * tc)
            elif op in ("fusion", "call", "conditional"):
                c = _attr(line, "calls") or _attr(line, "to_apply")
                if c:
                    walk(c, k)

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    real_entry = next((k for k in comps if comps[k] is comps[entry]
                       and k != "__entry__"), entry)
    walk(real_entry, 1.0)

    items = []
    for cname, lines in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _, rtype, op, rest = m.groups()
            operands = _operands(rest)
            if op == "dot":
                fl = _dot_flops(line, rtype, symtabs[cname], operands)
                sig = rtype.strip() + " <- " + ",".join(
                    symtabs[cname].get(o, "?") for o in operands[:2])
                items.append(("dot", sig, fl * k, k))
            else:
                kind = next((c for c in _COLL_KINDS
                             if op == c or op.startswith(c + "-")), None)
                if kind and not op.endswith("-done"):
                    ob = sum(_type_bytes(symtabs[cname].get(o, ""))
                             for o in operands)
                    rb = _type_bytes(rtype)
                    nb = 2 * ob if kind == "all-reduce" else (
                        ob if kind == "reduce-scatter" else rb)
                    items.append((kind, rtype.strip()[:90], nb * k, k))
    items.sort(key=lambda t: -t[2])
    return items[:top]
