"""HLO text analysis: collective-communication byte accounting.

``cost_analysis()`` has no collective-bytes entry, so we parse the
optimized HLO: every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute op contributes its operand bytes (the data each
participant moves).  This feeds the roofline's collective term.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "parse_shape_bytes", "count_ops",
           "UnknownDtypeError"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# Bracketed tokens that are legitimately byte-free in HLO type strings.
# Everything else unknown (f8e4m3fn, s4, ...) raises: silently counting
# a real dtype as zero bytes corrupts the roofline's collective term.
_ZERO_BYTE_TYPES = frozenset({"token"})

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128,4096]{2,1,0}   or  (f32[8], u32[4,4])
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


class UnknownDtypeError(ValueError):
    """An HLO shape carries a dtype outside the byte table."""


def parse_shape_bytes(shape_str: str, *, allow=()) -> int:
    """Total bytes of all array shapes in an HLO type string.

    Unknown dtypes are a loud ``UnknownDtypeError`` -- counting them as
    zero silently under-reports collective traffic (the pre-PR 10 bug).
    ``allow`` extends the zero-byte allowlist (``token`` is always
    allowed) for callers that knowingly parse exotic types.
    """
    total = 0
    allowed = _ZERO_BYTE_TYPES | frozenset(allow)
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            if dt in allowed:
                continue
            raise UnknownDtypeError(
                f"unknown dtype {dt!r} in HLO shape {shape_str!r}; add it "
                f"to hlo._DTYPE_BYTES or pass allow=({dt!r},) to treat it "
                f"as zero bytes")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_type(line: str) -> str:
    """The type annotation of an HLO instruction line (lhs of '= op')."""
    m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s/]+?))\s+[\w\-]+\(", line)
    return m.group(1) if m else ""


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals from optimized HLO text.

    Bytes counted are the *result* bytes of each collective instruction
    (what lands on this participant); per-op counts are also returned.
    ``fusion``/computation bodies are included since collectives never nest
    inside fusions.
    """
    out = defaultdict(lambda: {"bytes": 0, "count": 0})
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        opname = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-") \
               or opname == c + "-start" or opname == c + "-done":
                kind = c
                break
        if kind is None:
            continue
        if opname.endswith("-done"):
            continue  # counted at -start
        nbytes = parse_shape_bytes(m.group(1))
        out[kind]["bytes"] += nbytes
        out[kind]["count"] += 1
    total = sum(v["bytes"] for v in out.values())
    return {"total_bytes": total,
            "by_kind": {k: dict(v) for k, v in out.items()}}


def count_ops(hlo_text: str, opnames=("dot", "convolution")) -> dict:
    """Instruction counts by opcode, plus every collective opcode seen.

    Async collectives lower to ``-start``/``-done`` *pairs* describing
    ONE logical op: the pair is counted once, under the base opcode
    (``all-gather-start`` + ``all-gather-done`` -> ``all-gather: 1``) --
    the same convention as ``collective_bytes``.
    """
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.+?\s+([\w\-]+)\(",
                     line)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue                      # counted at its -start
        if op.endswith("-start"):
            op = op[: -len("-start")]
        counts[op] += 1
    return {k: counts.get(k, 0) for k in opnames} | {
        k: v for k, v in counts.items() if k.startswith(_COLLECTIVES)}
