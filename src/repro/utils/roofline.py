"""Three-term roofline model over a small hardware-spec registry.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program, i.e.
all devices together -- divided by the chip count here); collective bytes
from utils/hlo.py (per-participant already -- NOT divided again).

Hardware is resolved by name through :data:`HW_SPECS`:
:func:`detect_hw` maps ``jax.devices()[0].device_kind`` onto a registered
spec (explicitly overridable via its argument or the ``REPRO_HW``
environment variable), so the tuner's pruning model and the roofline
report stop assuming v5e.  Unknown kinds fall back to ``tpu-v5e``.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, asdict

__all__ = ["HwSpec", "HW_SPECS", "HW_V5E", "detect_hw", "get_hw",
           "register_hw", "Roofline", "roofline_from_analysis"]


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float      # FLOP/s per chip (bf16)
    hbm_bw: float          # bytes/s per chip
    ici_bw: float          # bytes/s per link


HW_V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                ici_bw=50e9)

# Registered specs, keyed by canonical name.  Numbers are public
# per-chip peaks (bf16 matmul FLOP/s, HBM bytes/s, per-link ICI
# bytes/s); "cpu" is a deliberately rough host-interpreter stand-in so
# interpret-mode tuning still ranks geometry by arithmetic/byte volume.
HW_SPECS: dict[str, HwSpec] = {
    "tpu-v4": HwSpec(name="tpu-v4", peak_flops=275e12, hbm_bw=1228e9,
                     ici_bw=50e9),
    "tpu-v5e": HW_V5E,
    "tpu-v5p": HwSpec(name="tpu-v5p", peak_flops=459e12, hbm_bw=2765e9,
                      ici_bw=100e9),
    "tpu-v6e": HwSpec(name="tpu-v6e", peak_flops=918e12, hbm_bw=1640e9,
                      ici_bw=100e9),
    "cpu": HwSpec(name="cpu", peak_flops=100e9, hbm_bw=20e9, ici_bw=10e9),
}

_DEFAULT_HW = "tpu-v5e"

# device_kind substrings -> registry keys, checked in order (the kind
# strings vary across jax versions: "TPU v5e", "TPU v5 lite", ...).
_KIND_PATTERNS = (
    ("v5 lite", "tpu-v5e"), ("v5e", "tpu-v5e"), ("v5p", "tpu-v5p"),
    ("v6", "tpu-v6e"), ("trillium", "tpu-v6e"), ("v4", "tpu-v4"),
    ("cpu", "cpu"),
)


def register_hw(spec: HwSpec) -> None:
    HW_SPECS[spec.name] = spec


def get_hw(name: str | None = None) -> HwSpec:
    """Spec by registry name; None/unknown falls back to the default."""
    return HW_SPECS.get(name or _DEFAULT_HW, HW_V5E)


def detect_hw(device_kind: str | None = None) -> HwSpec:
    """Resolve the HwSpec for this host.

    Precedence: explicit ``device_kind`` argument > ``REPRO_HW``
    environment override (a registry name) > ``jax.devices()[0]``
    autodetection > the v5e default.  jax is imported lazily and any
    failure degrades to the default -- callers never see an exception.
    """
    override = os.environ.get("REPRO_HW")
    if device_kind is None and override:
        return get_hw(override)
    kind = device_kind
    if kind is None:
        try:
            import jax
            kind = jax.devices()[0].device_kind
        except Exception:  # noqa: BLE001 -- detection must never raise
            return get_hw(None)
    low = str(kind).lower()
    if low in HW_SPECS:
        return HW_SPECS[low]
    for pat, name in _KIND_PATTERNS:
        if pat in low:
            return HW_SPECS[name]
    return get_hw(None)


@dataclass
class Roofline:
    flops: float               # whole-program HLO flops (all chips)
    bytes_accessed: float      # whole-program HLO bytes (unfused upper bd)
    collective_bytes: float    # per-participant collective bytes
    chips: int
    model_flops: float = 0.0   # 6 N D (dense) / 6 N_active D (MoE)
    bytes_min: float = 0.0     # per-device argument+output traffic
                               # (fusion-optimal lower bound)
    hw: str = _DEFAULT_HW

    @property
    def spec(self) -> HwSpec:
        return get_hw(self.hw)

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * self.spec.peak_flops)

    @property
    def memory_s(self) -> float:
        """Fusion-optimal bound: every input/output buffer touched once.
        (the unfused-HLO upper bound is memory_s_hlo)"""
        if self.bytes_min:
            return self.bytes_min / self.spec.hbm_bw
        return self.memory_s_hlo

    @property
    def memory_s_hlo(self) -> float:
        return self.bytes_accessed / (self.chips * self.spec.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.spec.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms (perfect overlap)
        is the roofline; we report the max term as the bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (t * self.chips * self.spec.peak_flops)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 memory_s_hlo=self.memory_s_hlo,
                 collective_s=self.collective_s, dominant=self.dominant,
                 step_time_s=self.step_time_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 mfu_bound=self.mfu_bound)
        return d


def roofline_from_analysis(cost: dict, coll_bytes: float, chips: int,
                           model_flops: float, bytes_min: float = 0.0,
                           hw: str | None = None) -> Roofline:
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(coll_bytes),
        chips=chips, model_flops=model_flops, bytes_min=bytes_min,
        hw=hw or detect_hw().name)
