"""Three-term roofline model for TPU v5e (target hardware).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program, i.e.
all devices together -- divided by the chip count here); collective bytes
from utils/hlo.py (per-participant already -- NOT divided again).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

__all__ = ["HW_V5E", "Roofline", "roofline_from_analysis"]


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float      # FLOP/s per chip (bf16)
    hbm_bw: float          # bytes/s per chip
    ici_bw: float          # bytes/s per link


HW_V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                ici_bw=50e9)


@dataclass
class Roofline:
    flops: float               # whole-program HLO flops (all chips)
    bytes_accessed: float      # whole-program HLO bytes (unfused upper bd)
    collective_bytes: float    # per-participant collective bytes
    chips: int
    model_flops: float = 0.0   # 6 N D (dense) / 6 N_active D (MoE)
    bytes_min: float = 0.0     # per-device argument+output traffic
                               # (fusion-optimal lower bound)
    hw: str = "tpu-v5e"

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * HW_V5E.peak_flops)

    @property
    def memory_s(self) -> float:
        """Fusion-optimal bound: every input/output buffer touched once.
        (the unfused-HLO upper bound is memory_s_hlo)"""
        if self.bytes_min:
            return self.bytes_min / HW_V5E.hbm_bw
        return self.memory_s_hlo

    @property
    def memory_s_hlo(self) -> float:
        return self.bytes_accessed / (self.chips * HW_V5E.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / HW_V5E.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms (perfect overlap)
        is the roofline; we report the max term as the bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (t * self.chips * HW_V5E.peak_flops)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 memory_s_hlo=self.memory_s_hlo,
                 collective_s=self.collective_s, dominant=self.dominant,
                 step_time_s=self.step_time_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 mfu_bound=self.mfu_bound)
        return d


def roofline_from_analysis(cost: dict, coll_bytes: float, chips: int,
                           model_flops: float,
                           bytes_min: float = 0.0) -> Roofline:
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(coll_bytes),
        chips=chips, model_flops=model_flops, bytes_min=bytes_min)
