"""Cost-model-seeded kernel geometry search.

Pipeline per ``(route, n, density_bucket, dtype, precision)`` key:

1. **Enumerate** every ``(lanes, steps_per_chunk, window)`` candidate on
   a power-of-two grid, validated by the PR 8 geometry auditor
   (``analysis/geometry.py::validate_tiling``) and deduplicated by the
   clamped ``(TB, C, Wu, num_blocks)`` it resolves to at this n -- no
   candidate can violate the VMEM / step-space / window invariants.
2. **Prune** with the analytic roofline model (:func:`model_cost`,
   ``utils/roofline.py`` hardware specs): rank by modeled time, keep the
   top-k.  The default geometry is always kept, so the winner can never
   measure slower than untuned.
3. **Measure** survivors through the existing public kernel entry points
   (``kernels/ops.py``): compile once, one warm-up call, then
   median-of-repeats wall time.  The compiled module's HLO feeds
   ``utils/hlo_cost.py::analyze_hlo`` for the *refined* prediction that
   is persisted next to the measurement -- the predicted-vs-measured
   ratio is the mispredict report consumed by
   ``benchmarks/roofline_report.py``.
4. **Persist** the winner as a :class:`~repro.tune.table.TableEntry`.

Everything here runs in interpret mode on CPU (``--interpret``) or
compiled on a real accelerator; the table records which via
``device_kind``.
"""

from __future__ import annotations

import statistics
import time

from ..analysis.geometry import validate_tiling
from ..core.stepspace import DEFAULT_GEOMETRY, Geometry
from ..utils.roofline import HwSpec, detect_hw
from .table import TableEntry, TuningTable, density_bucket, host_device_kind

__all__ = ["enumerate_candidates", "model_cost", "measure_candidate",
           "tune_key", "tune_table", "ROUTES"]

ROUTES = ("dense", "complex", "sparse", "campaign")

# Power-of-two candidate grid (requested knobs; kernel_geometry clamps
# them per n, enumerate_candidates dedups the clamped results).
LANES_GRID = (32, 64, 128, 256)
SPC_GRID = (32, 64, 128, 256)
WINDOW_GRID = (8, 16, 32)

_SUBLANE = 8

# In-kernel accumulation cost multipliers relative to plain adds
# (dd = 2-op twofloat-lite, kahan = 4 ops, dq = 7-op two_sum chains).
_PREC_MULT = {"dd": 1.0, "kahan": 2.0, "dq_fast": 2.5, "dq_acc": 3.5,
              "qq": 1.0}


def _pad(n: int) -> int:
    return max(_SUBLANE, -(-n // _SUBLANE) * _SUBLANE)


def enumerate_candidates(n: int) -> list[Geometry]:
    """Valid, deduplicated candidates for matrix size n.

    The default geometry is always first; every other candidate passed
    ``validate_tiling`` and resolves to a distinct clamped
    ``(TB, C, Wu, num_blocks)``.
    """
    out = [DEFAULT_GEOMETRY]
    seen = {DEFAULT_GEOMETRY.kernel_geometry(n)}
    for lanes in LANES_GRID:
        for spc in SPC_GRID:
            for window in WINDOW_GRID:
                if validate_tiling(n, lanes, spc, window):
                    continue
                g = Geometry(lanes, spc, window)
                resolved = g.kernel_geometry(n)
                if resolved in seen:
                    continue
                seen.add(resolved)
                out.append(g)
    return out


def model_cost(geometry: Geometry, n: int, *, route: str = "dense",
               density: float = 1.0, batch: int = 1, chips: int = 1,
               hw: HwSpec | None = None) -> float:
    """Analytic roofline time (seconds) for one kernel launch.

    Per Gray step each lane does the CEG column update (~2 n_pad VPU
    flops, density-scaled on the sparse route), the running-product
    accumulation (~2 n_pad flops, precision-multiplied), and an
    amortized share of the window-boundary one-hot matmul
    (2 n_pad^2 / Wu MXU flops).  HBM traffic is the per-block working
    set (A / schedule / state planes) streamed once per block, and each
    block pays a fixed launch overhead.  This is a *ranking* model --
    the persisted prediction is refined from compiled HLO
    (:func:`measure_candidate`); the mispredict report tracks how far
    off both are.
    """
    hw = hw or detect_hw()
    TB, C, Wu, nb = geometry.kernel_geometry(n)
    n_pad = _pad(n)
    space = TB * C * nb
    cplx = 4.0 if route == "complex" else 1.0
    dens = density if route == "sparse" else 1.0
    prec = _PREC_MULT.get("dq_acc", 3.5)

    update_flops = 2.0 * n_pad * dens
    accum_flops = 2.0 * n_pad * prec
    boundary_flops = 2.0 * n_pad * n_pad / Wu
    flops = batch * space * cplx * (update_flops + accum_flops)
    dot = batch * space * cplx * boundary_flops

    # VPU-class elementwise stream vs MXU dot stream (v5e VPU ~= MXU/32)
    t_vpu = flops / (chips * hw.peak_flops / 32.0)
    t_mxu = dot / (chips * hw.peak_flops)

    from ..analysis.geometry import block_vmem_bytes
    block_bytes = block_vmem_bytes(n, TB, Wu, complex_planes=(cplx > 1))
    t_mem = batch * nb * block_bytes / (chips * hw.hbm_bw)

    launch_overhead = 2e-6
    return max(t_vpu, t_mxu, t_mem) + batch * nb * launch_overhead / chips


def _hlo_predicted_s(compiled, *, chips: int, hw: HwSpec) -> float:
    """Refined prediction from the compiled module's HLO text."""
    from ..utils.hlo_cost import analyze_hlo
    try:
        cost = analyze_hlo(compiled.as_text())
    except Exception:  # noqa: BLE001 -- prediction is best-effort
        return 0.0
    t_vpu = cost.elementwise_flops / (chips * hw.peak_flops / 32.0)
    t_mxu = cost.dot_flops / (chips * hw.peak_flops)
    t_mem = cost.bytes_accessed / (chips * hw.hbm_bw)
    return max(t_vpu, t_mxu, t_mem)


def _median_time(call, args, repeats: int) -> float:
    import jax
    jax.block_until_ready(call(*args))      # warm (compile + first run)
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(call(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _route_callable(route: str, n: int, *, density: float, batch: int,
                    precision: str, interpret: bool, seed: int,
                    mesh=None):
    """(jitted fn, concrete args) measuring one launch of ``route``.

    dense / complex / sparse go through the public batched entries in
    ``kernels/ops.py``; ``campaign`` measures one
    ``slice_sums_on_mesh`` wave body (the distributed kernel shape).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    if route in ("dense", "complex"):
        As = rng.uniform(-1, 1, (batch, n, n))
        if route == "complex":
            As = As + 1j * rng.uniform(-1, 1, (batch, n, n))
        As = jnp.asarray(As)
        from ..kernels import ops as K

        def call(geometry):
            f = jax.jit(lambda xs: K.permanent_pallas_batched(
                xs, precision=precision, geometry=geometry,
                interpret=interpret))
            return f, (As,)
        return call

    if route == "sparse":
        from ..core.sparyser import SparseMatrix, pack_padded_ccs
        from ..kernels import ops as K
        sps = []
        for _ in range(batch):
            A = rng.uniform(0.1, 1, (n, n))
            mask = rng.uniform(size=(n, n)) < density
            np.fill_diagonal(mask, True)    # keep the permanent nonzero
            sps.append(SparseMatrix.from_dense(A * mask))
        A_stack, rows_stack, vals_stack = pack_padded_ccs(sps)
        args = (jnp.asarray(A_stack), jnp.asarray(rows_stack),
                jnp.asarray(vals_stack))

        def call(geometry):
            f = jax.jit(lambda a, r, v: K.sparse_batched_values_pallas(
                a, r, v, precision=precision, geometry=geometry,
                interpret=interpret))
            return f, args
        return call

    if route == "campaign":
        if mesh is None:
            raise ValueError("campaign route requires a mesh")
        from ..core.distributed import slice_sums_on_mesh
        from ..core.stepspace import plan_slices
        A = jnp.asarray(rng.uniform(-1, 1, (n, n)))
        D = mesh.devices.size
        ts, cps, cs = plan_slices(n, D)
        ids = jnp.arange(D, dtype=jnp.int32)

        def call(geometry):
            def f(slice_ids):
                return slice_sums_on_mesh(
                    A, mesh, slice_ids, chunks_per_slice=cps,
                    chunk_size=cs, precision=precision, backend="pallas",
                    geometry=geometry)
            return f, (ids,)
        return call

    raise ValueError(f"unknown tuning route {route!r}")


def measure_candidate(call_factory, geometry: Geometry, *, repeats: int,
                      chips: int, hw: HwSpec):
    """(measured_s, hlo_predicted_s) for one candidate geometry."""
    import jax
    f, args = call_factory(geometry)
    predicted = 0.0
    try:
        compiled = jax.jit(f).lower(*args).compile()
        predicted = _hlo_predicted_s(compiled, chips=chips, hw=hw)
        runner, rargs = compiled, args
    except Exception:  # noqa: BLE001 -- shard_map bodies may not re-jit
        runner, rargs = f, args
    measured = _median_time(runner, rargs, repeats)
    return measured, predicted


def tune_key(route: str, n: int, *, density: float = 1.0,
             dtype: str = "<f8", precision: str = "dq_acc",
             batch: int = 16, top_k: int = 3, repeats: int = 3,
             interpret: bool = True, seed: int = 0, mesh=None,
             hw: HwSpec | None = None):
    """Tune one table key; returns (TableEntry, candidate report rows).

    The report rows carry every *measured* candidate's modeled,
    HLO-predicted and measured times -- the raw material of the
    mispredict report.
    """
    hw = hw or detect_hw()
    chips = mesh.devices.size if (mesh is not None
                                  and route == "campaign") else 1
    cands = enumerate_candidates(n)
    ranked = sorted(
        cands, key=lambda g: model_cost(g, n, route=route, density=density,
                                        batch=batch, chips=chips, hw=hw))
    survivors = ranked[:max(1, top_k)]
    if DEFAULT_GEOMETRY not in survivors:
        survivors.append(DEFAULT_GEOMETRY)   # tuned >= untuned floor

    call_factory = _route_callable(route, n, density=density, batch=batch,
                                   precision=precision,
                                   interpret=interpret, seed=seed,
                                   mesh=mesh)
    report = []
    results = {}
    for g in survivors:
        measured, hlo_pred = measure_candidate(
            call_factory, g, repeats=repeats, chips=chips, hw=hw)
        modeled = model_cost(g, n, route=route, density=density,
                             batch=batch, chips=chips, hw=hw)
        predicted = hlo_pred or modeled
        results[g] = (measured, predicted)
        report.append({"route": route, "n": n, "geometry": g.tag(),
                       "modeled_s": modeled, "hlo_predicted_s": hlo_pred,
                       "predicted_s": predicted, "measured_s": measured,
                       "mispredict_ratio": (predicted / measured
                                            if measured else 0.0)})

    winner = min(results, key=lambda g: results[g][0])
    measured_s, predicted_s = results[winner]
    default_s = results[DEFAULT_GEOMETRY][0]
    # planner route names: complex matrices travel the dense route with a
    # complex dtype; campaign wave bodies are the step_sharded route
    plan_route = {"campaign": "step_sharded", "complex": "dense"}.get(
        route, route)
    entry = TableEntry(
        route=plan_route,
        n=n, density_bucket=density_bucket(density), dtype=dtype,
        precision=precision, device_kind=host_device_kind(),
        geometry=winner, predicted_s=predicted_s, measured_s=measured_s,
        default_s=default_s)
    return entry, report


def tune_table(routes, ns, *, density: float = 1.0,
               precision: str = "dq_acc", batch: int = 16, top_k: int = 3,
               repeats: int = 3, interpret: bool = True, seed: int = 0,
               mesh=None, table: TuningTable | None = None,
               progress=None):
    """Tune every (route, n) pair into a TuningTable.

    Routes map to dtypes: ``dense``/``sparse``/``campaign`` tune the
    ``<f8`` key, ``complex`` the ``<c16`` key.  Returns
    (table, report rows).
    """
    table = table or TuningTable()
    report = []
    for route in routes:
        dtype = "<c16" if route == "complex" else "<f8"
        dens = density if route == "sparse" else 1.0
        for n in ns:
            if n < 4:       # below the kernel floor (executor falls back)
                continue
            entry, rows = tune_key(
                route, n, density=dens, dtype=dtype, precision=precision,
                batch=batch, top_k=top_k, repeats=repeats,
                interpret=interpret, seed=seed, mesh=mesh)
            table.put(entry)
            report.extend(rows)
            if progress:
                progress(entry)
    return table, report
