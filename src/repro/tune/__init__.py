"""Kernel geometry autotuner (cost-model-seeded search + on-disk table).

``table.py`` is the jax-free persistence layer the planner reads
(:class:`~repro.tune.table.TuningTable`); ``search.py`` is the on-device
tuner that fills it (enumerate valid candidates -> rank by roofline
model -> measure top-k -> persist winners with the predicted-vs-measured
ratio).  ``launch/tune.py`` is the CLI; ``benchmarks/autotune.py`` gates
tuned >= untuned.
"""

from .table import TableEntry, TuningTable, density_bucket, resolve_geometry

__all__ = ["TableEntry", "TuningTable", "density_bucket",
           "resolve_geometry"]
