"""On-disk tuning table: persisted kernel-geometry winners.

The table is the contract between the tuner (``repro.tune.search``, runs
once per hardware) and the planner (``core.planner``, reads it on every
``build_plan`` when ``SolverConfig.tuning_table`` is set).  Keys follow
the result cache's identity discipline: an entry is addressed by
``(route, n, density_bucket, dtype, precision, device_kind)`` and the
whole file is versioned *and* content-hash keyed against the kernel
sources -- editing any file under ``kernels/`` invalidates every table
loudly (``ValueError`` at load), because a geometry tuned for one kernel
body may be invalid, slow, or numerically different for another.

Every entry re-validates against the PR 8 geometry auditor at load time
(rule PL007, ``analysis/geometry.py::validate_tiling``): a hand-edited
table cannot smuggle a VMEM- or step-space-violating geometry into the
planner.

This module is jax-free (the planner must stay importable without jax);
:func:`host_device_kind` imports jax lazily and only when a table is
actually consulted.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass
from functools import lru_cache

from ..core.stepspace import Geometry

__all__ = ["TABLE_FORMAT_VERSION", "TableEntry", "TuningTable",
           "density_bucket", "host_device_kind", "kernel_sources_hash",
           "resolve_geometry", "table_key"]

TABLE_FORMAT_VERSION = 1

# Any-device wildcard: entries tuned in interpret mode (CPU CI) are
# recorded under the concrete host kind; ``resolve`` falls back to this.
ANY_DEVICE = "any"


def kernel_sources_hash() -> str:
    """Content hash over every kernel source file.

    Mirrors ``core/cache.py``'s content-hash discipline: the tuning
    table's winners are only meaningful for the kernel bodies they were
    measured against, so the hash covers all of ``src/repro/kernels/``.
    """
    from .. import kernels
    kdir = os.path.dirname(os.path.abspath(kernels.__file__))
    h = hashlib.sha1()
    for fname in sorted(os.listdir(kdir)):
        if not fname.endswith(".py"):
            continue
        h.update(fname.encode())
        with open(os.path.join(kdir, fname), "rb") as f:
            h.update(f.read())
    return h.hexdigest()[:16]


# Density is quantized so nearby sparsities share one tuned geometry
# (and one table entry): quarter buckets, upper-edge labeled.
_DENSITY_EDGES = (0.25, 0.50, 0.75, 1.00)


def density_bucket(density: float) -> str:
    for edge in _DENSITY_EDGES:
        if density <= edge + 1e-12:
            return f"{edge:.2f}"
    return f"{_DENSITY_EDGES[-1]:.2f}"


def table_key(route: str, n: int, density_b: str, dtype: str,
              precision: str, device_kind: str) -> str:
    return f"{route}/n{n}/d{density_b}/{dtype}/{precision}/{device_kind}"


@lru_cache(maxsize=1)
def host_device_kind() -> str:
    """Normalized ``jax.devices()[0].device_kind`` (lazy; "cpu" fallback)."""
    try:
        import jax
        return str(jax.devices()[0].device_kind).strip().lower()
    except Exception:  # noqa: BLE001 -- detection must never raise
        return "cpu"


@dataclass(frozen=True)
class TableEntry:
    route: str
    n: int
    density_bucket: str
    dtype: str                 # numpy dtype.str of the leaf, e.g. "<f8"
    precision: str
    device_kind: str
    geometry: Geometry         # the winner (requested knobs, not clamped)
    predicted_s: float         # cost-model time for the winner
    measured_s: float          # median-of-repeats measured time
    default_s: float           # measured time of DEFAULT_GEOMETRY

    @property
    def mispredict_ratio(self) -> float:
        """Cost model predicted / measured (1.0 = perfect model)."""
        return self.predicted_s / self.measured_s if self.measured_s else 0.0

    @property
    def speedup(self) -> float:
        """Untuned-default time / tuned time (>= 1.0 by construction:
        the default is always in the measured candidate set)."""
        return self.default_s / self.measured_s if self.measured_s else 0.0

    def key(self) -> str:
        return table_key(self.route, self.n, self.density_bucket,
                         self.dtype, self.precision, self.device_kind)

    def to_dict(self) -> dict:
        return {"route": self.route, "n": self.n,
                "density_bucket": self.density_bucket, "dtype": self.dtype,
                "precision": self.precision,
                "device_kind": self.device_kind,
                "geometry": self.geometry.tag(),
                "predicted_s": self.predicted_s,
                "measured_s": self.measured_s,
                "default_s": self.default_s}

    @staticmethod
    def from_dict(d: dict) -> "TableEntry":
        return TableEntry(route=d["route"], n=int(d["n"]),
                          density_bucket=d["density_bucket"],
                          dtype=d["dtype"], precision=d["precision"],
                          device_kind=d["device_kind"],
                          geometry=Geometry.from_tag(d["geometry"]),
                          predicted_s=float(d["predicted_s"]),
                          measured_s=float(d["measured_s"]),
                          default_s=float(d["default_s"]))


class TuningTable:
    """In-memory view of the persisted table; ``entries`` keyed by
    :func:`table_key`."""

    def __init__(self, entries: dict[str, TableEntry] | None = None,
                 kernels_hash: str | None = None):
        self.entries: dict[str, TableEntry] = dict(entries or {})
        self.kernels_hash = kernels_hash or kernel_sources_hash()

    def put(self, entry: TableEntry) -> None:
        self.entries[entry.key()] = entry

    def get(self, route: str, n: int, density: float, dtype: str,
            precision: str,
            device_kind: str | None = None) -> TableEntry | None:
        """Entry for the key, preferring the concrete device kind and
        falling back to the ``any`` wildcard."""
        bucket = density_bucket(density)
        kinds = [device_kind or host_device_kind()]
        if ANY_DEVICE not in kinds:
            kinds.append(ANY_DEVICE)
        for kind in kinds:
            e = self.entries.get(
                table_key(route, n, bucket, dtype, precision, kind))
            if e is not None:
                return e
        return None

    def resolve(self, route: str, n: int, density: float, dtype: str,
                precision: str,
                device_kind: str | None = None) -> Geometry | None:
        e = self.get(route, n, density, dtype, precision, device_kind)
        return e.geometry if e is not None else None

    def validate(self) -> list[str]:
        """PL007: re-validate every entry against the geometry auditor."""
        from ..analysis.geometry import validate_tiling
        bad = []
        for key, e in self.entries.items():
            g = e.geometry
            for v in validate_tiling(e.n, g.lanes, g.steps_per_chunk,
                                     g.window):
                bad.append(f"[{key}] {v}")
        return bad

    def save(self, path: str) -> None:
        doc = {"format": "repro.tune.table/v%d" % TABLE_FORMAT_VERSION,
               "version": TABLE_FORMAT_VERSION,
               "kernels_hash": self.kernels_hash,
               "entries": [e.to_dict() for _, e in
                           sorted(self.entries.items())]}
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)   # atomic like core/resume.py
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def load(cls, path: str, *, strict_hash: bool = True) -> "TuningTable":
        """Load + loudly invalidate: version skew, kernel-source drift,
        and geometry-invariant violations (PL007) all raise ValueError."""
        with open(path) as f:
            doc = json.load(f)
        ver = doc.get("version")
        if ver != TABLE_FORMAT_VERSION:
            raise ValueError(
                f"tuning table {path}: format version {ver!r} != "
                f"{TABLE_FORMAT_VERSION} -- re-run the tuner "
                "(python -m repro.launch.tune)")
        have = doc.get("kernels_hash")
        want = kernel_sources_hash()
        if strict_hash and have != want:
            raise ValueError(
                f"tuning table {path}: kernel sources changed since "
                f"tuning (table hash {have!r}, current {want!r}) -- "
                "geometry winners are stale; re-run the tuner")
        entries = {}
        for d in doc.get("entries", ()):
            e = TableEntry.from_dict(d)
            entries[e.key()] = e
        table = cls(entries, kernels_hash=have)
        bad = table.validate()
        if bad:
            raise ValueError(
                f"tuning table {path}: {len(bad)} entr(ies) violate the "
                "geometry invariants (PL007): " + "; ".join(bad[:3]))
        return table


@lru_cache(maxsize=8)
def _load_cached(path: str, mtime_ns: int) -> TuningTable:
    return TuningTable.load(path)


def resolve_geometry(path: str, route: str, n: int, density: float,
                     dtype: str, precision: str,
                     device_kind: str | None = None) -> Geometry | None:
    """Planner entry point: table hit or None, mtime-cached per file.

    A missing file is a hard error (a configured-but-absent table is a
    deployment bug, not a tuning preference); a stale or invalid table
    raises from :meth:`TuningTable.load`.
    """
    st = os.stat(path)
    table = _load_cached(os.path.abspath(path), st.st_mtime_ns)
    return table.resolve(route, n, density, dtype, precision, device_kind)
