"""Model configuration and parameter-initialization substrate.

Pure-JAX models: parameters are nested dicts of jnp arrays; layer stacks
are *scanned* (stacked leading L dim) so HLO size -- and therefore SPMD
compile time on the 512-way dry-run -- is independent of depth.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ModelCfg", "ShapeInit", "init_tree", "param_count", "tree_bytes"]


@dataclass(frozen=True)
class ModelCfg:
    """One config object covers every assigned family; unused fields are
    ignored by families that don't need them."""
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio-encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention flavor ---
    rope_theta: float = 10000.0
    swa_window: int = 0              # 0 -> full attention
    mrope_sections: tuple = ()       # e.g. (16, 24, 24) for M-RoPE (qwen2-vl)
    attn_bias: bool = False
    # --- mlp flavor ---
    mlp: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    # --- MoE ---
    n_experts: int = 0               # 0 -> dense FFN
    top_k: int = 2
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0      # apply shared attn block every N layers
    # --- enc-dec (seamless) ---
    enc_layers: int = 0              # >0 -> encoder-decoder
    # --- numerics ---
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = jnp.float32
    # --- training ---
    tie_embeddings: bool = False
    notes: str = ""
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/unembed
        tables shard evenly over any power-of-2 model axis (standard
        padded-vocab practice); padded logits are masked in the loss."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)

    def reduced(self, **overrides) -> "ModelCfg":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(4, self.n_kv_heads)),
            d_ff=128, vocab=256, head_dim=16,
            dtype=jnp.float32, param_dtype=jnp.float32,
        )
        if self.n_experts:
            kw["n_experts"] = 4
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.shared_attn_period:
            kw["shared_attn_period"] = 2
            kw["n_layers"] = 4
        if self.enc_layers:
            kw["enc_layers"] = 2
        if self.mrope_sections:
            kw["mrope_sections"] = (2, 3, 3)
        kw.update(overrides)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Initialization: every param leaf is declared as (shape, init) so the same
# tree builds either real arrays (smoke tests) or ShapeDtypeStructs (dry-run)
# ---------------------------------------------------------------------------

@dataclass
class ShapeInit:
    shape: tuple
    kind: str = "normal"   # normal | zeros | ones | scaled
    scale: float = 0.02


def init_tree(tree, key, param_dtype, abstract: bool = False):
    """Materialize a nested dict of ShapeInit into arrays (or structs)."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ShapeInit))
    if abstract:
        out = [jax.ShapeDtypeStruct(l.shape, param_dtype) for l in leaves]
        return jax.tree.unflatten(treedef, out)
    keys = jax.random.split(key, len(leaves))
    out = []
    for l, k in zip(leaves, keys):
        if l.kind == "zeros":
            out.append(jnp.zeros(l.shape, param_dtype))
        elif l.kind == "ones":
            out.append(jnp.ones(l.shape, param_dtype))
        elif l.kind == "scaled":
            fan_in = l.shape[-2] if len(l.shape) >= 2 else l.shape[-1]
            out.append(jax.random.normal(k, l.shape, param_dtype)
                       / math.sqrt(fan_in))
        else:
            out.append(l.scale * jax.random.normal(k, l.shape, param_dtype))
    return jax.tree.unflatten(treedef, out)


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def tree_bytes(params) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(params))
