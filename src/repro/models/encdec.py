"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D).  The backbone is a standard
transformer encoder (bidirectional) + decoder (causal self-attn + cross
attn), both scanned.  Decode serving keeps a self-attention KV cache plus
per-layer cross KV computed once from the encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelCfg, ShapeInit
from . import layers as L
from . import actx
from .transformer import (_ffn, _norm, _qkv, layer_param_shapes,
                          norm_param_shapes, _stack_shapes, chunked_ce_loss)

__all__ = ["encdec_param_shapes", "encdec_loss", "encode", "decode_forward",
           "encdec_prefill", "encdec_decode_step"]


def encdec_param_shapes(cfg: ModelCfg) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ShapeInit((V, D), "normal", 0.02),     # decoder tokens
        "enc_layers": _stack_shapes(layer_param_shapes(cfg), cfg.enc_layers),
        "enc_norm": norm_param_shapes(cfg),
        "dec_layers": _stack_shapes(layer_param_shapes(cfg, cross_attn=True),
                                    cfg.n_layers),
        "final_norm": norm_param_shapes(cfg),
        "unembed": ShapeInit((D, V), "scaled"),
    }


def encode(params, embeds, cfg: ModelCfg, kv_chunk: int = 1024):
    """Bidirectional encoder over stub frame embeddings (B, Se, D)."""
    h = embeds.astype(cfg.dtype)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(h, lp):
        x = _norm(lp["ln1"], h, cfg)
        q, k, v = _qkv(lp["attn"], x, cfg)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        out = L.flash_attention(q, k, v, causal=False, kv_chunk=kv_chunk)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           lp["attn"]["wo"].astype(h.dtype))
        h = h + _ffn(lp["ffn"], _norm(lp["ln2"], h, cfg), cfg)
        return actx.batch_act(h), None

    body = jax.checkpoint(body, prevent_cse=False)
    h = actx.batch_act(h)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return _norm(params["enc_norm"], h, cfg)


def _cross_attention(p, x, memory, cfg, kv_chunk: int = 1024):
    dt = x.dtype
    B, S = x.shape[:2]
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)) \
        .reshape(B, S, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,de->bse", memory, p["wk"].astype(dt)) \
        .reshape(B, memory.shape[1], cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,de->bse", memory, p["wv"].astype(dt)) \
        .reshape(B, memory.shape[1], cfg.n_kv_heads, cfg.hd)
    out = L.flash_attention(q, k, v, causal=False, kv_chunk=kv_chunk)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))


def decode_forward(params, tokens, memory, cfg: ModelCfg,
                   kv_chunk: int = 1024):
    """Teacher-forced decoder pass (training)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(h, lp):
        x = _norm(lp["ln1"], h, cfg)
        q, k, v = _qkv(lp["attn"], x, cfg)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        out = L.flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           lp["attn"]["wo"].astype(h.dtype))
        h = h + _cross_attention(lp["xattn"], _norm(lp["lnx"], h, cfg),
                                 memory, cfg, kv_chunk)
        h = h + _ffn(lp["ffn"], _norm(lp["ln2"], h, cfg), cfg)
        return actx.batch_act(h), None

    body = jax.checkpoint(body, prevent_cse=False)
    h = actx.batch_act(h)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return _norm(params["final_norm"], h, cfg)


def encdec_loss(params, batch, cfg: ModelCfg, ce_chunk: int = 512):
    """batch: {enc_embeds (B,Se,D), dec_tokens (B,Sd), labels (B,Sd)}."""
    memory = encode(params, batch["enc_embeds"], cfg)
    h = decode_forward(params, batch["dec_tokens"], memory, cfg)
    return chunked_ce_loss(h, params["unembed"], batch["labels"],
                           batch.get("mask"), chunk=ce_chunk,
                           valid_vocab=cfg.vocab)


# ---------------------------------------------------------------- serving
def encdec_prefill(params, enc_embeds, cfg: ModelCfg, max_seq: int = 0,
                   cache_dtype=jnp.bfloat16):
    """Encode once; precompute per-decoder-layer cross K/V and an empty
    decoder self-attention cache of length max_seq."""
    memory = encode(params, enc_embeds, cfg)
    B, Se = memory.shape[:2]
    max_seq = max_seq or Se

    def xkv(lp):
        k = jnp.einsum("bsd,de->bse", memory,
                       lp["xattn"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bsd,de->bse", memory,
                       lp["xattn"]["wv"].astype(memory.dtype))
        return (k.reshape(B, Se, cfg.n_kv_heads, cfg.hd).astype(cache_dtype),
                v.reshape(B, Se, cfg.n_kv_heads, cfg.hd).astype(cache_dtype))

    xk, xv = jax.vmap(xkv)(params["dec_layers"])
    kv_shape = (cfg.n_layers, B, max_seq, cfg.n_kv_heads, cfg.hd)
    return memory, {"k": jnp.zeros(kv_shape, cache_dtype),
                    "v": jnp.zeros(kv_shape, cache_dtype),
                    "xk": xk, "xv": xv}


def encdec_decode_step(params, token, pos, cache, cfg: ModelCfg,
                       kv_chunk: int = 1024):
    """cache: {k, v (L,B,Sd,KV,hd) self; xk, xv (L,B,Se,KV,hd) cross}."""
    h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    B = h.shape[0]
    positions = jnp.full((B, 1), pos)
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(h, xs):
        lp, kc, vc, xk, xv = xs
        x = _norm(lp["ln1"], h, cfg)
        q, k_new, v_new = _qkv(lp["attn"], x, cfg)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        kc = L.dus_seq(kc, k_new, pos)
        vc = L.dus_seq(vc, v_new, pos)
        out = L.flash_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                causal=True, q_offset=pos, kv_valid=pos + 1,
                                kv_chunk=kv_chunk)
        out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           lp["attn"]["wo"].astype(h.dtype))
        # cross attention over the (precomputed) encoder memory KV
        xq = jnp.einsum("bsd,de->bse", _norm(lp["lnx"], h, cfg),
                        lp["xattn"]["wq"].astype(h.dtype)) \
            .reshape(B, 1, cfg.n_heads, cfg.hd)
        xout = L.flash_attention(xq, xk.astype(h.dtype), xv.astype(h.dtype),
                                 causal=False, kv_chunk=kv_chunk)
        xout = xout.reshape(B, 1, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", xout,
                           lp["xattn"]["wo"].astype(h.dtype))
        h = h + _ffn(lp["ffn"], _norm(lp["ln2"], h, cfg), cfg)
        return h, {"k": kc, "v": vc}

    h, new_self = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = _norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    V = logits.shape[-1]
    if cfg.vocab < V:
        logits = jnp.where(jnp.arange(V)[None, None, :] < cfg.vocab,
                           logits, -1e30)
    return logits, {"k": new_self["k"], "v": new_self["v"],
                    "xk": cache["xk"], "xv": cache["xv"]}
