"""Shared layer library (pure JAX, scan- and SPMD-friendly).

Design notes:

* **flash attention** -- chunked online-softmax over KV blocks
  (``lax.scan``), O(S) memory, supports causal, sliding-window, GQA,
  cross-attention and single-query decode.  Logits/softmax in f32.
  ``merge_partial_softmax`` implements the flash-decoding combine used when
  the KV cache is *sequence-sharded* across the mesh (serve/decode_sharded).
* **MoE** -- top-k routing with capacity-bounded scatter dispatch: tokens
  are placed into an (E, cap, D) buffer via cumsum slots, experts run as
  one batched einsum (MXU-friendly, active-expert FLOPs only, EP-shardable
  over the "model" axis), results gathered back with combine weights.
* **RoPE / M-RoPE** -- rotary embeddings; M-RoPE splits the frequency
  spectrum into (temporal, height, width) sections fed by 3D position ids
  (qwen2-vl).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import actx

__all__ = [
    "rmsnorm", "layernorm", "rope_cos_sin", "mrope_cos_sin", "apply_rope",
    "flash_attention", "merge_partial_softmax", "mlp_swiglu", "mlp_gelu", "dus_seq",
    "moe_ffn", "gqa_reshape", "grad_cast",
]


@jax.custom_vjp
def grad_cast(x):
    """Identity whose COTANGENT is cast to the primal dtype.

    f32 creeps into backward cotangents through mixed-precision dots
    (preferred_element_type=f32); left alone, the per-layer TP boundary
    all-reduces of dx then move f32 bytes.  Casting the cotangent to the
    activation dtype (bf16) at each TP consumer input halves those
    collective bytes -- the standard bf16-backward policy."""
    return x


def _gc_fwd(x):
    # residual must be a JAX value; a zero-size array carries the dtype
    return x, jnp.zeros((0,), x.dtype)


def _gc_bwd(proto, ct):
    return (ct.astype(proto.dtype),)


grad_cast.defvjp(_gc_fwd, _gc_bwd)


# ---------------------------------------------------------------- norms
def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) \
        + b.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope_cos_sin(positions, head_dim: int, theta: float = 10000.0):
    """positions (..., S) -> cos/sin (..., S, head_dim/2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3, head_dim: int, sections, theta: float = 1e6):
    """M-RoPE (qwen2-vl): positions3 (3, ..., S); sections sum to hd/2.

    Frequency components are partitioned into (temporal, h, w) groups; each
    group's angles come from the corresponding position stream.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang_all = positions3.astype(jnp.float32)[..., None] * inv  # (3,...,S,half)
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, ..., off:off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd); cos/sin (..., S, hd/2) -> rotated x."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
def gqa_reshape(q, n_kv: int):
    """(B, S, H, hd) -> (B, S, KVH, G, hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


NEG_INF = -1e30


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_valid=None, kv_chunk: int = 1024,
                    softmax_scale: float | None = None):
    """Chunked online-softmax attention.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KVH, hd) with H % KVH == 0.
    causal: mask kv_pos > q_pos (q_pos = q_offset + iq; q_offset may be a
      traced scalar -- decode).  window > 0 adds kv_pos > q_pos - window.
    kv_valid: optional traced scalar; positions >= kv_valid are masked
      (partially-filled decode caches).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    q, k, v = grad_cast(q), grad_cast(k), grad_cast(v)
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    qr = gqa_reshape(q, KVH)                              # (B,Sq,KVH,G,hd)

    kc = min(kv_chunk, Sk)
    pad = (-Sk) % kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sk + pad) // kc
    ks = k.reshape(B, nc, kc, KVH, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, kc, KVH, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)                     # (Sq,) maybe traced
    limit = jnp.asarray(Sk if kv_valid is None else kv_valid)

    def body(carry, inputs):
        m, l, acc = carry
        c, (kb, vb) = inputs
        kv_pos = c * kc + jnp.arange(kc)                  # (kc,)
        # dots keep the input dtype (bf16 on TPU -> MXU rate) and
        # accumulate in f32 (preferred_element_type)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, kc), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= kv_pos[None, :] < limit
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    # IMPORTANT: derive the carry inits from qr so GSPMD propagates the
    # head sharding into the scan carry -- literal zeros/full inits are
    # replicated and force XLA to all-gather heads and compute attention
    # replicated across the model axis (observed 16x dot-flops blowup)
    qz = qr[..., 0].transpose(0, 2, 3, 1).astype(jnp.float32) * 0
    m0 = qz + NEG_INF                                     # (B,KVH,G,Sq)
    l0 = qz
    a0 = qr.transpose(0, 2, 3, 1, 4).astype(jnp.float32) * 0
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nc), (ks, vs)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KVH,G,Sq,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def merge_partial_softmax(m, l, acc, axis_name: str):
    """Flash-decoding combine across a sequence-sharded KV cache.

    Each shard computes (m, l, acc) over its local KV range; the global
    softmax is reconstructed with one max-psum and one weighted psum.
    m, l: (...) running max / normalizer; acc: (..., hd).
    """
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]


def flash_attention_partial(q, k, v, *, q_offset=0, kv_offset=0,
                            kv_valid=None, causal=True, window: int = 0,
                            kv_chunk: int = 1024):
    """Like flash_attention but returns raw (m, l, acc) for cross-shard
    merging (sequence-sharded KV decode).  kv_offset is the global position
    of this shard's first key."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qr = gqa_reshape(q, KVH)
    kc = min(kv_chunk, Sk)
    pad = (-Sk) % kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sk + pad) // kc
    ks = k.reshape(B, nc, kc, KVH, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, kc, KVH, hd).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)
    limit = jnp.asarray(Sk if kv_valid is None else kv_valid)

    def body(carry, inputs):
        m, l, acc = carry
        c, (kb, vb) = inputs
        kv_pos = kv_offset + c * kc + jnp.arange(kc)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, kc), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= kv_pos[None, :] < limit
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    qz = qr[..., 0].transpose(0, 2, 3, 1).astype(jnp.float32) * 0
    m0 = qz + NEG_INF
    l0 = qz
    a0 = qr.transpose(0, 2, 3, 1, 4).astype(jnp.float32) * 0
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nc), (ks, vs)))
    return m, l, acc


def dus_seq(cache, new, pos):
    """dynamic_update_slice along dim 1 with dtype-consistent indices."""
    z = jnp.zeros((), dtype=jnp.asarray(pos).dtype)
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (z, jnp.asarray(pos), z, z))


# ---------------------------------------------------------------- mlp
def mlp_swiglu(x, wi, wg, wo):
    x = grad_cast(x)
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))


def mlp_gelu(x, wi, wo, bi=None, bo=None):
    x = grad_cast(x)
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    if bi is not None:
        h = h + bi.astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    y = jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))
    if bo is not None:
        y = y + bo.astype(x.dtype)
    return y


# ---------------------------------------------------------------- MoE
def moe_ffn(x, router_w, wi, wg, wo, *, top_k: int, capacity_factor: float,
            return_aux: bool = False):
    """Top-k MoE with GROUPED capacity dispatch (GShard/Switch style).

    x (B, S, D); router_w (D, E); wi/wg (E, D, F); wo (E, F, D).
    Each batch row is a dispatch group: routing, capacity slots (cumsum)
    and the (E, cap, D) buffers are all PER GROUP, so with the batch dim
    sharded over the data axes the dispatch never communicates -- the only
    collectives left are the expert-weight gathers / TP reductions.
    (A global-cumsum dispatch forces cross-device gathers of every token;
    observed as a 224s collective term on mixtral train_4k -- see
    EXPERIMENTS.md Perf H1.)  Overflowing tokens are dropped per group
    (capacity-factor semantics); aux losses push the router to balance.
    FLOPs ~= top_k * tokens * 3DF -- active experts only.
    """
    B, S, D = x.shape
    E = router_w.shape[1]
    cap = int(max(1, math.ceil(capacity_factor * top_k * S / E)))
    x = grad_cast(x)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (B, S, E)
    top_w, top_e = jax.lax.top_k(probs, top_k)            # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    def dispatch_group(xg, eg, wg_):
        """xg (S, D); eg (S, k); wg_ (S, k) -> (buf (E,cap,D), meta)."""
        e_flat = eg.reshape(-1)                           # (S*k,)
        w_flat = wg_.reshape(-1)
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
        keep = slot < cap
        x_rep = jnp.repeat(xg, top_k, axis=0)             # (S*k, D)
        buf = jnp.zeros((E, cap, D), xg.dtype)
        buf = buf.at[jnp.where(keep, e_flat, 0),
                     jnp.where(keep, slot, 0)].add(
            x_rep * keep[:, None].astype(xg.dtype))
        return buf, (e_flat, slot, keep, w_flat)

    buf, (e_flat, slot, keep, w_flat) = jax.vmap(dispatch_group)(
        x, top_e, top_w)                                  # buf (B,E,cap,D)
    # scatter/gather break GSPMD propagation: re-pin the group (batch) dim
    buf = actx.constrain(buf, actx.DP, None, None, None)

    h = jnp.einsum("becd,edf->becf", buf, wi.astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", buf, wg.astype(x.dtype))
    h = actx.constrain(h, actx.DP, None, None, actx.MDL)
    g = actx.constrain(g, actx.DP, None, None, actx.MDL)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    y = jnp.einsum("becf,efd->becd", h, wo.astype(x.dtype))  # (B,E,cap,D)
    y = actx.constrain(y, actx.DP, None, None, None)

    def combine_group(yg, e_flat, slot, keep, w_flat):
        y_tok = yg[jnp.where(keep, e_flat, 0), jnp.where(keep, slot, 0)]
        y_tok = y_tok * (w_flat * keep).astype(yg.dtype)[:, None]
        return y_tok.reshape(S, top_k, D).sum(axis=1)

    out = jax.vmap(combine_group)(y, e_flat, slot, keep, w_flat)

    if return_aux:
        oh = jax.nn.one_hot(top_e.reshape(B, -1), E).mean((0, 1)) * 1.0
        imp = probs.mean((0, 1))
        lb = E * jnp.sum(oh * imp)
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        return out, {"load_balance": lb, "router_z": z}
    return out
