"""Model registry: one uniform API over every assigned architecture.

``build(cfg)`` returns a ``Model`` exposing:

  param_shapes / init_params(key, abstract)   parameters (or structs)
  loss_fn(params, batch)                      training loss (scalar f32)
  prefill_fn(params, inputs)                  prompt pass -> (h, cache)
  decode_fn(params, inputs, cache)            one-token serve step
  input_specs(shape)                          ShapeDtypeStructs per cell
  model_flops(shape)                          6 N_active tokens (train),
                                              2 N_active tokens (serve)

The dry-run driver, trainer, server, benchmarks and smoke tests all consume
only this API.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .common import ModelCfg, init_tree
from . import transformer as T
from . import encdec as ED
from . import ssm_lm as SL


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclass
class Model:
    cfg: ModelCfg

    # ------------------------------------------------------------ params
    def param_shapes(self):
        c = self.cfg
        if c.family in ("dense", "moe", "vlm"):
            return T.lm_param_shapes(c)
        if c.family == "audio-encdec":
            return ED.encdec_param_shapes(c)
        if c.family == "ssm":
            return SL.mamba_lm_param_shapes(c)
        if c.family == "hybrid":
            return SL.zamba_param_shapes(c)
        raise ValueError(c.family)

    def init_params(self, key=None, abstract: bool = False):
        key = key if key is not None else jax.random.PRNGKey(0)
        return init_tree(self.param_shapes(), key, self.cfg.param_dtype,
                         abstract=abstract)

    def n_params(self) -> int:
        import numpy as _np
        from .common import ShapeInit
        tot = 0
        for leaf in jax.tree.leaves(
                self.param_shapes(),
                is_leaf=lambda x: isinstance(x, ShapeInit)):
            tot += int(_np.prod(leaf.shape))
        return tot

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts expert FFNs)."""
        c = self.cfg
        total = self.n_params()
        if not c.n_experts:
            return total
        expert = 3 * c.d_model * c.d_ff * c.n_experts * c.n_layers
        active = expert * c.top_k / c.n_experts
        return int(total - expert + active)

    # ------------------------------------------------------------ steps
    def loss_fn(self) -> Callable:
        c = self.cfg
        if c.family in ("dense", "moe", "vlm"):
            return lambda p, b: T.lm_loss(p, b, c)
        if c.family == "audio-encdec":
            return lambda p, b: ED.encdec_loss(p, b, c)
        if c.family == "ssm":
            return lambda p, b: SL.mamba_lm_loss(p, b, c)
        if c.family == "hybrid":
            return lambda p, b: SL.zamba_loss(p, b, c)
        raise ValueError(c.family)

    def prefill_fn(self, max_seq: int) -> Callable:
        c = self.cfg
        if c.family in ("dense", "moe", "vlm"):
            def f(p, b):
                return T.lm_prefill(p, b.get("tokens"), c, max_seq,
                                    embeds=b.get("embeds"),
                                    positions=b.get("positions"))
            return f
        if c.family == "audio-encdec":
            return lambda p, b: ED.encdec_prefill(p, b["enc_embeds"], c,
                                                  max_seq)
        if c.family == "ssm":
            return lambda p, b: SL.mamba_lm_prefill(p, b["tokens"], c)
        if c.family == "hybrid":
            return lambda p, b: SL.zamba_prefill(p, b["tokens"], c, max_seq)
        raise ValueError(c.family)

    def decode_fn(self, seq_ctx=None) -> Callable:
        c = self.cfg
        if c.family in ("dense", "moe", "vlm"):
            def f(p, b, cache):
                return T.lm_decode_step(p, b["token"], b["pos"], cache, c,
                                        positions=b.get("positions"),
                                        seq_ctx=seq_ctx)
            return f
        if c.family == "audio-encdec":
            return lambda p, b, cache: ED.encdec_decode_step(
                p, b["token"], b["pos"], cache, c)
        if c.family == "ssm":
            return lambda p, b, cache: SL.mamba_lm_decode_step(
                p, b["token"], b["pos"], cache, c)
        if c.family == "hybrid":
            return lambda p, b, cache: SL.zamba_decode_step(
                p, b["token"], b["pos"], cache, c, seq_ctx=seq_ctx)
        raise ValueError(c.family)

    # ------------------------------------------------------------ specs
    def input_specs(self, shape: ShapeCell) -> dict:
        """ShapeDtypeStructs for the step inputs of one cell (no alloc)."""
        c = self.cfg
        B, S, D = shape.global_batch, shape.seq, c.d_model
        i32, emb = jnp.int32, c.dtype
        if shape.kind == "train":
            if c.family == "vlm":
                return {"embeds": _sds((B, S, D), emb),
                        "positions": _sds((3, B, S), i32),
                        "labels": _sds((B, S), i32)}
            if c.family == "audio-encdec":
                return {"enc_embeds": _sds((B, S, D), emb),
                        "dec_tokens": _sds((B, S), i32),
                        "labels": _sds((B, S), i32)}
            return {"tokens": _sds((B, S), i32),
                    "labels": _sds((B, S), i32)}
        if shape.kind == "prefill":
            if c.family == "vlm":
                return {"embeds": _sds((B, S, D), emb),
                        "positions": _sds((3, B, S), i32)}
            if c.family == "audio-encdec":
                return {"enc_embeds": _sds((B, S, D), emb)}
            return {"tokens": _sds((B, S), i32)}
        # decode: one new token against a seq-long cache
        b = {"token": _sds((B, 1), i32), "pos": _sds((), i32)}
        if c.family == "vlm":
            b["positions"] = _sds((3, B, 1), i32)
        return b

    def cache_specs(self, shape: ShapeCell, cache_dtype=jnp.bfloat16) -> dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq
        if c.family in ("dense", "moe", "vlm"):
            kv = (c.n_layers, B, S, c.n_kv_heads, c.hd)
            return {"k": _sds(kv, cache_dtype), "v": _sds(kv, cache_dtype)}
        if c.family == "audio-encdec":
            kv = (c.n_layers, B, S, c.n_kv_heads, c.hd)
            return {"k": _sds(kv, cache_dtype), "v": _sds(kv, cache_dtype),
                    "xk": _sds(kv, cache_dtype), "xv": _sds(kv, cache_dtype)}
        if c.family == "ssm":
            st = SL.mamba2_state_shapes(c, B)
            return {"conv": _sds((c.n_layers,) + st["conv"], jnp.float32),
                    "ssm": _sds((c.n_layers,) + st["ssm"], jnp.float32)}
        if c.family == "hybrid":
            G, period, rem = SL.zamba_groups(c)
            st = SL.mamba2_state_shapes(c, B)
            out = {
                "conv": _sds((G, period) + st["conv"], jnp.float32),
                "ssm": _sds((G, period) + st["ssm"], jnp.float32),
                "k": _sds((G, B, S, c.n_kv_heads, c.hd), cache_dtype),
                "v": _sds((G, B, S, c.n_kv_heads, c.hd), cache_dtype),
            }
            if rem:
                out["conv_tail"] = _sds((rem,) + st["conv"], jnp.float32)
                out["ssm_tail"] = _sds((rem,) + st["ssm"], jnp.float32)
            return out
        raise ValueError(c.family)

    # ------------------------------------------------------------ flops
    def model_flops(self, shape: ShapeCell) -> float:
        """Useful-model FLOPs for the cell: 6 N_active tokens (train),
        2 N_active tokens (prefill/decode forward)."""
        tokens = shape.global_batch * (shape.seq if shape.kind != "decode"
                                       else 1)
        n = self.n_active_params()
        mult = 6.0 if shape.kind == "train" else 2.0
        # decode reads the whole KV cache: attention flops separate and
        # dominated by memory; 6ND/2ND convention per instructions
        return mult * n * tokens


def build(cfg: ModelCfg) -> Model:
    return Model(cfg)
