"""Parameter / activation PartitionSpec rules (GSPMD, mesh-agnostic).

Strategy (DESIGN.md Sec. 6): 2D-sharded params -- the "width" dim (heads,
ffn, vocab, experts, d_inner) over the ``model`` axis (TP/EP), the other
matrix dim over the combined data axes (``("pod", "data")``) for
FSDP/ZeRO-3-style weight sharding; optimizer state inherits the param
specs.  Scanned stacks add leading unsharded layer dims (auto-padded).

The rules are name-based over the param-tree paths, so they apply uniformly
to every family in the zoo.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .common import ModelCfg, ShapeInit

__all__ = ["param_specs", "batch_specs", "cache_specs_sharding"]


def _base_spec(path: str, name: str, cfg: ModelCfg, fsdp, mdl,
               mdl_size: int = 16):
    """(base_ndim, spec) for an unstacked leaf, or None -> replicated."""
    is_moe = cfg.n_experts > 0 and "/ffn/" in path and "shared" not in path
    # EP when the expert count divides the model axis, else TP inside each
    # expert (e.g. mixtral 8e on a 16-way model axis)
    moe_ep = is_moe and cfg.n_experts % mdl_size == 0
    if name == "embed":
        return 2, P(mdl, fsdp)
    if name == "unembed":
        return 2, P(fsdp, mdl)
    if name in ("wq", "wk", "wv"):
        return 2, P(fsdp, mdl)
    if name == "wo" and "attn" in path.rsplit("/", 2)[-2]:
        return 2, P(mdl, fsdp)
    if name == "router":
        return 2, P(fsdp, None)
    if name in ("wi", "wg"):
        if is_moe:
            return (3, P(mdl, fsdp, None)) if moe_ep else (3, P(None, fsdp, mdl))
        return 2, P(fsdp, mdl)
    if name == "wo":  # ffn wo
        if is_moe:
            return (3, P(mdl, None, fsdp)) if moe_ep else (3, P(None, mdl, fsdp))
        return 2, P(mdl, fsdp)
    if name in ("bq", "bk", "bv", "bi"):
        return 1, P(mdl)
    if name == "bo":
        return 1, P(None)
    # --- mamba ---
    if name == "in_proj":
        return 2, P(fsdp, mdl)
    if name == "out_proj":
        return 2, P(mdl, fsdp)
    if name == "conv_w":
        return 2, P(None, mdl)
    if name in ("conv_b", "dt_bias", "A_log", "Dskip", "norm_w"):
        return 1, P(mdl)
    # --- norms (w/b) and everything else: replicated ---
    return 1, P(None)


_MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def set_mesh_sizes(sizes: dict) -> None:
    """Axis sizes used for divisibility checks in param_specs."""
    _MESH_SIZES.clear()
    _MESH_SIZES.update(sizes)


def param_specs(cfg: ModelCfg, shapes_tree, *, fsdp=("data",), mdl="model",
                mdl_size: int = 16, serve: bool = False):
    """PartitionSpec tree matching a param-shapes tree (ShapeInit leaves).

    serve=True: weights stay RESIDENT (no FSDP over the data axes -- a
    per-token weight all-gather costs ~150 ms/token on a 35B decode cell;
    see EXPERIMENTS.md Perf H4).  MoE expert tables keep the data-axis
    sharding for memory (they exceed HBM replicated)."""
    fsdp = tuple(fsdp) if isinstance(fsdp, (tuple, list)) else (fsdp,)
    fsdp_axis = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        name = pstr.rsplit("/", 1)[-1]
        ndim = len(leaf.shape)
        is_moe_w = (cfg.n_experts > 0 and "/ffn/" in pstr
                    and "shared" not in pstr and name in ("wi", "wg", "wo"))
        eff_fsdp = fsdp_axis if (not serve or is_moe_w) else None
        base_ndim, spec = _base_spec(pstr, name, cfg, eff_fsdp, mdl,
                                     mdl_size)
        pad = ndim - base_ndim
        if pad < 0:  # scalar-ish leaf
            return P()
        full = (None,) * pad + tuple(spec)
        # drop axes that do not divide the dim evenly (e.g. 1-d params
        # under full fsdp sharding)
        fixed = []
        for dim, ax in zip(leaf.shape, full):
            if ax is None:
                fixed.append(None)
                continue
            names = ax if isinstance(ax, tuple) else (ax,)
            sz = 1
            import math as _m
            for nm in names:
                sz *= _MESH_SIZES.get(nm, 0) or 1
            fixed.append(ax if sz and dim % sz == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(
        visit, shapes_tree, is_leaf=lambda x: isinstance(x, ShapeInit))


def batch_specs(cfg: ModelCfg, input_tree, *, dp=("data",), mdl="model"):
    """PartitionSpecs for step inputs: batch over the data axes.
    dp=None replicates the batch dim (e.g. global_batch=1 cells)."""
    if dp is None:
        dp_axis = None
    else:
        dp = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
        dp_axis = dp if len(dp) > 1 else dp[0]

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name == "positions" and nd == 3:       # (3, B, S)
            return P(None, dp_axis, None)
        if name in ("embeds", "enc_embeds"):      # (B, S, D)
            return P(dp_axis, None, None)
        if nd >= 2:                               # tokens/labels (B, S)
            return P(*((dp_axis,) + (None,) * (nd - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(visit, input_tree)


def cache_specs_sharding(cfg: ModelCfg, cache_tree, *, dp=("data",),
                         mdl="model", seq_sharded: bool = False):
    """PartitionSpecs for decode caches.

    KV tensors (..., B, S, KVH, hd): batch over dp; then either kv-heads
    over model (divisible case) or the sequence dim over model
    (seq_sharded; flash-decoding combine in the decode step).
    SSM states (..., B, H, n, p): heads over model.
    """
    if dp is None:
        dp_axis = None
    else:
        dp = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
        dp_axis = dp if len(dp) > 1 else dp[0]

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):
            # (L_or_G, B, S, KVH, hd)
            if seq_sharded:
                return P(None, dp_axis, mdl, None, None)
            return P(None, dp_axis, None, mdl, None)
        if name in ("ssm", "ssm_tail"):
            # (..., B, H, n, p): batch over dp, heads over model
            pad = nd - 4
            return P(*((None,) * pad + (dp_axis, mdl, None, None)))
        if name in ("conv", "conv_tail"):
            # (..., B, K-1, ch): channels over model
            pad = nd - 3
            return P(*((None,) * pad + (dp_axis, None, mdl)))
        return P()

    return jax.tree_util.tree_map_with_path(visit, cache_tree)
