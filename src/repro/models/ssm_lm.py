"""SSM and hybrid language models: mamba2-370m and zamba2-1.2b.

* mamba2 LM: embed -> N x (rmsnorm -> mamba2 mixer -> residual) -> norm ->
  unembed; scanned stack.
* zamba2 hybrid: mamba2 backbone with ONE weight-shared attention+MLP block
  applied after every ``shared_attn_period`` mamba layers (arXiv:2411.15242;
  the shared block's weights are reused at every application site).

Decode state is O(1) in sequence length for the mamba layers (conv window +
SSM state) plus a KV cache per shared-attention application site (zamba2),
which is why these two archs run the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelCfg, ShapeInit
from . import layers as L
from . import actx
from .mamba2 import (mamba2_block, mamba2_block_decode, mamba2_param_shapes,
                     mamba2_state_shapes)
from .transformer import (_ffn, _norm, _qkv, attn_param_shapes,
                          ffn_param_shapes, norm_param_shapes,
                          _stack_shapes, chunked_ce_loss)

__all__ = [
    "mamba_lm_param_shapes", "mamba_lm_loss", "mamba_lm_forward",
    "mamba_lm_init_state", "mamba_lm_decode_step",
    "zamba_param_shapes", "zamba_loss", "zamba_forward",
    "zamba_init_state", "zamba_decode_step", "zamba_groups",
]


# =========================================================== mamba2 LM
def _mamba_layer_shapes(cfg: ModelCfg) -> dict:
    return {"ln": norm_param_shapes(cfg), "mixer": mamba2_param_shapes(cfg)}


def mamba_lm_param_shapes(cfg: ModelCfg) -> dict:
    return {
        "embed": ShapeInit((cfg.padded_vocab, cfg.d_model), "normal", 0.02),
        "layers": _stack_shapes(_mamba_layer_shapes(cfg), cfg.n_layers),
        "final_norm": norm_param_shapes(cfg),
        "unembed": ShapeInit((cfg.d_model, cfg.padded_vocab), "scaled"),
    }


def mamba_lm_forward(params, tokens, cfg: ModelCfg, remat: bool = True):
    h = actx.batch_act(jnp.take(params["embed"], tokens,
                                axis=0).astype(cfg.dtype))

    def body(h, lp):
        h = h + mamba2_block(lp["mixer"], _norm(lp["ln"], h, cfg), cfg)
        return actx.batch_act(h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return _norm(params["final_norm"], h, cfg)


def mamba_lm_loss(params, batch, cfg: ModelCfg, ce_chunk: int = 512):
    h = mamba_lm_forward(params, batch["tokens"], cfg)
    return chunked_ce_loss(h, params["unembed"], batch["labels"],
                           batch.get("mask"), chunk=ce_chunk)


def mamba_lm_prefill(params, tokens, cfg: ModelCfg):
    """Process a prompt, returning (final hidden, decode state)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(h, lp):
        y, st = mamba2_block(lp["mixer"], _norm(lp["ln"], h, cfg), cfg,
                             return_state=True)
        return actx.batch_act(h + y), st

    body = jax.checkpoint(body, prevent_cse=False)
    h = actx.batch_act(h)
    h, states = jax.lax.scan(body, h, params["layers"])
    return _norm(params["final_norm"], h, cfg), states


def mamba_lm_init_state(cfg: ModelCfg, batch: int, dtype=jnp.float32):
    s = mamba2_state_shapes(cfg, batch)
    return {
        "conv": jnp.zeros((cfg.n_layers,) + s["conv"], dtype),
        "ssm": jnp.zeros((cfg.n_layers,) + s["ssm"], dtype),
    }


def mamba_lm_decode_step(params, token, pos, state, cfg: ModelCfg):
    """O(1) decode: no KV cache, just per-layer (conv, ssm) states."""
    h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)

    def body(h, xs):
        lp, conv, ssm = xs
        y, new = mamba2_block_decode(lp["mixer"], _norm(lp["ln"], h, cfg),
                                     {"conv": conv, "ssm": ssm}, cfg)
        return h + y, (new["conv"], new["ssm"])

    h, (conv, ssm) = jax.lax.scan(
        body, h, (params["layers"], state["conv"], state["ssm"]))
    h = _norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    V = logits.shape[-1]
    if cfg.vocab < V:
        logits = jnp.where(jnp.arange(V)[None, None, :] < cfg.vocab,
                           logits, -1e30)
    return logits, {"conv": conv, "ssm": ssm}


# =========================================================== zamba2 hybrid
def zamba_groups(cfg: ModelCfg):
    """(n_groups, period, remainder) covering cfg.n_layers mamba layers."""
    period = cfg.shared_attn_period
    return cfg.n_layers // period, period, cfg.n_layers % period


def zamba_param_shapes(cfg: ModelCfg) -> dict:
    G, period, rem = zamba_groups(cfg)
    layer = _mamba_layer_shapes(cfg)
    shapes = {
        "embed": ShapeInit((cfg.padded_vocab, cfg.d_model), "normal", 0.02),
        # grouped stack: (G, period, ...) so one scan-of-scan covers it
        "groups": _stack_shapes(_stack_shapes(layer, period), G),
        # the weight-SHARED attention+MLP block (one copy, reused G times)
        "shared": {
            "ln1": norm_param_shapes(cfg),
            "attn": attn_param_shapes(cfg),
            "ln2": norm_param_shapes(cfg),
            "ffn": ffn_param_shapes(cfg),
        },
        "final_norm": norm_param_shapes(cfg),
        "unembed": ShapeInit((cfg.d_model, cfg.padded_vocab), "scaled"),
    }
    if rem:
        shapes["tail"] = _stack_shapes(layer, rem)
    return shapes


def _shared_attn_block(sp, h, cfg, cos, sin, kv_chunk: int = 1024):
    B, S = h.shape[:2]
    x = _norm(sp["ln1"], h, cfg)
    q, k, v = _qkv(sp["attn"], x, cfg)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    out = L.flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    h = h + jnp.einsum("bse,ed->bsd", out, sp["attn"]["wo"].astype(h.dtype))
    return h + _ffn(sp["ffn"], _norm(sp["ln2"], h, cfg), cfg)


def zamba_forward(params, tokens, cfg: ModelCfg, remat: bool = True):
    h = actx.batch_act(jnp.take(params["embed"], tokens,
                                axis=0).astype(cfg.dtype))
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
    shared = params["shared"]

    def mamba_body(h, lp):
        h = h + mamba2_block(lp["mixer"], _norm(lp["ln"], h, cfg), cfg)
        return actx.batch_act(h), None

    if remat:
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    def group_body(h, gp):
        h, _ = jax.lax.scan(mamba_body, h, gp)
        h = _shared_attn_block(shared, h, cfg, cos, sin)
        return actx.batch_act(h), None

    h, _ = jax.lax.scan(group_body, h, params["groups"])
    if "tail" in params:
        h, _ = jax.lax.scan(mamba_body, h, params["tail"])
    return _norm(params["final_norm"], h, cfg)


def zamba_loss(params, batch, cfg: ModelCfg, ce_chunk: int = 512):
    h = zamba_forward(params, batch["tokens"], cfg)
    return chunked_ce_loss(h, params["unembed"], batch["labels"],
                           batch.get("mask"), chunk=ce_chunk,
                           valid_vocab=cfg.vocab)


def zamba_init_state(cfg: ModelCfg, batch: int, max_seq: int,
                     cache_dtype=jnp.bfloat16, state_dtype=jnp.float32):
    G, period, rem = zamba_groups(cfg)
    s = mamba2_state_shapes(cfg, batch)
    st = {
        "conv": jnp.zeros((G, period) + s["conv"], state_dtype),
        "ssm": jnp.zeros((G, period) + s["ssm"], state_dtype),
        "k": jnp.zeros((G, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                       cache_dtype),
        "v": jnp.zeros((G, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                       cache_dtype),
    }
    if rem:
        st["conv_tail"] = jnp.zeros((rem,) + s["conv"], state_dtype)
        st["ssm_tail"] = jnp.zeros((rem,) + s["ssm"], state_dtype)
    return st


def zamba_prefill(params, tokens, cfg: ModelCfg, max_seq: int,
                  cache_dtype=jnp.bfloat16, kv_chunk: int = 1024):
    """Prompt pass: mamba states + shared-attention KV caches."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
    shared = params["shared"]

    def mamba_body(h, lp):
        y, st = mamba2_block(lp["mixer"], _norm(lp["ln"], h, cfg), cfg,
                             return_state=True)
        return actx.batch_act(h + y), st

    mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    def group_body(h, gp):
        h, st = jax.lax.scan(mamba_body, h, gp)
        x = _norm(shared["ln1"], h, cfg)
        q, k, v = _qkv(shared["attn"], x, cfg)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        out = L.flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           shared["attn"]["wo"].astype(h.dtype))
        h = h + _ffn(shared["ffn"], _norm(shared["ln2"], h, cfg), cfg)
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        return h, (st, kc, vc)

    h, (gstates, kc, vc) = jax.lax.scan(group_body, h, params["groups"])
    state = {"conv": gstates["conv"], "ssm": gstates["ssm"], "k": kc, "v": vc}
    if "tail" in params:
        h, st = jax.lax.scan(mamba_body, h, params["tail"])
        state["conv_tail"], state["ssm_tail"] = st["conv"], st["ssm"]
    return _norm(params["final_norm"], h, cfg), state


def zamba_decode_step(params, token, pos, state, cfg: ModelCfg, *,
                      seq_ctx=None, kv_chunk: int = 1024):
    """One hybrid decode step.  Mamba layers use O(1) state; each shared
    attention application site has its own KV cache (G, B, S, KV, hd),
    optionally sequence-sharded (seq_ctx; long_500k)."""
    from .transformer import _decode_attn_sharded
    h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    B = h.shape[0]
    positions = jnp.full((B, 1), pos)
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
    shared = params["shared"]

    def mamba_body(h, xs):
        lp, conv, ssm = xs
        y, new = mamba2_block_decode(lp["mixer"], _norm(lp["ln"], h, cfg),
                                     {"conv": conv, "ssm": ssm}, cfg)
        return h + y, (new["conv"], new["ssm"])

    def group_body(h, xs):
        gp, conv, ssm, kc, vc = xs
        h, (conv, ssm) = jax.lax.scan(mamba_body, h, (gp, conv, ssm))
        x = _norm(shared["ln1"], h, cfg)
        q, k_new, v_new = _qkv(shared["attn"], x, cfg)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        if seq_ctx is not None:
            out, kc, vc = _decode_attn_sharded(q, kc, vc, k_new, v_new, pos,
                                               cfg, seq_ctx)
        else:
            kc = L.dus_seq(kc, k_new, pos)
            vc = L.dus_seq(vc, v_new, pos)
            out = L.flash_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                    causal=True, q_offset=pos,
                                    kv_valid=pos + 1, kv_chunk=kv_chunk)
        out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           shared["attn"]["wo"].astype(h.dtype))
        h = h + _ffn(shared["ffn"], _norm(shared["ln2"], h, cfg), cfg)
        return h, (conv, ssm, kc, vc)

    h, (conv, ssm, kc, vc) = jax.lax.scan(
        group_body, h, (params["groups"], state["conv"], state["ssm"],
                        state["k"], state["v"]))
    new_state = dict(state, conv=conv, ssm=ssm, k=kc, v=vc)
    if "tail" in params:
        h, (ct, st_) = jax.lax.scan(
            mamba_body, h,
            (params["tail"], state["conv_tail"], state["ssm_tail"]))
        new_state["conv_tail"], new_state["ssm_tail"] = ct, st_
    h = _norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    V = logits.shape[-1]
    if cfg.vocab < V:
        logits = jnp.where(jnp.arange(V)[None, None, :] < cfg.vocab,
                           logits, -1e30)
    return logits, new_state
