"""Activation-sharding context: with_sharding_constraint at layer
boundaries (the GSPMD equivalent of MaxText's logical sharding rules).

Without explicit constraints XLA may propagate the *embedding table's*
sharding (feature dim over the data axis) into the residual stream and
keep the batch replicated -- observed as a 16x per-device FLOP blowup on
the production mesh.  The step builders activate the context inside the
traced function, so every (re)trace applies the constraints; with no
context active (CPU smoke tests) the helpers are no-ops.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_ctx",
                                                      default=None)

DP = "__dp__"      # placeholder: data axes (batch dim)
MDL = "__mdl__"    # placeholder: model axis


@contextlib.contextmanager
def use(mesh, dp_axes, model_axis, seq_parallel: bool = False):
    """Activate constraints for code traced within this block.

    seq_parallel: residual-stream tensors additionally shard their
    sequence dim over the model axis (Megatron-SP) -- converts the
    per-layer TP boundary all-reduces into reduce-scatter/all-gather
    pairs and shards norm/residual compute.
    """
    token = _CTX.set((mesh, tuple(dp_axes) if dp_axes else None,
                      model_axis, seq_parallel))
    try:
        yield
    finally:
        _CTX.reset(token)


def _resolve(axis, dp, mdl):
    if axis == DP:
        if dp is None:
            return None
        return dp if len(dp) > 1 else dp[0]
    if axis == MDL:
        return mdl
    return axis


def constrain(x, *spec):
    """with_sharding_constraint(x, P(spec)) under the active context.

    spec entries: DP, MDL, None, or literal axis names.  No-op when no
    context is active; per-dim fallback to replicated when a dim is not
    divisible by its axes (tiny smoke shapes, S=1 decode).
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, dp, mdl = ctx[0], ctx[1], ctx[2]
    resolved = list(_resolve(a, dp, mdl) for a in spec)
    for i, (dim, axes) in enumerate(zip(x.shape, resolved)):
        if axes is None:
            continue
        names = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        if dim % size != 0:
            resolved[i] = None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def seq_parallel_on() -> bool:
    ctx = _CTX.get()
    return bool(ctx and len(ctx) > 3 and ctx[3])


def batch_act(h):
    """Residual-stream constraint: (B, S, D); batch over data axes, and
    with sequence parallelism the seq dim over the model axis."""
    if seq_parallel_on():
        return constrain(h, DP, MDL, None)
    return constrain(h, DP, None, None)
