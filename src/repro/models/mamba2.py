"""Mamba2 (SSD -- state-space duality, arXiv:2405.21060) in pure JAX.

The SSD algorithm computes the selective-SSM recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T        (per head)
    y_t = C_t h_t + D x_t

with a *chunked* dual form: quadratic attention-like matmuls inside chunks
of length Q (MXU-friendly) and a linear state hand-off between chunks
(``lax.scan``).  ``ssd_scan_ref`` is the naive O(S) recurrence used as the
test oracle.  Single-token decode keeps (conv window, SSM state) as the
per-layer cache -- O(1) in sequence length, which is why mamba2/zamba2 are
the assigned ``long_500k`` architectures.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelCfg, ShapeInit
from .layers import rmsnorm

__all__ = ["mamba2_param_shapes", "mamba2_block", "mamba2_block_decode",
           "ssd_chunked", "ssd_scan_ref", "mamba2_state_shapes"]


# ---------------------------------------------------------------- SSD core
def ssd_scan_ref(x, dt, A, B, C):
    """Naive recurrence oracle.  x (b,s,h,p); dt (b,s,h); A (h,);
    B, C (b,s,n).  Returns y (b,s,h,p), final state (b,h,n,p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    a = jnp.exp(dt * A[None, None, :])                    # (b,s,h)

    def step(state, inp):
        a_t, dtx_t, B_t, C_t = inp
        # state (b,h,n,p)
        state = state * a_t[..., None, None] + \
            B_t[:, None, :, None] * dtx_t[:, :, None, :]
        y = jnp.einsum("bn,bhnp->bhp", C_t, state)
        return state, y

    dtx = dt[..., None] * x                               # (b,s,h,p)
    s0 = jnp.zeros((b, h, n, p), x.dtype)
    state, ys = jax.lax.scan(
        step, s0,
        (a.transpose(1, 0, 2), dtx.transpose(1, 0, 2, 3),
         B.transpose(1, 0, 2), C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), state


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD.  Shapes as ssd_scan_ref; S % chunk == 0 (caller pads).

    All heavy ops are batched matmuls; the only sequential part is a scan
    over S/chunk chunk-states.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = chunk
    nc = s // Q
    xc = x.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, n)
    Cc = C.reshape(b, nc, Q, n)

    loga = dtc * A[None, None, None, :]                   # (b,nc,Q,h) <= 0
    L = jnp.cumsum(loga, axis=2)                          # cumulative decay
    Ltot = L[:, :, -1, :]                                 # (b,nc,h)

    # --- intra-chunk (quadratic, causal-masked) ---
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))               # (b,nc,Q,Q)
    decay = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :])  # (b,nc,Q,K,h)
    causal = np.tril(np.ones((Q, Q), dtype=bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    M = CB[..., None] * decay * dtc[:, :, None, :, :]     # (b,nc,Q,K,h)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M.astype(jnp.float32),
                         xc.astype(jnp.float32))

    # --- chunk states ---
    sdecay = jnp.exp(Ltot[:, :, None, :] - L) * dtc       # (b,nc,Q,h)
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc.astype(jnp.float32),
                     sdecay.astype(jnp.float32), xc.astype(jnp.float32))

    # --- inter-chunk scan ---
    def step(state, inp):
        S_chunk, ltot = inp                               # (b,h,n,p), (b,h)
        prev = state
        state = state * jnp.exp(ltot)[..., None, None] + S_chunk
        return state, prev

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    state, prevs = jax.lax.scan(
        step, s0, (S_c.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)                # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc.astype(jnp.float32),
                         jnp.exp(L).astype(jnp.float32), prevs)
    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    return y, state


# ---------------------------------------------------------------- block
def mamba2_param_shapes(cfg: ModelCfg) -> dict:
    D = cfg.d_model
    din = cfg.d_inner
    n = cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = din + 2 * n
    return {
        "in_proj": ShapeInit((D, 2 * din + 2 * n + H), "scaled"),
        "conv_w": ShapeInit((cfg.ssm_conv, conv_ch), "normal", 0.1),
        "conv_b": ShapeInit((conv_ch,), "zeros"),
        "dt_bias": ShapeInit((H,), "zeros"),
        "A_log": ShapeInit((H,), "ones"),
        "Dskip": ShapeInit((H,), "ones"),
        "norm_w": ShapeInit((din,), "ones"),
        "out_proj": ShapeInit((din, D), "scaled"),
    }


def _split_proj(cfg: ModelCfg, zxbcdt):
    din, n, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:din + din + 2 * n]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d: xBC (B,S,ch), w (K,ch)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def mamba2_block(params, x, cfg: ModelCfg, return_state: bool = False):
    """Full-sequence mamba2 mixer.  x (B, S, D) -> (B, S, D).

    With ``return_state`` also returns the decode cache {conv, ssm} at the
    end of the sequence (prefill)."""
    B_, S, D = x.shape
    din, n, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC_raw, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, params["conv_w"].astype(x.dtype),
                       params["conv_b"].astype(x.dtype))
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :din].reshape(B_, S, H, P)
    Bmat = xBC[..., din:din + n]
    Cmat = xBC[..., din + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    y, final = ssd_chunked(xs, dt, A, Bmat, Cmat, Q)
    y = y[:, :S]
    y = y + params["Dskip"].astype(jnp.float32)[None, None, :, None] \
        * xs[:, :S].astype(jnp.float32)
    y = y.reshape(B_, S, din).astype(x.dtype)
    # gated RMSNorm then out projection
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                params["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    if not return_state:
        return out
    # NOTE: with padding the final chunked state includes zero-decay padded
    # steps (dt=0 -> a=1, contribution 0), so it equals the state at S.
    K = cfg.ssm_conv
    conv_win = xBC_raw[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
        xBC_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"conv": conv_win.astype(jnp.float32),
                 "ssm": final.astype(jnp.float32)}


def mamba2_state_shapes(cfg: ModelCfg, batch: int):
    """Per-layer decode cache: (conv window, SSM state)."""
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": (batch, cfg.ssm_conv - 1, conv_ch),
        "ssm": (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
    }


def mamba2_block_decode(params, x1, cache, cfg: ModelCfg):
    """Single-token step.  x1 (B, 1, D); cache {conv (B,K-1,ch),
    ssm (B,H,n,P)} -> (y1, new_cache).  O(1) in sequence length."""
    B_, _, D = x1.shape
    din, n, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x1, params["in_proj"].astype(x1.dtype))
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = xBC[:, 0]                                        # (B, ch)
    window = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    w = params["conv_w"].astype(x1.dtype)                  # (K, ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) \
        + params["conv_b"].astype(x1.dtype)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x1.dtype)
    xs = conv_out[:, :din].reshape(B_, H, P)
    Bv = conv_out[:, din:din + n]
    Cv = conv_out[:, din + n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                           # (B,H)
    ssm = cache["ssm"] * a[..., None, None] + \
        Bv[:, None, :, None] * (dt[..., None] * xs)[:, :, None, :]
    y = jnp.einsum("bn,bhnp->bhp", Cv.astype(jnp.float32),
                   ssm.astype(jnp.float32))
    y = y + params["Dskip"].astype(jnp.float32)[None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B_, 1, din).astype(x1.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype),
                params["norm_w"])
    y = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x1.dtype))
    new_cache = {"conv": window[:, 1:], "ssm": ssm.astype(cache["ssm"].dtype)}
    return y, new_cache
