"""Decoder-only transformer LM family (pure JAX, scan-over-layers).

Covers: stablelm-3b, starcoder2-3b, command-r-35b, granite-34b (MQA),
qwen2-vl-72b (M-RoPE backbone), phi3.5-moe, mixtral-8x22b (MoE + SWA).

Layer stacks are scanned with stacked parameters (leading L dim): HLO size
and SPMD partitioning cost are depth-independent, which keeps the 512-way
dry-run compilable on one CPU core.  ``jax.checkpoint`` wraps the scanned
body for remat.

Serving: ``prefill`` builds the KV cache with chunked flash attention;
``decode_step`` appends one token.  When the cache is sequence-sharded
(decode_32k / long_500k meshes), attention runs under a nested
``shard_map`` with the flash-decoding partial-softmax combine
(layers.merge_partial_softmax).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..utils.compat import shard_map
from .common import ModelCfg, ShapeInit
from . import layers as L
from . import actx

__all__ = ["lm_param_shapes", "lm_forward", "lm_loss", "lm_prefill",
           "lm_decode_step", "attention", "decoder_layer", "chunked_ce_loss",
           "SeqShardCtx"]


@dataclass(frozen=True)
class SeqShardCtx:
    """Present when the decode KV cache is sequence-sharded over a mesh
    axis; attention then uses shard_map + flash-decoding combine."""
    mesh: Any
    axis: str       # mesh axis name sharding the KV sequence dim
    dp_axes: tuple  # mesh axes sharding the batch dim


# ---------------------------------------------------------------- shapes
def attn_param_shapes(cfg: ModelCfg) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": ShapeInit((D, H * hd), "scaled"),
        "wk": ShapeInit((D, KV * hd), "scaled"),
        "wv": ShapeInit((D, KV * hd), "scaled"),
        "wo": ShapeInit((H * hd, D), "scaled"),
    }
    if cfg.attn_bias:
        p.update(bq=ShapeInit((H * hd,), "zeros"),
                 bk=ShapeInit((KV * hd,), "zeros"),
                 bv=ShapeInit((KV * hd,), "zeros"))
    return p


def ffn_param_shapes(cfg: ModelCfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.n_experts:
        E = cfg.n_experts
        return {
            "router": ShapeInit((D, E), "scaled"),
            "wi": ShapeInit((E, D, F), "scaled"),
            "wg": ShapeInit((E, D, F), "scaled"),
            "wo": ShapeInit((E, F, D), "scaled"),
        }
    if cfg.mlp == "gelu":
        return {"wi": ShapeInit((D, F), "scaled"),
                "wo": ShapeInit((F, D), "scaled"),
                "bi": ShapeInit((F,), "zeros"),
                "bo": ShapeInit((D,), "zeros")}
    return {"wi": ShapeInit((D, F), "scaled"),
            "wg": ShapeInit((D, F), "scaled"),
            "wo": ShapeInit((F, D), "scaled")}


def norm_param_shapes(cfg: ModelCfg) -> dict:
    D = cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": ShapeInit((D,), "ones"), "b": ShapeInit((D,), "zeros")}
    return {"w": ShapeInit((D,), "ones")}


def layer_param_shapes(cfg: ModelCfg, cross_attn: bool = False) -> dict:
    p = {
        "ln1": norm_param_shapes(cfg),
        "attn": attn_param_shapes(cfg),
        "ln2": norm_param_shapes(cfg),
        "ffn": ffn_param_shapes(cfg),
    }
    if cross_attn:
        p["lnx"] = norm_param_shapes(cfg)
        p["xattn"] = attn_param_shapes(cfg)
    return p


def _stack_shapes(tree, n: int):
    return jax.tree.map(
        lambda s: ShapeInit((n,) + s.shape, s.kind, s.scale), tree,
        is_leaf=lambda x: isinstance(x, ShapeInit))


def lm_param_shapes(cfg: ModelCfg) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ShapeInit((V, D), "normal", 0.02),
        "layers": _stack_shapes(layer_param_shapes(cfg), cfg.n_layers),
        "final_norm": norm_param_shapes(cfg),
        "unembed": ShapeInit((D, V), "scaled"),
    }


# ---------------------------------------------------------------- pieces
def _norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return L.layernorm(x, p["w"], p["b"])
    return L.rmsnorm(x, p["w"])


def _qkv(p, x, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if cfg.attn_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _rope(cfg, positions):
    """positions: (B, S) or (3, B, S) for M-RoPE; returns (cos, sin)."""
    if cfg.mrope_sections:
        return L.mrope_cos_sin(positions, cfg.hd, cfg.mrope_sections,
                               cfg.rope_theta)
    return L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)


def attention(p, x, cfg, cos, sin, *, causal=True, kv_chunk=1024):
    q, k, v = _qkv(p, x, cfg)
    if cos is not None:
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    out = L.flash_attention(q, k, v, causal=causal, window=cfg.swa_window,
                            kv_chunk=kv_chunk)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))


def _ffn(p, x, cfg):
    if cfg.n_experts:
        return L.moe_ffn(x, p["router"], p["wi"], p["wg"], p["wo"],
                         top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor)
    if cfg.mlp == "gelu":
        return L.mlp_gelu(x, p["wi"], p["wo"], p.get("bi"), p.get("bo"))
    return L.mlp_swiglu(x, p["wi"], p["wg"], p["wo"])


def decoder_layer(p, h, cfg, cos, sin, *, causal=True, kv_chunk=1024):
    a = attention(p["attn"], _norm(p["ln1"], h, cfg), cfg, cos, sin,
                  causal=causal, kv_chunk=kv_chunk)
    h = h + a
    m = _ffn(p["ffn"], _norm(p["ln2"], h, cfg), cfg)
    return h + m


# ---------------------------------------------------------------- forward
def lm_forward(params, tokens, cfg: ModelCfg, *, embeds=None, positions=None,
               kv_chunk: int = 1024, remat: bool = True):
    """Full-sequence forward to final hidden states (B, S, D)."""
    if embeds is None:
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        B, S = tokens.shape
    else:
        h = embeds.astype(cfg.dtype)
        B, S = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = _rope(cfg, positions)

    h = actx.batch_act(h)

    def body(h, lp):
        h = decoder_layer(lp, h, cfg, cos, sin, kv_chunk=kv_chunk)
        return actx.batch_act(h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return _norm(params["final_norm"], h, cfg)


def chunked_ce_loss(h, unembed, labels, mask=None, chunk: int = 512,
                    valid_vocab: int | None = None):
    """Cross-entropy without materializing (B, S, V): scan over S chunks.

    h (B, S, D) final hidden; labels (B, S) int32; mask (B, S) optional.
    valid_vocab: mask logits >= valid_vocab (padded-vocab rows) to -inf.
    Returns mean loss over unmasked tokens (f32).
    """
    B, S, D = h.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S + pad), bool)
    nc = (S + pad) // c
    hs = h.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, c).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, c).transpose(1, 0, 2)

    V = unembed.shape[-1]
    vocab_ok = None
    if valid_vocab is not None and valid_vocab < V:
        vocab_ok = (jnp.arange(V) < valid_vocab)

    def body(carry, inp):
        tot, cnt = carry
        hc, lc, mc = inp
        logits = jnp.einsum("bsd,dv->bsv", hc.astype(jnp.float32),
                            unembed.astype(jnp.float32))
        if vocab_ok is not None:
            logits = jnp.where(vocab_ok[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg: ModelCfg, *, kv_chunk: int = 1024,
            ce_chunk: int = 512):
    """batch: {tokens|embeds, labels, [positions], [mask]} -> scalar loss."""
    h = lm_forward(params, batch.get("tokens"), cfg,
                   embeds=batch.get("embeds"),
                   positions=batch.get("positions"), kv_chunk=kv_chunk)
    return chunked_ce_loss(h, params["unembed"], batch["labels"],
                           batch.get("mask"), chunk=ce_chunk,
                           valid_vocab=cfg.vocab)


# ---------------------------------------------------------------- serving
def init_kv_cache(cfg: ModelCfg, batch: int, max_seq: int,
                  dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_prefill(params, tokens, cfg: ModelCfg, max_seq: int, *, embeds=None,
               positions=None, kv_chunk: int = 1024, cache_dtype=jnp.bfloat16):
    """Builds the KV cache and returns (last hidden, cache)."""
    if embeds is None:
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        B, S = tokens.shape
    else:
        h = embeds.astype(cfg.dtype)
        B, S = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = _rope(cfg, positions)

    h = actx.batch_act(h)

    def body(h, lp):
        x = _norm(lp["ln1"], h, cfg)
        q, k, v = _qkv(lp["attn"], x, cfg)
        if cos is not None:
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        out = L.flash_attention(q, k, v, causal=True, window=cfg.swa_window,
                                kv_chunk=kv_chunk)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           lp["attn"]["wo"].astype(h.dtype))
        h = h + _ffn(lp["ffn"], _norm(lp["ln2"], h, cfg), cfg)
        h = actx.batch_act(h)
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        return h, {"k": kc, "v": vc}

    body = jax.checkpoint(body, prevent_cse=False)
    h, cache = jax.lax.scan(body, h, params["layers"])
    return _norm(params["final_norm"], h, cfg), cache


def _decode_attn_sharded(q, kc, vc, k_new, v_new, pos, cfg, ctx: SeqShardCtx):
    """Decode attention with a sequence-sharded KV cache (flash-decoding).

    q (B,1,H,hd) replicated over ctx.axis; kc/vc (B,S,KV,hd) sharded on S;
    the new token's (k, v) are written into the owning shard, then each
    shard computes partial softmax stats merged with one psum.
    """
    from jax.sharding import PartitionSpec as P_
    S_total = kc.shape[1]
    nsh = ctx.mesh.shape[ctx.axis]
    shard = S_total // nsh
    dp_axes = ctx.dp_axes if ctx.dp_axes else None

    def body(q, kc, vc, k_new, v_new, pos):
        idx = jax.lax.axis_index(ctx.axis)
        lo = idx * shard
        loc = jnp.clip(pos - lo, 0, shard - 1)
        in_range = (pos >= lo) & (pos < lo + shard)
        kup = L.dus_seq(kc, k_new, loc)
        vup = L.dus_seq(vc, v_new, loc)
        kc2 = jnp.where(in_range, kup, kc)
        vc2 = jnp.where(in_range, vup, vc)
        m, l, acc = L.flash_attention_partial(
            q, kc2.astype(q.dtype), vc2.astype(q.dtype),
            q_offset=pos, kv_offset=lo, kv_valid=pos + 1,
            causal=True, window=cfg.swa_window)
        out = L.merge_partial_softmax(m, l, acc, ctx.axis)
        return out, kc2, vc2

    spec_kv = P_(dp_axes, ctx.axis, None, None)
    out, kc2, vc2 = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P_(dp_axes, None, None, None), spec_kv, spec_kv,
                  P_(dp_axes, None, None, None),
                  P_(dp_axes, None, None, None), P_()),
        out_specs=(P_(dp_axes, None, None, None, None), spec_kv, spec_kv),
        check_vma=False,
    )(q, kc, vc, k_new, v_new, pos)
    B, KV, G, Sq, hd = out.shape
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, KV * G, hd)
    return out.astype(q.dtype), kc2, vc2


def lm_decode_step(params, token, pos, cache, cfg: ModelCfg, *,
                   positions=None, seq_ctx: SeqShardCtx | None = None,
                   kv_chunk: int = 1024):
    """One decode step.  token (B, 1) int32 (or embeds (B,1,D)); pos traced
    scalar; cache {k, v} (L, B, S, KV, hd).  Returns (logits, new cache)."""
    if token.ndim == 2:
        h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    else:
        h = token.astype(cfg.dtype)
    B = h.shape[0]
    if positions is None:
        positions = jnp.full((B, 1), pos)
    cos, sin = _rope(cfg, positions)

    def body(h, xs):
        lp, kc, vc = xs
        x = _norm(lp["ln1"], h, cfg)
        q, k_new, v_new = _qkv(lp["attn"], x, cfg)
        if cos is not None:
            q = L.apply_rope(q, cos, sin)
            k_new = L.apply_rope(k_new, cos, sin)
        if seq_ctx is not None:
            out, kc, vc = _decode_attn_sharded(
                q, kc, vc, k_new, v_new, pos, cfg, seq_ctx)
        else:
            kc = L.dus_seq(kc, k_new, pos)
            vc = L.dus_seq(vc, v_new, pos)
            out = L.flash_attention(
                q, kc.astype(q.dtype), vc.astype(q.dtype), causal=True,
                window=cfg.swa_window, q_offset=pos, kv_valid=pos + 1,
                kv_chunk=kv_chunk)
        out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
        h = h + jnp.einsum("bse,ed->bsd", out,
                           lp["attn"]["wo"].astype(h.dtype))
        h = h + _ffn(lp["ffn"], _norm(lp["ln2"], h, cfg), cfg)
        return actx.batch_act(h), {"k": kc, "v": vc}

    h, new_cache = jax.lax.scan(body, h, (params["layers"],
                                          cache["k"], cache["v"]))
    h = _norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    V = logits.shape[-1]
    if cfg.vocab < V:
        logits = jnp.where(jnp.arange(V)[None, None, :] < cfg.vocab,
                           logits, -1e30)
    return logits, new_cache
