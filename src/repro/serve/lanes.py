"""Priority lanes, per-request deadlines, and typed load-shedding.

The always-on service (``serve/loop.py``) does not use the solver's
size+deadline flush queue -- it owns admission.  This module is the
mechanism layer:

* :class:`LaneSpec` -- one priority lane (name, strict priority, default
  SLO).  ``DEFAULT_LANES`` ships an ``interactive`` lane (priority 0,
  tight SLO) and a ``bulk`` lane (priority 1, loose SLO).
* :class:`ServeTicket` -- the service-side future for one admitted (or
  shed) request: carries admission/completion timestamps, the absolute
  deadline, and -- when shed -- a typed :class:`ShedReason`.  Every
  rejection is typed; a ticket can never be silently dropped.
* :class:`ShedReason` / :class:`ShedError` -- the typed rejection
  vocabulary (queue depth, step-cost budget, deadline expiry, shutdown).
  ``ticket.result()`` on a shed ticket raises ``ShedError``.
* :class:`LaneQueue` -- admitted tickets in per-(lane, bucket-key) FIFO
  order, where the bucket key is ``(n, is_complex)`` (matrices sharing a
  key share one device program).  ``take(key, k)`` drains a bucket's
  worth across lanes in priority order, so an interactive request is
  never stuck behind bulk traffic of the same size -- and bulk traffic
  backfills an interactive bucket's spare slots instead of fragmenting
  device programs.

Policy (when to dispatch, when to shed) lives in the serve loop; this
module only keeps the books, against an injected clock.
"""

from __future__ import annotations

import enum
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Iterator

import numpy as np

__all__ = ["LaneSpec", "DEFAULT_LANES", "ShedReason", "ShedError",
           "ServeTicket", "LaneQueue", "request_cost"]


@dataclass(frozen=True)
class LaneSpec:
    """One priority lane.  Lower ``priority`` preempts higher; ``slo_s``
    is the lane's default admission->result deadline (None = no
    deadline)."""
    name: str
    priority: int
    slo_s: float | None = None


DEFAULT_LANES = (LaneSpec("interactive", 0, slo_s=2.0),
                 LaneSpec("bulk", 1, slo_s=30.0))


class ShedReason(enum.Enum):
    """Why a request was rejected or dropped.  Every shed carries one."""
    QUEUE_FULL = "queue_full"            # admission: depth backpressure
    COST_BUDGET = "cost_budget"          # admission: est. step-cost budget
    DEADLINE_EXPIRED = "deadline_expired"  # queued past its deadline
    SHUTDOWN = "shutdown"                # service stopped with work queued


class ShedError(RuntimeError):
    """Raised by ``ServeTicket.result()`` when the request was shed."""

    def __init__(self, reason: ShedReason, detail: str = ""):
        self.reason = reason
        self.detail = detail
        super().__init__(f"request shed ({reason.value})"
                         + (f": {detail}" if detail else ""))


def request_cost(n: int) -> float:
    """Ryser step-space size of one dense n x n request (the planner's
    dispatch-free cost proxy) -- the unit of the admission budget."""
    return float(n) * float(2 ** max(0, n - 1))


_TICKET_IDS = itertools.count()

QUEUED = "queued"
DONE = "done"
SHED = "shed"


class ServeTicket:
    """Service-side future for one request (admitted or shed)."""

    def __init__(self, matrix: np.ndarray, lane: LaneSpec, t_submit: float,
                 deadline: float | None):
        self.id = next(_TICKET_IDS)
        self.matrix = matrix
        self.n = matrix.shape[0]
        self.is_complex = bool(np.iscomplexobj(matrix))
        self.lane = lane
        self.t_submit = t_submit             # admission timestamp
        self.deadline = deadline             # absolute, or None
        self.cost = request_cost(self.n)
        self.status = QUEUED
        self.value: complex | float | None = None
        self.t_done: float | None = None
        self.shed_reason: ShedReason | None = None
        self.shed_detail: str = ""

    @property
    def key(self) -> tuple[int, bool]:
        """Bucket key: same-key tickets share one device program."""
        return (self.n, self.is_complex)

    @property
    def done(self) -> bool:
        return self.status == DONE

    @property
    def shed(self) -> bool:
        return self.status == SHED

    @property
    def latency_s(self) -> float | None:
        """Admission->result (or ->shed) latency; None while queued."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    def result(self) -> complex | float:
        """The permanent; raises :class:`ShedError` for shed tickets and
        ``RuntimeError`` while still queued (drive the loop first)."""
        if self.status == SHED:
            raise ShedError(self.shed_reason, self.shed_detail)
        if self.status != DONE:
            raise RuntimeError(f"ticket {self.id} still queued -- "
                               f"step/drain the serve loop to resolve it")
        return self.value

    def _resolve(self, value, now: float) -> None:
        self.value = value
        self.t_done = now
        self.status = DONE

    def _shed(self, reason: ShedReason, detail: str, now: float) -> None:
        self.shed_reason = reason
        self.shed_detail = detail
        self.t_done = now
        self.status = SHED


class LaneQueue:
    """Admitted tickets, per-(lane, bucket-key) FIFO, priority-ordered.

    Tracks total depth and the summed step-cost estimate of queued work
    (the backpressure signals) incrementally.
    """

    def __init__(self, lanes: tuple[LaneSpec, ...] = DEFAULT_LANES):
        if not lanes:
            raise ValueError("need at least one lane")
        names = [l.name for l in lanes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate lane names: {names}")
        self.lanes = tuple(sorted(lanes, key=lambda l: l.priority))
        self.by_name = {l.name: l for l in self.lanes}
        # lane name -> bucket key -> FIFO of queued tickets
        self._q: dict[str, dict[tuple, deque[ServeTicket]]] = \
            {l.name: {} for l in self.lanes}
        self.depth = 0
        self.pending_cost = 0.0

    def lane(self, name: str | None) -> LaneSpec:
        if name is None:
            return self.lanes[0]
        try:
            return self.by_name[name]
        except KeyError:
            raise ValueError(f"unknown lane {name!r}; configured: "
                             f"{sorted(self.by_name)}") from None

    def admit(self, ticket: ServeTicket) -> None:
        self._q[ticket.lane.name].setdefault(ticket.key,
                                             deque()).append(ticket)
        self.depth += 1
        self.pending_cost += ticket.cost

    def _drop(self, ticket: ServeTicket) -> None:
        self.depth -= 1
        self.pending_cost -= ticket.cost

    def _iter_queues(self) -> Iterator[tuple[LaneSpec, tuple,
                                             deque[ServeTicket]]]:
        for lane in self.lanes:
            for key, q in self._q[lane.name].items():
                if q:
                    yield lane, key, q

    def shed_expired(self, now: float) -> list[ServeTicket]:
        """Remove and return every queued ticket whose deadline passed.

        The caller marks them shed (DEADLINE_EXPIRED) -- the queue only
        decides membership.
        """
        out: list[ServeTicket] = []
        for lane, key, q in self._iter_queues():
            keep = deque()
            while q:
                t = q.popleft()
                if t.deadline is not None and now >= t.deadline:
                    self._drop(t)
                    out.append(t)
                else:
                    keep.append(t)
            q.extend(keep)
        return out

    def ready_keys(self, now: float) -> list[tuple[int, float, tuple]]:
        """Every bucket key with queued work, as (best priority, oldest
        admission time, key) sorted most-urgent first -- the serve loop's
        dispatch-order view."""
        best: dict[tuple, tuple[int, float]] = {}
        for lane, key, q in self._iter_queues():
            cand = (lane.priority, q[0].t_submit)
            if key not in best or cand < best[key]:
                best[key] = cand
        return sorted((p, t, k) for k, (p, t) in best.items())

    def key_depth(self, key: tuple) -> int:
        return sum(len(self._q[l.name].get(key, ()))
                   for l in self.lanes)

    def take(self, key: tuple, k: int) -> list[ServeTicket]:
        """Drain up to ``k`` tickets of ``key`` across lanes in priority
        order (FIFO within a lane) -- one bucket's worth."""
        out: list[ServeTicket] = []
        for lane in self.lanes:
            q = self._q[lane.name].get(key)
            while q and len(out) < k:
                t = q.popleft()
                self._drop(t)
                out.append(t)
            if len(out) >= k:
                break
        return out

    def drain_all(self) -> list[ServeTicket]:
        """Remove and return everything (shutdown shedding)."""
        out: list[ServeTicket] = []
        for lane, key, q in self._iter_queues():
            while q:
                t = q.popleft()
                self._drop(t)
                out.append(t)
        return out
