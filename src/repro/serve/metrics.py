"""Observability layer for the always-on permanent service.

Monotonic-clock histograms and counters for the serve loop, exported in
ONE schema -- the benchmark gate (``benchmarks/serve_soak.py``), the
periodic log line, and the JSON snapshot endpoint all read the same
counters, and ``PermanentSolver.stats()`` (dispatch/cache accounting +
the executor's per-leaf ``leaf_timings``) is embedded verbatim.

Snapshot schema (``ServeMetrics.snapshot()``)::

    {
      "schema": "repro.serve.metrics/v1",
      "uptime_s": float,                  # monotonic, since construction
      "requests": {
        "admitted": int,                  # tickets submitted (admission
                                          #   attempts, incl. ones shed
                                          #   at the door)
        "completed": int,                 # tickets resolved with a value
        "pending": int,                   # still queued (loop-supplied)
        "shed": {reason: int, ...},       # typed rejections, by reason
        "shed_total": int                 # sum of the above
      },                                  # invariant: admitted ==
                                          #   completed+shed_total+pending
      "latency_s": {                      # admission -> result
        "overall": HIST, "<lane>": HIST, ...
      },
      "queue_depth": HIST,                # sampled once per loop tick
      "bucket_occupancy": HIST,           # served/batch-capacity per
      "dispatches": int,                  #   bucket dispatch
      "cache_hit_rate": float | None,     # solver result cache (mirror)
      "campaign_fraction": float | None,  # interleaved campaign progress
      "solver": <PermanentSolver.stats()>,  # incl. cache + leaf_timings
      "compile_cache": <serve.compile_cache.compile_stats()> | None
    }

    HIST = {"count": int, "mean": float, "p50": float, "p99": float,
            "max": float}

Quantiles come from fixed log-spaced bucket histograms (no sample
retention -- bounded memory under millions of requests); ``p50``/``p99``
are bucket upper-bound estimates, conservative by at most one bucket
width (~26% with the default 10-buckets-per-decade layout).

:func:`start_metrics_server` serves the snapshot as JSON over stdlib
HTTP (``GET /metrics``) for scraping; ``ServeMetrics.log_line()`` is the
one-line periodic summary the loop prints.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Callable

from .lanes import ShedReason

__all__ = ["Histogram", "ServeMetrics", "start_metrics_server"]

SCHEMA = "repro.serve.metrics/v1"


class Histogram:
    """Fixed log-spaced-bucket histogram with quantile estimation.

    Buckets span [lo, hi) at ``per_decade`` buckets per decade, plus
    underflow/overflow buckets; observation is O(log buckets), memory is
    O(buckets) regardless of sample count.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e5,
                 per_decade: int = 10):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        import math
        decades = math.log10(hi / lo)
        nb = max(1, round(decades * per_decade))
        ratio = (hi / lo) ** (1.0 / nb)
        self._edges = [lo * ratio ** i for i in range(nb + 1)]
        self._counts = [0] * (nb + 2)        # + underflow / overflow
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.max = max(self.max, v)
        import bisect
        self._counts[bisect.bisect_right(self._edges, v)] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Upper-bound estimate of the q-quantile (0 when empty)."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target and c:
                if i == 0:                       # underflow bucket
                    return self._edges[0]
                if i > len(self._edges) - 1:     # overflow bucket
                    return self.max
                return min(self._edges[i], self.max)
        return self.max

    def to_json(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99),
                "max": self.max}


class ServeMetrics:
    """Counters + histograms for one service instance (injected clock)."""

    def __init__(self, clock: Callable[[], float] = time.monotonic, *,  # permlint: disable=PL004  # injectable default; tests override
                 lanes: tuple[str, ...] = ()):
        self._clock = clock
        self.t_start = clock()
        self.admitted = 0
        self.completed = 0
        self.shed: dict[str, int] = {}
        self.dispatches = 0
        self.latency = Histogram()
        self.lane_latency: dict[str, Histogram] = \
            {name: Histogram() for name in lanes}
        self.queue_depth = Histogram(lo=1.0, hi=1e6, per_decade=10)
        self.bucket_occupancy = Histogram(lo=1e-3, hi=10.0, per_decade=20)
        self._last_log = self.t_start

    # -- recording (called by the serve loop) -------------------------------

    def record_admit(self, ticket) -> None:
        """Count every submission -- including tickets shed at the door,
        so admitted == completed + shed_total + pending always holds."""
        self.admitted += 1

    def record_shed(self, ticket) -> None:
        reason: ShedReason = ticket.shed_reason
        self.shed[reason.value] = self.shed.get(reason.value, 0) + 1

    def record_complete(self, ticket) -> None:
        self.completed += 1
        lat = ticket.latency_s
        if lat is not None:
            self.latency.observe(lat)
            h = self.lane_latency.setdefault(ticket.lane.name, Histogram())
            h.observe(lat)

    def record_dispatch(self, served: int, capacity: int) -> None:
        self.dispatches += 1
        self.bucket_occupancy.observe(served / max(1, capacity))

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.observe(float(depth))

    # -- exporting -----------------------------------------------------------

    @property
    def shed_total(self) -> int:
        return sum(self.shed.values())

    def snapshot(self, *, pending: int = 0, solver_stats: dict | None = None,
                 compile_stats: dict | None = None,
                 campaign_fraction: float | None = None) -> dict:
        """The one JSON shape (see module docstring for the schema)."""
        cache = (solver_stats or {}).get("cache")
        return {
            "schema": SCHEMA,
            "uptime_s": self._clock() - self.t_start,
            "requests": {"admitted": self.admitted,
                         "completed": self.completed,
                         "pending": pending,
                         "shed": dict(sorted(self.shed.items())),
                         "shed_total": self.shed_total},
            "latency_s": {"overall": self.latency.to_json(),
                          **{name: h.to_json()
                             for name, h in sorted(
                                 self.lane_latency.items())}},
            "queue_depth": self.queue_depth.to_json(),
            "bucket_occupancy": self.bucket_occupancy.to_json(),
            "dispatches": self.dispatches,
            "cache_hit_rate": cache["hit_rate"] if cache else None,
            "campaign_fraction": campaign_fraction,
            "solver": solver_stats,
            "compile_cache": compile_stats,
        }

    def log_line(self, *, pending: int = 0,
                 cache_hit_rate: float | None = None,
                 campaign_fraction: float | None = None) -> str:
        """One-line periodic summary (same counters as the snapshot)."""
        lat = self.latency
        parts = [f"[serve] up={self._clock() - self.t_start:.0f}s",
                 f"admitted={self.admitted}",
                 f"done={self.completed}",
                 f"shed={self.shed_total}",
                 f"pending={pending}",
                 f"p50={lat.quantile(0.5) * 1e3:.0f}ms",
                 f"p99={lat.quantile(0.99) * 1e3:.0f}ms",
                 f"depth_p99={self.queue_depth.quantile(0.99):.0f}",
                 f"occ={self.bucket_occupancy.mean:.2f}"]
        if cache_hit_rate is not None:
            parts.append(f"cache={cache_hit_rate:.0%}")
        if campaign_fraction is not None:
            parts.append(f"campaign={campaign_fraction:.1%}")
        return " ".join(parts)

    def should_log(self, every_s: float) -> bool:
        """True (and reset the timer) when ``every_s`` elapsed since the
        last periodic log line."""
        now = self._clock()
        if now - self._last_log >= every_s:
            self._last_log = now
            return True
        return False


def start_metrics_server(snapshot_fn: Callable[[], dict], port: int = 0,
                         host: str = "127.0.0.1"):
    """Serve ``snapshot_fn()`` as JSON on ``GET /metrics`` (stdlib only).

    Returns the started ``ThreadingHTTPServer`` (daemon thread; call
    ``.shutdown()`` to stop).  ``port=0`` binds an ephemeral port --
    read it back from ``server.server_address``.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path not in ("/metrics", "/"):
                self.send_error(404)
                return
            body = json.dumps(snapshot_fn(), indent=1).encode()
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):     # quiet: the loop owns logging
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server
