"""Persistent XLA compilation cache wiring + kernel-geometry warm-up.

A cold serving process pays a retrace storm: every (batch, n) bucket
geometry it meets traces and XLA-compiles before the first result comes
back.  Two layers fix that:

* :func:`enable_compile_cache` points ``jax``'s persistent compilation
  cache (``jax.config`` ``jax_compilation_cache_dir`` wiring, thresholds
  zeroed so every executable persists) at an on-disk directory keyed the
  same way ``core/cache.py`` keys results -- by content, here the HLO +
  compile options, so identical programs across process restarts load
  their executable from disk instead of re-invoking XLA.
* :func:`warmup` runs the serve plan's kernel geometries -- every
  (n, device-batch, dtype) bucket program the loop can dispatch -- through
  a throwaway solver before traffic is admitted.  Tracing happens once,
  up front; with a warm disk cache the XLA compile step is a cache hit,
  so a restarted process serves its first bucket with zero compiles.
  The throwaway solver plans with the *serving* config, so when
  ``SolverConfig.tuning_table`` is set the planner resolves the tuned
  kernel geometry per bucket and the warmed programs ARE the tuned
  ones -- a tuned service serves its first bucket with zero XLA
  compiles, same as an untuned one (``benchmarks/serve_soak.py`` gates
  this across two cold processes).

:func:`compile_stats` exposes jax's compilation-cache monitoring events
(requests / persistent hits / persistent misses) as plain counters; the
soak benchmark compares them across two cold starts to prove the
first-bucket-without-recompiling property, and ``serve/metrics.py``
embeds them in its snapshot schema.
"""

from __future__ import annotations

import os
import time
from typing import Sequence

import numpy as np

__all__ = ["enable_compile_cache", "install_compile_listener",
           "compile_stats", "reset_compile_stats", "warmup",
           "quantized_batches"]

# jax monitoring event names -> our counter keys
_EVENTS = {
    "/jax/compilation_cache/compile_requests_use_cache": "requests",
    "/jax/compilation_cache/cache_hits": "persistent_hits",
    "/jax/compilation_cache/cache_misses": "persistent_misses",
}

_counts = {v: 0 for v in _EVENTS.values()}
_installed = False


def _listener(event: str, **kwargs) -> None:
    key = _EVENTS.get(event)
    if key is not None:
        _counts[key] += 1


def install_compile_listener() -> None:
    """Idempotently register the jax monitoring listener backing
    :func:`compile_stats`."""
    global _installed
    if _installed:
        return
    from jax._src import monitoring
    monitoring.register_event_listener(_listener)
    _installed = True


def compile_stats() -> dict:
    """Cumulative persistent-compilation-cache counters for this process.

    ``requests`` counts XLA compiles that consulted the persistent
    cache; each was either a ``persistent_hits`` (executable loaded from
    disk) or a ``persistent_misses`` (really compiled, then stored).
    All zero until :func:`enable_compile_cache` ran.
    """
    return dict(_counts)


def reset_compile_stats() -> None:
    for k in _counts:
        _counts[k] = 0


def enable_compile_cache(path: str) -> str:
    """Wire jax's persistent compilation cache at ``path`` (created if
    missing) and start counting cache events.  Returns the path."""
    import jax
    path = os.path.abspath(os.path.expanduser(path))
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # persist everything: the bucket programs this service compiles are
    # small and hot, and the default thresholds would skip exactly the
    # tiny-n programs the retrace storm is made of
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    install_compile_listener()
    return path


def quantized_batches(max_batch: int) -> tuple[int, ...]:
    """The device-batch sizes the serve loop dispatches: powers of two up
    to (and including, when itself a power of two) ``max_batch``, capped
    at the next power of two otherwise.

    Quantizing dispatch sizes bounds the trace space -- continuous
    batching produces arbitrary partial buckets, and every distinct
    (B, n, n) shape is its own trace+compile.  The loop pads a partial
    bucket up to the next size in this ladder.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(b)                    # next pow2 >= max_batch
    return tuple(out)


def warmup(config, geometries: Sequence[tuple], *,
           distributed_ctx=None, seed: int = 0,
           progress=None) -> dict:
    """Trace + compile every bucket program in ``geometries`` before
    traffic arrives.

    ``config`` is the serving :class:`~repro.core.planner.SolverConfig`;
    ``geometries`` is an iterable of ``(n, batch)`` or
    ``(n, batch, is_complex)`` tuples -- typically every ``n`` the
    service expects crossed with :func:`quantized_batches`.  Runs each
    geometry once through a throwaway solver (result cache off, so the
    synthetic warm-up matrices never pollute the serving cache; the jit
    and persistent-compile caches warmed here are process/disk-global).
    The solver keeps the serving config's ``geometry`` override and
    ``tuning_table`` -- bucket programs are planned with the same
    resolved kernel geometry the live loop will dispatch, so tuning
    never reintroduces a first-bucket compile.
    Returns ``{"geometries", "seconds", "compile"}`` where ``compile`` is
    the :func:`compile_stats` delta of the pass.
    """
    from ..core.solver import PermanentSolver

    solver = PermanentSolver(config.replace(cache=False),
                             distributed_ctx=distributed_ctx)
    rng = np.random.default_rng(seed)
    before = compile_stats()
    t0 = time.perf_counter()
    done = 0
    for geom in geometries:
        n, batch = geom[0], geom[1]
        is_complex = bool(geom[2]) if len(geom) > 2 else False
        mats = rng.uniform(-1.0, 1.0, (batch, n, n))
        if is_complex:
            mats = mats + 1j * rng.uniform(-1.0, 1.0, (batch, n, n))
        solver.execute(solver.plan_batch(list(mats)))
        done += 1
        if progress is not None:
            progress(n, batch, is_complex)
    after = compile_stats()
    return {"geometries": done,
            "seconds": time.perf_counter() - t0,
            "compile": {k: after[k] - before[k] for k in after}}
