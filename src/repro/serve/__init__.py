"""Always-on permanent service: continuous batching, priority lanes,
SLOs, and observability over the PR 1-6 plan/execute solver stack.

    from repro.serve import PermanentService, ServiceConfig

    svc = PermanentService(SolverConfig(precision="dq_acc"),
                           ServiceConfig(max_batch=32,
                                         warmup_ns=(10,),
                                         compile_cache_dir=".xla-cache"))
    t = svc.submit(A, lane="interactive")
    svc.drain()
    print(t.result(), svc.snapshot()["latency_s"]["overall"]["p99"])

Layering: ``lanes`` (admission mechanism: priority lanes, deadlines,
typed shedding) -> ``loop`` (the service: continuous batching, back-
pressure, campaign interleaving) -> ``metrics`` (one snapshot schema) +
``compile_cache`` (persistent XLA cache + warm-up).  ``launch/serve.py``
is the CLI over this package.
"""

from .compile_cache import (compile_stats, enable_compile_cache,
                            quantized_batches, warmup)
from .lanes import (DEFAULT_LANES, LaneQueue, LaneSpec, ServeTicket,
                    ShedError, ShedReason, request_cost)
from .loop import CampaignSpec, PermanentService, ServiceConfig, run_soak
from .metrics import Histogram, ServeMetrics, start_metrics_server

__all__ = [
    "CampaignSpec", "DEFAULT_LANES", "Histogram", "LaneQueue", "LaneSpec",
    "PermanentService", "ServeMetrics", "ServeTicket", "ServiceConfig",
    "ShedError", "ShedReason", "compile_stats", "enable_compile_cache",
    "quantized_batches", "request_cost", "run_soak",
    "start_metrics_server", "warmup",
]
