"""Always-on permanent service: vLLM-style continuous batching.

The solver's own queue (PR 6) flushes a size bucket when it fills or its
oldest request ages out -- between triggers the device idles even with
work queued.  :class:`PermanentService` inverts that: a synchronous loop
(``submit`` / ``step`` / ``drain``) that dispatches whenever the device
is free, filling each dispatch with whatever compatible work is queued
-- batches form from requests that arrived *during* the previous
dispatch, not from waiting out a deadline.  On top of admission it adds
the production concerns the solver queue has no opinion on:

* **Priority lanes + per-request deadlines** (``serve/lanes.py``): an
  interactive request never waits behind bulk traffic of the same
  shape, bulk backfills interactive buckets' spare slots, and a request
  queued past its deadline is shed -- typed, never silently dropped.
* **Backpressure**: admission refuses work (``ShedReason.QUEUE_FULL`` /
  ``COST_BUDGET``) when queue depth or the summed Ryser step-cost
  estimate of queued work exceeds budget.  ``submit`` never raises --
  the returned ticket carries the typed reason and ``result()`` raises
  :class:`~repro.serve.lanes.ShedError`.
* **Bounded trace space**: dispatched buckets are padded up to a
  power-of-two ladder (``quantize_buckets``) with *distinct* random
  filler matrices -- distinct because the executor dedups repeated
  leaves within a batch and the result cache would swallow repeats
  across batches, either of which would shrink the device batch back to
  an unquantized shape.  Combined with the persistent compilation cache
  and a warm-up pass over the ladder (``serve/compile_cache.py``), a
  cold process serves its first bucket without a retrace storm.  (With
  the result cache on, a mid-stream dispatch whose tickets partly hit
  the cache still runs the device program at the miss count -- the
  ladder bounds the *cold* trace space, which is where the storm is.)
* **Observability** (``serve/metrics.py``): every admit/shed/complete/
  dispatch lands in one snapshot schema; ``step`` prints a periodic
  one-line summary.
* **Campaign interleaving**: a :class:`CampaignSpec` threads PR 6's
  step-space campaign through the loop -- waves advance after each
  bucket dispatch, and ``drain`` runs the campaign to completion.

``fill_first=True`` pins the loop to the PR 6 solver-queue semantics
(dispatch only full or deadline-aged buckets, no shedding, no padding);
``launch/serve.py``'s ``run_permanent_serving`` runs in that mode and is
bitwise-identical to the old implementation, because a bucket then
reaches ``plan_batch`` with exactly the same matrices in the same order.

All timing flows through one injected monotonic clock (tests pass a
fake; deadlines, latencies, and log cadence are then deterministic).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import numpy as np

from .compile_cache import (compile_stats, enable_compile_cache,
                            quantized_batches, warmup)
from .lanes import (DEFAULT_LANES, LaneQueue, LaneSpec, ServeTicket,
                    ShedReason)
from .metrics import ServeMetrics

__all__ = ["ServiceConfig", "CampaignSpec", "PermanentService", "run_soak"]

_LANE_DEFAULT = object()      # submit(): "use the lane's slo_s as deadline"


@dataclass(frozen=True)
class ServiceConfig:
    """Admission + dispatch policy for one :class:`PermanentService`.

    The numeric solver knobs (precision, backend, result cache) live in
    :class:`~repro.core.planner.SolverConfig`; this holds only the
    service-side policy.
    """
    max_batch: int = 32                  # bucket capacity per dispatch
    lanes: tuple[LaneSpec, ...] = DEFAULT_LANES
    max_queue_depth: int = 4096          # admission: depth backpressure
    max_pending_cost: float = float("inf")  # admission: step-cost budget
    quantize_buckets: bool = True        # pad dispatches to the pow2 ladder
    fill_first: bool = False             # legacy PR 6 flush semantics
    deadline_s: float = 0.05             # fill_first: bucket age-out trigger
    log_every_s: float = 10.0            # periodic log-line cadence
    compile_cache_dir: str | None = None  # persistent XLA cache location
    warmup_ns: tuple[int, ...] = ()      # pre-compile these matrix sizes ...
    warmup_complex: bool = False         # ... (optionally x complex) x ladder


@dataclass
class CampaignSpec:
    """A PR 6 step-space campaign interleaved with serving: ``waves``
    checkpointed waves advance after every bucket dispatch, and the
    campaign runs to completion when the request stream drains."""
    matrix: Any
    mesh: Any = None                     # step mesh (None = all devices)
    waves: int = 1                       # waves per bucket dispatch
    checkpoint: str | None = None        # JobState .npz path
    slices: int = 64
    lanes: int = 1024


class PermanentService:
    """The always-on loop: admission -> lanes -> bucket dispatch.

    Single-threaded by design: ``submit`` only admits (constant-time
    bookkeeping), ``step`` does at most one bucket dispatch, ``drain``
    steps until the queue is empty.  Callers own the thread; an open
    loop is ``run_soak``, a closed one is ``ticket.result()`` after
    ``drain()``.
    """

    def __init__(self, solver_config=None, service: ServiceConfig | None = None,
                 *, distributed_ctx: Any | None = None,
                 campaign: CampaignSpec | None = None,
                 clock: Callable[[], float] | None = None,
                 log: Callable[[str], None] = print,
                 filler_seed: int = 0x5eed):
        from ..core.solver import PermanentSolver, SolverConfig

        self.scfg = service or ServiceConfig()
        if self.scfg.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.scfg.max_batch}")
        solver_config = solver_config or SolverConfig()
        self._clock = clock if clock is not None \
            else (solver_config.clock or time.monotonic)  # permlint: disable=PL004  # sanctioned injectable-clock default
        self._log = log
        self._queue = LaneQueue(self.scfg.lanes)
        self.metrics = ServeMetrics(self._clock,
                                    lanes=tuple(l.name
                                                for l in self._queue.lanes))
        # filler matrices for pow2 padding; its own stream so padding
        # never perturbs caller-visible randomness
        self._filler_rng = np.random.default_rng(filler_seed)
        self._ladder = quantized_batches(self.scfg.max_batch)
        # (key, served, plan+execute seconds, trigger) per dispatch --
        # the wrapper in launch/serve.py derives its latency report here
        self.dispatch_log: list[tuple[tuple, int, float, str]] = []

        if self.scfg.compile_cache_dir:
            enable_compile_cache(self.scfg.compile_cache_dir)
        self.solver = PermanentSolver(solver_config,
                                      distributed_ctx=distributed_ctx,
                                      clock=self._clock)
        self.warmup_report: dict | None = None
        if self.scfg.warmup_ns:
            batches = self._ladder if self.scfg.quantize_buckets \
                else (self.scfg.max_batch,)
            geoms = [(n, b, c)
                     for n in self.scfg.warmup_ns
                     for b in batches
                     for c in ((False, True) if self.scfg.warmup_complex
                               else (False,))]
            self.warmup_report = warmup(solver_config, geoms,
                                        distributed_ctx=distributed_ctx)

        self._campaign = campaign
        self._camp_state: dict = {"state": None, "value": None}
        if campaign is not None:
            self._camp_setup(campaign)

    # -- campaign interleaving ----------------------------------------------

    def _camp_setup(self, spec: CampaignSpec) -> None:
        from ..core.stepspace import plan_slices
        cmat = np.asarray(spec.matrix)
        mesh = spec.mesh
        if mesh is None:
            import jax
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()), ("step",))
        ts, cps, C = plan_slices(cmat.shape[0], spec.slices, 1, spec.lanes)
        self._camp_args = (cmat, mesh, ts, cps, C)

    def _advance_campaign(self, waves: int | None) -> None:
        """Run up to ``waves`` campaign waves (None = to completion);
        state threads across calls so each dispatch resumes in place."""
        if self._campaign is None or self._camp_state["value"] is not None:
            return
        from ..core.distributed import run_campaign
        cmat, mesh, ts, cps, C = self._camp_args
        # backend must follow the solver config (the permlint PL003 audit
        # caught this dropped kwarg: a pallas-configured service silently
        # ran jnp waves) -- same jnp/pallas collapse as the planner's
        # campaign route, since run_campaign knows only those two bodies.
        backend = "pallas" if self.solver.config.backend == "pallas" \
            else "jnp"
        # tuned kernel geometry follows the same resolution order as the
        # planner's campaign route (config override > tuning table >
        # kernel defaults); jnp wave bodies have no kernel geometry
        geometry = None
        if backend == "pallas":
            from ..core.planner import ROUTE_CAMPAIGN, _resolve_geometry
            geometry = _resolve_geometry(
                self.solver.config, ROUTE_CAMPAIGN, cmat.shape[0],
                float(np.count_nonzero(cmat)) / cmat.size,
                cmat.dtype.str, self.solver.config.precision)
        val, st = run_campaign(
            cmat, mesh, total_slices=ts, chunks_per_slice=cps,
            chunk_size=C, precision=self.solver.config.precision,
            backend=backend, geometry=geometry,
            checkpoint_path=self._campaign.checkpoint,
            state=self._camp_state["state"], max_waves=waves)
        self._camp_state["state"], self._camp_state["value"] = st, val

    @property
    def campaign_value(self):
        return self._camp_state["value"]

    @property
    def campaign_fraction(self) -> float | None:
        st = self._camp_state["state"]
        if st is not None:
            return st.fraction_done()
        return None if self._campaign is None else 0.0

    # -- admission -----------------------------------------------------------

    @property
    def pending(self) -> int:
        return self._queue.depth

    def submit(self, A, *, lane: str | None = None,
               deadline_s=_LANE_DEFAULT,
               t_submit: float | None = None) -> ServeTicket:
        """Admit one matrix; returns a :class:`ServeTicket` immediately.

        Never raises on load: a refused request comes back as a ticket
        already shed with a typed reason (``QUEUE_FULL`` when depth is at
        ``max_queue_depth``, ``COST_BUDGET`` when the queued step-cost
        estimate would exceed ``max_pending_cost``).  ``deadline_s`` is
        relative to admission; defaults to the lane's ``slo_s``; pass
        ``None`` for no deadline.  ``t_submit`` backdates admission to an
        arrival time (open-loop drivers), so queueing latency counts
        from arrival, not from the submit call.
        """
        A = np.asarray(A)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"square matrix required, got {A.shape}")
        now = self._clock()
        t_sub = now if t_submit is None else t_submit
        lane_spec = self._queue.lane(lane)
        if deadline_s is _LANE_DEFAULT:
            deadline_s = lane_spec.slo_s
        deadline = None if deadline_s is None else t_sub + deadline_s
        ticket = ServeTicket(A, lane_spec, t_sub, deadline)
        self.metrics.record_admit(ticket)
        if self._queue.depth >= self.scfg.max_queue_depth:
            ticket._shed(ShedReason.QUEUE_FULL,
                         f"queue depth {self._queue.depth} at limit "
                         f"{self.scfg.max_queue_depth}", now)
            self.metrics.record_shed(ticket)
            return ticket
        if self._queue.pending_cost + ticket.cost > \
                self.scfg.max_pending_cost:
            ticket._shed(ShedReason.COST_BUDGET,
                         f"queued step-cost {self._queue.pending_cost:.3g} "
                         f"+ {ticket.cost:.3g} exceeds budget "
                         f"{self.scfg.max_pending_cost:.3g}", now)
            self.metrics.record_shed(ticket)
            return ticket
        self._queue.admit(ticket)
        return ticket

    # -- the loop ------------------------------------------------------------

    def step(self) -> int:
        """One loop tick: shed expired work, then dispatch at most one
        bucket.  Returns the number of tickets resolved (0 = nothing
        ready)."""
        now = self._clock()
        for t in self._queue.shed_expired(now):
            t._shed(ShedReason.DEADLINE_EXPIRED,
                    f"queued past deadline by {now - t.deadline:.3g}s",
                    now)
            self.metrics.record_shed(t)
        self.metrics.sample_queue_depth(self._queue.depth)
        key, trigger = self._pick_bucket(now)
        served = self._dispatch(key, trigger) if key is not None else 0
        if self._log is not None \
                and self.metrics.should_log(self.scfg.log_every_s):
            self._log(self.metrics.log_line(
                pending=self._queue.depth,
                cache_hit_rate=self._cache_hit_rate(),
                campaign_fraction=self.campaign_fraction))
        return served

    def drain(self, *, finish_campaign: bool = True) -> int:
        """Step until the queue is empty (every ticket resolved or shed);
        then run any interleaved campaign to completion.  Returns the
        number of tickets resolved."""
        total = 0
        while self._queue.depth:
            served = self.step()
            if served == 0 and self._queue.depth:
                # fill_first tail: a partial bucket never meets the
                # size/age trigger -- the drain forces the raggeds out
                ready = self._queue.ready_keys(self._clock())
                if not ready:
                    break
                _, _, key = ready[0]
                served = self._dispatch(key, "drain")
            total += served
        if finish_campaign:
            self._advance_campaign(None)
        return total

    def shutdown(self) -> list[ServeTicket]:
        """Shed everything still queued (typed ``SHUTDOWN``); returns the
        shed tickets."""
        now = self._clock()
        out = self._queue.drain_all()
        for t in out:
            t._shed(ShedReason.SHUTDOWN, "service shut down with work "
                    "queued", now)
            self.metrics.record_shed(t)
        return out

    def _pick_bucket(self, now: float):
        ready = self._queue.ready_keys(now)
        if not ready:
            return None, None
        if not self.scfg.fill_first:
            # continuous batching: the device is free (we are being
            # stepped), so serve the most urgent bucket at whatever
            # depth it has
            _, _, key = ready[0]
            return key, "ready"
        # legacy PR 6 semantics: only full or deadline-aged buckets.
        # Scan every key -- a full bucket must dispatch even when a
        # non-full, older one sorts ahead of it.
        for _, t_oldest, key in ready:
            if self._queue.key_depth(key) >= self.scfg.max_batch:
                return key, "size"
            if now - t_oldest >= self.scfg.deadline_s:
                return key, "age"
        return None, None

    def _dispatch(self, key: tuple, trigger: str) -> int:
        tickets = self._queue.take(key, self.scfg.max_batch)
        n, is_complex = key
        mats = [t.matrix for t in tickets]
        if self.scfg.quantize_buckets:
            target = next(b for b in self._ladder if b >= len(mats))
            for _ in range(target - len(mats)):
                F = self._filler_rng.uniform(-1.0, 1.0, (n, n))
                if is_complex:
                    F = F + 1j * self._filler_rng.uniform(-1.0, 1.0,
                                                          (n, n))
                mats.append(F)
        t0 = time.perf_counter()
        plan = self.solver.plan_batch(mats)
        out = self.solver.execute(plan)
        dt = time.perf_counter() - t0
        t_done = self._clock()
        for t, v in zip(tickets, out):      # padded tail values discarded
            t._resolve(complex(v) if t.is_complex else float(v), t_done)
            self.metrics.record_complete(t)
        self.metrics.record_dispatch(len(tickets), self.scfg.max_batch)
        self.dispatch_log.append((key, len(tickets), dt, trigger))
        if self._campaign is not None:
            self._advance_campaign(self._campaign.waves)
        return len(tickets)

    # -- exporting -----------------------------------------------------------

    def _cache_hit_rate(self) -> float | None:
        if self.solver.cache is None:
            return None
        return self.solver.cache.stats()["hit_rate"]

    def snapshot(self) -> dict:
        """The ``repro.serve.metrics/v1`` snapshot (see serve/metrics.py)."""
        return self.metrics.snapshot(
            pending=self._queue.depth,
            solver_stats=self.solver.stats(),
            compile_stats=(compile_stats()
                           if self.scfg.compile_cache_dir else None),
            campaign_fraction=self.campaign_fraction)


def run_soak(service: PermanentService, *, requests: int, rate_hz: float,
             n: int = 12, density: float = 1.0,
             complex_entries: bool = False, repeat_pool: int = 8,
             seed: int = 0, lane_cycle: Sequence[str] | None = None,
             expire_every: int = 0,
             sleep: Callable[[float], None] | None = time.sleep) -> dict:
    """Open-loop Poisson soak: drive ``service`` with seeded exponential
    inter-arrival times at ``rate_hz`` and step the loop between
    arrivals (the single-threaded stand-in for "dispatch whenever the
    device is free").

    Requests draw from a ``repeat_pool``-sized matrix pool (result-cache
    traffic) and round-robin over ``lane_cycle`` (default: every
    configured lane).  ``expire_every=k`` gives every k-th request an
    already-expired deadline -- a deterministic source of
    ``DEADLINE_EXPIRED`` sheds so the typed-shed path is exercised on
    every run.  Tickets are backdated to their arrival time, so latency
    includes time spent queued behind an in-flight dispatch.

    Returns ``{"snapshot", "tickets", "wall_s", "arrival_span_s"}``;
    ``benchmarks/serve_soak.py`` gates on the snapshot.
    """
    if requests < 1 or rate_hz <= 0:
        raise ValueError(f"need requests >= 1 and rate_hz > 0, got "
                         f"{requests}, {rate_hz}")
    rng = np.random.default_rng(seed)

    def draw():
        M = rng.uniform(-1.0, 1.0, (n, n))
        if complex_entries:
            M = M + 1j * rng.uniform(-1.0, 1.0, (n, n))
        if density < 1.0:
            M = M * (rng.uniform(0, 1, (n, n)) < density)
        return M

    pool = [draw() for _ in range(max(1, repeat_pool))]
    picks = rng.integers(0, len(pool), requests)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, requests))
    lanes = list(lane_cycle) if lane_cycle is not None \
        else [l.name for l in service._queue.lanes]

    clock = service._clock
    t0 = clock()
    tickets = []
    for i in range(requests):
        target = t0 + arrivals[i]
        while clock() < target:
            # device free until the next arrival: serve queued work
            if service.step() == 0:
                wait = target - clock()
                if wait <= 0:
                    break
                if sleep is not None:
                    sleep(min(wait, 1e-3))
                else:
                    break               # fake clock: nothing will age
        kwargs = {}
        if expire_every and i % expire_every == expire_every - 1:
            kwargs["deadline_s"] = -1.0      # expired on arrival
        # backdate to the arrival time (not past the clock, which may
        # lag the schedule under an injected fake clock)
        tickets.append(service.submit(
            pool[picks[i]], lane=lanes[i % len(lanes)],
            t_submit=min(target, clock()), **kwargs))
    service.drain()
    return {"snapshot": service.snapshot(), "tickets": tickets,
            "wall_s": clock() - t0, "arrival_span_s": float(arrivals[-1])}
