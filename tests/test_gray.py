"""Gray-code machinery: unit + property tests (hypothesis)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import gray as G


def test_gray_table_matches_paper_table1():
    # paper Table 1: 3-bit Gray codes and changed bits
    codes = [G.gray(g) for g in range(8)]
    assert codes == [0b000, 0b001, 0b011, 0b010, 0b110, 0b111, 0b101, 0b100]
    changed = [G.ctz(g) for g in range(1, 8)]
    assert changed == [0, 1, 0, 2, 0, 1, 0]


def test_cbl_palindrome_and_recursion():
    for nbits in range(1, 10):
        cbl = [G.ctz(g) for g in range(1, 1 << nbits)]
        assert cbl == cbl[::-1], "CBL must be a palindrome"
        if nbits >= 2:
            prev = [G.ctz(g) for g in range(1, 1 << (nbits - 1))]
            assert cbl == prev + [nbits - 1] + prev[::-1]


def test_changed_bit_schedule_uniform_across_aligned_chunks():
    # the CEG property: for chunk size 2^k, local steps w = 1..2^k-1 have
    # chunk-independent changed bits
    for k in [1, 2, 3, 5]:
        C = 1 << k
        sched = G.changed_bit_schedule(k)
        for base in [0, C, 4 * C, 31 * C]:
            actual = [G.ctz(base + w) for w in range(1, C)]
            assert list(sched) == actual


@given(st.integers(min_value=1, max_value=2**62))
@settings(max_examples=200, deadline=None)
def test_step_sign_consistent_with_gray_flip(g):
    j = G.ctz(g)
    before = G.gray_bit(g - 1, j)
    after = G.gray_bit(g, j)
    assert before != after, "exactly bit j flips"
    assert G.step_sign(g) == (1 if after == 1 else -1)


@given(st.integers(min_value=0, max_value=2**62), st.integers(0, 62))
@settings(max_examples=200, deadline=None)
def test_gray_bits_matrix_matches_bigint(start, nbits_seed):
    nbits = max(1, nbits_seed)
    M = G.gray_bits_matrix(np.array([start], dtype=np.uint64), nbits)
    for j in range(nbits):
        assert M[j, 0] == G.gray_bit(start, j)


def test_step_sign_jnp_matches_python():
    gs = np.arange(1, 4097, dtype=np.uint64)
    js = np.array([G.ctz(int(g)) for g in gs], dtype=np.uint64)
    got = np.asarray(G.step_sign_jnp(jnp.asarray(gs), jnp.asarray(js)))
    want = np.array([G.step_sign(int(g)) for g in gs])
    np.testing.assert_array_equal(got, want)


def test_accum_sign_parity():
    # popcount(gray(g)) parity == parity of g
    for g in range(1, 1 << 12):
        assert G.accum_sign(g) == (1 if bin(G.gray(g)).count("1") % 2 == 0
                                   else -1)
