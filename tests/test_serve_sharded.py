"""Sequence-sharded decode (flash-decoding combine) vs unsharded reference.

Runs in a subprocess with 4 fake devices (XLA_FLAGS is init-time)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_seq_sharded_decode_matches_unsharded():
    out = _run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.model import build, ShapeCell
        from repro.train.train_step import build_serve_steps

        # force the seq policy: starcoder2 has kv=2, model axis 4 -> seq
        cfg = get_config("starcoder2-3b").reduced(
            n_heads=4, n_kv_heads=2, d_model=64, head_dim=16, vocab=512)
        model = build(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        S, B = 32, 4

        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cell = ShapeCell("d", "decode", S, B)
        step, shards, cshard, policy = build_serve_steps(model, mesh, cell)
        assert policy == "seq", policy

        # build a half-filled cache via prefill on ONE device mesh
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        pstep, _, _, _ = build_serve_steps(
            model, mesh1, ShapeCell("p", "prefill", S, B))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 16)), jnp.int32)
        # prefill at S so the cache is already full length
        h, cache = model.prefill_fn(S)(params, {"tokens": toks})

        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        inputs = {"token": tok, "pos": jnp.int32(16)}
        # unsharded reference decode
        ref_logits, _ = model.decode_fn(None)(params, inputs, cache)
        # sharded decode
        got_logits, _ = step(params, inputs, jax.device_put(cache, cshard))
        np.testing.assert_allclose(np.asarray(got_logits),
                                   np.asarray(ref_logits),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_train_step_on_small_mesh_matches_single_device():
    out = _run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.model import build
        from repro.train.optimizer import AdamWConfig, adamw_init
        from repro.train.train_step import build_train_step

        cfg = get_config("stablelm-3b").reduced()
        model = build(cfg)
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                       jnp.int32)}
        losses = {}
        for shape, axes in [((1, 1), ("data", "model")),
                            ((2, 2), ("data", "model"))]:
            mesh = jax.make_mesh(shape, axes)
            bundle = build_train_step(model, mesh, opt_cfg, donate=False)
            params = model.init_params(jax.random.PRNGKey(0))
            opt = adamw_init(params)
            _, _, m = bundle.step_fn(params, opt, batch)
            losses[shape] = float(m["loss"])
        assert abs(losses[(1, 1)] - losses[(2, 2)]) < 1e-3, losses
        print("OK", losses)
    """)
    assert "OK" in out
