"""uint32-pair 64-bit emulation vs Python bigints (property tests)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import u64emu as U

u62 = st.integers(min_value=0, max_value=(1 << 62) - 1)


def _pair(v):
    return (jnp.uint32((v >> 32) & 0xFFFFFFFF), jnp.uint32(v & 0xFFFFFFFF))


def _val(p):
    return (int(p[0]) << 32) | int(p[1])


@given(u62, st.integers(0, (1 << 32) - 1))
@settings(max_examples=200, deadline=None)
def test_add_u32(a, b):
    assert _val(U.u64_add_u32(_pair(a), jnp.uint32(b))) == (a + b) % (1 << 64)


@given(u62, u62)
@settings(max_examples=200, deadline=None)
def test_add(a, b):
    assert _val(U.u64_add(_pair(a), _pair(b))) == (a + b) % (1 << 64)


@given(st.integers(0, (1 << 40) - 1), st.integers(0, 23))
@settings(max_examples=200, deadline=None)
def test_shl(a, k):
    assert _val(U.u64_shl(_pair(a), k)) == (a << k) % (1 << 64)


@given(u62)
@settings(max_examples=200, deadline=None)
def test_gray(a):
    assert _val(U.u64_gray(_pair(a))) == a ^ (a >> 1)


@given(u62, st.integers(0, 62))
@settings(max_examples=300, deadline=None)
def test_bit(a, j):
    got = int(U.u64_bit(_pair(a), jnp.uint32(j)))
    assert got == (a >> j) & 1


@given(st.integers(1, (1 << 62) - 1))
@settings(max_examples=300, deadline=None)
def test_ctz(a):
    want = (a & -a).bit_length() - 1
    assert int(U.u64_ctz(_pair(a))) == want


def test_ctz32_all_bits():
    v = jnp.asarray(np.uint32(1) << np.arange(32, dtype=np.uint32))
    got = np.asarray(U.ctz32(v))
    np.testing.assert_array_equal(got, np.arange(32))


@given(u62, u62)
@settings(max_examples=200, deadline=None)
def test_leq(a, b):
    assert bool(U.u64_leq(_pair(a), _pair(b))) == (a <= b)


def test_vectorized_lane_math():
    lanes = np.arange(4096, dtype=np.uint64) + (1 << 40)
    hi = jnp.asarray((lanes >> 32).astype(np.uint32))
    lo = jnp.asarray((lanes & 0xFFFFFFFF).astype(np.uint32))
    g = U.u64_gray((hi, lo))
    want = lanes ^ (lanes >> np.uint64(1))
    got = (np.asarray(g[0], dtype=np.uint64) << np.uint64(32)) | \
        np.asarray(g[1], dtype=np.uint64)
    np.testing.assert_array_equal(got, want)
