"""Batched permanent engine vs naive oracle and the scalar engine.

Covers the tentpole paths: vmapped chunked Ryser, batched SpaRyser,
batch-grid Pallas kernel, and the bucketed ``permanent_batch`` dispatcher
(real / complex / binary stacks, mixed dense+sparse in one call, ragged
sizes, batch-of-one equivalence).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine, oracle, ryser, sparyser
from repro.core.stepspace import Geometry as G
from repro.kernels import ops

RNG = np.random.default_rng(20260725)


def _rand_sparse(n, density, rng=RNG):
    return rng.uniform(0.5, 1.5, (n, n)) * (rng.uniform(0, 1, (n, n)) < density)


# ---------------------------------------------------------------------------
# core.ryser.perm_ryser_batched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,B", [(1, 3), (2, 4), (5, 6), (8, 8), (10, 3)])
def test_ryser_batched_matches_oracle(n, B):
    As = RNG.uniform(-1, 1, (B, n, n))
    got = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As), num_chunks=64))
    ref = np.array([oracle.perm_ryser_exact(A) for A in As])
    np.testing.assert_allclose(got, ref, rtol=1e-10, atol=1e-12)


def test_ryser_batched_equals_scalar_chunked():
    As = RNG.uniform(-1, 1, (5, 9, 9))
    got = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As), num_chunks=32))
    for b in range(5):
        one = float(ryser.perm_ryser_chunked(jnp.asarray(As[b]),
                                             num_chunks=32))
        assert got[b] == one, "batched must reuse the scalar chunk body"


@pytest.mark.parametrize("precision", ["dd", "dq_fast", "dq_acc", "kahan"])
def test_ryser_batched_precision_modes(precision):
    As = RNG.uniform(-1, 1, (4, 8, 8))
    got = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As), num_chunks=16,
                                              precision=precision))
    ref = np.array([oracle.perm_ryser_exact(A) for A in As])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-13)


def test_ryser_batched_complex_stack():
    As = RNG.uniform(-1, 1, (4, 7, 7)) + 1j * RNG.uniform(-1, 1, (4, 7, 7))
    got = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As)))
    ref = np.array([oracle.perm_ryser_exact(A) for A in As])
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_ryser_batched_complex_batch_shape_invariant():
    # the split-plane engine's values must not depend on the batch extent
    # (the basis of the sharded complex path's bit-identity contract)
    As = RNG.normal(size=(6, 7, 7)) + 1j * RNG.normal(size=(6, 7, 7))
    for prec in ("dd", "dq_fast", "dq_acc", "qq", "kahan"):
        full = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As),
                                                   num_chunks=16,
                                                   precision=prec))
        for B in (1, 2, 5):
            sub = np.asarray(ryser.perm_ryser_batched(jnp.asarray(As[:B]),
                                                      num_chunks=16,
                                                      precision=prec))
            assert np.array_equal(sub, full[:B]), (prec, B)
        one = complex(np.asarray(ryser.perm_ryser_chunked(
            jnp.asarray(As[0]), num_chunks=16, precision=prec)))
        assert one == complex(full[0]), \
            "complex scalar straggler must match its bucket value"


def test_ryser_batched_rejects_non_stack():
    with pytest.raises(ValueError):
        ryser.perm_ryser_batched(jnp.zeros((3, 4, 5)))


# ---------------------------------------------------------------------------
# core.sparyser.perm_sparyser_batched
# ---------------------------------------------------------------------------

def test_sparyser_batched_matches_oracle():
    mats = [_rand_sparse(9, 0.25) for _ in range(6)]
    sps = [sparyser.SparseMatrix.from_dense(M) for M in mats]
    got = sparyser.perm_sparyser_batched(sps, num_chunks=64)
    ref = np.array([oracle.perm_ryser_exact(M) for M in mats])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)


def test_sparyser_batched_complex_matches_oracle():
    mats = [(RNG.normal(size=(8, 8)) + 1j * RNG.normal(size=(8, 8)))
            * (RNG.uniform(0, 1, (8, 8)) < 0.3) for _ in range(4)]
    sps = [sparyser.SparseMatrix.from_dense(M) for M in mats]
    got = sparyser.perm_sparyser_batched(sps, num_chunks=16)
    ref = np.array([oracle.perm_ryser_exact(M) for M in mats])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)
    # scalar complex straggler matches its bucket value bitwise
    one = sparyser.perm_sparyser_chunked(sps[0], num_chunks=16)
    assert one == sparyser.perm_sparyser_batched(sps[:1],
                                                 num_chunks=16)[0].item()


def test_sparyser_batched_mixed_degrees_pad_to_bucket_max():
    # very different column degrees in one bucket: padding must stay inert
    mats = [_rand_sparse(8, d) for d in (0.15, 0.5, 0.9)]
    sps = [sparyser.SparseMatrix.from_dense(M) for M in mats]
    got = sparyser.perm_sparyser_batched(sps, num_chunks=16)
    ref = np.array([oracle.perm_ryser_exact(M) for M in mats])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)


# ---------------------------------------------------------------------------
# kernels.ops.permanent_pallas_batched (batch-grid kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["baseline", "batched"])
def test_pallas_batched_matches_oracle(mode):
    As = RNG.uniform(-1, 1, (5, 8, 8))
    got = np.asarray(ops.permanent_pallas_batched(
        jnp.asarray(As), mode=mode, geometry=G(8, 8, 4)))
    ref = np.array([oracle.perm_ryser_exact(A) for A in As])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)


def test_pallas_batched_equals_scalar_kernel():
    As = RNG.uniform(-1, 1, (3, 9, 9))
    got = np.asarray(ops.permanent_pallas_batched(
        jnp.asarray(As), geometry=G(8, 8, 4)))
    for b in range(3):
        one = float(ops.permanent_pallas(As[b], mode="batched", geometry=G(8, 8, 4)))
        np.testing.assert_allclose(got[b], one, rtol=1e-12)


def test_pallas_batched_complex_matches_oracle():
    # ISSUE 4: complex stacks run the split-plane (batch, block) kernel
    Cs = RNG.uniform(-1, 1, (4, 8, 8)) + 1j * RNG.uniform(-1, 1, (4, 8, 8))
    got = np.asarray(ops.permanent_pallas_batched(
        jnp.asarray(Cs), geometry=G(8, 8, 4)))
    ref = np.array([oracle.perm_ryser_exact(C) for C in Cs])
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_pallas_batched_complex_equals_scalar_complex_kernel():
    Cs = RNG.uniform(-1, 1, (3, 9, 9)) + 1j * RNG.uniform(-1, 1, (3, 9, 9))
    for prec in ("dd", "kahan", "dq_acc"):
        got = np.asarray(ops.permanent_pallas_batched(
            jnp.asarray(Cs), precision=prec, geometry=G(8, 8, 4)))
        for b in range(3):
            one = complex(np.asarray(ops.permanent_pallas(
                Cs[b], precision=prec, geometry=G(8, 8, 4))))
            assert got[b] == one, \
                "batch grid must reuse the scalar complex block body"


def test_pallas_batched_rejects_schedmat():
    with pytest.raises(ValueError):
        ops.permanent_pallas_batched(jnp.zeros((2, 5, 5)), mode="schedmat")


# ---------------------------------------------------------------------------
# engine.permanent_batch (the public bucketed dispatcher)
# ---------------------------------------------------------------------------

def test_batch_real_stack_matches_scalar_engine():
    As = RNG.uniform(-1, 1, (12, 8, 8))
    got = engine.permanent_batch(As)
    ref = np.array([engine.permanent(A) for A in As])
    assert got.dtype == np.float64
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_batch_complex_stack():
    Cs = [RNG.normal(size=(7, 7)) + 1j * RNG.normal(size=(7, 7))
          for _ in range(5)]
    got = engine.permanent_batch(Cs)
    ref = np.array([engine.permanent(C) for C in Cs])
    assert got.dtype == np.complex128
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_batch_binary_stack_exact_integers():
    Bs = (RNG.uniform(0, 1, (6, 10, 10)) < 0.5).astype(np.int64)
    got = engine.permanent_batch(Bs)
    ref = np.array([float(oracle.perm_bigint(b)) for b in Bs])
    np.testing.assert_allclose(np.round(got), ref)


def test_batch_mixed_density_one_call():
    # dense + sparse dispatch inside a single permanent_batch call
    mats = [RNG.uniform(-1, 1, (8, 8)) for _ in range(4)]
    mats += [_rand_sparse(9, 0.22) for _ in range(4)]
    got, reports = engine.permanent_batch(mats, return_report=True)
    ref = np.array([engine.permanent(M) for M in mats])
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)
    tags = ",".join(t for r in reports for t in r.dispatch)
    assert "dense_batch" in tags


def test_batch_of_one_equals_scalar():
    A = RNG.uniform(-1, 1, (10, 10))
    assert engine.permanent_batch([A])[0] == engine.permanent(A)
    Ssp = _rand_sparse(9, 0.2)
    np.testing.assert_allclose(engine.permanent_batch([Ssp])[0],
                               engine.permanent(Ssp), rtol=1e-12)


def test_batch_ragged_sizes_fall_back_to_scalar():
    mats = [RNG.uniform(-1, 1, (n, n)) for n in (4, 6, 8, 8, 1, 2)]
    got = engine.permanent_batch(mats)
    ref = np.array([engine.permanent(M) for M in mats])
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_batch_pallas_backend():
    As = RNG.uniform(-1, 1, (6, 8, 8))
    got = engine.permanent_batch(As, backend="pallas", preprocess=False)
    ref = np.array([engine.permanent(A, backend="pallas", preprocess=False)
                    for A in As])
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_batch_dm_zeroed_matrix_gives_zero():
    # a matrix with an empty row has permanent 0; DM must short-circuit it
    A = RNG.uniform(-1, 1, (6, 6)) * (RNG.uniform(0, 1, (6, 6)) < 0.3)
    A[2, :] = 0.0
    mats = [A, RNG.uniform(-1, 1, (6, 6))]
    got = engine.permanent_batch(mats)
    assert got[0] == 0.0
    np.testing.assert_allclose(got[1], engine.permanent(mats[1]), rtol=1e-10)


def test_batch_rejects_bad_inputs():
    with pytest.raises(ValueError):
        engine.permanent_batch([np.zeros((3, 4))])


def test_batch_complex_distributed_without_mesh_downgrades():
    # complex distributed batches are allowed now (ISSUE 4); without a
    # mesh ctx they downgrade to jnp with a tag, exactly like real ones
    Cs = RNG.normal(size=(3, 6, 6)) + 1j * RNG.normal(size=(3, 6, 6))
    got, reports = engine.permanent_batch(Cs, backend="distributed",
                                          preprocess=False,
                                          return_report=True)
    ref = engine.permanent_batch(Cs, preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=0)
    tags = [t for r in reports for t in r.dispatch]
    assert any("distributed->jnp" in t for t in tags), tags
