"""Offline fallback for the ``hypothesis`` property-testing library.

The property-test modules import ``hypothesis`` at module scope; on
machines without it (offline CI images) they error at *collection*,
taking the whole tier-1 run down.  This stub implements the tiny slice
of the hypothesis API the test-suite actually uses -- ``given``,
``settings``, ``assume``, ``strategies.integers/floats`` and
``hypothesis.extra.numpy.arrays`` -- replaying a *deterministic* set of
examples per test (range boundaries first, then seeded pseudo-random
draws), so the properties still get exercised on fixed inputs.

``tests/conftest.py`` calls :func:`install` only when the real library
is missing; when hypothesis is installed this module is inert.
"""

from __future__ import annotations

import os
import random
import sys
import types
import zlib

import numpy as np

# Cap on replayed examples per test (the real library's max_examples is
# honored up to this bound; property bodies here can be expensive).
MAX_EXAMPLES = int(os.environ.get("HYPOTHESIS_STUB_MAX_EXAMPLES", "10"))


class _Rejected(Exception):
    """Raised by assume(False); the current example is skipped."""


def assume(condition):
    if not condition:
        raise _Rejected
    return True


class _Strategy:
    """A deterministic example source: fixed boundary values, then seeded
    pseudo-random draws."""

    def __init__(self, boundary, draw):
        self._boundary = boundary
        self._draw = draw

    def example(self, rnd: random.Random, idx: int):
        if idx < len(self._boundary):
            return self._boundary[idx]
        return self._draw(rnd)


def integers(min_value=None, max_value=None):
    lo = -(2 ** 62) if min_value is None else int(min_value)
    hi = (2 ** 62) - 1 if max_value is None else int(max_value)
    boundary = [lo, hi, (lo + hi) // 2]
    if lo <= 0 <= hi:
        boundary.append(0)
    if lo <= 1 <= hi:
        boundary.append(1)
    seen = set()
    boundary = [b for b in boundary if not (b in seen or seen.add(b))]
    return _Strategy(boundary, lambda rnd: rnd.randint(lo, hi))


def floats(min_value=None, max_value=None, *, allow_nan=None,
           allow_infinity=None, allow_subnormal=None, width=64):
    lo = -1e308 if min_value is None else float(min_value)
    hi = 1e308 if max_value is None else float(max_value)
    boundary = [lo, hi, (lo + hi) / 2.0]
    for v in (0.0, 1.0, -1.0, 0.5):
        if lo <= v <= hi:
            boundary.append(v)
    seen = set()
    boundary = [b for b in boundary if not (b in seen or seen.add(b))]

    def draw(rnd: random.Random):
        if rnd.random() < 0.5:
            return rnd.uniform(lo, hi)
        # magnitude-scaled draw: exercises exponents a uniform draw over a
        # wide range would never hit (all draws are normalized floats)
        span = max(abs(lo), abs(hi), 1.0)
        mag = 10.0 ** rnd.uniform(-12, np.log10(span))
        val = mag if rnd.random() < 0.5 else -mag
        return min(max(val, lo), hi)

    return _Strategy(boundary, draw)


def _np_arrays(dtype, shape, *, elements=None, fill=None, unique=False):
    """hypothesis.extra.numpy.arrays lookalike (elements strategy only)."""
    dtype = np.dtype(dtype)
    shape = tuple(shape) if not isinstance(shape, int) else (shape,)
    size = int(np.prod(shape)) if shape else 1
    elems = elements if elements is not None else floats(-1.0, 1.0)

    def draw(rnd: random.Random):
        flat = [elems.example(rnd, len(elems._boundary) + i + rnd.randrange(4))
                for i in range(size)]
        return np.asarray(flat, dtype=dtype).reshape(shape)

    # boundary example: all entries at each boundary value of the elements
    boundary = [np.full(shape, b, dtype=dtype) for b in elems._boundary[:2]]
    return _Strategy(boundary, draw)


def settings(*args, max_examples=None, deadline=None, **kwargs):
    """Decorator recording max_examples; composes with given() either way."""
    def deco(func):
        func._hyp_settings = {"max_examples": max_examples}
        return func
    if args and callable(args[0]):  # bare @settings
        return deco(args[0])
    return deco


def given(*strategies_args, **strategies_kwargs):
    if strategies_kwargs:
        raise NotImplementedError(
            "hypothesis stub supports positional @given strategies only")

    def deco(func):
        def wrapper():
            cfg = getattr(wrapper, "_hyp_settings", None) \
                or getattr(func, "_hyp_settings", None) or {}
            want = cfg.get("max_examples") or MAX_EXAMPLES
            want = min(want, MAX_EXAMPLES)
            rnd = random.Random(zlib.crc32(func.__qualname__.encode()))
            ran = 0
            for idx in range(want * 8):  # head-room for assume() rejections
                if ran >= want:
                    break
                try:
                    args = [s.example(rnd, idx) for s in strategies_args]
                    func(*args)
                    ran += 1
                except _Rejected:
                    continue
            assert ran > 0, f"all stub examples rejected for {func.__name__}"

        wrapper.__name__ = func.__name__
        wrapper.__qualname__ = func.__qualname__
        wrapper.__doc__ = func.__doc__
        wrapper.__module__ = func.__module__
        wrapper._hyp_inner = func
        return wrapper

    return deco


def install():
    """Register stub ``hypothesis`` modules in sys.modules."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = "offline stub (tests/_hypothesis_stub.py)"
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    hyp.strategies = st

    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = _np_arrays
    extra.numpy = hnp
    hyp.extra = extra

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp
