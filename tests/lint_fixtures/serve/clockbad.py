"""PL004 fixture: wall clock read outside the injectable default site."""
import time


def deadline_expired(t0):
    return time.monotonic() - t0 > 1.0   # PL004
