"""PL003 fixture: a guarded kwarg accepted but not forwarded."""


def engine(A, *, precision="dq_acc", num_chunks=4096):
    return A, precision, num_chunks


def solve(A, *, precision="dq_acc", num_chunks=4096):
    # PL003 twice: engine() accepts both guarded kwargs, neither is
    # forwarded -- the exact tiny-n fallback bug shape from PRs 5/6.
    return engine(A)
