"""PLF01 fixture: an unused module-level import."""
import os
import sys                            # PLF01: never referenced


def cwd():
    return os.getcwd()
