"""PL006 fixture: a cache key missing backend and dtype."""


class ResultCache:
    @staticmethod
    def key(leaf_key, route, precision, backend="jnp", num_chunks=4096,
            dtype="<f8", geometry="-"):
        return (leaf_key, route, precision, backend, num_chunks, dtype,
                geometry)


def lookup(leaf_key):
    return ResultCache.key(leaf_key, "dense", "dq_acc")   # PL006
