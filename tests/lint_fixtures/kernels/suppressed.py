"""Suppression fixture: the finding must land in the inventory."""
import jax.numpy as jnp


def block_epilogue(parts):
    return jnp.sum(parts)  # permlint: disable=PL001  # fixture: inventoried, not hidden
