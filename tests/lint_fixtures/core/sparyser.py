"""PL002 fixture: vmap over a complex engine body."""
import jax


def permanent_complex_batch(As):
    def body(A):
        return A.sum()
    return jax.vmap(body)(As)        # PL002: lax.map only
