"""PL001 fixture: raw jnp reductions on an accumulation path."""
import jax.numpy as jnp


def permanent_terms(parts):
    total = jnp.sum(parts)           # PL001: shape-dependent association
    scale = jnp.prod(parts)          # PL001
    return total * scale
