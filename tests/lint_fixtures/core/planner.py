"""PL005 fixture: a SolverConfig field left unclassified."""
from dataclasses import dataclass


@dataclass
class SolverConfig:
    precision: str = "dq_acc"
    new_knob: int = 7                # not in either tuple below


@dataclass
class ExecutionPlan:
    _NUMERIC_FIELDS = ("precision",)
    _POLICY_FIELDS = ()

    def fingerprint(self):
        return self._NUMERIC_FIELDS
