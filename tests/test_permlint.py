"""permlint (ISSUE 8): every rule fires on its red fixture, the real
tree lints clean (suppressions inventoried, never hidden), the orphan
inventory surfaces the seed leftovers, and the geometry auditor
validates every registered route without touching a device.

The linter itself is jax-free; only the geometry-route tests import jax
(abstract evaluation only).
"""

import ast
import json
import os
import subprocess
import sys

from repro.analysis.lint import (DEFAULT_EXCLUDES, ENTRY_POINTS, lint_file,
                                 lint_paths, main, orphan_modules,
                                 parse_suppressions)
from repro.analysis.rules import RULES, SignatureIndex

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "lint_fixtures")
SRC = os.path.join(REPO, "src")
TESTS = os.path.join(REPO, "tests")


def _lint_fixture(relpath):
    """(active, suppressed) for one fixture, with a signature index
    built from the fixture itself (PL003 needs callee signatures)."""
    path = os.path.join(FIXTURES, relpath)
    with open(path, encoding="utf-8") as f:
        source = f.read()
    idx = SignatureIndex()
    try:
        idx.add(ast.parse(source))
    except SyntaxError:
        pass                          # lint_file reports it as PLE901
    return lint_file(path, idx, source=source)


# ---------------------------------------------------------------------------
# Every rule has a failing fixture
# ---------------------------------------------------------------------------

def test_pl001_fires_on_raw_reductions():
    active, _ = _lint_fixture("core/ryser.py")
    rules = [f.rule for f in active]
    assert rules.count("PL001") == 2        # one jnp.sum, one jnp.prod


def test_pl002_fires_on_vmap_complex_body():
    active, _ = _lint_fixture("core/sparyser.py")
    assert any(f.rule == "PL002" for f in active)


def test_pl003_fires_on_dropped_kwarg():
    active, _ = _lint_fixture("passthrough.py")
    pl003 = [f for f in active if f.rule == "PL003"]
    dropped = {m for f in pl003 for m in ("precision", "num_chunks")
               if repr(m) in f.message}
    assert dropped == {"precision", "num_chunks"}


def test_pl004_fires_on_wall_clock():
    active, _ = _lint_fixture("serve/clockbad.py")
    assert any(f.rule == "PL004" for f in active)


def test_pl005_fires_on_unclassified_field():
    active, _ = _lint_fixture("core/planner.py")
    pl005 = [f for f in active if f.rule == "PL005"]
    assert pl005 and "new_knob" in pl005[0].message


def test_pl006_fires_on_incomplete_cache_key():
    active, _ = _lint_fixture("cachekey.py")
    pl006 = [f for f in active if f.rule == "PL006"]
    assert pl006
    assert "backend" in pl006[0].message and "dtype" in pl006[0].message


def test_plf01_fires_on_unused_import():
    active, _ = _lint_fixture("unused.py")
    assert any(f.rule == "PLF01" and "'sys'" in f.message for f in active)


def test_ple901_fires_on_syntax_error():
    active, _ = _lint_fixture("broken.py.txt")
    assert [f.rule for f in active] == ["PLE901"]


def test_every_registered_rule_has_a_red_fixture():
    """No rule may exist without a fixture proving it can fire."""
    fired = set()
    for rel in ("core/ryser.py", "core/sparyser.py", "passthrough.py",
                "serve/clockbad.py", "core/planner.py", "cachekey.py",
                "unused.py"):
        active, _ = _lint_fixture(rel)
        fired |= {f.rule for f in active}
    assert fired == set(RULES)


# ---------------------------------------------------------------------------
# Suppressions: honored on the flagged line, inventoried in the report
# ---------------------------------------------------------------------------

def test_suppression_moves_finding_to_inventory():
    active, suppressed = _lint_fixture("kernels/suppressed.py")
    assert not active
    assert [s.rule for s in suppressed] == ["PL001"]
    assert suppressed[0].suppressed


def test_suppression_comment_line_covers_next_line():
    sup = parse_suppressions("# permlint: disable=PL001\nx = 1\n")
    assert sup[1] == {"PL001"} and sup[2] == {"PL001"}


def test_suppression_only_disables_named_rule():
    src = ("import jax.numpy as jnp\n"
           "def f(parts):\n"
           "    return jnp.sum(parts)  # permlint: disable=PL002\n")
    idx = SignatureIndex()
    idx.add(ast.parse(src))
    active, suppressed = lint_file("core/ryser.py", idx, source=src)
    assert any(f.rule == "PL001" for f in active)
    assert not suppressed


# ---------------------------------------------------------------------------
# The real tree
# ---------------------------------------------------------------------------

def test_tree_lints_clean_with_inventoried_suppressions():
    report = lint_paths([SRC, TESTS])
    assert [f.render() for f in report["findings"]] == []
    # the deliberate sites (kernel lane reduces, shape-stable step-space
    # sums, sanctioned clock defaults) are counted, not hidden
    assert len(report["suppressions"]) >= 30
    by_rule = {s.rule for s in report["suppressions"]}
    assert {"PL001", "PL002", "PL004"} <= by_rule


def test_fixture_corpus_is_excluded_from_tree_walk():
    assert "lint_fixtures" in DEFAULT_EXCLUDES
    report = lint_paths([TESTS])
    assert not any("lint_fixtures" in f.path for f in report["findings"])


def test_orphan_inventory_post_retirement():
    orphans = set(orphan_modules([SRC]))
    # the LM seed tree (models/, configs/, train/, ckpt/) retired in
    # PR 10 -- it must never come back as unreachable dead weight
    for prefix in ("repro.models", "repro.configs", "repro.train",
                   "repro.ckpt"):
        assert not any(m.startswith(prefix) for m in orphans), orphans
    # the only sanctioned orphan: the pure-jnp kernel-geometry oracle,
    # imported by tests alone (its entire purpose)
    assert orphans == {"repro.kernels.ref"}, orphans
    # the live stack is NOT orphaned
    for mod in ("repro.core.solver", "repro.core.planner",
                "repro.core.distributed", "repro.serve.loop",
                "repro.kernels.ryser_pallas", "repro.core.sparyser",
                "repro.analysis.ir", "repro.analysis.contracts",
                "repro.utils.hlo"):
        assert mod not in orphans, mod
    assert set(ENTRY_POINTS) & orphans == set()


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = tmp_path / "core" / "ryser.py"
    bad.parent.mkdir()
    bad.write_text("import jax.numpy as jnp\n"
                   "def f(p):\n"
                   "    return jnp.sum(p)\n")
    assert main([str(bad), "--json"]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["version"] == "permlint/1"
    assert [f["rule"] for f in report["findings"]] == ["PL001"]

    good = tmp_path / "clean.py"
    good.write_text("X = 1\n")
    assert main([str(good)]) == 0
    assert main([str(good), "--rules", "NOPE"]) == 2
    assert main([str(tmp_path / "missing.py")]) == 2


def test_cli_runs_clean_on_repo_as_subprocess():
    """The acceptance criterion, exercised exactly as CI runs it."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src", "tests"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


# ---------------------------------------------------------------------------
# Geometry auditor: every audit passes, no device work
# ---------------------------------------------------------------------------

def test_geometry_audits_pass():
    from repro.analysis.geometry import run_audits
    results = run_audits(with_jax=True)
    for name, violations in results.items():
        assert violations == [], f"{name}: {violations}"
    assert set(results) == {"kernel-geometry", "vmem-budget",
                            "step-coverage", "sentinel-masking",
                            "routes", "eval-shape", "tuning-table"}


def test_geometry_jax_free_audits_run_without_jax_import():
    """--no-jax must work in a bare interpreter (the CI lint job runs
    before the test matrix installs anything heavy)."""
    from repro.analysis.geometry import run_audits
    results = run_audits(with_jax=False)
    assert set(results) == {"kernel-geometry", "vmem-budget",
                            "step-coverage", "sentinel-masking",
                            "tuning-table"}
    assert all(v == [] for v in results.values())


def test_geometry_sentinel_audit_catches_double_record():
    """The audit detects the PR 6 bug shape: a wave re-issuing a
    completed slice."""
    from repro.analysis import geometry
    from repro.core import resume

    orig = resume.JobState

    class Sticky(resume.JobState):
        def record_wave(self, slice_ids, his, los):
            super().record_wave(slice_ids, his, los)
            self.done[0] = False      # slice 0 re-queues forever... once
            if getattr(self, "_relapsed", False):
                self.done[0] = True
            self._relapsed = True

        @staticmethod
        def create(matrix, total_slices, **kw):
            st = orig.create(matrix, total_slices, **kw)
            return Sticky(**{k: getattr(st, k) for k in (
                "fingerprint", "total_slices", "done", "hi", "lo",
                "precision", "backend", "chunks_per_slice", "chunk_size",
                "version")})

    resume.JobState = Sticky
    try:
        bad = geometry.audit_sentinel_masking(ns=(8,), device_counts=(4,))
    finally:
        resume.JobState = orig
    assert any("recorded twice" in v for v in bad)


def test_geometry_cli_check():
    from repro.analysis.geometry import main as gmain
    assert gmain(["--check", "--no-jax"]) == 0
    assert gmain([]) == 2
