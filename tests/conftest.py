import os
import sys

# Make src/ importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Offline fallback: the property-test modules import hypothesis at module
# scope; without this shim they error at collection on machines where the
# library can't be installed.  The stub replays deterministic examples.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()

# f64 is required for the permanent engines' precision semantics on CPU.
# NOTE: device count is NOT forced here -- smoke tests must see 1 device;
# multi-device behaviour is tested via subprocesses (test_distributed.py)
# and the dry-run driver sets its own XLA_FLAGS before importing jax.
import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
