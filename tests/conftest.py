import os
import sys

# Make src/ importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# f64 is required for the permanent engines' precision semantics on CPU.
# NOTE: device count is NOT forced here -- smoke tests must see 1 device;
# multi-device behaviour is tested via subprocesses (test_distributed.py)
# and the dry-run driver sets its own XLA_FLAGS before importing jax.
import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
