"""SparseMatrix storage, SpaRyser engine, and the Alg.-4 dispatcher."""

import numpy as np
import pytest

from repro.core import engine, oracle
from repro.core.sparyser import SparseMatrix, perm_sparyser_chunked

RNG = np.random.default_rng(23)


def _rand_sparse(n, density, rng=RNG):
    A = rng.uniform(0.5, 1.5, (n, n)) * (rng.uniform(0, 1, (n, n)) < density)
    return A


# ------------------------------------------------------------- CRS/CCS
def test_crs_ccs_roundtrip_paper_fig1_shape():
    A = _rand_sparse(6, 0.4)
    sp = SparseMatrix.from_dense(A)
    assert sp.rptrs[0] == 0 and sp.rptrs[-1] == sp.nnz
    assert sp.cptrs[0] == 0 and sp.cptrs[-1] == sp.nnz
    np.testing.assert_allclose(sp.to_dense(), A)


def test_padded_columns_cover_all_nonzeros():
    A = _rand_sparse(8, 0.3)
    sp = SparseMatrix.from_dense(A)
    rows, vals = sp.padded_columns()
    rebuilt = np.zeros((9, 8))
    for j in range(8):
        for r, v in zip(rows[j], vals[j]):
            rebuilt[r, j] += v
    np.testing.assert_allclose(rebuilt[:8], A)
    assert not rebuilt[8].any() or np.allclose(rebuilt[8], 0)


# ------------------------------------------------------------- SpaRyser
@pytest.mark.parametrize("n,density", [(6, 0.4), (9, 0.3), (11, 0.25),
                                       (12, 0.5)])
def test_sparyser_matches_exact(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    got = perm_sparyser_chunked(SparseMatrix.from_dense(A), num_chunks=8)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("precision", ["dd", "kahan", "dq_acc"])
def test_sparyser_precisions(precision):
    A = _rand_sparse(10, 0.35)
    ref = oracle.perm_ryser_exact(A)
    got = perm_sparyser_chunked(SparseMatrix.from_dense(A), num_chunks=8,
                                precision=precision)
    np.testing.assert_allclose(got, ref, rtol=1e-8, atol=1e-12)


# ------------------------------------------------------------- engine
@pytest.mark.parametrize("n,density", [(8, 1.0), (10, 0.35), (11, 0.2),
                                       (7, 0.6)])
def test_engine_dispatch_correct(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    got, rep = engine.permanent(A, return_report=True)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)
    assert rep.n == n


def test_engine_density_dispatch_rule():
    dense = _rand_sparse(8, 0.95)
    _, rep = engine.permanent(dense, preprocess=False, return_report=True)
    assert all(d.startswith("dense") for d in rep.dispatch)
    sparse = _rand_sparse(14, 0.18)
    _, rep = engine.permanent(sparse, preprocess=False, return_report=True)
    # every sizeable leaf should route to the sparse kernel (<30% density)
    assert any(d.startswith("sparse") for d in rep.dispatch) or \
        not rep.dispatch


def test_engine_structurally_singular():
    A = np.zeros((6, 6))
    A[:, :4] = 1.0
    assert engine.permanent(A) == 0.0


def test_engine_complex():
    A = _rand_sparse(7, 0.8) + 1j * _rand_sparse(7, 0.8)
    ref = oracle.perm_ryser_exact(A)
    got = engine.permanent(A)
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_engine_binary_counts_matchings():
    # permanent of biadjacency 0/1 matrix == #perfect matchings
    A = np.array([[1, 1, 0], [0, 1, 1], [1, 0, 1]], dtype=float)
    assert round(engine.permanent(A)) == 2


def test_engine_pallas_backend():
    A = _rand_sparse(9, 0.9)
    ref = oracle.perm_ryser_exact(A)
    got = engine.permanent(A, backend="pallas", preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=1e-8)


def test_engine_identity_and_permutation():
    assert round(engine.permanent(np.eye(8))) == 1
    P = np.eye(8)[RNG.permutation(8)]
    assert round(engine.permanent(P)) == 1
