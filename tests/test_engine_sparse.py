"""SparseMatrix storage, SpaRyser engine (jnp + Pallas), Alg.-4 dispatch.

ISSUE 5 additions: the padded-CCS SpaRyser *kernel* (kernels/ryser_sparse)
against the oracle and the jnp engine per precision mode, the dense/sparse
cross-parity suite (the same matrix through both routes), the scalar
sparse dispatch-tag / tiny-bucket passthrough regressions, and the
8-device ragged sparse bucket subprocess (mesh jnp bitwise, mesh pallas
kernel 1e-9).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine, oracle, ryser
from repro.core.sparyser import (SparseMatrix, perm_sparyser_batched,
                                 perm_sparyser_chunked)
from repro.core.stepspace import Geometry
from repro.kernels import ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RNG = np.random.default_rng(23)

PRECISIONS = ("dd", "dq_fast", "dq_acc", "qq", "kahan")
# small kernel geometry: full coverage of the step space, CI-sized blocks
KGEO = dict(geometry=Geometry(8, 8, 4))


def _rand_sparse(n, density, rng=RNG):
    A = rng.uniform(0.5, 1.5, (n, n)) * (rng.uniform(0, 1, (n, n)) < density)
    return A


def _rand_sparse_ns(n, density, rng=RNG, cx=False):
    """Sparse test matrix with a guaranteed nonzero permanent (unit-ish
    diagonal kept dense) and guaranteed sub-switch density, so the
    Alg.-4 router always takes the sparse route regardless of RNG
    history -- relative-error checks need a live reference."""
    while True:
        mask = (rng.uniform(0, 1, (n, n)) < density) | np.eye(n, dtype=bool)
        if mask.sum() / (n * n) < 0.29:
            break
    A = rng.uniform(0.5, 1.5, (n, n)) * mask
    if cx:
        A = A + 1j * rng.normal(size=(n, n)) * mask
    return A


# ------------------------------------------------------------- CRS/CCS
def test_crs_ccs_roundtrip_paper_fig1_shape():
    A = _rand_sparse(6, 0.4)
    sp = SparseMatrix.from_dense(A)
    assert sp.rptrs[0] == 0 and sp.rptrs[-1] == sp.nnz
    assert sp.cptrs[0] == 0 and sp.cptrs[-1] == sp.nnz
    np.testing.assert_allclose(sp.to_dense(), A)


def test_padded_columns_cover_all_nonzeros():
    A = _rand_sparse(8, 0.3)
    sp = SparseMatrix.from_dense(A)
    rows, vals = sp.padded_columns()
    rebuilt = np.zeros((9, 8))
    for j in range(8):
        for r, v in zip(rows[j], vals[j]):
            rebuilt[r, j] += v
    np.testing.assert_allclose(rebuilt[:8], A)
    assert not rebuilt[8].any() or np.allclose(rebuilt[8], 0)


# ------------------------------------------------------------- SpaRyser
@pytest.mark.parametrize("n,density", [(6, 0.4), (9, 0.3), (11, 0.25),
                                       (12, 0.5)])
def test_sparyser_matches_exact(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    got = perm_sparyser_chunked(SparseMatrix.from_dense(A), num_chunks=8)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("precision", ["dd", "kahan", "dq_acc"])
def test_sparyser_precisions(precision):
    A = _rand_sparse(10, 0.35)
    ref = oracle.perm_ryser_exact(A)
    got = perm_sparyser_chunked(SparseMatrix.from_dense(A), num_chunks=8,
                                precision=precision)
    np.testing.assert_allclose(got, ref, rtol=1e-8, atol=1e-12)


# ------------------------------------------------------------- engine
@pytest.mark.parametrize("n,density", [(8, 1.0), (10, 0.35), (11, 0.2),
                                       (7, 0.6)])
def test_engine_dispatch_correct(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    got, rep = engine.permanent(A, return_report=True)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-12)
    assert rep.n == n


def test_engine_density_dispatch_rule():
    dense = _rand_sparse(8, 0.95)
    _, rep = engine.permanent(dense, preprocess=False, return_report=True)
    assert all(d.startswith("dense") for d in rep.dispatch)
    sparse = _rand_sparse(14, 0.18)
    _, rep = engine.permanent(sparse, preprocess=False, return_report=True)
    # every sizeable leaf should route to the sparse kernel (<30% density)
    assert any(d.startswith("sparse") for d in rep.dispatch) or \
        not rep.dispatch


def test_engine_structurally_singular():
    A = np.zeros((6, 6))
    A[:, :4] = 1.0
    assert engine.permanent(A) == 0.0


def test_engine_complex():
    A = _rand_sparse(7, 0.8) + 1j * _rand_sparse(7, 0.8)
    ref = oracle.perm_ryser_exact(A)
    got = engine.permanent(A)
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_engine_binary_counts_matchings():
    # permanent of biadjacency 0/1 matrix == #perfect matchings
    A = np.array([[1, 1, 0], [0, 1, 1], [1, 0, 1]], dtype=float)
    assert round(engine.permanent(A)) == 2


def test_engine_pallas_backend():
    A = _rand_sparse(9, 0.9)
    ref = oracle.perm_ryser_exact(A)
    got = engine.permanent(A, backend="pallas", preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=1e-8)


def test_engine_identity_and_permutation():
    assert round(engine.permanent(np.eye(8))) == 1
    P = np.eye(8)[RNG.permutation(8)]
    assert round(engine.permanent(P)) == 1


# ---------------------------------------------------------- sparse kernel
@pytest.mark.parametrize("n,density", [(4, 0.5), (6, 0.4), (8, 0.25),
                                       (11, 0.25), (12, 0.5)])
def test_sparse_kernel_matches_exact(n, density):
    A = _rand_sparse(n, density)
    want = oracle.perm_ryser_exact(A)
    got = float(np.asarray(ops.permanent_pallas_sparse(
        SparseMatrix.from_dense(A), **KGEO)))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_sparse_kernel_complex_matches_exact():
    A = _rand_sparse_ns(8, 0.3, cx=True)
    want = oracle.perm_ryser_exact(A)
    got = complex(np.asarray(ops.permanent_pallas_sparse(
        SparseMatrix.from_dense(A), **KGEO)))
    assert abs(got - want) / abs(want) < 1e-9


@pytest.mark.parametrize("precision", PRECISIONS)
def test_sparse_kernel_batched_matches_jnp(precision):
    sps = [SparseMatrix.from_dense(_rand_sparse_ns(9, 0.2))
           for _ in range(4)]
    ref = np.asarray(perm_sparyser_batched(sps, precision=precision))
    got = np.asarray(ops.permanent_pallas_sparse_batched(
        sps, precision=precision, **KGEO))
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_sparse_kernel_batched_complex_matches_jnp():
    sps = [SparseMatrix.from_dense(_rand_sparse_ns(8, 0.25, cx=True))
           for _ in range(3)]
    ref = np.asarray(perm_sparyser_batched(sps))
    got = np.asarray(ops.permanent_pallas_sparse_batched(sps, **KGEO))
    assert np.max(np.abs(got - ref) / np.abs(ref)) < 1e-9


def test_sparse_kernel_default_geometry():
    # the executor's default launch parameters, not just the tiny CI ones
    A = _rand_sparse_ns(12, 0.3)
    got = float(np.asarray(ops.permanent_pallas_sparse(
        SparseMatrix.from_dense(A))))
    np.testing.assert_allclose(got, oracle.perm_ryser_exact(A), rtol=1e-9)


def test_sparse_kernel_scalar_matches_batched_member():
    # scalar launch and bucket launch share one block body: a ragged
    # straggler served scalar must agree with the same leaf in a bucket
    # (they share the "pallas" cache identity)
    sps = [SparseMatrix.from_dense(_rand_sparse_ns(9, 0.2))
           for _ in range(3)]
    bucket = np.asarray(ops.permanent_pallas_sparse_batched(sps, **KGEO))
    solo = np.array([float(np.asarray(ops.permanent_pallas_sparse(
        sp, **KGEO))) for sp in sps])
    assert np.array_equal(bucket, solo)


# ------------------------------------------- dense/sparse cross-parity
@pytest.mark.parametrize("precision", PRECISIONS)
def test_cross_parity_real_per_precision(precision):
    """The same matrix through all four route/backend pairs agrees to the
    established 1e-9 pallas tolerance per precision mode."""
    A = _rand_sparse_ns(10, 0.25)
    sp = SparseMatrix.from_dense(A)
    vals = {
        "jnp_dense": float(np.asarray(ryser.perm_ryser_chunked(
            A, precision=precision))),
        "jnp_sparse": float(perm_sparyser_chunked(sp, precision=precision)),
        "pallas_dense": float(np.asarray(ops.permanent_pallas(
            A, precision=precision, **KGEO))),
        "pallas_sparse": float(np.asarray(ops.permanent_pallas_sparse(
            sp, precision=precision, **KGEO))),
    }
    ref = vals["jnp_dense"]
    for name, v in vals.items():
        assert abs(v - ref) / abs(ref) < 1e-9, (name, v, ref)


def test_cross_parity_complex():
    A = _rand_sparse_ns(8, 0.25, cx=True)
    sp = SparseMatrix.from_dense(A)
    ref = complex(np.asarray(ryser.perm_ryser_chunked(A)))
    for name, v in (
            ("jnp_sparse", complex(perm_sparyser_chunked(sp))),
            ("pallas_dense", complex(np.asarray(
                ops.permanent_pallas(A, **KGEO)))),
            ("pallas_sparse", complex(np.asarray(
                ops.permanent_pallas_sparse(sp, **KGEO))))):
        assert abs(v - ref) / abs(ref) < 1e-9, (name, v, ref)


def test_cross_parity_distributed_vs_jnp_bitwise():
    # the jnp<->distributed pairing keeps its stronger contract: a mesh-
    # sharded sparse bucket is BIT-identical to the local jnp engine
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import distributed
    sps = [SparseMatrix.from_dense(_rand_sparse_ns(9, 0.2))
           for _ in range(3)]
    for prec in PRECISIONS:
        got = distributed.sparse_batch_permanents_on_mesh(
            sps, mesh, precision=prec)
        ref = np.asarray(perm_sparyser_batched(sps, precision=prec))
        assert np.array_equal(got, ref), prec


def test_mesh_pallas_sparse_matches_jnp():
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import distributed
    sps = [SparseMatrix.from_dense(_rand_sparse_ns(9, 0.2, cx=cx))
           for cx in (False, False, True)]
    for group in (sps[:2], sps[2:]):
        got = distributed.sparse_batch_permanents_on_mesh(
            group, mesh, backend="pallas")
        ref = np.asarray(perm_sparyser_batched(group))
        assert np.max(np.abs(got - ref) / np.abs(ref)) < 1e-9


# ----------------------------------------------- ISSUE 5 satellite fixes
def test_tiny_bucket_fallback_passes_precision_and_chunks():
    # regression (ISSUE 5): the n <= 2 fallback used to call the scalar
    # path with DEFAULT precision/num_chunks, silently dropping the
    # caller's config
    sps = [SparseMatrix.from_dense(RNG.uniform(0.5, 1.5, (2, 2)))
           for _ in range(3)]
    got = perm_sparyser_batched(sps, num_chunks=8, precision="kahan")
    ref = np.array([perm_sparyser_chunked(sp, num_chunks=8,
                                          precision="kahan")
                    for sp in sps])
    assert np.array_equal(got, ref)


def test_scalar_sparse_tags_name_backend():
    # regression (ISSUE 5): scalar sparse dispatch tags carry backend
    # attribution (and a downgrade suffix when another strategy serves
    # the leaf), like every batch tag
    A = _rand_sparse_ns(9, 0.2)
    _, rep = engine.permanent(A, preprocess=False, return_report=True)
    assert rep.dispatch == ["sparse(n=9,jnp)"]
    _, rep = engine.permanent(A, backend="pallas", preprocess=False,
                              return_report=True)
    assert rep.dispatch == ["sparse(n=9,pallas)"]
    # n < 4: the kernel can't run -- tagged downgrade, not a silent lie
    # (2 nonzeros in 9 cells keeps an n=3 leaf under the density switch)
    T = np.zeros((3, 3))
    T[0, 0], T[1, 1] = 1.0, 2.0
    _, rep = engine.permanent(T, backend="pallas", preprocess=False,
                              return_report=True)
    assert rep.dispatch == ["sparse(n=3,pallas->jnp)"]


def test_sparse_bucket_pallas_no_downgrade_tag():
    # acceptance (ISSUE 5): no ``pallas->jnp`` downgrade tag on sparse
    # buckets with n >= 4 -- the bucket runs the batch-grid SpaRyser
    # kernel natively
    mats = [_rand_sparse_ns(9, 0.2) for _ in range(4)]
    got, reports = engine.permanent_batch(mats, backend="pallas",
                                          preprocess=False,
                                          return_report=True)
    ref = engine.permanent_batch(mats, preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=1e-9)
    tags = [t for r in reports for t in r.dispatch]
    assert tags and not any("->" in t for t in tags), tags
    assert any(t.startswith("sparse_batch") for t in tags)


def test_scalar_sparse_pallas_matches_engine():
    A = _rand_sparse_ns(10, 0.22)
    ref = engine.permanent(A, preprocess=False)
    got = engine.permanent(A, backend="pallas", preprocess=False)
    assert abs(got - ref) / abs(ref) < 1e-9


# ------------------------------------------- 8-device subprocess (slow)
@pytest.mark.slow
def test_eight_device_ragged_sparse_bucket_pallas_and_jnp():
    """Mesh-sharded ragged sparse bucket on 8 forced host devices: the
    jnp body stays bitwise vs the local engine, the pallas body (kernel
    per device) agrees to the 1e-9 kernel tolerance -- real and complex."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax; jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core import distributed, sparyser
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(55)
        for cx in (False, True):
            mask = lambda n: (rng.uniform(0, 1, (n, n)) < 0.25) \\
                | np.eye(n, dtype=bool)
            def mat(n):
                m = mask(n)
                A = rng.uniform(0.5, 1.5, (n, n)) * m
                if cx:
                    A = A + 1j * rng.normal(size=(n, n)) * m
                return sparyser.SparseMatrix.from_dense(A)
            sps = [mat(10) for _ in range(13)]   # ragged over 8 devices
            ref = np.asarray(sparyser.perm_sparyser_batched(sps))
            got = distributed.sparse_batch_permanents_on_mesh(sps, mesh)
            assert np.array_equal(got, ref), ("jnp body bitwise", cx)
            gpl = distributed.sparse_batch_permanents_on_mesh(
                sps, mesh, backend="pallas")
            rel = np.max(np.abs(gpl - ref) / np.abs(ref))
            assert rel < 1e-9, ("pallas body", cx, rel)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
