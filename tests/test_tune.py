"""Kernel autotuner: table round-trip, loud invalidation, resolution.

ISSUE 9's unit layer.  Everything here is jax-free (table + planner
resolution are deliberately importable without jax); the measured tuning
path is exercised end-to-end by ``benchmarks/autotune.py`` and the
interpret-mode CI smoke job.
"""

import json

import pytest

from repro.analysis.geometry import audit_tuning_table, validate_tiling
from repro.core.planner import SolverConfig, _resolve_geometry, build_plan
from repro.core.stepspace import DEFAULT_GEOMETRY, Geometry
from repro.tune.search import enumerate_candidates, model_cost
from repro.tune.table import (TABLE_FORMAT_VERSION, TableEntry, TuningTable,
                              density_bucket, kernel_sources_hash)
from repro.utils.roofline import HW_SPECS, detect_hw, get_hw

G_TUNED = Geometry(64, 32, 8)


def _entry(route="dense", n=12, bucket="1.00", dtype="<f8",
           precision="dq_acc", device_kind="any", geometry=G_TUNED):
    return TableEntry(route=route, n=n, density_bucket=bucket, dtype=dtype,
                      precision=precision, device_kind=device_kind,
                      geometry=geometry, predicted_s=2e-3, measured_s=1e-3,
                      default_s=1.5e-3)


# ---------------------------------------------------------------------------
# Geometry + table round-trip
# ---------------------------------------------------------------------------

def test_geometry_tag_roundtrip():
    assert DEFAULT_GEOMETRY.tag() == "128x64x16"
    for g in (DEFAULT_GEOMETRY, G_TUNED, Geometry(8, 8, 8, max_blocks=4)):
        assert Geometry.from_tag(g.tag()) == g


def test_table_roundtrip(tmp_path):
    table = TuningTable()
    table.put(_entry())
    table.put(_entry(route="sparse", bucket="0.25",
                     geometry=Geometry(32, 64, 8)))
    p = str(tmp_path / "t.json")
    table.save(p)
    back = TuningTable.load(p)
    assert back.entries == table.entries
    assert back.kernels_hash == kernel_sources_hash()
    e = back.get("dense", 12, 1.0, "<f8", "dq_acc")
    assert e is not None and e.geometry == G_TUNED
    assert e.speedup == pytest.approx(1.5)
    assert e.mispredict_ratio == pytest.approx(2.0)


def test_table_rejects_version_skew(tmp_path):
    p = str(tmp_path / "t.json")
    table = TuningTable()
    table.put(_entry())
    table.save(p)
    doc = json.load(open(p))
    doc["version"] = TABLE_FORMAT_VERSION + 1
    json.dump(doc, open(p, "w"))
    with pytest.raises(ValueError, match="format version"):
        TuningTable.load(p)


def test_table_rejects_kernel_source_drift(tmp_path):
    # winners measured against other kernel bodies are stale: loud error,
    # with an explicit opt-out for inspection tooling
    p = str(tmp_path / "t.json")
    table = TuningTable()
    table.put(_entry())
    table.save(p)
    doc = json.load(open(p))
    doc["kernels_hash"] = "deadbeefdeadbeef"
    json.dump(doc, open(p, "w"))
    with pytest.raises(ValueError, match="kernel sources changed"):
        TuningTable.load(p)
    assert TuningTable.load(p, strict_hash=False).entries


def test_table_rejects_pl007_violating_entry(tmp_path):
    # a hand-edited table cannot smuggle an invalid geometry past the
    # PR 8 auditor into the planner
    p = str(tmp_path / "t.json")
    table = TuningTable()
    table.put(_entry())
    table.save(p)
    doc = json.load(open(p))
    doc["entries"][0]["geometry"] = "7x5x3"     # nothing power-of-two
    json.dump(doc, open(p, "w"))
    with pytest.raises(ValueError, match="PL007"):
        TuningTable.load(p)
    # the lint-side audit reports the same file instead of raising
    assert audit_tuning_table(p)
    assert audit_tuning_table(str(tmp_path / "missing.json")) == []


def test_density_bucketing():
    assert density_bucket(0.05) == "0.25"
    assert density_bucket(0.25) == "0.25"
    assert density_bucket(0.26) == "0.50"
    assert density_bucket(0.80) == "1.00"
    assert density_bucket(1.00) == "1.00"


def test_table_device_kind_wildcard():
    table = TuningTable()
    table.put(_entry(device_kind="any"))
    # a concrete host kind falls back to the "any" wildcard row
    assert table.resolve("dense", 12, 1.0, "<f8", "dq_acc",
                         device_kind="tpu v5e") == G_TUNED
    assert table.resolve("dense", 13, 1.0, "<f8", "dq_acc") is None


# ---------------------------------------------------------------------------
# candidate enumeration + cost model
# ---------------------------------------------------------------------------

def test_enumerate_candidates_valid_and_deduped():
    for n in (8, 12, 16):
        cands = enumerate_candidates(n)
        assert cands[0] == DEFAULT_GEOMETRY
        resolved = set()
        for g in cands:
            assert validate_tiling(n, g.lanes, g.steps_per_chunk,
                                   g.window) == []
            resolved.add(g.kernel_geometry(n))
        assert len(resolved) == len(cands), "clamped duplicates survived"


def test_model_cost_orders_sanely():
    # monotone in n and batch; complex costs more than real; the model
    # only needs to RANK candidates, so only ordering is asserted
    g = DEFAULT_GEOMETRY
    assert model_cost(g, 16) > model_cost(g, 12)
    assert model_cost(g, 12, batch=64) > model_cost(g, 12, batch=1)
    assert model_cost(g, 12, route="complex") > model_cost(g, 12)
    assert model_cost(g, 12, route="sparse", density=0.2) \
        < model_cost(g, 12, route="sparse", density=1.0)


# ---------------------------------------------------------------------------
# planner resolution: config override > table hit > defaults
# ---------------------------------------------------------------------------

def test_resolve_precedence(tmp_path):
    p = str(tmp_path / "t.json")
    table = TuningTable()
    table.put(_entry())
    table.save(p)
    over = Geometry(8, 8, 8)
    # explicit config override wins even over a table hit
    assert _resolve_geometry(
        SolverConfig(geometry=over, tuning_table=p),
        "dense", 12, 1.0, "<f8", "dq_acc") == over
    # table hit
    assert _resolve_geometry(
        SolverConfig(tuning_table=p),
        "dense", 12, 1.0, "<f8", "dq_acc") == G_TUNED
    # no table, no override: kernel defaults (None)
    assert _resolve_geometry(
        SolverConfig(), "dense", 12, 1.0, "<f8", "dq_acc") is None
    # campaign wave bodies fall back to the dense entry
    assert _resolve_geometry(
        SolverConfig(tuning_table=p),
        "step_sharded", 12, 1.0, "<f8", "dq_acc") == G_TUNED


def test_resolve_missing_table_is_loud(tmp_path):
    cfg = SolverConfig(tuning_table=str(tmp_path / "nope.json"))
    with pytest.raises(OSError):
        _resolve_geometry(cfg, "dense", 12, 1.0, "<f8", "dq_acc")


# ---------------------------------------------------------------------------
# geometry is part of plan identity
# ---------------------------------------------------------------------------

def test_plan_records_geometry_in_identity(tmp_path):
    import numpy as np
    A = np.random.default_rng(0).uniform(0.2, 1.0, (8, 8))
    base = dict(backend="pallas", preprocess=False)
    plain = build_plan([A], SolverConfig(**base), batched=True)
    tuned = build_plan([A], SolverConfig(geometry=G_TUNED, **base),
                       batched=True)
    assert plain.leaves[0].geometry is None
    assert tuned.leaves[0].geometry == G_TUNED
    # fingerprint and --plan-json both carry the resolved geometry
    assert plain.fingerprint() != tuned.fingerprint()
    leaf_json = tuned.to_json()["leaves"][0]
    assert leaf_json["geometry"] == G_TUNED.tag()
    assert plain.to_json()["leaves"][0]["geometry"] is None
    # two distinct geometries are two distinct identities
    tuned2 = build_plan([A], SolverConfig(geometry=Geometry(8, 8, 8),
                                          **base), batched=True)
    assert tuned2.fingerprint() != tuned.fingerprint()
    # non-pallas backends never carry geometry, even when configured
    jnp_plan = build_plan([A], SolverConfig(geometry=G_TUNED,
                                            preprocess=False), batched=True)
    assert jnp_plan.leaves[0].geometry is None


# ---------------------------------------------------------------------------
# hardware registry
# ---------------------------------------------------------------------------

def test_detect_hw_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_HW", raising=False)
    assert detect_hw("TPU v5 lite").name == "tpu-v5e"
    assert detect_hw("TPU v4").name == "tpu-v4"
    assert detect_hw("weird accelerator").name == "tpu-v5e"  # default
    # explicit argument beats the environment override ...
    monkeypatch.setenv("REPRO_HW", "tpu-v5p")
    assert detect_hw("TPU v4").name == "tpu-v4"
    # ... and the environment override beats autodetection
    assert detect_hw().name == "tpu-v5p"
    assert get_hw("no-such-hw") == HW_SPECS["tpu-v5e"]
