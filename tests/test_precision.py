"""Compensated arithmetic: error-free transformation properties."""

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import precision as P

# subnormals excluded: XLA may flush them, and error-free transformation
# guarantees hold for normalized floats only
finite = st.floats(min_value=-1e30, max_value=1e30,
                   allow_nan=False, allow_infinity=False,
                   allow_subnormal=False)


@given(finite, finite)
@settings(max_examples=300, deadline=None)
def test_two_sum_error_free(a, b):
    s, e = P.two_sum(jnp.float64(a), jnp.float64(b))
    # exact identity: s + e == a + b in exact arithmetic
    from fractions import Fraction
    lhs = Fraction(float(s)) + Fraction(float(e))
    rhs = Fraction(a) + Fraction(b)
    assert lhs == rhs


@given(st.floats(min_value=-1e15, max_value=1e15, allow_nan=False,
                 allow_infinity=False, allow_subnormal=False),
       st.floats(min_value=-1e15, max_value=1e15, allow_nan=False,
                 allow_infinity=False, allow_subnormal=False))
@settings(max_examples=300, deadline=None)
def test_two_prod_error_free(a, b):
    from hypothesis import assume
    # the EFT requires the product (and its error) not to underflow
    assume(a == 0 or b == 0 or abs(a * b) > 1e-250)
    p, e = P.two_prod(jnp.float64(a), jnp.float64(b))
    from fractions import Fraction
    lhs = Fraction(float(p)) + Fraction(float(e))
    rhs = Fraction(a) * Fraction(b)
    assert lhs == rhs


def test_twofloat_accumulation_beats_plain_sum():
    # classic: sum of 1 + N tiny values that vanish in plain f64
    tiny = 1e-20
    N = 1000
    plain = jnp.float64(1.0)
    acc = P.tf_from(jnp.float64(1.0))
    acc_fast = P.tf_from(jnp.float64(1.0))
    kah = (jnp.float64(1.0), jnp.float64(0.0))
    for _ in range(N):
        plain = plain + tiny
        acc = P.tf_add_acc(acc, jnp.float64(tiny))
        acc_fast = P.tf_add_fast(acc_fast, jnp.float64(tiny))
        kah = P.kahan_add(kah, jnp.float64(tiny))
    exact = 1.0 + N * tiny
    assert float(plain) == 1.0  # demonstrates the failure mode
    assert abs(float(acc.hi) + float(acc.lo) - exact) < 1e-30
    assert abs(float(acc_fast.hi) + float(acc_fast.lo) - exact) < 1e-30
    # Kahan keeps the residual in its compensation term
    assert abs((float(kah[0]) - float(kah[1])) - exact) < 1e-17


def test_tf_mul_extends_precision():
    a = P.tf_from(jnp.float64(1.0) + jnp.float64(2.0) ** -40)
    b = jnp.float64(1.0) + jnp.float64(2.0) ** -40
    prod = P.tf_mul(a, b)
    from fractions import Fraction
    exact = (Fraction(1) + Fraction(2) ** -40) ** 2
    got = Fraction(float(prod.hi)) + Fraction(float(prod.lo))
    assert abs(got - exact) < Fraction(2) ** -100


def test_split_constant_by_dtype():
    assert P._split_const(jnp.float64(0).dtype) == float((1 << 27) + 1)
    assert P._split_const(jnp.float32(0).dtype) == float((1 << 12) + 1)


@given(finite)
@settings(max_examples=100, deadline=None)
def test_tf_roundtrip(a):
    t = P.tf_from(jnp.float64(a))
    assert float(P.tf_value(t)) == a
