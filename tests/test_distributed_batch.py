"""Mesh-sharded batch execution (ISSUE 3 tentpole).

The ``distributed_batch`` strategy shards a same-size bucket's leading
axis over ``core.distributed``'s mesh; its contract is BIT-IDENTICAL
values to the ``jnp`` backend per precision mode.  Fast tests run on a
1-device mesh in-process (the smoke-test contract keeps this process at
1 device); real multi-device coverage runs in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (also exercised
directly by the CI multi-device job and ``benchmarks/batch_sharding``).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import distributed, engine, ryser, sparyser
from repro.core.executor import available_backends, get_backend
from repro.core.solver import PermanentSolver, SolverConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RNG = np.random.default_rng(20260726)

PRECISIONS = ("dd", "dq_fast", "dq_acc", "qq", "kahan")


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _rand_sparse(n, density, rng=RNG):
    return rng.uniform(0.5, 1.5, (n, n)) * (rng.uniform(0, 1, (n, n)) < density)


# ---------------------------------------------------------------------------
# entry points: bit-identity vs the jnp batched engines
# ---------------------------------------------------------------------------

def test_batch_on_mesh_bitwise_matches_jnp_per_precision():
    stack = RNG.uniform(-1, 1, (5, 9, 9))
    mesh = _mesh1()
    for prec in PRECISIONS:
        got = distributed.batch_permanents_on_mesh(stack, mesh,
                                                   precision=prec)
        ref = np.asarray(ryser.perm_ryser_batched(stack, precision=prec))
        assert np.array_equal(got, ref), prec


def test_sparse_batch_on_mesh_bitwise_matches_jnp():
    sps = [sparyser.SparseMatrix.from_dense(_rand_sparse(8, 0.25))
           for _ in range(3)]
    got = distributed.sparse_batch_permanents_on_mesh(sps, _mesh1())
    ref = np.asarray(sparyser.perm_sparyser_batched(sps))
    assert np.array_equal(got, ref)


def test_batch_on_mesh_tiny_n_closed_forms():
    stack = RNG.uniform(-1, 1, (4, 2, 2))
    got = distributed.batch_permanents_on_mesh(stack, _mesh1())
    ref = np.asarray(ryser.perm_ryser_batched(stack))
    np.testing.assert_allclose(got, ref, rtol=0)


def test_batch_on_mesh_validates_shape():
    with pytest.raises(ValueError):
        distributed.batch_permanents_on_mesh(np.zeros((3, 4, 5)), _mesh1())


# ---------------------------------------------------------------------------
# ISSUE 4 tentpole: complex is first-class at every distributed entry
# ---------------------------------------------------------------------------

def _rand_complex(B, n, rng=RNG):
    return rng.normal(size=(B, n, n)) + 1j * rng.normal(size=(B, n, n))


def test_complex_batch_on_mesh_bitwise_matches_jnp_per_precision():
    stack = _rand_complex(5, 8)
    mesh = _mesh1()
    for prec in PRECISIONS:
        got = distributed.batch_permanents_on_mesh(stack, mesh,
                                                   precision=prec)
        ref = np.asarray(ryser.perm_ryser_batched(stack, precision=prec))
        assert np.array_equal(got, ref), prec


def test_complex_sparse_batch_on_mesh_bitwise_matches_jnp():
    sps = [sparyser.SparseMatrix.from_dense(
        _rand_complex(1, 8)[0] * (RNG.uniform(0, 1, (8, 8)) < 0.3))
        for _ in range(3)]
    got = distributed.sparse_batch_permanents_on_mesh(sps, _mesh1())
    ref = np.asarray(sparyser.perm_sparyser_batched(sps))
    assert np.array_equal(got, ref)


def test_complex_accepted_at_every_distributed_entry():
    # no remaining "real-only" ValueError anywhere in core.distributed
    C = _rand_complex(1, 6)[0]
    mesh = _mesh1()
    ref = complex(engine.permanent(C))
    v = distributed.permanent_on_mesh(C, mesh)
    assert abs(complex(v) - ref) / abs(ref) < 1e-12
    r = distributed.DistributedPermanent(mesh).permanent(C)
    assert isinstance(r, complex)
    assert abs(r - ref) / abs(ref) < 1e-12
    for backend in ("distributed", "distributed_batch"):
        solver = PermanentSolver(backend=backend, distributed_ctx=mesh)
        assert solver.plan(C).is_complex
        assert solver.plan_batch([C]).is_complex
        req = solver.submit(C)
        solver.flush()
        assert abs(req.result() - ref) / abs(ref) < 1e-12
    vals = engine.permanent_batch([C, C], backend="distributed",
                                  distributed_ctx=mesh)
    np.testing.assert_allclose(vals, [ref, ref], rtol=1e-12)


def test_complex_solver_with_mesh_shards_bitwise_no_downgrade():
    mesh = _mesh1()
    mats = list(_rand_complex(4, 8)) \
        + [_rand_complex(1, 9)[0] * (RNG.uniform(0, 1, (9, 9)) < 0.25)
           for _ in range(3)]
    dist = PermanentSolver(SolverConfig(backend="distributed",
                                        preprocess=False),
                           distributed_ctx=mesh)
    jnp_s = PermanentSolver(SolverConfig(backend="jnp", preprocess=False))
    got, reports = dist.execute(dist.plan_batch(mats), return_report=True)
    ref = jnp_s.execute(jnp_s.plan_batch(mats))
    assert np.array_equal(got, ref), \
        "sharded complex buckets must be bit-identical to jnp"
    assert not dist.stats()["downgrades"]
    tags = [t for r in reports for t in r.dispatch]
    assert any(t.startswith("dense_batch") and "->" not in t for t in tags)


def test_complex_qq_plan_tags_precision_downgrade():
    C = _rand_complex(2, 6)
    solver = PermanentSolver(SolverConfig(precision="qq", preprocess=False))
    plan = solver.plan_batch(list(C))
    assert plan.precision == "kahan"
    assert plan.precision_downgrade == "qq->kahan"
    assert plan.to_json()["precision_downgrade"] == "qq->kahan"
    _, reports = solver.execute(plan, return_report=True)
    tags = [t for r in reports for t in r.dispatch]
    assert any("precision(qq->kahan)" in t for t in tags), tags
    assert any("precision(qq->kahan)" in t
               for t in solver.stats()["downgrades"])
    # real plans carry no such tag
    real_plan = solver.plan_batch([RNG.uniform(-1, 1, (6, 6))])
    assert real_plan.precision_downgrade is None


# ---------------------------------------------------------------------------
# executor routing: registry, sharded buckets, tagged downgrades
# ---------------------------------------------------------------------------

def test_registry_has_distributed_batch_strategy():
    assert "distributed_batch" in available_backends()
    be = get_backend("distributed_batch")
    assert be.name == "distributed_batch"
    # no mesh attached -> batch methods signal downgrade
    assert be.dense_batch(RNG.uniform(-1, 1, (3, 5, 5)),
                          precision="dq_acc", num_chunks=64) is None


def test_solver_without_mesh_downgrades_with_tag():
    mats = [RNG.uniform(-1, 1, (7, 7)) for _ in range(3)]
    solver = PermanentSolver(SolverConfig(backend="distributed",
                                          preprocess=False))
    vals, reports = solver.execute(solver.plan_batch(mats),
                                   return_report=True)
    ref = engine.permanent_batch(mats, preprocess=False)
    np.testing.assert_allclose(vals, ref, rtol=0)
    tags = [t for r in reports for t in r.dispatch]
    assert any("distributed->jnp" in t for t in tags), tags
    assert solver.stats()["downgrades"]


def test_solver_with_mesh_shards_buckets_bitwise():
    mesh = _mesh1()
    mats = [RNG.uniform(-1, 1, (8, 8)) for _ in range(4)] \
        + [_rand_sparse(9, 0.22) for _ in range(3)]
    dist = PermanentSolver(SolverConfig(backend="distributed",
                                        preprocess=False),
                           distributed_ctx=mesh)
    jnp_s = PermanentSolver(SolverConfig(backend="jnp", preprocess=False))
    got, reports = dist.execute(dist.plan_batch(mats), return_report=True)
    ref = jnp_s.execute(jnp_s.plan_batch(mats))
    assert np.array_equal(got, ref), "sharded buckets must be bit-identical"
    assert not dist.stats()["downgrades"]
    tags = [t for r in reports for t in r.dispatch]
    assert any(t.startswith("dense_batch") and "->" not in t for t in tags)


def test_bare_mesh_accepted_as_ctx_for_queue():
    mesh = _mesh1()
    solver = PermanentSolver(SolverConfig(backend="distributed",
                                          queue_max_batch=4,
                                          queue_max_delay_s=1e9),
                             distributed_ctx=mesh)
    mats = [RNG.uniform(-1, 1, (6, 6)) for _ in range(4)]
    reqs = [solver.submit(M) for M in mats]
    assert all(r.done for r in reqs)
    ref = engine.permanent_batch(mats)
    assert np.array_equal(np.array([r.result() for r in reqs]), ref)
    assert not solver.stats()["downgrades"]


# ---------------------------------------------------------------------------
# cache interaction: sharded values live under their own cache identity
# ---------------------------------------------------------------------------

def test_sharded_bucket_cache_roundtrip():
    mesh = _mesh1()
    mats = [RNG.uniform(-1, 1, (7, 7)) for _ in range(3)]
    solver = PermanentSolver(SolverConfig(backend="distributed",
                                          preprocess=False),
                             distributed_ctx=mesh)
    v1 = solver.execute(solver.plan_batch(mats))
    dispatches = solver.stats()["device_dispatches"]
    assert all(k[3] == "distributed_batch" for k in solver.cache._data), \
        "sharded values must be cached under the producing strategy"
    v2 = solver.execute(solver.plan_batch(mats))
    assert np.array_equal(v1, v2)
    assert solver.stats()["device_dispatches"] == dispatches, \
        "second pass must be all cache hits"


def test_singleton_bucket_under_mesh_stays_bitwise_and_cacheable():
    # a 1-leaf bucket must NOT fall back to the scalar step-space split
    # (not bit-identical to the batch engines, and its cache entry would
    # live under a key the batched probes never read)
    mesh = _mesh1()
    A = RNG.uniform(-1, 1, (8, 8))
    solver = PermanentSolver(SolverConfig(backend="distributed",
                                          preprocess=False),
                             distributed_ctx=mesh)
    v1 = solver.execute(solver.plan_batch([A]))
    jnp_solver = PermanentSolver(SolverConfig(backend="jnp",
                                              preprocess=False))
    ref = jnp_solver.execute(jnp_solver.plan_batch([A]))
    assert np.array_equal(v1, ref)
    assert all(k[3] == "distributed_batch" for k in solver.cache._data)
    dispatches = solver.stats()["device_dispatches"]
    v2 = solver.execute(solver.plan_batch([A]))
    assert np.array_equal(v1, v2)
    assert solver.stats()["device_dispatches"] == dispatches, \
        "singleton's cache entry must satisfy the batched probe"


def test_downgraded_and_sharded_values_use_distinct_cache_keys():
    # same solver config, with vs without a mesh: the no-mesh run caches
    # jnp numbers under "jnp", never under the distributed identity
    mats = [RNG.uniform(-1, 1, (6, 6)) for _ in range(3)]
    no_mesh = PermanentSolver(SolverConfig(backend="distributed",
                                           preprocess=False))
    no_mesh.execute(no_mesh.plan_batch(mats))
    assert all(k[3] == "jnp" for k in no_mesh.cache._data)


# ---------------------------------------------------------------------------
# multi-device subprocesses (XLA_FLAGS is init-time)
# ---------------------------------------------------------------------------

def _run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    full = textwrap.dedent("""
        import jax; jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core import distributed, engine, ryser, sparyser
        from repro.core.solver import PermanentSolver, SolverConfig
        mesh = jax.make_mesh((8,), ("data",))
    """) + textwrap.dedent(code)
    r = subprocess.run([sys.executable, "-c", full], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_eight_device_dense_bitwise_with_ragged_tail():
    out = _run_sub("""
        rng = np.random.default_rng(3)
        for n, B in ((10, 11), (13, 21)):   # B % 8 != 0: padded + masked
            stack = rng.uniform(-1, 1, (B, n, n))
            for prec in ("dd", "dq_fast", "dq_acc", "qq", "kahan"):
                got = distributed.batch_permanents_on_mesh(
                    stack, mesh, precision=prec)
                ref = np.asarray(ryser.perm_ryser_batched(
                    stack, precision=prec))
                assert np.array_equal(got, ref), (n, B, prec)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_eight_device_sparse_route_bitwise():
    out = _run_sub("""
        rng = np.random.default_rng(4)
        sps = [sparyser.SparseMatrix.from_dense(
                   rng.uniform(0.5, 1.5, (11, 11))
                   * (rng.uniform(0, 1, (11, 11)) < 0.25))
               for _ in range(13)]          # ragged over 8 devices
        got = distributed.sparse_batch_permanents_on_mesh(sps, mesh)
        ref = np.asarray(sparyser.perm_sparyser_batched(sps))
        assert np.array_equal(got, ref)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_eight_device_complex_dense_bitwise_with_ragged_tail():
    out = _run_sub("""
        rng = np.random.default_rng(6)
        for n, B in ((8, 11), (10, 21)):    # B % 8 != 0: padded + masked
            stack = rng.normal(size=(B, n, n)) \\
                + 1j * rng.normal(size=(B, n, n))
            for prec in ("dd", "dq_fast", "dq_acc", "qq", "kahan"):
                got = distributed.batch_permanents_on_mesh(
                    stack, mesh, precision=prec)
                ref = np.asarray(ryser.perm_ryser_batched(
                    stack, precision=prec))
                assert np.array_equal(got, ref), (n, B, prec)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_eight_device_complex_sparse_route_bitwise():
    out = _run_sub("""
        rng = np.random.default_rng(7)
        sps = [sparyser.SparseMatrix.from_dense(
                   (rng.normal(size=(9, 9)) + 1j * rng.normal(size=(9, 9)))
                   * (rng.uniform(0, 1, (9, 9)) < 0.3))
               for _ in range(13)]          # ragged over 8 devices
        got = distributed.sparse_batch_permanents_on_mesh(sps, mesh)
        ref = np.asarray(sparyser.perm_sparyser_batched(sps))
        assert np.array_equal(got, ref)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_eight_device_complex_solver_queue_and_cache():
    out = _run_sub("""
        rng = np.random.default_rng(8)
        pool = [rng.normal(size=(8, 8)) + 1j * rng.normal(size=(8, 8))
                for _ in range(6)]
        stream = [pool[i] for i in rng.integers(0, 6, 32)]
        dist = PermanentSolver(SolverConfig(backend="distributed",
                                            queue_max_batch=16,
                                            queue_max_delay_s=1e9),
                               distributed_ctx=mesh)
        reqs = [dist.submit(M) for M in stream]
        dist.flush()
        got = np.array([r.result() for r in reqs])
        ref = engine.permanent_batch(stream)
        assert np.array_equal(got, ref), np.abs(got - ref).max()
        st = dist.stats()
        assert not st["downgrades"], st["downgrades"]
        assert st["cache"]["hits"] > 0, "repeat pool must hit the cache"
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_eight_device_solver_queue_and_cache():
    out = _run_sub("""
        rng = np.random.default_rng(5)
        pool = [rng.uniform(-1, 1, (9, 9)) for _ in range(6)]
        stream = [pool[i] for i in rng.integers(0, 6, 32)]
        dist = PermanentSolver(SolverConfig(backend="distributed",
                                            queue_max_batch=16,
                                            queue_max_delay_s=1e9),
                               distributed_ctx=mesh)
        reqs = [dist.submit(M) for M in stream]
        dist.flush()
        got = np.array([r.result() for r in reqs])
        ref = engine.permanent_batch(stream)
        assert np.array_equal(got, ref), np.abs(got - ref).max()
        st = dist.stats()
        assert not st["downgrades"], st["downgrades"]
        assert st["cache"]["hits"] > 0, "repeat pool must hit the cache"
        print("OK")
    """)
    assert "OK" in out
