"""Step-space campaign route: planning, execution, kill/resume identity.

Fast tests exercise the planner's ``step_sharded`` routing, the
``CampaignBackend`` numerics on one device, the JobState config-safety
contract and the sentinel wave padding in-process.  The slow tests drive
the ``repro.launch.campaign`` CLI in subprocesses -- SIGKILL mid-wave at
one forced device count, resume at another -- and assert the printed
value is bitwise-identical to an uninterrupted run, per precision mode,
real and complex (XLA_FLAGS must be set before jax initializes, hence
subprocesses; the main test process keeps 1 device).
"""

import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import distributed, oracle, resume
from repro.core.planner import ROUTE_CAMPAIGN, SolverConfig, build_plan
from repro.core.solver import PermanentSolver
from repro.core.stepspace import Geometry, chunk_geometry, plan_slices

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _campaign_cfg(**kw):
    base = dict(preprocess=False, campaign_threshold=1.0,
                campaign_slices=8, campaign_lanes=8)
    base.update(kw)
    return SolverConfig(**base)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def test_plan_routes_large_leaf_to_campaign():
    A = np.random.default_rng(0).uniform(0.2, 1.0, (10, 10))
    plan = build_plan([A], _campaign_cfg(), batched=False)
    (leaf,) = plan.leaves
    assert leaf.route == ROUTE_CAMPAIGN
    spec = leaf.campaign
    assert spec is not None
    assert spec.total_slices * spec.chunks_per_slice * spec.chunk_size \
        == 1 << 9
    assert spec.backend == "jnp" and spec.precision == plan.precision
    # the spec is part of the serialized plan and the summary
    j = plan.to_json()
    assert j["leaves"][0]["campaign"]["total_slices"] == spec.total_slices
    assert "step_sharded" in plan.summary()


def test_plan_threshold_none_disables_campaign():
    A = np.random.default_rng(0).uniform(0.2, 1.0, (10, 10))
    plan = build_plan([A], _campaign_cfg(campaign_threshold=None),
                      batched=False)
    assert plan.leaves[0].route == "dense"
    assert plan.leaves[0].campaign is None


def test_plan_fingerprint_sees_campaign_spec():
    A = np.random.default_rng(0).uniform(0.2, 1.0, (10, 10))
    p1 = build_plan([A], _campaign_cfg(), batched=False)
    p2 = build_plan([A], _campaign_cfg(), batched=False)
    p3 = build_plan([A], _campaign_cfg(campaign_lanes=16), batched=False)
    assert p1 == p2
    assert p1 != p3          # different slice geometry -> different plan


def test_stepspace_decomposition_invariants():
    for n in (8, 12, 20, 33):
        for slices in (1, 8, 64):
            ts, cps, C = plan_slices(n, slices, 1, 32)
            assert ts * cps * C == 1 << (n - 1)
            assert C >= 2 and (C & (C - 1)) == 0
        T, C, k = chunk_geometry(n, 64)
        assert T * C == 1 << (n - 1) and C == 1 << k


# ---------------------------------------------------------------------------
# execution (single device)
# ---------------------------------------------------------------------------

def test_campaign_backend_matches_oracle_real():
    A = np.random.default_rng(1).uniform(0.2, 1.0, (10, 10))
    ref = oracle.perm_ryser_exact(A)
    solver = PermanentSolver(_campaign_cfg())
    plan = solver.plan(A)
    assert plan.leaves[0].route == ROUTE_CAMPAIGN
    got = solver.execute(plan)
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_campaign_backend_matches_oracle_complex():
    rng = np.random.default_rng(2)
    C = rng.uniform(0.2, 1.0, (8, 8)) + 1j * rng.uniform(0.2, 1.0, (8, 8))
    ref = oracle.perm_ryser_exact(C)
    solver = PermanentSolver(_campaign_cfg())
    got = solver.execute(solver.plan(C))
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_campaign_pause_resume_through_solver(tmp_path):
    A = np.random.default_rng(3).uniform(0.2, 1.0, (10, 10))
    ckpt = str(tmp_path / "job.npz")
    # 64 slices: a 2-wave budget cannot finish the campaign even when
    # XLA_FLAGS forces a multi-device host (wave size == device count)
    cfg = _campaign_cfg(campaign_checkpoint=ckpt, campaign_slices=64,
                        campaign_lanes=2)
    budgeted = PermanentSolver(cfg.replace(campaign_max_waves=2))
    with pytest.raises(distributed.CampaignPaused):
        budgeted.execute(budgeted.plan(A))
    st = resume.JobState.load(ckpt)
    assert 0 < st.fraction_done() < 1
    # a fresh solver resumes from the checkpoint and matches an
    # uninterrupted run bitwise
    resumed = PermanentSolver(cfg)
    got = resumed.execute(resumed.plan(A))
    clean = PermanentSolver(_campaign_cfg(campaign_slices=64,
                                          campaign_lanes=2))
    ref = clean.execute(clean.plan(A))
    assert np.float64(got) == np.float64(ref)


def test_sentinel_slices_contribute_exact_zero():
    # wave padding regression: idle lanes carry slice id -1 and must be
    # masked to exactly 0.0, never recompute slice 0
    A = np.random.default_rng(4).uniform(0.2, 1.0, (10, 10))
    mesh = jax.make_mesh((1,), ("step",))
    ts, cps, C = plan_slices(10, 1, 4, 8)
    his, los = distributed.slice_sums_on_mesh(
        A, mesh, np.array([-1], dtype=np.int32),
        chunks_per_slice=cps, chunk_size=C)
    assert his[0] == 0.0 and los[0] == 0.0
    real0, reallo0 = distributed.slice_sums_on_mesh(
        A, mesh, np.array([0], dtype=np.int32),
        chunks_per_slice=cps, chunk_size=C)
    assert real0[0] != 0.0


# ---------------------------------------------------------------------------
# checkpoint config safety
# ---------------------------------------------------------------------------

def _one_wave(A, ckpt, **kw):
    mesh = jax.make_mesh((1,), ("step",))
    ts, cps, C = plan_slices(A.shape[0], 8, 1, 8)
    args = dict(total_slices=ts, chunks_per_slice=cps, chunk_size=C,
                max_waves=1)
    args.update(kw)
    return distributed.run_campaign(A, mesh, checkpoint_path=ckpt, **args)


def test_checkpoint_rejects_config_mismatch(tmp_path):
    A = np.random.default_rng(5).uniform(0.2, 1.0, (10, 10))
    ckpt = str(tmp_path / "job.npz")
    val, st = _one_wave(A, ckpt)
    assert val is None and st.fraction_done() > 0
    for bad in (dict(precision="dd"), dict(backend="pallas"),
                dict(chunk_size=4, chunks_per_slice=2 * st.chunks_per_slice),
                dict(geometry=Geometry(64, 32, 8))):
        with pytest.raises(ValueError, match="config mismatch"):
            _one_wave(A, ckpt, **bad)
    # different total_slices fails on the slice count, not silently
    with pytest.raises(ValueError):
        mesh = jax.make_mesh((1,), ("step",))
        distributed.run_campaign(
            A, mesh, total_slices=2 * st.total_slices,
            chunks_per_slice=st.chunks_per_slice // 2,
            chunk_size=st.chunk_size, checkpoint_path=ckpt)
    # and the matching config still resumes fine
    val2, _ = _one_wave(A, ckpt, max_waves=None)
    assert val2 is not None


def test_checkpoint_rejects_geometry_mismatch(tmp_path):
    # ISSUE 9: kernel geometry is numeric identity -- partial sums
    # accumulated under one tuned geometry must never be extended under
    # another, even when every other config knob matches
    A = np.random.default_rng(9).uniform(0.2, 1.0, (10, 10))
    ckpt = str(tmp_path / "tuned.npz")
    g_tuned = Geometry(64, 32, 8)
    val, st = _one_wave(A, ckpt, backend="pallas", geometry=g_tuned)
    assert val is None and st.geometry == g_tuned.tag()
    for other in (Geometry(128, 64, 16), None):
        with pytest.raises(ValueError, match="config mismatch"):
            _one_wave(A, ckpt, backend="pallas", geometry=other)
    # same geometry resumes and finishes
    val2, _ = _one_wave(A, ckpt, backend="pallas", geometry=g_tuned,
                        max_waves=None)
    assert val2 is not None


def test_checkpoint_rejects_preversion_format(tmp_path):
    # a seed-format (v1) checkpoint has no version/config fields
    p = str(tmp_path / "old.npz")
    np.savez(p, fingerprint="abc", total_slices=4,
             done=np.zeros(4, bool), hi=np.zeros(4), lo=np.zeros(4))
    with pytest.raises(ValueError, match="config-safety"):
        resume.JobState.load(p)


def test_jobstate_persists_config_fields(tmp_path):
    A = np.random.default_rng(6).uniform(0.2, 1.0, (8, 8))
    st = resume.JobState.create(A, 4, precision="kahan", backend="pallas",
                                chunks_per_slice=2, chunk_size=16,
                                geometry="64x32x8")
    p = str(tmp_path / "s.npz")
    st.save(p)
    st2 = resume.JobState.load(p)
    assert (st2.precision, st2.backend) == ("kahan", "pallas")
    assert (st2.chunks_per_slice, st2.chunk_size) == (2, 16)
    assert st2.geometry == "64x32x8"
    assert st2.version == resume.FORMAT_VERSION


# ---------------------------------------------------------------------------
# kill/resume bitwise identity (subprocess, forced device counts)
# ---------------------------------------------------------------------------

def _cli_env(devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return env


def _cli(args, devices):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.campaign", *args],
        env=_cli_env(devices), capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    return r.stdout


def _value_of(out: str) -> str:
    # compare the %.17e-printed value as a string: exact round-trip of
    # the float64 (pair), i.e. bitwise comparison across processes
    for line in out.splitlines():
        if "perm(A) =" in line:
            return line.split("perm(A) =")[1].split("  (")[0].strip()
    raise AssertionError(f"no value line in output:\n{out}")


def _run_and_kill_mid_wave(args, devices):
    """Start the CLI, SIGKILL it right after its first durable wave."""
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.campaign", *args],
        env=_cli_env(devices), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    try:
        for line in p.stdout:
            if "[campaign] wave" in line:
                # the line prints only after its checkpoint hit disk
                os.kill(p.pid, signal.SIGKILL)
                break
        p.wait(timeout=120)
    finally:
        p.stdout.close()
        if p.poll() is None:
            p.kill()
            p.wait(timeout=120)


CASES = [
    (False, "dd"), (False, "dq_acc"), (False, "kahan"),
    (True, "dq_acc"), (True, "qq"),
]


@pytest.mark.slow
@pytest.mark.parametrize("use_complex,precision", CASES)
def test_sigkill_resume_bitwise_identical(tmp_path, use_complex, precision):
    ckpt = str(tmp_path / "job.npz")
    base = ["--n", "16", "--slices", "64", "--lanes", "8",
            "--precision", precision, "--seed", "9"]
    if use_complex:
        base.append("--complex")

    # reference: uninterrupted run on 8 devices
    ref = _value_of(_cli([*base, "--checkpoint",
                          str(tmp_path / "ref.npz")], devices=8))

    # kill mid-campaign on a 2-device mesh (32 waves: the SIGKILL lands
    # with most slices still pending)
    _run_and_kill_mid_wave([*base, "--checkpoint", ckpt, "--devices", "2"],
                           devices=8)
    st = resume.JobState.load(ckpt)
    assert 0 < st.fraction_done() < 1, "kill landed outside the campaign"
    # the checkpoint records the EFFECTIVE precision (complex qq plans
    # execute under kahan -- the planner's documented downgrade)
    expect = "kahan" if use_complex and precision == "qq" else precision
    assert st.precision == expect

    # resume under a DIFFERENT device count; value must match bitwise
    got = _value_of(_cli([*base, "--checkpoint", ckpt], devices=8))
    assert got == ref, (got, ref)


@pytest.mark.slow
def test_campaign_cli_pause_exit_code(tmp_path):
    ckpt = str(tmp_path / "job.npz")
    args = ["--n", "14", "--slices", "16", "--lanes", "8",
            "--checkpoint", ckpt, "--max-waves", "1"]
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.campaign", *args],
        env=_cli_env(4), capture_output=True, text=True, timeout=600)
    assert r.returncode == 3, r.stdout + r.stderr[-2000:]
    assert "paused" in r.stdout
    assert resume.JobState.load(ckpt).fraction_done() < 1
