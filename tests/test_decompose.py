"""DM elimination + Forbert-Marx compression: permanent-preserving props."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import decompose as D
from repro.core import oracle

RNG = np.random.default_rng(7)


def _rand_sparse(n, density, rng=RNG):
    return (rng.uniform(0.5, 1.5, (n, n))
            * (rng.uniform(0, 1, (n, n)) < density))


# ---------------------------------------------------------------- matching
def test_matching_complete_on_dense():
    adj = [list(range(6)) for _ in range(6)]
    ml, mr = D.hopcroft_karp(adj, 6, 6)
    assert -1 not in ml and sorted(ml) == list(range(6))


def test_matching_detects_deficiency():
    # two rows share a single column -> no perfect matching
    adj = [[0], [0], [1]]
    ml, _ = D.hopcroft_karp(adj, 3, 2)
    assert sum(m != -1 for m in ml) == 2


# ---------------------------------------------------------------- SCC
def test_scc_cycle_and_chain():
    # 0->1->2->0 cycle; 3->4 chain
    adj = [[1], [2], [0], [4], []]
    comp = D.strongly_connected_components(adj)
    assert comp[0] == comp[1] == comp[2]
    assert comp[3] != comp[4]
    assert len({comp[0], comp[3], comp[4]}) == 3


# ---------------------------------------------------------------- DM
@pytest.mark.parametrize("n,density", [(6, 0.4), (8, 0.35), (10, 0.3),
                                       (9, 0.5)])
def test_dm_preserves_permanent(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    Ap, removed = D.dm_eliminate(A)
    got = oracle.perm_ryser_exact(Ap)
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=1e-300)


def test_dm_triangular_keeps_only_diagonal():
    L = np.tril(RNG.uniform(1, 2, (10, 10)))
    Lp, removed = D.dm_eliminate(L)
    assert np.allclose(Lp, np.diag(np.diag(L)))
    assert removed == 45


def test_dm_structurally_singular_is_zero():
    A = np.zeros((5, 5))
    A[:, :3] = 1.0
    Ap, _ = D.dm_eliminate(A)
    assert not Ap.any()


def test_dm_never_removes_from_fully_indecomposable():
    # a circulant with 3 nonzeros per row/col is fully indecomposable
    n = 8
    A = np.zeros((n, n))
    for i in range(n):
        for d in [0, 1, 2]:
            A[i, (i + d) % n] = 1.0 + i + d
    Ap, removed = D.dm_eliminate(A)
    assert removed == 0


# ---------------------------------------------------------------- FM
@pytest.mark.parametrize("n,density", [(7, 0.35), (9, 0.4), (11, 0.3),
                                       (8, 0.6)])
def test_fm_preserves_permanent(n, density):
    A = _rand_sparse(n, density)
    ref = oracle.perm_ryser_exact(A)
    leaves = D.fm_decompose(A)
    tot = sum(l.coef * oracle.perm_ryser_exact(l.matrix) for l in leaves)
    np.testing.assert_allclose(tot, ref, rtol=1e-9, atol=1e-12)


def test_fm_leaves_have_min_degree_above_threshold():
    A = _rand_sparse(12, 0.4)
    for leaf in D.fm_decompose(A, max_min_nnz=4):
        M = leaf.matrix
        if M.shape[0] <= 2:
            continue
        mask = M != 0
        assert min(mask.sum(axis=0).min(), mask.sum(axis=1).min()) > 4


def test_fm_diagonal_collapses_fully():
    d = RNG.uniform(1, 2, 6)
    leaves = D.fm_decompose(np.diag(d))
    tot = sum(l.coef * oracle.perm_ryser_exact(l.matrix) for l in leaves)
    np.testing.assert_allclose(tot, np.prod(d), rtol=1e-12)
    # should fold to pure coefficients (1x1 ones)
    assert all(l.matrix.shape == (1, 1) for l in leaves)


def test_fm_complex_entries():
    A = _rand_sparse(8, 0.4).astype(np.complex128)
    A += 1j * _rand_sparse(8, 0.4)
    ref = oracle.perm_ryser_exact(A)
    leaves = D.fm_decompose(A)
    tot = sum(l.coef * oracle.perm_ryser_exact(l.matrix) for l in leaves)
    np.testing.assert_allclose(tot, ref, rtol=1e-9)


@given(st.integers(5, 9), st.floats(0.2, 0.7))
@settings(max_examples=20, deadline=None)
def test_property_dm_then_fm_preserves_permanent(n, density):
    rng = np.random.default_rng(n * 1000 + int(density * 100))
    A = _rand_sparse(n, density, rng)
    ref = oracle.perm_ryser_exact(A)
    Ap, _ = D.dm_eliminate(A)
    leaves = D.fm_decompose(Ap)
    tot = sum(l.coef * oracle.perm_ryser_exact(l.matrix) for l in leaves)
    np.testing.assert_allclose(tot, ref, rtol=1e-9, atol=1e-12)
