"""PermanentSolver plan/execute lifecycle: plans, cache, queue, wrappers.

Covers the ISSUE-2 acceptance surface: plan determinism and
serializability, cache hit/miss accounting (same matrix twice -> one
device dispatch), queue flush on both size and deadline triggers, the
leaf scalar-normalization and pallas->jnp downgrade-tag bugfixes, and
wrapper equivalence (``permanent`` == plan+execute).
"""

import json

import numpy as np
import pytest

from repro.core import engine
from repro.core.cache import ResultCache
from repro.core.executor import available_backends, get_backend
from repro.core.planner import SolverConfig, build_plan
from repro.core.solver import PermanentSolver

RNG = np.random.default_rng(20260726)


def _rand_sparse(n, density, rng=RNG):
    return rng.uniform(0.5, 1.5, (n, n)) * (rng.uniform(0, 1, (n, n)) < density)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# plans: determinism, inspection, serialization
# ---------------------------------------------------------------------------

def test_plan_determinism():
    A = _rand_sparse(10, 0.3)
    solver = PermanentSolver()
    p1, p2 = solver.plan(A), solver.plan(A)
    assert p1 == p2
    assert p1.json(sort_keys=True) == p2.json(sort_keys=True)
    assert p1 != solver.plan(A + 1.0)


def test_plan_batch_determinism_and_buckets():
    mats = [RNG.uniform(-1, 1, (8, 8)) for _ in range(3)]
    solver = PermanentSolver(preprocess=False)
    p1, p2 = solver.plan_batch(mats), solver.plan_batch(mats)
    assert p1 == p2
    assert p1.batched and not solver.plan(mats[0]).batched
    # three same-size dense leaves share one bucket
    assert p1.buckets == {("dense", 8): [0, 1, 2]}
    assert p1.estimated_steps == 3 * 8 * 2 ** 7


def test_plan_is_json_serializable():
    A = _rand_sparse(12, 0.25)
    plan = PermanentSolver().plan(A)
    blob = json.loads(plan.json())
    assert blob["matrices"][0]["n"] == 12
    assert len(blob["leaves"]) == len(plan.leaves)
    assert all(b["route"] in ("dense", "sparse", "inline")
               for b in blob["buckets"])
    assert "plan[scalar]" in plan.summary()


def test_plan_validates_shapes():
    solver = PermanentSolver()
    with pytest.raises(ValueError):
        solver.plan(np.zeros((3, 4)))
    with pytest.raises(ValueError):
        solver.plan_batch([np.zeros((3, 4))])
    # complex distributed batch plans are first-class now (ISSUE 4)
    assert PermanentSolver(backend="distributed").plan_batch(
        [np.eye(3, dtype=complex)]).is_complex
    assert PermanentSolver(backend="distributed").plan_batch(
        [np.eye(3)]).batched


# ---------------------------------------------------------------------------
# wrapper equivalence: permanent/permanent_batch == plan + execute
# ---------------------------------------------------------------------------

def test_wrapper_equivalence_scalar():
    A = RNG.uniform(-1, 1, (10, 10))
    solver = PermanentSolver()
    assert engine.permanent(A) == solver.execute(solver.plan(A))


def test_wrapper_equivalence_sparse_and_complex():
    solver = PermanentSolver()
    Ssp = _rand_sparse(10, 0.2)
    np.testing.assert_allclose(solver.execute(solver.plan(Ssp)),
                               engine.permanent(Ssp), rtol=1e-12)
    C = RNG.normal(size=(7, 7)) + 1j * RNG.normal(size=(7, 7))
    np.testing.assert_allclose(solver.execute(solver.plan(C)),
                               engine.permanent(C), rtol=1e-12)


def test_wrapper_equivalence_batch():
    mats = [RNG.uniform(-1, 1, (8, 8)) for _ in range(4)] \
        + [_rand_sparse(9, 0.22) for _ in range(3)]
    solver = PermanentSolver()
    got = solver.execute(solver.plan_batch(mats))
    np.testing.assert_allclose(got, engine.permanent_batch(mats), rtol=1e-12)


def test_execute_return_report_shapes():
    solver = PermanentSolver()
    A = RNG.uniform(-1, 1, (6, 6))
    val, report = solver.execute(solver.plan(A), return_report=True)
    assert report.value == val and report.n == 6
    vals, reports = solver.execute(solver.plan_batch([A, A]),
                                   return_report=True)
    assert len(reports) == 2 and vals.shape == (2,)


# ---------------------------------------------------------------------------
# result cache: hit/miss accounting, device-dispatch elision
# ---------------------------------------------------------------------------

def test_cache_same_matrix_twice_one_device_dispatch():
    A = RNG.uniform(-1, 1, (9, 9))
    solver = PermanentSolver()
    v1 = solver.execute(solver.plan(A))
    after_first = solver.stats()["device_dispatches"]
    assert after_first >= 1
    v2 = solver.execute(solver.plan(A))
    st = solver.stats()
    assert v2 == v1
    assert st["device_dispatches"] == after_first, \
        "second execute must be served from the result cache"
    assert st["cache"]["hits"] >= 1
    assert st["cache"]["misses"] >= 1


def test_cache_hits_across_batch_members():
    A = RNG.uniform(-1, 1, (8, 8))
    B = RNG.uniform(-1, 1, (8, 8))
    solver = PermanentSolver(preprocess=False)
    vals = solver.execute(solver.plan_batch([A, B, A, A]))
    # one bucket over the two unique leaves after cache dedup is not
    # attempted (first pass is cold), but a second pass is all hits
    solver2_dispatches = solver.stats()["device_dispatches"]
    vals2 = solver.execute(solver.plan_batch([A, B, A, A]))
    np.testing.assert_allclose(vals2, vals, rtol=1e-15)
    st = solver.stats()
    assert st["device_dispatches"] == solver2_dispatches
    assert vals[0] == vals[2] == vals[3]


def test_cache_respects_precision_and_backend():
    key_a = ResultCache.key("abc", "dense", "dq_acc", "jnp", 64, "<f8", "-")
    key_b = ResultCache.key("abc", "dense", "kahan", "jnp", 64, "<f8", "-")
    key_c = ResultCache.key("abc", "dense", "dq_acc", "pallas", 64, "<f8",
                            "-")
    key_d = ResultCache.key("abc", "dense", "dq_acc", "jnp", 64, "<c16", "-")
    # geometry is numeric identity: the same leaf under two kernel
    # geometries (and under the geometry-free default) never shares
    key_e = ResultCache.key("abc", "dense", "dq_acc", "pallas", 64, "<f8",
                            "128x64x16")
    key_f = ResultCache.key("abc", "dense", "dq_acc", "pallas", 64, "<f8",
                            "64x32x8")
    assert len({key_a, key_b, key_c, key_d, key_e, key_f}) == 6


def test_cache_lru_eviction_and_stats():
    cache = ResultCache(max_entries=2)
    cache.put(("a",), 1.0)
    cache.put(("b",), 2.0)
    assert cache.get(("a",)) == 1.0       # refresh "a"
    cache.put(("c",), 3.0)                # evicts "b"
    assert cache.get(("b",)) is None
    assert cache.get(("a",)) == 1.0
    st = cache.stats()
    assert st["evictions"] == 1 and st["entries"] == 2
    assert st["hits"] == 2 and st["misses"] == 1


def test_batch_duplicates_survive_tiny_cache():
    # dedup of duplicate leaves must resolve from the call's own results,
    # even when the LRU is smaller than the batch's distinct-leaf count
    A = RNG.uniform(-1, 1, (7, 7))
    B = RNG.uniform(-1, 1, (7, 7))
    solver = PermanentSolver(preprocess=False, cache_entries=1)
    got = solver.execute(solver.plan_batch([A, A, B]))
    ref = engine.permanent_batch([A, A, B], preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=1e-12)
    assert got[0] == got[1]


def test_cache_disabled_solver_never_caches():
    A = RNG.uniform(-1, 1, (8, 8))
    solver = PermanentSolver(cache=False)
    solver.execute(solver.plan(A))
    solver.execute(solver.plan(A))
    st = solver.stats()
    assert st["cache"] is None
    assert st["device_dispatches"] == 2


# ---------------------------------------------------------------------------
# async request queue: size + deadline flush triggers
# ---------------------------------------------------------------------------

def test_queue_flushes_on_size_trigger():
    clock = FakeClock()
    solver = PermanentSolver(queue_max_batch=4, queue_max_delay_s=1e9,
                             clock=clock)
    mats = [RNG.uniform(-1, 1, (7, 7)) for _ in range(4)]
    reqs = [solver.submit(M) for M in mats[:3]]
    assert not any(r.done for r in reqs), "below queue_max_batch: no flush"
    reqs.append(solver.submit(mats[3]))
    assert all(r.done for r in reqs), "4th submit must flush the bucket"
    assert solver.pending == 0 and solver.flushes == 1
    ref = engine.permanent_batch(mats)
    np.testing.assert_allclose([r.result() for r in reqs], ref, rtol=1e-12)


def test_queue_flushes_on_deadline_trigger():
    clock = FakeClock()
    solver = PermanentSolver(queue_max_batch=100, queue_max_delay_s=0.5,
                             clock=clock)
    r1 = solver.submit(RNG.uniform(-1, 1, (6, 6)))
    assert not r1.done and solver.pending == 1
    assert solver.poll() == 0, "deadline not reached yet"
    clock.t = 0.6
    assert solver.poll() == 1
    assert r1.done and solver.pending == 0


def test_queue_deadline_checked_on_submit():
    clock = FakeClock()
    solver = PermanentSolver(queue_max_batch=100, queue_max_delay_s=0.5,
                             clock=clock)
    r1 = solver.submit(RNG.uniform(-1, 1, (6, 6)))
    clock.t = 0.7
    r2 = solver.submit(RNG.uniform(-1, 1, (5, 5)))
    # submitting polls deadlines: the aged 6x6 bucket flushed; the fresh
    # 5x5 bucket did not
    assert r1.done and not r2.done
    assert solver.pending == 1
    solver.flush()
    assert r2.done


def test_queue_size_buckets_are_independent():
    clock = FakeClock()
    solver = PermanentSolver(queue_max_batch=2, queue_max_delay_s=1e9,
                             clock=clock)
    a = solver.submit(RNG.uniform(-1, 1, (6, 6)))
    b = solver.submit(RNG.uniform(-1, 1, (7, 7)))
    assert not a.done and not b.done
    c = solver.submit(RNG.uniform(-1, 1, (6, 6)))
    assert a.done and c.done and not b.done, \
        "only the full 6x6 bucket flushes"
    assert b.result() is not None and b.done


def test_queue_result_forces_flush():
    solver = PermanentSolver(queue_max_batch=100, queue_max_delay_s=1e9)
    A = RNG.uniform(-1, 1, (8, 8))
    req = solver.submit(A)
    assert not req.done
    np.testing.assert_allclose(req.result(), engine.permanent(A), rtol=1e-12)


def test_queue_accepts_distributed_backend_and_complex():
    # ISSUE 3 lifted the jnp|pallas-only guard; ISSUE 4 the real-only one:
    # complex submits queue and flush like any other request (downgrading
    # to jnp without a mesh)
    solver = PermanentSolver(backend="distributed")
    C = RNG.normal(size=(5, 5)) + 1j * RNG.normal(size=(5, 5))
    creq = solver.submit(C)
    assert solver.pending == 1
    A = RNG.uniform(-1, 1, (5, 5))
    req = solver.submit(A)
    np.testing.assert_allclose(req.result(), engine.permanent(A), rtol=1e-12)
    np.testing.assert_allclose(creq.result(), engine.permanent(C),
                               rtol=1e-12)


def test_queue_repeated_submatrices_hit_cache():
    A = RNG.uniform(-1, 1, (8, 8))
    solver = PermanentSolver(queue_max_batch=4, queue_max_delay_s=1e9)
    for _ in range(4):
        solver.submit(A.copy())
    st = solver.stats()
    assert st["flushes"] == 1
    assert st["cache"]["hits"] >= 1, \
        "identical queued matrices must dedup through the result cache"


# ---------------------------------------------------------------------------
# satellite bugfixes: scalar normalization + pallas->jnp downgrade tags
# ---------------------------------------------------------------------------

def test_sparse_route_returns_python_scalar():
    Ssp = _rand_sparse(10, 0.2)
    v, report = engine.permanent(Ssp, preprocess=False, return_report=True)
    assert report.dispatch == ["sparse(n=10,jnp)"]
    assert isinstance(v, float) and not isinstance(v, np.floating)
    vc = engine.permanent(Ssp.astype(np.complex128) * (1 + 0.5j),
                          preprocess=False)
    assert isinstance(vc, complex) and not isinstance(vc, np.complexfloating)


def test_batch_complex_pallas_runs_native_no_downgrade():
    # ISSUE 4: complex buckets run the split-plane batch-grid kernel --
    # no ``pallas->jnp`` downgrade tag on dense batch routes with n >= 4
    Cs = [RNG.normal(size=(6, 6)) + 1j * RNG.normal(size=(6, 6))
          for _ in range(3)]
    got, reports = engine.permanent_batch(Cs, backend="pallas",
                                          preprocess=False,
                                          return_report=True)
    ref = engine.permanent_batch(Cs, preprocess=False)
    np.testing.assert_allclose(got, ref, rtol=1e-9)
    tags = [t for r in reports for t in r.dispatch]
    assert tags and not any("->" in t for t in tags), tags
    assert all(t.startswith("dense_batch") for t in tags)


def test_batch_real_pallas_does_not_tag_downgrade():
    As = RNG.uniform(-1, 1, (3, 8, 8))
    _, reports = engine.permanent_batch(As, backend="pallas",
                                        preprocess=False, return_report=True)
    tags = [t for r in reports for t in r.dispatch]
    assert tags and not any("->" in t for t in tags)


# ---------------------------------------------------------------------------
# satellite bugfixes (ISSUE 3): stale downgrade cache keys, per-bucket
# result() flush, fingerprint over-identity
# ---------------------------------------------------------------------------

def test_downgraded_bucket_caches_under_producing_backend():
    # a no-mesh bucket under distributed downgrades to jnp; its values
    # must be cached under the *producing* backend ("jnp"), never the
    # configured one, so a jnp number can never satisfy a genuine
    # sharded-bucket lookup
    Cs = [RNG.normal(size=(6, 6)) + 1j * RNG.normal(size=(6, 6))
          for _ in range(3)]
    solver = PermanentSolver(SolverConfig(backend="distributed",
                                          preprocess=False))
    solver.execute(solver.plan_batch(Cs))
    assert len(solver.cache._data) == 3
    assert all(k[3] == "jnp" for k in solver.cache._data), \
        "downgraded values must be cached under the backend that " \
        "actually produced them"


def test_downgraded_values_are_reusable_by_jnp_plans():
    # the flip side of correct keying: jnp-produced downgrade values ARE
    # legitimate jnp results, so a later jnp-backend plan over the same
    # matrices is served entirely from the shared cache
    from repro.core.executor import execute_plan
    from repro.core.planner import build_plan
    Cs = [RNG.normal(size=(6, 6)) + 1j * RNG.normal(size=(6, 6))
          for _ in range(3)]
    shared = ResultCache(64)
    plan_d = build_plan(Cs, SolverConfig(backend="distributed",
                                         preprocess=False), batched=True)
    totals_d, _, stats_d = execute_plan(plan_d, cache=shared)  # no mesh ctx
    assert stats_d.downgrades
    plan_j = build_plan(Cs, SolverConfig(backend="jnp", preprocess=False),
                        batched=True)
    totals_j, _, stats_j = execute_plan(plan_j, cache=shared)
    assert stats_j.device_dispatches == 0, \
        "jnp plan must be served from the downgraded distributed run's cache"
    assert stats_j.cache_hits == 3
    np.testing.assert_allclose(totals_j, totals_d, rtol=0)


def test_pallas_and_jnp_sparse_values_use_distinct_cache_keys():
    # ISSUE 5 satellite: sparse attribution follows the same produced-by
    # logic as dense -- a pallas-sparse value (kernel numerics) and a
    # jnp-sparse value must never collide under one cache key
    mats = [_rand_sparse(9, 0.22) for _ in range(3)]
    pall = PermanentSolver(SolverConfig(backend="pallas",
                                        preprocess=False))
    pall.execute(pall.plan_batch(mats))
    assert pall.cache._data and \
        all(k[3] == "pallas" for k in pall.cache._data), \
        "sparse kernel values must carry the pallas cache identity"
    jnp_s = PermanentSolver(SolverConfig(backend="jnp", preprocess=False))
    jnp_s.execute(jnp_s.plan_batch(mats))
    assert all(k[3] == "jnp" for k in jnp_s.cache._data)
    # same leaves, same config except backend: the key sets are disjoint
    assert not (set(pall.cache._data) & set(jnp_s.cache._data))
    # scalar sparse path carries the same identity as the bucket path
    scal = PermanentSolver(SolverConfig(backend="pallas",
                                        preprocess=False))
    scal.execute(scal.plan(mats[0]))
    assert all(k[3] == "pallas" for k in scal.cache._data)


def test_same_leaf_under_two_geometries_never_shares_a_cache_entry():
    # ISSUE 9: kernel geometry is numeric identity -- one matrix executed
    # under two valid geometries lands in two cache entries (each tagged
    # with its geometry), and a shared cache never serves one geometry's
    # value to the other
    from repro.core.stepspace import Geometry
    A = RNG.uniform(0.2, 1.0, (8, 8))
    g1, g2 = Geometry(128, 64, 16), Geometry(8, 8, 8)
    s1 = PermanentSolver(SolverConfig(backend="pallas", preprocess=False,
                                      geometry=g1))
    v1 = s1.execute(s1.plan_batch([A]))
    s2 = PermanentSolver(SolverConfig(backend="pallas", preprocess=False,
                                      geometry=g2))
    s2.cache = s1.cache                  # share the cache across configs
    v2 = s2.execute(s2.plan_batch([A]))
    np.testing.assert_allclose(v2, v1, rtol=1e-12)
    tags = {k[6] for k in s1.cache._data}
    assert tags == {g1.tag(), g2.tag()}, tags
    assert len(s1.cache._data) == 2
    assert s1.stats()["cache"]["hits"] == 0
    assert s2.stats()["cache"]["hits"] == 0, \
        "the second geometry must recompute, not hit the first's entry"


def test_cache_key_separates_real_and_zero_imag_complex_leaves():
    # ISSUE 4 satellite: dtype is an explicit cache-key component -- a
    # float64 leaf and a complex128 leaf with zero imaginary part are
    # different computations (real engine vs split-plane engine) and must
    # never share a cache entry
    A = RNG.uniform(-1, 1, (6, 6))
    solver = PermanentSolver(SolverConfig(preprocess=False))
    vr = solver.execute(solver.plan_batch([A]))
    vc = solver.execute(solver.plan_batch([A.astype(np.complex128)]))
    np.testing.assert_allclose(np.real(vc), vr, rtol=1e-12)
    dtypes = {k[5] for k in solver.cache._data}
    assert dtypes == {"<f8", "<c16"}, dtypes
    assert len(solver.cache._data) == 2, \
        "real and zero-imag complex leaves must occupy distinct entries"
    st = solver.stats()
    assert st["cache"]["hits"] == 0, \
        "the complex plan must not be served from the real plan's entry"
    # and the raw key helper keeps them apart even for equal content hashes
    kr = ResultCache.key("h", "dense", "dq_acc", "jnp", 64, "<f8", "-")
    kc = ResultCache.key("h", "dense", "dq_acc", "jnp", 64, "<c16", "-")
    assert kr != kc


def test_complex_qq_caches_under_effective_precision():
    # plan.precision is the effective one: complex qq stores under kahan,
    # and a later explicit-kahan plan over the same matrices is a pure
    # cache hit (identical numerics), while real qq entries stay separate
    C = RNG.normal(size=(6, 6)) + 1j * RNG.normal(size=(6, 6))
    solver = PermanentSolver(SolverConfig(precision="qq",
                                          preprocess=False))
    v_qq = solver.execute(solver.plan_batch([C]))
    assert all(k[2] == "kahan" for k in solver.cache._data)
    kah = PermanentSolver(SolverConfig(precision="kahan", preprocess=False))
    kah.cache = solver.cache
    v_k = kah.execute(kah.plan_batch([C]))
    np.testing.assert_allclose(v_k, v_qq, rtol=0)
    assert kah.stats()["cache"]["hits"] == 1


def test_genuine_pallas_values_keep_their_own_cache_identity():
    # real n >= 4 buckets really run the pallas kernel: their cache
    # entries must NOT collide with jnp's for the same matrices
    As = [RNG.uniform(-1, 1, (6, 6)) for _ in range(3)]
    solver = PermanentSolver(SolverConfig(backend="pallas",
                                          preprocess=False))
    solver.execute(solver.plan_batch(As))
    assert all(k[3] == "pallas" for k in solver.cache._data)


def test_result_flushes_only_own_bucket():
    # a planning failure in an unrelated size bucket must not raise out
    # of result() -- before the fix, result() flushed EVERY bucket
    solver = PermanentSolver(queue_max_batch=100, queue_max_delay_s=1e9)
    boom = RuntimeError("unrelated 6x6 bucket is broken")
    orig = solver.plan_batch

    def plan_batch(mats):
        if mats[0].shape[0] == 6:
            raise boom
        return orig(mats)

    solver.plan_batch = plan_batch
    r6 = solver.submit(RNG.uniform(-1, 1, (6, 6)))
    r7 = solver.submit(RNG.uniform(-1, 1, (7, 7)))
    val = r7.result()                     # must not touch the 6x6 bucket
    assert r7.done and not r6.done
    np.testing.assert_allclose(val, engine.permanent(r7.matrix), rtol=1e-12)
    assert solver.pending == 1, "the broken bucket stays queued"
    with pytest.raises(RuntimeError):     # full flush still surfaces it
        solver.flush()


def test_fingerprint_ignores_queue_and_cache_policy():
    A = RNG.uniform(-1, 1, (8, 8))
    base = SolverConfig()
    p1 = build_plan([A], base, batched=False)
    p2 = build_plan([A], base.replace(cache=False, cache_entries=7,
                                      queue_max_batch=999,
                                      queue_max_delay_s=1e9),
                    batched=False)
    assert p1 == p2, "queue/cache policy must not perturb plan identity"
    assert p1.fingerprint() == p2.fingerprint()
    # numerics-affecting fields still count
    assert p1 != build_plan([A], base.replace(num_chunks=128), batched=False)
    assert p1 != build_plan([A], base.replace(precision="kahan"),
                            batched=False)
    assert p1 != build_plan([A], base.replace(backend="pallas"),
                            batched=False)


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_backend_registry_contents():
    assert {"jnp", "pallas", "distributed"} <= set(available_backends())
    assert get_backend("jnp").name == "jnp"
    with pytest.raises(ValueError):
        get_backend("nope")


def test_unknown_backend_raises_at_execute():
    cfg = SolverConfig(backend="nope", cache=False)
    plan = build_plan([np.eye(4)], cfg, batched=False)
    from repro.core.executor import execute_plan
    with pytest.raises(ValueError):
        execute_plan(plan)
