"""HLO cost analyzer: trip-count scaling, dot flops, collective bytes."""

import jax
import jax.numpy as jnp

from repro.utils.hlo import collective_bytes, parse_shape_bytes
from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import Roofline


def _hlo_of(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[4,8]") == 128
    assert parse_shape_bytes("bf16[2,3]{1,0}") == 12
    assert parse_shape_bytes("(f32[2], u32[4])") == 24
    assert parse_shape_bytes("pred[]") == 1


def test_dot_flops_exact():
    a = jnp.ones((32, 64), jnp.float32)
    b = jnp.ones((64, 16), jnp.float32)
    cost = analyze_hlo(_hlo_of(lambda a, b: a @ b, a, b))
    assert cost.dot_flops == 2 * 32 * 64 * 16


def test_scan_trip_count_multiplies_flops():
    a = jnp.ones((8, 8), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=20)
        return out

    cost = analyze_hlo(_hlo_of(f, a))
    # 20 iterations x (2 * 8^3); XLA may pre/peel one, allow slack
    want = 20 * 2 * 8 ** 3
    assert want * 0.9 <= cost.dot_flops <= want * 1.2, cost.dot_flops
    assert cost.while_count >= 1


def test_nested_scan_trip_counts_compose():
    a = jnp.ones((4, 4), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ a, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=3)
        return out

    cost = analyze_hlo(_hlo_of(f, a))
    want = 15 * 2 * 4 ** 3
    assert want * 0.9 <= cost.dot_flops <= want * 1.3


def test_elementwise_flops_counted():
    a = jnp.ones((128,), jnp.float32)
    cost = analyze_hlo(_hlo_of(lambda a: a * 2 + 1, a))
    assert cost.elementwise_flops >= 128  # at least the fused add/mul


def test_collective_bytes_parser_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,8]) -> f32[16,8] {
  %p = f32[16,8]{1,0} parameter(0)
  %ag = f32[64,8]{1,0} all-gather(%p), dimensions={0}
  %ar = f32[16,8]{1,0} all-reduce(%p), to_apply=%add
  ROOT %out = f32[16,8]{1,0} copy(%ar)
}
"""
    out = collective_bytes(hlo)
    assert out["by_kind"]["all-gather"]["bytes"] == 64 * 8 * 4
    assert out["by_kind"]["all-reduce"]["bytes"] == 16 * 8 * 4
    assert out["by_kind"]["all-gather"]["count"] == 1


def test_roofline_terms_and_dominant():
    rl = Roofline(flops=197e12 * 256, bytes_accessed=0.0,
                  collective_bytes=100e9, chips=256,
                  model_flops=100e12 * 256, bytes_min=819e9)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 2.0) < 1e-9
    assert rl.dominant == "collective"
    assert 0 < rl.mfu_bound < 1
    assert abs(rl.useful_flops_ratio - 100 / 197) < 1e-9
