"""HLO cost analyzer: trip-count scaling, dot flops, collective bytes,
unknown-dtype loudness, async -start/-done pair counting."""

import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo import (UnknownDtypeError, collective_bytes, count_ops,
                             parse_shape_bytes)
from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import Roofline


def _hlo_of(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[4,8]") == 128
    assert parse_shape_bytes("bf16[2,3]{1,0}") == 12
    assert parse_shape_bytes("(f32[2], u32[4])") == 24
    assert parse_shape_bytes("pred[]") == 1


def test_parse_shape_bytes_unknown_dtype_is_loud():
    with pytest.raises(UnknownDtypeError, match="f8e4m3fn"):
        parse_shape_bytes("f8e4m3fn[16]")
    with pytest.raises(UnknownDtypeError, match="s4"):
        parse_shape_bytes("(f32[2], s4[8])")
    # token is legitimately byte-free, always allowed
    assert parse_shape_bytes("(f32[2], token[])") == 8
    # the escape hatch must be explicit, per dtype
    assert parse_shape_bytes("f8e4m3fn[16]", allow=("f8e4m3fn",)) == 0
    assert parse_shape_bytes("(f32[2], f8e4m3fn[16])",
                             allow=("f8e4m3fn",)) == 8


def test_dot_flops_exact():
    a = jnp.ones((32, 64), jnp.float32)
    b = jnp.ones((64, 16), jnp.float32)
    cost = analyze_hlo(_hlo_of(lambda a, b: a @ b, a, b))
    assert cost.dot_flops == 2 * 32 * 64 * 16


def test_scan_trip_count_multiplies_flops():
    a = jnp.ones((8, 8), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=20)
        return out

    cost = analyze_hlo(_hlo_of(f, a))
    # 20 iterations x (2 * 8^3); XLA may pre/peel one, allow slack
    want = 20 * 2 * 8 ** 3
    assert want * 0.9 <= cost.dot_flops <= want * 1.2, cost.dot_flops
    assert cost.while_count >= 1


def test_nested_scan_trip_counts_compose():
    a = jnp.ones((4, 4), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ a, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=3)
        return out

    cost = analyze_hlo(_hlo_of(f, a))
    want = 15 * 2 * 4 ** 3
    assert want * 0.9 <= cost.dot_flops <= want * 1.3


def test_elementwise_flops_counted():
    a = jnp.ones((128,), jnp.float32)
    cost = analyze_hlo(_hlo_of(lambda a: a * 2 + 1, a))
    assert cost.elementwise_flops >= 128  # at least the fused add/mul


def test_collective_bytes_parser_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,8]) -> f32[16,8] {
  %p = f32[16,8]{1,0} parameter(0)
  %ag = f32[64,8]{1,0} all-gather(%p), dimensions={0}
  %ar = f32[16,8]{1,0} all-reduce(%p), to_apply=%add
  ROOT %out = f32[16,8]{1,0} copy(%ar)
}
"""
    out = collective_bytes(hlo)
    assert out["by_kind"]["all-gather"]["bytes"] == 64 * 8 * 4
    assert out["by_kind"]["all-reduce"]["bytes"] == 16 * 8 * 4
    assert out["by_kind"]["all-gather"]["count"] == 1


_ASYNC_HLO = """
HloModule m

%fused (a: f64[32]) -> f64[32] {
  %a = f64[32]{0} parameter(0)
  %two = f64[32]{0} multiply(%a, %a)
  ROOT %fr = f64[32]{0} add(%two, %a)
}

ENTRY %main (p: f64[32]) -> f64[32] {
  %p = f64[32]{0} parameter(0)
  %f = f64[32]{0} fusion(%p), kind=kLoop, calls=%fused
  %ar-start = f64[32]{0} all-reduce-start(%f), to_apply=%add
  %ar-done = f64[32]{0} all-reduce-done(%ar-start)
  %ag-start = (f64[32]{0}, f64[128]{0}) all-gather-start(%ar-done), dimensions={0}
  %ag-done = f64[128]{0} all-gather-done(%ag-start)
  %d = f64[32]{0} dot(%p, %p), lhs_contracting_dims={}, rhs_contracting_dims={}
  ROOT %out = f64[32]{0} copy(%ar-done)
}
"""


def test_async_collective_pairs_count_once():
    out = collective_bytes(_ASYNC_HLO)
    # -start/-done describe ONE logical collective each
    assert out["by_kind"]["all-reduce"]["count"] == 1
    assert out["by_kind"]["all-gather"]["count"] == 1
    # all-reduce bytes from the -start result; the tuple-shaped
    # all-gather-start result counts both the operand and output buffers
    assert out["by_kind"]["all-reduce"]["bytes"] == 32 * 8
    assert out["by_kind"]["all-gather"]["bytes"] == (32 + 128) * 8


def test_count_ops_merges_async_pairs_and_sees_fusion_bodies():
    counts = count_ops(_ASYNC_HLO, opnames=("dot", "multiply", "add"))
    assert counts["dot"] == 1
    # ops inside the fusion computation body are instruction lines too
    assert counts["multiply"] == 1
    assert counts["add"] == 1
    # the async pair appears once, under the base opcode -- never as
    # separate -start/-done (or double-counted) entries
    assert counts["all-reduce"] == 1
    assert counts["all-gather"] == 1
    assert not any(k.endswith("-start") or k.endswith("-done")
                   for k in counts)


def test_roofline_terms_and_dominant():
    rl = Roofline(flops=197e12 * 256, bytes_accessed=0.0,
                  collective_bytes=100e9, chips=256,
                  model_flops=100e12 * 256, bytes_min=819e9)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 2.0) < 1e-9
    assert rl.dominant == "collective"
    assert 0 < rl.mfu_bound < 1
    assert abs(rl.useful_flops_ratio - 100 / 197) < 1e-9
