"""Pallas kernel vs ref.py oracle: shape/dtype/geometry sweeps (interpret)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import oracle
from repro.kernels import ops, ref
from repro.core.stepspace import Geometry as G

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("n", [4, 5, 6, 8, 10, 12, 14])
@pytest.mark.parametrize("mode", ["baseline", "batched"])
def test_kernel_matches_exact(n, mode):
    A = RNG.uniform(-1, 1, (n, n))
    want = oracle.perm_ryser_exact(A)
    got = float(ops.permanent_pallas(A, mode=mode, geometry=G(8, 8, 4)))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("lanes,spc,win", [(4, 4, 2), (16, 16, 16),
                                           (8, 32, 8), (32, 4, 4),
                                           (2, 2, 2), (64, 8, 8)])
@pytest.mark.parametrize("mode", ["baseline", "batched"])
def test_geometry_sweep(lanes, spc, win, mode):
    A = RNG.uniform(-1, 1, (11, 11))
    want = oracle.perm_ryser_exact(A)
    got = float(ops.permanent_pallas(A, mode=mode, geometry=G(lanes, spc, win)))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("dtype,rtol", [(np.float64, 1e-9),
                                        (np.float32, 5e-4)])
@pytest.mark.parametrize("mode", ["baseline", "batched"])
def test_dtype_sweep(dtype, rtol, mode):
    A = RNG.uniform(0.1, 1.0, (10, 10)).astype(dtype)
    want = oracle.perm_ryser_exact(A.astype(np.float64))
    got = float(ops.permanent_pallas(A, mode=mode, geometry=G(8, 8, 4)))
    np.testing.assert_allclose(got, want, rtol=rtol)


@pytest.mark.parametrize("precision", ["dd", "kahan", "dq_acc"])
def test_precision_modes(precision):
    A = RNG.uniform(-1, 1, (10, 10))
    want = oracle.perm_ryser_exact(A)
    got = float(ops.permanent_pallas(A, precision=precision, geometry=G(8, 8, 4)))
    np.testing.assert_allclose(got, want, rtol=1e-8)


def test_block_partials_match_ref_oracle():
    """Per-block decomposition must match ref.py exactly (same blocking)."""
    n = 10
    A = RNG.uniform(-1, 1, (n, n))
    out, (TB, C, Wu, blocks) = ops.block_partials_pallas(
        A, geometry=G(8, 8, 4))
    want = ref.block_partials_ref(A, TB=TB, C=C, num_blocks=blocks)
    got = np.asarray(out[:, 0] + out[:, 1])
    np.testing.assert_allclose(
        got, np.asarray(want[:, 0] + want[:, 1]), rtol=1e-12, atol=1e-15)


def test_device_offset_partials_compose():
    """Two half-space kernel calls (as two devices would run) must sum to
    the full-space result -- the distributed decomposition invariant."""
    n = 11
    A = RNG.uniform(-1, 1, (n, n))
    TB, C, Wu, blocks = G(8, 8, 4).kernel_geometry(n)
    assert blocks % 2 == 0
    full, _ = ops.block_partials_pallas(A, geometry=G(8, 8, 4))
    lo_half, _ = ops.block_partials_pallas(
        A, dev_chunk_base=0, num_blocks=blocks // 2, geometry=G(8, 8, 4))
    hi_half, _ = ops.block_partials_pallas(
        A, dev_chunk_base=(blocks // 2) * TB, num_blocks=blocks // 2,
        geometry=G(8, 8, 4))
    np.testing.assert_allclose(float(jnp.sum(full)),
                               float(jnp.sum(lo_half) + jnp.sum(hi_half)),
                               rtol=1e-12)


def test_kernel_vs_ref_permanent_api():
    n = 9
    A = RNG.uniform(-1, 1, (n, n))
    TB, C, Wu, blocks = G(8, 8, 4).kernel_geometry(n)
    a = float(ops.permanent_pallas(A, geometry=G(8, 8, 4)))
    b = float(ref.permanent_ref(A, TB=TB, C=C, num_blocks=blocks))
    np.testing.assert_allclose(a, b, rtol=1e-12)


@given(st.integers(4, 9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_property_kernel_matches_oracle(n, seed):
    rng = np.random.default_rng(seed)
    A = rng.uniform(-1, 1, (n, n))
    want = oracle.perm_ryser_exact(A)
    got = float(ops.permanent_pallas(A, geometry=G(4, 4, 4)))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_all_ones_family():
    for n in [6, 9, 12]:
        A = np.full((n, n), 0.5)
        want = oracle.all_ones_permanent(n, 0.5)
        got = float(ops.permanent_pallas(A, geometry=G(8, 8, 8)))
        np.testing.assert_allclose(got, want, rtol=1e-10)


# ---------------------------------------------------------------- complex
@pytest.mark.parametrize("n", [4, 6, 9, 12])
def test_complex_kernel_matches_oracle(n):
    """Split re/im kernel (boson-sampling workloads) vs Fraction oracle."""
    rng = np.random.default_rng(100 + n)
    A = rng.normal(size=(n, n)) + 1j * rng.normal(size=(n, n))
    want = oracle.perm_ryser_exact(A)
    got = complex(np.asarray(ops.permanent_pallas(
        A, geometry=G(8, 8, 4))))
    assert abs(got - want) / abs(want) < 1e-9


@pytest.mark.parametrize("precision", ["dd", "kahan", "dq_acc"])
def test_complex_kernel_precisions(precision):
    rng = np.random.default_rng(77)
    A = rng.normal(size=(10, 10)) + 1j * rng.normal(size=(10, 10))
    want = oracle.perm_ryser_exact(A)
    got = complex(np.asarray(ops.permanent_pallas(
        A, precision=precision, geometry=G(8, 16, 8))))
    assert abs(got - want) / abs(want) < 1e-8


def test_complex_unitary_submatrix_probability():
    """|perm|^2 of a Haar-unitary submatrix is a valid probability."""
    rng = np.random.default_rng(5)
    z = (rng.normal(size=(8, 8)) + 1j * rng.normal(size=(8, 8)))
    q, r = np.linalg.qr(z)
    U = q * (np.diag(r) / np.abs(np.diag(r)))
    sub = U[:4, :4]
    amp = complex(np.asarray(ops.permanent_pallas(
        sub, geometry=G(4, 4, 4))))
    want = oracle.perm_ryser_exact(sub)
    assert abs(amp - want) / abs(want) < 1e-10
    assert 0 <= abs(amp) ** 2 <= 1 + 1e-9
