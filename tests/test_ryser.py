"""Dense Ryser engines vs exact oracles + precision-mode properties."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import oracle, ryser
from repro.core.precision import PRECISION_MODES

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14])
def test_seq_matches_exact(n):
    A = RNG.uniform(-1, 1, (n, n))
    ref = oracle.perm_ryser_exact(A)
    got = float(ryser.perm_ryser_seq(jnp.asarray(A)))
    np.testing.assert_allclose(got, ref, rtol=1e-10, atol=1e-14)


@pytest.mark.parametrize("n", [3, 4, 6, 9, 11, 13])
@pytest.mark.parametrize("chunks", [2, 8, 64])
def test_chunked_matches_exact(n, chunks):
    A = RNG.uniform(-1, 1, (n, n))
    ref = oracle.perm_ryser_exact(A)
    got = float(ryser.perm_ryser_chunked(jnp.asarray(A), num_chunks=chunks))
    np.testing.assert_allclose(got, ref, rtol=1e-10, atol=1e-14)


@pytest.mark.parametrize("precision", PRECISION_MODES)
def test_all_precision_modes_correct(precision):
    A = RNG.uniform(-1, 1, (10, 10))
    ref = oracle.perm_ryser_exact(A)
    got = float(ryser.perm_ryser_chunked(jnp.asarray(A), num_chunks=16,
                                         precision=precision))
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-13)


def test_definition_small_n():
    for n in range(1, 7):
        A = RNG.uniform(-1, 1, (n, n))
        d = oracle.perm_definition(A)
        r = oracle.perm_ryser_exact(A)
        np.testing.assert_allclose(d, r, rtol=1e-10, atol=1e-14)


def test_binary_matrix_exact_integer():
    for n in [6, 10, 13]:
        A = (RNG.uniform(0, 1, (n, n)) < 0.5).astype(np.int64)
        bi = oracle.perm_bigint(A)
        got = float(ryser.perm_ryser_chunked(
            jnp.asarray(A, dtype=jnp.float64), num_chunks=8))
        assert round(got) == bi


def test_complex_matrix():
    n = 8
    A = RNG.uniform(-1, 1, (n, n)) + 1j * RNG.uniform(-1, 1, (n, n))
    ref = oracle.perm_ryser_exact(A)
    got = complex(np.asarray(ryser.perm_ryser_chunked(
        jnp.asarray(A), num_chunks=8, precision="kahan")))
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_all_ones_closed_form():
    # the paper's Sec. 5 validation family: perm(a * ones(n)) = n! a^n
    for n, a in [(6, 1.0), (8, 0.5), (10, 2.0)]:
        A = np.full((n, n), a)
        ref = oracle.all_ones_permanent(n, a)
        got = float(ryser.perm_ryser_chunked(jnp.asarray(A), num_chunks=8))
        np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_transpose_invariance():
    A = RNG.uniform(-1, 1, (9, 9))
    a = float(ryser.perm_ryser_chunked(jnp.asarray(A)))
    b = float(ryser.perm_ryser_chunked(jnp.asarray(A.T)))
    np.testing.assert_allclose(a, b, rtol=1e-10)


def test_row_scaling_linearity():
    # perm is linear in each row
    A = RNG.uniform(-1, 1, (8, 8))
    B = A.copy()
    B[3] *= 2.5
    a = float(ryser.perm_ryser_chunked(jnp.asarray(A)))
    b = float(ryser.perm_ryser_chunked(jnp.asarray(B)))
    np.testing.assert_allclose(b, 2.5 * a, rtol=1e-9)


@given(hnp.arrays(np.float64, (5, 5),
                  elements=st.floats(min_value=-2, max_value=2,
                                     allow_nan=False)))
@settings(max_examples=30, deadline=None)
def test_property_matches_exact_oracle(A):
    ref = oracle.perm_ryser_exact(A)
    got = float(ryser.perm_ryser_chunked(jnp.asarray(A), num_chunks=4))
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-10)


def test_chunk_geometry_invariants():
    for n in range(3, 30):
        for req in [1, 2, 7, 64, 10**6]:
            T, C, k = ryser.chunk_geometry(n, req)
            assert T * C == 1 << (n - 1)
            assert C == 1 << k and k >= 1
            assert T & (T - 1) == 0
