"""Always-on permanent service (ISSUE 7): lanes, SLOs, backpressure,
metrics schema, legacy parity, and the warm-compile-cache cold start.

Everything time-dependent runs against an injected FakeClock -- deadline
expiry, lane ordering, and log cadence are deterministic, never sleeps.
The compile-cache test is a real two-cold-subprocess comparison and is
marked slow (CI's multidevice job runs it).
"""

import json
import os
import subprocess
import sys
import urllib.request

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from repro.core.engine import permanent
from repro.core.solver import PermanentSolver, SolverConfig, SolverError
from repro.serve import (DEFAULT_LANES, Histogram, LaneQueue, LaneSpec,
                         PermanentService, ServiceConfig,
                         ShedError, ShedReason, quantized_batches,
                         run_soak, start_metrics_server)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def mk(rng, n=5, complex_entries=False):
    M = rng.uniform(-1, 1, (n, n))
    if complex_entries:
        M = M + 1j * rng.uniform(-1, 1, (n, n))
    return M


def service(clock, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("log_every_s", float("inf"))
    return PermanentService(SolverConfig(backend="jnp"),
                            ServiceConfig(**kw), clock=clock, log=None)


# -- lanes / priority ---------------------------------------------------------

class TestLanes:
    def test_interactive_preempts_bulk(self):
        """A later interactive request dispatches before earlier bulk
        traffic of the same shape."""
        clock = FakeClock()
        svc = service(clock, max_batch=2)
        rng = np.random.default_rng(0)
        bulk = [svc.submit(mk(rng), lane="bulk", deadline_s=None)
                for _ in range(3)]
        inter = svc.submit(mk(rng), lane="interactive", deadline_s=None)
        svc.step()                      # one bucket of 2
        assert inter.done
        # the interactive ticket took one slot; oldest bulk backfilled
        assert bulk[0].done and not bulk[1].done and not bulk[2].done
        svc.drain()
        assert all(t.done for t in bulk)

    def test_unknown_lane_rejected(self):
        svc = service(FakeClock())
        with pytest.raises(ValueError, match="unknown lane"):
            svc.submit(np.eye(3), lane="nope")

    def test_lane_queue_priority_order(self):
        q = LaneQueue(DEFAULT_LANES)
        assert [l.name for l in q.lanes] == ["interactive", "bulk"]
        assert q.lane(None).name == "interactive"

    def test_values_match_scalar_engine(self):
        """Continuous dispatch with pow2 padding stays bitwise equal to
        the scalar engine (batch-shape independence + discarded pad)."""
        clock = FakeClock()
        svc = service(clock, max_batch=4)
        rng = np.random.default_rng(1)
        mats = [mk(rng, n=6) for _ in range(5)]
        ts = [svc.submit(M, deadline_s=None) for M in mats]
        svc.drain()
        for t, M in zip(ts, mats):
            assert t.result() == permanent(M)

    def test_complex_bucket(self):
        clock = FakeClock()
        svc = service(clock, max_batch=2)
        rng = np.random.default_rng(2)
        mats = [mk(rng, n=5, complex_entries=True) for _ in range(3)]
        ts = [svc.submit(M, deadline_s=None) for M in mats]
        svc.drain()
        for t, M in zip(ts, mats):
            assert t.result() == permanent(M)


# -- deadlines / shedding -----------------------------------------------------

class TestShedding:
    def test_deadline_expiry_sheds_with_reason(self):
        clock = FakeClock()
        svc = service(clock)
        t = svc.submit(np.eye(4), deadline_s=1.0)
        clock.t = 1.5
        svc.step()
        assert t.shed and t.shed_reason is ShedReason.DEADLINE_EXPIRED
        with pytest.raises(ShedError) as ei:
            t.result()
        assert ei.value.reason is ShedReason.DEADLINE_EXPIRED

    def test_lane_slo_is_default_deadline(self):
        clock = FakeClock()
        svc = service(clock)            # interactive slo_s=2.0
        t = svc.submit(np.eye(4), lane="interactive")
        clock.t = 2.1
        svc.step()
        assert t.shed and t.shed_reason is ShedReason.DEADLINE_EXPIRED

    def test_queue_full_backpressure(self):
        clock = FakeClock()
        svc = service(clock, max_queue_depth=2)
        rng = np.random.default_rng(3)
        ts = [svc.submit(mk(rng), deadline_s=None) for _ in range(3)]
        assert not ts[0].shed and not ts[1].shed
        assert ts[2].shed and ts[2].shed_reason is ShedReason.QUEUE_FULL
        assert "queue depth" in ts[2].shed_detail
        svc.drain()
        assert ts[0].done and ts[1].done

    def test_cost_budget_backpressure(self):
        clock = FakeClock()
        svc = service(clock, max_pending_cost=100.0)
        rng = np.random.default_rng(4)
        a = svc.submit(mk(rng, n=5), deadline_s=None)   # cost 5*16 = 80
        b = svc.submit(mk(rng, n=5), deadline_s=None)   # 160 > 100
        assert not a.shed
        assert b.shed and b.shed_reason is ShedReason.COST_BUDGET

    def test_shutdown_sheds_typed(self):
        clock = FakeClock()
        svc = service(clock)
        t = svc.submit(np.eye(4), deadline_s=None)
        (shed,) = svc.shutdown()
        assert shed is t and t.shed_reason is ShedReason.SHUTDOWN

    def test_result_before_dispatch_raises(self):
        svc = service(FakeClock())
        t = svc.submit(np.eye(4), deadline_s=None)
        with pytest.raises(RuntimeError, match="still queued"):
            t.result()


# -- fill_first (legacy PR 6 semantics) --------------------------------------

class TestFillFirst:
    def test_dispatch_only_when_full_or_aged(self):
        clock = FakeClock()
        svc = service(clock, max_batch=3, fill_first=True, deadline_s=5.0,
                      quantize_buckets=False,
                      lanes=(LaneSpec("default", 0, slo_s=None),))
        rng = np.random.default_rng(5)
        a = svc.submit(mk(rng), deadline_s=None)
        assert svc.step() == 0          # 1 of 3: waits
        b = svc.submit(mk(rng), deadline_s=None)
        assert svc.step() == 0
        c = svc.submit(mk(rng), deadline_s=None)
        assert svc.step() == 3          # full bucket dispatches
        assert a.done and b.done and c.done
        d = svc.submit(mk(rng), deadline_s=None)
        assert svc.step() == 0
        clock.t = 6.0                   # ... until the age trigger
        assert svc.step() == 1
        assert d.done

    def test_full_bucket_beats_older_partial(self):
        """A full bucket dispatches even when an older, non-full bucket
        of another size sorts ahead of it."""
        clock = FakeClock()
        svc = service(clock, max_batch=2, fill_first=True, deadline_s=1e9,
                      quantize_buckets=False,
                      lanes=(LaneSpec("default", 0, slo_s=None),))
        rng = np.random.default_rng(6)
        older = svc.submit(mk(rng, n=6), deadline_s=None)
        full = [svc.submit(mk(rng, n=7), deadline_s=None) for _ in range(2)]
        assert svc.step() == 2
        assert all(t.done for t in full) and not older.done

    def test_legacy_wrapper_matches_direct_solver_queue(self):
        """run_permanent_serving over the service == driving the PR 6
        solver queue by hand, bitwise."""
        from repro.launch.serve import run_permanent_serving

        out = run_permanent_serving(n=6, batch=4, requests=10,
                                    repeat_pool=3, deadline_s=1e9, seed=11)
        # reference: the solver queue directly, same stream construction
        rng = np.random.default_rng(11)
        pool = [rng.uniform(-1, 1, (6, 6)) for _ in range(3)]
        mats = [pool[i] for i in rng.integers(0, 3, 10)]
        solver = PermanentSolver(SolverConfig(
            backend="jnp", queue_max_batch=4, queue_max_delay_s=1e9))
        reqs = [solver.submit(M) for M in mats]
        solver.flush()
        ref = np.array([r.result() for r in reqs])
        assert np.array_equal(out["values"], ref)
        assert out["batches"] == 3      # 2 full + ragged tail
        snap = out["snapshot"]
        assert snap["requests"]["completed"] == 10
        assert snap["requests"]["shed_total"] == 0


# -- metrics ------------------------------------------------------------------

class TestMetrics:
    def test_histogram_quantiles(self):
        h = Histogram(lo=1e-3, hi=1e3)
        for v in [0.01] * 98 + [5.0, 8.0]:
            h.observe(v)
        assert h.count == 100
        assert h.quantile(0.5) <= 0.02
        assert 5.0 <= h.quantile(0.99) <= 8.0
        assert h.to_json()["max"] == 8.0

    def test_snapshot_schema_and_consistency(self):
        clock = FakeClock()
        svc = service(clock, max_queue_depth=3)
        rng = np.random.default_rng(7)
        for i in range(5):
            svc.submit(mk(rng), lane="bulk" if i % 2 else "interactive",
                       deadline_s=None if i != 1 else 0.0)
        clock.t = 0.5
        svc.drain()
        snap = svc.snapshot()
        assert snap["schema"] == "repro.serve.metrics/v1"
        req = snap["requests"]
        assert req["admitted"] == (req["completed"] + req["shed_total"]
                                   + req["pending"])
        assert req["pending"] == 0
        # depth cap 3: submits 4 and 5 bounce; submit 2 expires queued
        assert req["shed"] == {"deadline_expired": 1, "queue_full": 2}
        assert snap["latency_s"]["overall"]["count"] == req["completed"]
        assert "interactive" in snap["latency_s"]
        assert snap["queue_depth"]["count"] >= 1
        assert snap["dispatches"] >= 1
        # the solver's stats (incl. per-leaf timings) come through whole
        assert snap["solver"]["device_dispatches"] >= 1
        assert any(k.startswith("dense_batch(")
                   for k in snap["solver"]["leaf_timings"])
        json.dumps(snap)                # JSON-clean end to end

    def test_leaf_timing_shape(self):
        clock = FakeClock()
        svc = service(clock)
        svc.submit(np.random.default_rng(8).uniform(-1, 1, (5, 5)),
                   deadline_s=None)
        svc.drain()
        (key, t), *_ = svc.solver.stats()["leaf_timings"].items()
        assert set(t) == {"count", "leaves", "total_s", "max_s", "mean_s"}
        assert t["count"] >= 1 and t["total_s"] > 0

    def test_metrics_http_endpoint(self):
        clock = FakeClock()
        svc = service(clock)
        server = start_metrics_server(svc.snapshot, port=0)
        try:
            port = server.server_address[1]
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
                snap = json.loads(r.read())
            assert snap["schema"] == "repro.serve.metrics/v1"
        finally:
            server.shutdown()

    def test_periodic_log_line(self):
        clock = FakeClock()
        lines = []
        svc = PermanentService(
            SolverConfig(backend="jnp"),
            ServiceConfig(max_batch=2, log_every_s=10.0),
            clock=clock, log=lines.append)
        svc.submit(np.eye(3), deadline_s=None)
        svc.step()
        assert not lines                # cadence not reached
        clock.t = 11.0
        svc.step()
        assert len(lines) == 1 and "p99=" in lines[0]


# -- solver-layer satellites --------------------------------------------------

class TestSolverSatellites:
    def test_solver_error_names_bucket_and_count(self, monkeypatch):
        solver = PermanentSolver(SolverConfig(backend="jnp",
                                              queue_max_batch=100))
        req = solver.submit(np.eye(4))
        monkeypatch.setattr(solver, "_flush_bucket", lambda n: 0)
        with pytest.raises(SolverError, match=r"n=4 left 1 request"):
            req.result()

    def test_solver_config_clock_injected(self):
        clock = FakeClock()
        solver = PermanentSolver(SolverConfig(
            backend="jnp", clock=clock, queue_max_batch=100,
            queue_max_delay_s=2.0))
        req = solver.submit(np.eye(3))
        assert solver.poll() == 0
        clock.t = 2.5
        assert solver.poll() == 1 and req.done

    def test_solver_config_clock_excluded_from_json(self):
        cfg = SolverConfig(backend="jnp", clock=FakeClock())
        plan = PermanentSolver(cfg).plan(np.eye(3))
        js = plan.to_json()              # dict; must be json-clean
        assert "clock" not in js["config"]
        json.dumps(js)
        # and the clock doesn't break plan equality/fingerprints
        assert cfg.replace(clock=None) == cfg

    def test_admission_hooks_fire(self):
        seen = {"submit": 0, "flush": []}
        solver = PermanentSolver(SolverConfig(backend="jnp",
                                              queue_max_batch=2))
        solver.on_submit = lambda req: seen.__setitem__(
            "submit", seen["submit"] + 1)
        solver.on_flush = lambda n, served, dt: seen["flush"].append(
            (n, served))
        solver.submit(np.eye(4))
        solver.submit(np.eye(4))        # fills the bucket -> flush
        assert seen["submit"] == 2
        assert seen["flush"] == [(4, 2)]


# -- soak helper --------------------------------------------------------------

class TestSoak:
    def test_run_soak_deterministic_clock(self):
        """Open-loop soak under a fake clock: every request resolves or
        sheds, forced expiries land as typed deadline sheds."""
        clock = FakeClock()
        svc = service(clock, max_batch=4)
        out = run_soak(svc, requests=12, rate_hz=1000.0, n=5,
                       repeat_pool=3, seed=9, expire_every=4, sleep=None)
        snap = out["snapshot"]
        req = snap["requests"]
        assert req["admitted"] == 12 + 0
        assert req["shed"] == {"deadline_expired": 3}
        assert req["completed"] == 9 and req["pending"] == 0
        assert snap["solver"]["cache"]["hits"] > 0   # repeat pool
        statuses = [("shed" if t.shed else "done") for t in out["tickets"]]
        assert statuses.count("shed") == 3

    def test_quantized_ladder(self):
        assert quantized_batches(8) == (1, 2, 4, 8)
        assert quantized_batches(6) == (1, 2, 4, 8)
        assert quantized_batches(1) == (1,)
        with pytest.raises(ValueError):
            quantized_batches(0)


# -- cold start / compile cache ----------------------------------------------

_SUB = r"""
import sys

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.solver import SolverConfig
from repro.serve import PermanentService, ServiceConfig, compile_stats

svc = PermanentService(
    SolverConfig(backend="jnp"),
    ServiceConfig(max_batch=4, compile_cache_dir=sys.argv[1],
                  warmup_ns=(6,), log_every_s=float("inf")),
    log=None)
warm = svc.warmup_report["compile"]
s0 = compile_stats()
t = svc.submit(np.random.default_rng(0).uniform(-1, 1, (6, 6)),
               deadline_s=None)
svc.step()
assert t.done
s1 = compile_stats()
print(f"STATS,warm_misses={warm['persistent_misses']},"
      f"warm_hits={warm['persistent_hits']},"
      f"first_misses={s1['persistent_misses'] - s0['persistent_misses']}")
"""


@pytest.mark.slow
def test_warm_compile_cache_cold_start(tmp_path):
    """Two cold processes sharing a compilation-cache dir: the second
    warms up without a single XLA compile, and neither compiles anything
    for its first dispatched bucket."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")

    def cold_run():
        r = subprocess.run(
            [sys.executable, "-c", _SUB, str(tmp_path / "xla-cache")],
            env=env, capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
        line = next(l for l in r.stdout.splitlines()
                    if l.startswith("STATS,"))
        return dict(kv.split("=") for kv in line[6:].split(","))

    run1, run2 = cold_run(), cold_run()
    assert int(run1["warm_misses"]) > 0          # cold cache: compiled
    assert int(run2["warm_misses"]) == 0         # warm cache: no compiles
    assert int(run2["warm_hits"]) > 0
    assert int(run1["first_misses"]) == 0        # warm-up covered the
    assert int(run2["first_misses"]) == 0        # first bucket's geometry


def test_campaign_backend_follows_solver_config(monkeypatch):
    """Regression (found by permlint's passthrough audit): the service's
    campaign waves must run under the solver's configured backend -- a
    pallas-configured service used to silently drop the kwarg and run
    jnp wave bodies."""
    from repro.core import distributed
    from repro.serve.loop import CampaignSpec

    captured = {}

    def fake_run_campaign(A, mesh, **kw):
        captured.update(kw)
        return 1.0, None

    monkeypatch.setattr(distributed, "run_campaign", fake_run_campaign)
    rng = np.random.default_rng(0)
    for solver_backend, expect in (("pallas", "pallas"), ("jnp", "jnp"),
                                   ("distributed", "jnp")):
        svc = PermanentService(
            SolverConfig(backend=solver_backend),
            ServiceConfig(max_batch=2, log_every_s=float("inf")),
            campaign=CampaignSpec(matrix=mk(rng, 8), waves=1),
            clock=FakeClock(), log=None)
        captured.clear()
        svc._advance_campaign(1)
        assert captured["backend"] == expect, solver_backend
        assert captured["precision"] == svc.solver.config.precision
