"""permprove (ISSUE 10): the IR verifier traces every entry clean
against the committed goldens, the drift gate catches a mutated engine
body, each PLI rule fires on its red input, sanctioned sites land in
the suppression inventory (never hidden), and the CLI contract holds.

Everything here is abstract tracing / compile-only -- no device data.
"""

import json
import os
import subprocess
import sys

from repro.analysis import contracts
from repro.analysis import ir
from repro.analysis.contracts import (ConvertRecord, ReduceRecord, Sanction,
                                      apply_sanctions, lines_batch_variant,
                                      pli101_reductions, pli102_dtype_flow,
                                      pli103_batch_invariance,
                                      pli104_collectives)
from repro.analysis.rules import Finding

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

ONE_ENTRY = "dense_jnp.f64.scalar"


def _entry(name):
    (e,) = [e for e in ir.ENTRIES if e.name == name]
    return e


# ---------------------------------------------------------------------------
# Tracing + canonical rendering
# ---------------------------------------------------------------------------

def test_entry_registry_covers_every_route():
    names = {e.name for e in ir.ENTRIES}
    assert len(names) == 20
    for route in ("dense", "sparse"):
        for engine in ("jnp", "pallas"):
            for dtype in ("f64", "c128"):
                for arity in ("scalar", "batch"):
                    assert f"{route}_{engine}.{dtype}.{arity}" in names
    for engine in ("jnp", "pallas"):
        for dtype in ("f64", "c128"):
            assert f"campaign_{engine}.{dtype}.wave" in names


def test_canonical_render_is_deterministic():
    import jax
    jax.config.update("jax_enable_x64", True)
    e = _entry(ONE_ENTRY)
    lines1 = ir.canonical_lines(ir.trace_entry(e, "dq_acc"))
    lines2 = ir.canonical_lines(ir.trace_entry(e, "dq_acc"))
    assert lines1 == lines2
    assert ir.fingerprint(lines1) == ir.fingerprint(lines2)
    # address-free: nothing like 0x7f... may leak into the goldens
    assert not any("0x" in ln for ln in lines1)


def test_precisions_trace_to_distinct_fingerprints():
    import jax
    jax.config.update("jax_enable_x64", True)
    e = _entry(ONE_ENTRY)
    fps = {p: ir.fingerprint(ir.canonical_lines(ir.trace_entry(e, p)))
           for p in ir.PRECISIONS}
    # the compensated-arithmetic variants emit genuinely different IR
    assert len(set(fps.values())) > 1


# ---------------------------------------------------------------------------
# The committed goldens: everything green (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_full_check_against_committed_goldens_is_clean():
    report = ir.run_check(with_mesh=False)
    assert [f.render() for f in report["findings"]] == []
    assert report["goldens"]["drifted"] == []
    assert report["goldens"]["missing"] == []
    assert report["goldens"]["skipped"] is None
    assert len(report["entries"]) == 20


def test_drift_gate_catches_mutated_engine_body(monkeypatch, tmp_path):
    """Mutate a traced body (replace the fixed-order twofloat tree sum
    with a raw reassociable sum) -> the fingerprint gate must fire with
    the entry named and a readable diff for the text precision."""
    import jax
    import jax.numpy as jnp
    from repro.core import ryser

    def raw_sum(hi, lo):
        # permlint: disable=PL001 -- deliberately-bad body for the test
        return jnp.sum(hi) + jnp.sum(lo), jnp.zeros(())

    monkeypatch.setattr(ryser, "tf_tree_sum", raw_sum)
    # the engine wraps its traced body in jax.jit; drop the warm trace so
    # the mutation is actually retraced (and again on the way out, so
    # later tests never see the poisoned cache entry)
    jax.clear_caches()
    try:
        report = ir.run_check(entries_pattern=ONE_ENTRY, with_mesh=False)
    finally:
        monkeypatch.undo()
        jax.clear_caches()
    drifted = report["goldens"]["drifted"]
    assert drifted, "mutated body must be reported as golden drift"
    assert all(d["entry"] == ONE_ENTRY for d in drifted)
    assert {d["precision"] for d in drifted} == set(ir.PRECISIONS)
    by_prec = {d["precision"]: d for d in drifted}
    text_drift = by_prec[ir.TEXT_PRECISION]
    assert text_drift["diff"] and "---" in text_drift["diff"]
    assert text_drift["want"] != text_drift["got"]


def test_bless_round_trip(tmp_path):
    gdir = str(tmp_path / "goldens")
    blessed = ir.bless(entries_pattern=ONE_ENTRY, golden_dir=gdir)
    assert blessed["goldens"]["blessed"] == [ONE_ENTRY]
    gpath = ir.golden_path(_entry(ONE_ENTRY), gdir)
    assert os.path.exists(gpath)
    # re-checking against the fresh bless is clean
    report = ir.run_check(entries_pattern=ONE_ENTRY, golden_dir=gdir,
                          with_mesh=False)
    assert report["goldens"]["drifted"] == []
    assert report["goldens"]["missing"] == []
    # parse/render round-trip preserves every section
    with open(gpath, encoding="utf-8") as f:
        text = f.read()
    gold = ir.parse_golden(text)
    assert set(gold["sections"]) == set(ir.PRECISIONS)
    for prec, (fp, lines) in gold["sections"].items():
        assert len(fp) == 16
        if prec == ir.TEXT_PRECISION:
            assert lines and ir.fingerprint(lines) == fp
        else:
            assert lines is None


def test_missing_golden_is_reported(tmp_path):
    report = ir.run_check(entries_pattern=ONE_ENTRY,
                          golden_dir=str(tmp_path / "empty"),
                          with_mesh=False)
    assert report["goldens"]["missing"] == [ONE_ENTRY]


def test_jax_version_skew_skips_fingerprint_gate_loudly(tmp_path):
    gdir = str(tmp_path / "goldens")
    ir.bless(entries_pattern=ONE_ENTRY, golden_dir=gdir)
    gpath = ir.golden_path(_entry(ONE_ENTRY), gdir)
    with open(gpath, encoding="utf-8") as f:
        text = f.read()
    with open(gpath, "w", encoding="utf-8") as f:
        f.write(text.replace(f"jax: {ir._jax_version()}", "jax: 0.0.0"))
    report = ir.run_check(entries_pattern=ONE_ENTRY, golden_dir=gdir,
                          with_mesh=False)
    # skipped is a loud marker, not a silent pass...
    assert "0.0.0" in report["goldens"]["skipped"]
    # ...and no phantom drift is invented
    assert report["goldens"]["drifted"] == []


# ---------------------------------------------------------------------------
# PLI rules fire on red inputs
# ---------------------------------------------------------------------------

def test_pli102_flags_float_truncation_only():
    reds = [ConvertRecord(index=3, src="f64", dst="f32"),      # truncation
            ConvertRecord(index=4, src="c128", dst="c64"),     # truncation
            ConvertRecord(index=5, src="f32", dst="f64"),      # widening ok
            ConvertRecord(index=6, src="i64", dst="i32"),      # int: not ours
            ConvertRecord(index=7, src="f64", dst="pred")]     # bool: not ours
    out = pli102_dtype_flow("e", reds, "dq_acc")
    assert [f.line for f in out] == [3, 4]
    assert all(f.rule == "PLI102" for f in out)


def test_pli103_allows_only_b_proportional_extents():
    # 10 = 2*B at B=5 vs 14 = 2*B at B=7: sanctioned scaling
    assert lines_batch_variant("v1:f64[10,6] = foo v0",
                               "v1:f64[14,6] = foo v0", 5, 7)
    # a constant equal to B in one trace but literal in the other: flagged
    assert not lines_batch_variant("v1 = add lit(5:i32) v0",
                                   "v1 = add lit(5:i32) v2", 5, 7)
    # floats must not be tokenized as integers
    assert lines_batch_variant("v1 = mul lit(1.5:f64) v0",
                               "v1 = mul lit(1.5:f64) v0", 5, 7)
    out = pli103_batch_invariance(
        "e", "dd", ["x = foo[sz=10]", "y = bar"],
        ["x = foo[sz=11]", "y = bar"], 5, 7)
    assert len(out) == 1 and out[0].rule == "PLI103"
    # structural divergence (different line counts) is one loud finding
    out = pli103_batch_invariance("e", "dd", ["a", "b"], ["a"], 5, 7)
    assert len(out) == 1 and "program shape depends" in out[0].message


def test_pli101_flags_batch_tracking_reductions():
    pinned = ReduceRecord(0, "reduce_sum", "f64", (16,))
    batchy_a = ReduceRecord(1, "reduce_sum", "f64", (5,))
    batchy_b = ReduceRecord(1, "reduce_sum", "f64", (7,))
    out = pli101_reductions("e", "dd", [pinned, batchy_a],
                            [pinned, batchy_b], 5, 7)
    assert len(out) == 1
    assert out[0].rule == "PLI101" and out[0].line == 1
    # record-count mismatch: PLI103 owns it, PLI101 must not cascade
    assert pli101_reductions("e", "dd", [pinned], [], 5, 7) == []
    # pinned extents (plan geometry) never fire
    assert pli101_reductions("e", "dd", [pinned], [pinned], 5, 7) == []


_HLO = """\
HloModule m
ENTRY e {
  %p = f64[8]{0} parameter(0)
  %ar = f64[8]{0} all-reduce(%p), to_apply=%add
  ROOT %t = f64[8]{0} tanh(%ar)
}
"""


def test_pli104_budget_in_budget_is_suppressed_not_hidden():
    out = pli104_collectives("prog", _HLO, {"all-reduce": 2})
    assert len(out) == 1 and out[0].suppressed
    assert "within budget" in out[0].message


def test_pli104_over_budget_and_unknown_kind_are_active():
    over = pli104_collectives("prog", _HLO, {"all-reduce": 0})
    assert len(over) == 1 and not over[0].suppressed
    assert "sanctioned max 0" in over[0].message
    banned = pli104_collectives("prog", _HLO, {})
    assert len(banned) == 1 and not banned[0].suppressed
    assert "unsanctioned collective kind" in banned[0].message


def test_sanctions_move_findings_into_inventory(monkeypatch):
    f = Finding("PLI102", "dense_jnp.f64.scalar", 3, 0,
                "value path truncates f64->f32")
    active, supp = apply_sanctions([f])
    assert active == [f] and supp == []
    monkeypatch.setattr(contracts, "SANCTIONED", (Sanction(
        rule="PLI102", entry="dense_jnp.*", match="truncates f64->f32",
        reason="test"),))
    active, supp = apply_sanctions([f])
    assert active == []
    assert len(supp) == 1 and supp[0].suppressed
    assert "[sanctioned: test]" in supp[0].message


def test_run_check_inventories_presuppressed_findings(tmp_path,
                                                      monkeypatch):
    """PLI104's in-budget findings arrive pre-suppressed; run_check must
    carry them into the report's suppression inventory."""
    monkeypatch.setattr(
        ir, "_mesh_programs",
        lambda log=None: [("prog", _HLO, {"all-reduce": 2})])
    ir.bless(entries_pattern=ONE_ENTRY,
             golden_dir=str(tmp_path / "g"))
    report = ir.run_check(entries_pattern=ONE_ENTRY,
                          golden_dir=str(tmp_path / "g"), with_mesh=True)
    assert report["findings"] == []
    assert report["mesh"]["checked"] == 1
    assert any(s.rule == "PLI104" and s.suppressed
               for s in report["suppressions"])


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def test_cli_usage_and_bad_pattern_exit_2(capsys):
    assert ir.main([]) == 2
    assert ir.main(["--check", "--entries", "no_such_entry*"]) == 2
    capsys.readouterr()


def test_cli_check_one_entry_in_process_exits_0(capsys):
    rc = ir.main(["--check", "--entries", ONE_ENTRY, "--no-mesh", "-q"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 finding(s)" in out


def test_cli_missing_goldens_exit_1(tmp_path, capsys):
    rc = ir.main(["--check", "--entries", ONE_ENTRY, "--no-mesh", "-q",
                  "--goldens", str(tmp_path / "empty")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "GOLDEN MISSING" in out


def test_cli_full_check_as_subprocess(tmp_path):
    """The acceptance criterion, exercised exactly as CI runs it: the
    __main__ path forces 8 host devices, so the PLI104 collective audit
    runs against a real (host) mesh."""
    report_path = str(tmp_path / "ir_report.json")
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.ir", "--check", "-q",
         "--report", report_path],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-3000:]
    assert "0 finding(s)" in proc.stdout
    with open(report_path, encoding="utf-8") as f:
        report = json.load(f)
    assert report["version"] == "permprove/1"
    assert report["findings"] == []
    assert len(report["entries"]) == 20
    # the mesh audit really ran (not silently skipped)...
    assert report["mesh"]["checked"] == 6
    assert report["mesh"]["skipped"] is None
    # ...and the deliberate (hi, lo) psum pairs are inventoried
    pli104 = [s for s in report["suppressions"] if s["rule"] == "PLI104"]
    assert len(pli104) == 2
    assert all("within budget" in s["message"] for s in pli104)
