"""Distributed permanent runtime: shard_map path, checkpoint, elasticity.

Multi-device coverage runs in subprocesses (XLA_FLAGS must be set before
jax initializes; the main test process keeps 1 device per the smoke-test
contract).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import distributed, oracle, resume

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_single_device_mesh_path():
    mesh = jax.make_mesh((1,), ("data",))
    A = np.random.default_rng(0).uniform(-1, 1, (10, 10))
    ref = oracle.perm_ryser_exact(A)
    got = float(distributed.permanent_on_mesh(A, mesh, lanes_per_device=16))
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_plan_slices_covers_space():
    for n in [8, 12, 20, 33, 56]:
        for d in [1, 8, 256, 512]:
            ts, cps, C = distributed.plan_slices(n, d)
            assert ts * cps * C == 1 << (n - 1)
            assert C >= 2 and (C & (C - 1)) == 0


def _run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    full = textwrap.dedent("""
        import jax; jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core import distributed, oracle
    """) + textwrap.dedent(code)
    r = subprocess.run([sys.executable, "-c", full], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_multi_device_matches_oracle():
    out = _run_sub("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        A = np.random.default_rng(5).uniform(-1, 1, (12, 12))
        ref = oracle.perm_ryser_exact(A)
        got = float(distributed.permanent_on_mesh(A, mesh, lanes_per_device=16))
        assert np.isclose(got, ref, rtol=1e-10), (got, ref)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_three_axis_pod_mesh():
    out = _run_sub("""
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        A = np.random.default_rng(6).uniform(-1, 1, (11, 11))
        ref = oracle.perm_ryser_exact(A)
        got = float(distributed.permanent_on_mesh(A, mesh, lanes_per_device=8))
        assert np.isclose(got, ref, rtol=1e-10), (got, ref)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_pallas_backend_matches_oracle():
    out = _run_sub("""
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        A = np.random.default_rng(11).uniform(-1, 1, (13, 13))
        ref = oracle.perm_ryser_exact(A)
        for be in ("jnp", "pallas"):
            got = float(distributed.permanent_on_mesh(
                A, mesh, lanes_per_device=32, backend=be))
            assert np.isclose(got, ref, rtol=1e-9), (be, got, ref)
        print("OK")
    """, devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_checkpoint_restart_elastic():
    out = _run_sub("""
        import tempfile, os
        mesh = jax.make_mesh((8,), ("data",))
        A = np.random.default_rng(7).uniform(-1, 1, (12, 12))
        ref = oracle.perm_ryser_exact(A)
        with tempfile.TemporaryDirectory() as d:
            ckpt = os.path.join(d, "job.npz")
            r1 = distributed.DistributedPermanent(
                mesh, slices_per_device=2, lanes_per_device=8,
                checkpoint_path=ckpt)
            class Stop(Exception): pass
            calls = []
            def cb(s):
                calls.append(s.fraction_done())
                if len(calls) == 1: raise Stop
            try: r1.permanent(A, progress_cb=cb)
            except Stop: pass
            assert 0 < calls[-1] < 1
            # resume with fewer devices (elastic restart after 'failure')
            mesh2 = jax.make_mesh((2,), ("data",))
            r2 = distributed.DistributedPermanent(
                mesh2, slices_per_device=8, lanes_per_device=8,
                checkpoint_path=ckpt)
            got = r2.permanent(A)
            assert np.isclose(got, ref, rtol=1e-10), (got, ref)
        print("OK")
    """)
    assert "OK" in out


def test_jobstate_roundtrip(tmp_path):
    A = np.random.default_rng(1).uniform(-1, 1, (8, 8))
    st = resume.JobState.create(A, 16)
    st.record_wave([0, 3, 5], [1.0, 2.0, 3.0], [0.0, 1e-20, 0.0])
    p = str(tmp_path / "s.npz")
    st.save(p)
    st2 = resume.JobState.load(p)
    assert st2.pending_slices() == [i for i in range(16) if i not in (0, 3, 5)]
    hi, lo = st2.reduce()
    assert abs(hi - 6.0) < 1e-12


def test_jobstate_rejects_wrong_matrix(tmp_path):
    A = np.random.default_rng(1).uniform(-1, 1, (8, 8))
    B = A + 1e-9
    st = resume.JobState.create(A, 4)
    p = str(tmp_path / "s.npz")
    st.save(p)
    with pytest.raises(ValueError):
        resume.JobState.load_or_create(p, B, 4)
