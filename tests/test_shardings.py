"""Sharding rules: param specs (TP/FSDP/serve), batch/cache specs, actx."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import actx
from repro.models import shardings as SH
from repro.models.model import ShapeCell, build


@pytest.fixture(autouse=True)
def _mesh_sizes():
    SH.set_mesh_sizes({"pod": 2, "data": 16, "model": 16})


def _leaf(specs, *path):
    node = specs
    for k in path:
        node = node[k]
    return node


def test_dense_param_specs_tp():
    cfg = get_config("command-r-35b")
    specs = SH.param_specs(cfg, build(cfg).param_shapes(),
                           fsdp=("data",), mdl="model")
    assert _leaf(specs, "embed") == P("model", "data")
    assert _leaf(specs, "unembed") == P("data", "model")
    # scanned stack: leading None
    assert _leaf(specs, "layers", "attn", "wq") == P(None, "data", "model")
    assert _leaf(specs, "layers", "attn", "wo") == P(None, "model", "data")
    assert _leaf(specs, "layers", "ffn", "wi") == P(None, "data", "model")
    assert _leaf(specs, "layers", "ln1", "w") == P(None, None)


def test_moe_ep_vs_tp_fallback():
    phi = get_config("phi3.5-moe-42b-a6.6b")   # 16 experts: EP
    specs = SH.param_specs(phi, build(phi).param_shapes(),
                           fsdp=("data",), mdl="model", mdl_size=16)
    assert _leaf(specs, "layers", "ffn", "wi") == P(None, "model", "data",
                                                    None)
    mix = get_config("mixtral-8x22b")           # 8 experts: TP fallback
    specs = SH.param_specs(mix, build(mix).param_shapes(),
                           fsdp=("data",), mdl="model", mdl_size=16)
    assert _leaf(specs, "layers", "ffn", "wi") == P(None, None, "data",
                                                    "model")


def test_serve_mode_keeps_weights_resident():
    cfg = get_config("command-r-35b")
    specs = SH.param_specs(cfg, build(cfg).param_shapes(),
                           fsdp=("data",), mdl="model", serve=True)
    # no data-axis (FSDP) sharding on dense weights in serve mode
    assert _leaf(specs, "layers", "attn", "wq") == P(None, None, "model")
    assert _leaf(specs, "layers", "ffn", "wo") == P(None, "model", None)
    # but MoE expert tables keep the data axis (memory)
    mix = get_config("mixtral-8x22b")
    specs = SH.param_specs(mix, build(mix).param_shapes(),
                           fsdp=("data",), mdl="model", serve=True)
    assert "data" in tuple(_leaf(specs, "layers", "ffn", "wi"))


def test_fsdp_strategy_specs():
    cfg = get_config("stablelm-3b")
    specs = SH.param_specs(cfg, build(cfg).param_shapes(),
                           fsdp=("data", "model"), mdl=None, mdl_size=1)
    wq = _leaf(specs, "layers", "attn", "wq")
    assert wq == P(None, ("data", "model"), None)


def test_divisibility_fallback_drops_axis():
    cfg = get_config("stablelm-3b").reduced(d_model=24)  # 24 % 256 != 0
    specs = SH.param_specs(cfg, build(cfg).param_shapes(),
                           fsdp=("data", "model"), mdl=None, mdl_size=1)
    # fsdp over 256 does not divide 24 -> replicated
    assert _leaf(specs, "layers", "attn", "wq")[1] is None


def test_batch_and_cache_specs():
    cfg = get_config("command-r-35b")
    model = build(cfg)
    cell = ShapeCell("d", "decode", 32768, 128)
    b = SH.batch_specs(cfg, model.input_specs(cell), dp=("data",))
    assert b["token"] == P("data", None)
    assert b["pos"] == P()
    c = SH.cache_specs_sharding(cfg, model.cache_specs(cell), dp=("data",),
                                seq_sharded=True)
    assert c["k"] == P(None, "data", "model", None, None)
    c2 = SH.cache_specs_sharding(cfg, model.cache_specs(cell), dp=None,
                                 seq_sharded=False)
    assert c2["k"] == P(None, None, None, "model", None)


def test_actx_noop_without_context():
    x = jnp.ones((4, 8, 16))
    assert actx.batch_act(x) is x


def test_actx_constrains_under_context():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.ones((4, 8, 16))
    with actx.use(mesh, ("data",), "data"):
        y = actx.batch_act(x)
    assert y.shape == x.shape  # constraint applied without error


def test_actx_divisibility_per_dim_fallback():
    mesh = jax.make_mesh((1,), ("data",))
    # dim 3 not divisible by nothing (size-1 axes divide everything);
    # exercise the per-dim path with a fake 2-device requirement
    with actx.use(mesh, ("data",), "data"):
        y = actx.constrain(jnp.ones((3, 5)), actx.DP, None)
    assert y.shape == (3, 5)
