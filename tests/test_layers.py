"""Layer library: flash attention vs naive softmax, MoE dispatch, RoPE."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_valid=None):
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k) / math.sqrt(hd)
    qp = q_offset + jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
        if window:
            mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kp < kv_valid
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


@pytest.mark.parametrize("Sq,Sk,H,KVH,kc", [(16, 16, 4, 4, 8),
                                            (16, 16, 4, 2, 4),
                                            (32, 32, 8, 1, 16),
                                            (8, 24, 4, 4, 16)])
def test_flash_matches_naive(Sq, Sk, H, KVH, kc):
    rng = np.random.default_rng(0)
    B, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KVH, hd)), jnp.float32)
    causal = Sq == Sk
    got = L.flash_attention(q, k, v, causal=causal, kv_chunk=kc)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_sliding_window():
    rng = np.random.default_rng(1)
    B, S, H, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    got = L.flash_attention(q, k, v, causal=True, window=8, kv_chunk=8)
    want = naive_attention(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_decode_with_partial_cache():
    rng = np.random.default_rng(2)
    B, S, H, hd = 2, 24, 2, 8
    pos = 13
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    got = L.flash_attention(q, k, v, causal=True, q_offset=pos,
                            kv_valid=pos + 1, kv_chunk=8)
    want = naive_attention(q, k, v, causal=True, q_offset=pos,
                           kv_valid=pos + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_partial_softmax_merge_equals_full():
    """flash-decoding: sharded partial stats merged == unsharded result."""
    rng = np.random.default_rng(3)
    B, S, H, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    full = L.flash_attention(q, k, v, causal=True, q_offset=S - 1,
                             kv_valid=S, kv_chunk=8)
    # two half-shards with explicit merge math
    m1, l1, a1 = L.flash_attention_partial(
        q, k[:, :16], v[:, :16], q_offset=S - 1, kv_offset=0, kv_valid=S)
    m2, l2, a2 = L.flash_attention_partial(
        q, k[:, 16:], v[:, 16:], q_offset=S - 1, kv_offset=16, kv_valid=S)
    mg = jnp.maximum(m1, m2)
    lg = l1 * jnp.exp(m1 - mg) + l2 * jnp.exp(m2 - mg)
    ag = a1 * jnp.exp(m1 - mg)[..., None] + a2 * jnp.exp(m2 - mg)[..., None]
    out = (ag / lg[..., None]).transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- MoE
def naive_moe(x, router_w, wi, wg, wo, top_k):
    N, D = x.shape
    E = router_w.shape[1]
    probs = jax.nn.softmax(x @ router_w, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for i in range(N):
        acc = jnp.zeros((D,))
        for j in range(top_k):
            e = int(top_e[i, j])
            h = x[i] @ wi[e]
            g = jax.nn.silu(x[i] @ wg[e])
            acc += top_w[i, j] * ((h * g) @ wo[e])
        out = out.at[i].set(acc)
    return out


def test_moe_matches_naive_when_capacity_ample():
    rng = np.random.default_rng(4)
    B, S, D, F, E, k = 2, 8, 16, 32, 4, 2
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    rw = jnp.asarray(rng.normal(size=(D, E)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(E, F, D)) * 0.1, jnp.float32)
    got = L.moe_ffn(x, rw, wi, wg, wo, top_k=k, capacity_factor=8.0)
    want = naive_moe(x.reshape(-1, D), rw, wi, wg, wo, k).reshape(B, S, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_tokens_not_correctness():
    rng = np.random.default_rng(5)
    B, S, D, F, E = 1, 16, 8, 16, 2
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    rw = jnp.zeros((D, E), jnp.float32)  # uniform router -> balanced-ish
    wi = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(E, F, D)) * 0.1, jnp.float32)
    out = L.moe_ffn(x, rw, wi, wg, wo, top_k=1, capacity_factor=0.25)
    assert np.isfinite(np.asarray(out)).all()
    # with tiny capacity some outputs must be exactly zero (dropped)
    assert (np.abs(np.asarray(out)).sum(-1) == 0).any()


def test_moe_aux_losses():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    rw = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(4, 16, 32)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(4, 16, 32)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(4, 32, 16)) * 0.1, jnp.float32)
    out, aux = L.moe_ffn(x, rw, wi, wg, wo, top_k=2, capacity_factor=2.0,
                         return_aux=True)
    assert float(aux["load_balance"]) >= 1.0 - 1e-6  # >= 1 by Cauchy-Schwarz
    assert float(aux["router_z"]) > 0


# ---------------------------------------------------------------- RoPE
def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(7)
    B, S, H, hd = 1, 16, 2, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(pos, hd)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot_at(p, d):
        cq, sq = L.rope_cos_sin(jnp.full((1, 1), p), hd)
        ck, sk = L.rope_cos_sin(jnp.full((1, 1), p + d), hd)
        return float(jnp.sum(L.apply_rope(q, cq, sq)
                             * L.apply_rope(k, ck, sk)))

    assert abs(dot_at(0, 3) - dot_at(7, 3)) < 1e-4


def test_mrope_sections():
    B, S, hd = 1, 8, 16
    pos3 = jnp.stack([jnp.broadcast_to(jnp.arange(S)[None], (B, S))] * 3)
    cos, sin = L.mrope_cos_sin(pos3, hd, (2, 3, 3))
    assert cos.shape == (B, S, hd // 2)
    # identical position streams == plain rope
    c2, s2 = L.rope_cos_sin(pos3[0], hd, theta=1e6)
    np.testing.assert_allclose(np.asarray(cos), np.asarray(c2), rtol=1e-6)


def test_grad_cast_dtype():
    x = jnp.ones((4,), jnp.bfloat16)

    def f(x):
        return jnp.sum(L.grad_cast(x).astype(jnp.float32) ** 2)

    g = jax.grad(f)(x)
    assert g.dtype == jnp.bfloat16
