"""Optimizer, data pipeline, checkpointing, train loop integration."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import (latest_step, load_pytree,
                                   restore_train_state, save_pytree,
                                   save_train_state)
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.model import ShapeCell, build
from repro.train.data import SyntheticLM
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   lr_schedule)
from repro.train.train_step import build_train_step, decode_kv_policy


# ----------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw of ||w||^2
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    p2, _, m = adamw_update(cfg, params, grads, state)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 1.1  # step bounded despite 1e6 grads


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           [0, 5, 10, 50, 99]]
    assert lrs[0] < lrs[1] < lrs[2]           # warmup rises
    assert lrs[2] > lrs[3] > lrs[4]           # cosine decays
    assert lrs[4] >= 0.099                    # floor


def test_no_weight_decay_on_norms():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1,
                      total_steps=10)
    params = {"ln1": {"w": jnp.ones(4)}, "ffn": {"wi": jnp.ones((4, 4))}}
    state = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, params, zeros, state)
    assert float(jnp.abs(p2["ln1"]["w"] - 1).max()) < 1e-6  # no decay
    assert float(jnp.abs(p2["ffn"]["wi"] - 1).max()) > 1e-3  # decayed


# ----------------------------------------------------------------- data
def test_synthetic_stream_deterministic():
    cfg = get_config("stablelm-3b").reduced()
    cell = ShapeCell("t", "train", 32, 4)
    s1 = SyntheticLM(cfg, cell, seed=7).host_batch(3)
    s2 = SyntheticLM(cfg, cell, seed=7).host_batch(3)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    s3 = SyntheticLM(cfg, cell, seed=8).host_batch(3)
    assert not np.array_equal(s1["tokens"], s3["tokens"])
    # labels are inputs shifted by one
    full = SyntheticLM(cfg, cell, seed=7)._tokens(3, 0, 4, 32)
    np.testing.assert_array_equal(s1["labels"], full[:, 1:])


# ----------------------------------------------------------------- ckpt
def test_pytree_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    p = str(tmp_path / "x.npz")
    save_pytree(p, tree, extra={"step": 5})
    got, extra = load_pytree(p, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert int(extra["step"]) == 5


def test_train_state_keep_last(tmp_path):
    params = {"w": jnp.ones(3)}
    opt = adamw_init(params)
    for s in [10, 20, 30, 40]:
        save_train_state(str(tmp_path), s, params, opt, keep=2)
    assert latest_step(str(tmp_path)) == 40
    restored = restore_train_state(str(tmp_path), params, opt)
    assert restored is not None and restored[2] == 40


# ----------------------------------------------------------------- loop
def test_train_loop_learns_and_resumes(tmp_path):
    from repro.launch.train import run_training
    _, _, h1 = run_training("starcoder2-3b", steps=10, seq=32,
                            global_batch=2, reduced=True,
                            ckpt_dir=str(tmp_path), ckpt_every=5)
    assert latest_step(str(tmp_path)) == 10
    # resume continues from step 10 without redoing earlier steps
    _, _, h2 = run_training("starcoder2-3b", steps=12, seq=32,
                            global_batch=2, reduced=True,
                            ckpt_dir=str(tmp_path), ckpt_every=5)
    assert h2[0][0] >= 10


def test_microbatch_grad_accum_matches_full_batch():
    cfg = get_config("stablelm-3b").reduced()
    model = build(cfg)
    mesh = make_local_mesh()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    b_full = build_train_step(model, mesh, opt_cfg, donate=False)
    b_micro = build_train_step(model, mesh, opt_cfg, microbatch=2,
                               donate=False)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    p1, _, m1 = b_full.step_fn(params, opt, batch)
    p2, _, m2 = b_micro.step_fn(params, opt, batch)
    # losses agree exactly; updated params agree to accumulation tolerance
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-4, d


def test_decode_kv_policy_rules():
    mesh = make_local_mesh(model_axis=1)
    assert decode_kv_policy(get_config("mamba2-370m"), mesh) == "state"
    # single-device model axis: everything divides
    assert decode_kv_policy(get_config("command-r-35b"), mesh) == "heads"
