"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + prefill/decode on CPU; shapes + finiteness asserted.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, FULL_ATTENTION_ARCHS, get_config
from repro.models.model import Model, build

RNG = np.random.default_rng(0)
SMOKE_SEQ = 32
SMOKE_BATCH = 2


def _smoke_batch(model: Model, kind: str):
    c = model.cfg
    B, S, D = SMOKE_BATCH, SMOKE_SEQ, c.d_model
    t = lambda shape: jnp.asarray(RNG.integers(0, c.vocab, shape), jnp.int32)
    e = lambda shape: jnp.asarray(RNG.normal(size=shape) * 0.02, c.dtype)
    if kind == "train":
        if c.family == "vlm":
            pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S)).copy()
            return {"embeds": e((B, S, D)),
                    "positions": jnp.asarray(pos, jnp.int32),
                    "labels": t((B, S))}
        if c.family == "audio-encdec":
            return {"enc_embeds": e((B, S, D)), "dec_tokens": t((B, S)),
                    "labels": t((B, S))}
        return {"tokens": t((B, S)), "labels": t((B, S))}
    if kind == "prefill":
        if c.family == "vlm":
            pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S)).copy()
            return {"embeds": e((B, S, D)),
                    "positions": jnp.asarray(pos, jnp.int32)}
        if c.family == "audio-encdec":
            return {"enc_embeds": e((B, S, D))}
        return {"tokens": t((B, S))}
    raise ValueError(kind)


@pytest.fixture(scope="module")
def models():
    return {a: build(get_config(a).reduced()) for a in ARCH_IDS}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    """Exact published numbers from the assignment table."""
    expect = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }[arch]
    c = get_config(arch)
    assert c.n_layers == expect[0] and c.d_model == expect[1]
    if expect[2] is not None:
        assert c.n_heads == expect[2] and c.n_kv_heads == expect[3]
    assert c.d_ff == expect[4] and c.vocab == expect[5]
    if arch == "mamba2-370m":
        assert c.ssm_state == 128
    if arch == "zamba2-1.2b":
        assert c.ssm_state == 64
    if arch == "phi3.5-moe-42b-a6.6b":
        assert c.n_experts == 16 and c.top_k == 2
    if arch == "mixtral-8x22b":
        assert c.n_experts == 8 and c.top_k == 2 and c.swa_window > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(models, arch):
    model = models[arch]
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _smoke_batch(model, "train")
    loss, grads = jax.value_and_grad(model.loss_fn())(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch} bad grad norm {gn}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(models, arch):
    model = models[arch]
    c = model.cfg
    params = model.init_params(jax.random.PRNGKey(2))
    batch = _smoke_batch(model, "prefill")
    max_seq = SMOKE_SEQ + 4
    h, cache = model.prefill_fn(max_seq)(params, batch)
    assert np.isfinite(np.asarray(h, dtype=np.float32)).all()
    decode = model.decode_fn()
    tok = jnp.asarray(RNG.integers(0, c.vocab, (SMOKE_BATCH, 1)), jnp.int32)
    inputs = {"token": tok, "pos": jnp.int32(SMOKE_SEQ)}
    if c.family == "vlm":
        inputs["positions"] = jnp.full((3, SMOKE_BATCH, 1), SMOKE_SEQ,
                                       jnp.int32)
    logits, new_cache = decode(params, inputs, cache)
    assert logits.shape == (SMOKE_BATCH, 1, c.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch} logits NaN"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_and_cache_specs_defined(arch):
    model = build(get_config(arch))
    for name in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
        from repro.models.model import SHAPES
        cell = SHAPES[name]
        if name == "long_500k" and arch in FULL_ATTENTION_ARCHS:
            continue  # skipped cell (documented in DESIGN.md)
        specs = model.input_specs(cell)
        assert all(isinstance(s, jax.ShapeDtypeStruct)
                   for s in jax.tree.leaves(specs))
        if cell.kind == "decode":
            cache = model.cache_specs(cell)
            assert all(isinstance(s, jax.ShapeDtypeStruct)
                       for s in jax.tree.leaves(cache))
        assert model.model_flops(cell) > 0


def test_param_counts_plausible():
    """Full-config param counts should be in the advertised ballpark."""
    expect = {
        "command-r-35b": (30e9, 40e9),
        "granite-34b": (30e9, 40e9),
        "qwen2-vl-72b": (60e9, 80e9),
        "mixtral-8x22b": (120e9, 150e9),   # total (not active)
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "stablelm-3b": (2e9, 4e9),
        "starcoder2-3b": (2.5e9, 3.6e9),
        "seamless-m4t-large-v2": (1.4e9, 2.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build(get_config(arch)).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_less_than_total():
    m = build(get_config("mixtral-8x22b"))
    assert m.n_active_params() < 0.45 * m.n_params()
