"""Result-cache microbenchmark: cache-hit perms/sec vs cold dispatch.

Boson-sampling pipelines resample overlapping submatrices, so a serving
stream contains many repeats of few distinct matrices.  This benchmark
builds such a stream (``requests`` draws from ``unique`` distinct n x n
matrices), then compares:

* **cold**   -- stateless ``engine.permanent_batch`` over the stream
  (every repeat recomputed on device; the pre-solver serving shape);
* **solver** -- ``PermanentSolver.plan_batch`` + ``execute`` with a fresh
  result cache (repeats resolve from the content-hash cache, only the
  distinct leaves touch the device);
* **warm**   -- a second solver pass over the same stream (every leaf a
  cache hit: the steady-state resampling regime).

Acceptance gate (ISSUE 2): the fresh-cache solver pass must deliver
>= 2x the cold perms/sec on the repeated stream.

    PYTHONPATH=src python -m benchmarks.solver_cache [--n 12] [--requests 256]
    PYTHONPATH=src python -m benchmarks.run --only solver_cache --check
"""

from __future__ import annotations

import argparse
import time

import numpy as np

SPEEDUP_GATE = 2.0


def _time(fn, repeats: int = 3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def run(n: int = 12, requests: int = 256, unique: int = 16,
        precision: str = "dq_acc", backend: str = "jnp",
        repeats: int = 3, seed: int = 0):
    from repro.core import engine
    from repro.core.solver import PermanentSolver, SolverConfig

    rng = np.random.default_rng(seed)
    pool = [rng.uniform(-1, 1, (n, n)) for _ in range(unique)]
    stream = [pool[i] for i in rng.integers(0, unique, requests)]
    cfg = SolverConfig(precision=precision, backend=backend,
                       cache_entries=max(4096, requests))

    # warm the jitted bucket programs (both the full-stream and the
    # deduped-unique batch shapes) so every timed pass sees the same
    # compiled state -- we measure dispatch, not tracing
    engine.permanent_batch(stream, precision=precision, backend=backend)
    engine.permanent_batch(pool, precision=precision, backend=backend)

    cold_vals = None

    def cold():
        nonlocal cold_vals
        cold_vals = engine.permanent_batch(stream, precision=precision,
                                           backend=backend)

    cold_s = _time(cold, repeats)

    solver_vals = None
    fresh_stats = None

    def fresh_cache():
        nonlocal solver_vals, fresh_stats
        solver = PermanentSolver(cfg)     # cold cache every repeat
        solver_vals = solver.execute(solver.plan_batch(stream))
        fresh_stats = solver.stats()

    fresh_s = _time(fresh_cache, repeats)

    warm_solver = PermanentSolver(cfg)
    warm_plan = warm_solver.plan_batch(stream)
    warm_solver.execute(warm_plan)        # populate the cache
    warm_s = _time(lambda: warm_solver.execute(warm_plan), repeats)

    np.testing.assert_allclose(solver_vals, cold_vals, rtol=1e-9,
                               atol=1e-12)
    cold_pps = requests / cold_s
    fresh_pps = requests / fresh_s
    warm_pps = requests / warm_s
    return [{"n": n, "requests": requests, "unique": unique,
             "cold_perms_per_s": f"{cold_pps:.0f}",
             "solver_perms_per_s": f"{fresh_pps:.0f}",
             "warm_perms_per_s": f"{warm_pps:.0f}",
             "speedup": f"{fresh_pps / cold_pps:.2f}",
             "warm_speedup": f"{warm_pps / cold_pps:.2f}",
             "hit_rate": f"{fresh_stats['cache']['hit_rate']:.2f}",
             "device_dispatches": fresh_stats["device_dispatches"]}]


def check(rows) -> bool:
    """ISSUE-2 acceptance gate: fresh-cache solver >= 2x cold dispatch."""
    speedup = float(rows[0]["speedup"])
    ok = speedup >= SPEEDUP_GATE
    status = "OK" if ok else "FAIL"
    print(f"# solver_cache gate: {speedup:.2f}x vs required "
          f"{SPEEDUP_GATE:.1f}x -- {status}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--unique", type=int, default=16)
    ap.add_argument("--precision", default="dq_acc")
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--check", action="store_true",
                    help="enforce the >= 2x acceptance gate")
    args = ap.parse_args()

    import jax
    jax.config.update("jax_enable_x64", True)

    rows = run(n=args.n, requests=args.requests, unique=args.unique,
               precision=args.precision, backend=args.backend)
    for r in rows:
        print("solver_cache," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
