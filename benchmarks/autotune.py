"""Autotuner gate: tuned geometry >= untuned on every kernel route.

ISSUE 9's tentpole gate.  A forced-8-device subprocess tunes the
``dense`` / ``complex`` / ``sparse`` batch routes and the ``campaign``
wave body at one (n, bucket) point through ``repro.tune.search`` (top-k
cost-model-ranked candidates measured, the default geometry always in
the measured set), persists the winners as a ``repro.tune.table`` JSON,
and prints one row per tuned key.  A SECOND cold subprocess then loads
the table purely through ``SolverConfig.tuning_table`` -- no tuner
import, no re-measuring -- and proves the planner picks the winners up:
plan leaves carry the tuned geometry tag, the plan executes, and the
table file is byte-identical afterwards.

Gates (``--check``):

* ``speedup = default_s / tuned_s >= 1.0`` for every tuned key -- the
  tuner may never make a route slower than the untuned default (this
  holds by construction: the winner is the measured argmin over a set
  that always contains the default);
* the cold pickup process resolved a geometry for every probed route
  and its plans executed.

The per-candidate predicted-vs-measured rows are written to
``$DRYRUN_DIR/autotune/mispredict.json`` (its own subdirectory, so the
roofline report's dry-run cell glob never misparses it) and surfaced by
``benchmarks/roofline_report.py``; model error is REPORTED (top
mispredicts), never gated -- the measurement, not the model, picks
winners.

    PYTHONPATH=src python -m benchmarks.autotune [--check] [--fast]
    PYTHONPATH=src python -m benchmarks.run --only autotune --check
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

DEVICES = 8
N = 12
BUCKET = 64
N_FAST = 8
BUCKET_FAST = 8
ROUTES = ("dense", "complex", "sparse", "campaign")
SPARSE_DENSITY = 0.25        # tuned bucket "0.25" -- the sparse route's

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_REPORT_DIR = os.path.join(
    os.environ.get("DRYRUN_DIR", "experiments/dryrun"), "autotune")

_WORKER_TUNE = r"""
import json

import jax
jax.config.update("jax_enable_x64", True)
from jax.sharding import Mesh
import numpy as np

from repro.tune.search import tune_table

mesh = Mesh(np.array(jax.devices()), ("step",))
table, report = tune_table(
    {routes!r}, ({n},), density={density}, batch={bucket},
    top_k={top_k}, repeats={repeats}, interpret=True, seed=0, mesh=mesh)
table.save({table!r})
with open({report!r}, "w") as f:
    json.dump({{"rows": report}}, f, indent=1)
for e in sorted(table.entries.values(), key=lambda e: e.key()):
    print(f"ROW,kind=tune,route={{e.route}},n={{e.n}},"
          f"dtype={{e.dtype}},density={{e.density_bucket}},"
          f"geometry={{e.geometry.tag()}},"
          f"default_ms={{e.default_s * 1e3:.3f}},"
          f"tuned_ms={{e.measured_s * 1e3:.3f}},"
          f"speedup={{e.speedup:.4f}},"
          f"mispredict={{e.mispredict_ratio:.3f}}")
"""

_WORKER_PICKUP = r"""
import hashlib

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.solver import PermanentSolver, SolverConfig
from repro.tune.table import TuningTable

n = {n}
B = {bucket}
table_path = {table!r}
digest0 = hashlib.sha256(open(table_path, "rb").read()).hexdigest()
table = TuningTable.load(table_path)     # loud if stale/invalid
rng = np.random.default_rng(7)

solver = PermanentSolver(SolverConfig(
    backend="pallas", preprocess=False, cache=False,
    tuning_table=table_path))
for route, dtype in (("dense", "<f8"), ("dense", "<c16")):
    mats = rng.uniform(0.2, 1.2, (B, n, n))
    if dtype == "<c16":
        mats = mats + 1j * rng.uniform(0.2, 1.2, (B, n, n))
    want = table.resolve(route, n, 1.0, dtype, "dq_acc")
    plan = solver.plan_batch(list(mats))
    tags = sorted({{l.geometry.tag() if l.geometry else "-"
                   for l in plan.leaves}})
    vals = solver.execute(plan)
    finite = bool(np.all(np.isfinite(np.asarray(vals, dtype=complex))))
    picked = int(want is not None and tags == [want.tag()])
    print(f"ROW,kind=pickup,route={{route}},dtype={{dtype}},"
          f"picked={{picked}},geometry={{tags[0]}},executed={{int(finite)}}")

# sparse + campaign winners resolve from the persisted table too (the
# planner consults the same resolve(); no measuring happened here)
res_sparse = table.resolve("sparse", n, {density}, "<f8", "dq_acc")
res_camp = table.resolve("step_sharded", n, 1.0, "<f8", "dq_acc")
digest1 = hashlib.sha256(open(table_path, "rb").read()).hexdigest()
print(f"ROW,kind=resolve,sparse={{int(res_sparse is not None)}},"
      f"campaign={{int(res_camp is not None)}},"
      f"table_unchanged={{int(digest0 == digest1)}}")
"""


def _spawn(code: str, devices: int, timeout: int = 1500):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"autotune worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    return [dict(kv.split("=", 1) for kv in line[4:].split(","))
            for line in r.stdout.splitlines() if line.startswith("ROW,")]


def run(n: int = N, bucket: int = BUCKET, devices: int = DEVICES,
        top_k: int = 2, repeats: int = 3, report_dir: str = _REPORT_DIR):
    """Tune in one cold subprocess, pick up in a second; returns rows."""
    os.makedirs(report_dir, exist_ok=True)
    report = os.path.join(report_dir, "mispredict.json")
    with tempfile.TemporaryDirectory() as tmp:
        table = os.path.join(tmp, "table.json")
        rows = _spawn(_WORKER_TUNE.format(
            routes=tuple(ROUTES), n=n, bucket=bucket,
            density=SPARSE_DENSITY, top_k=top_k, repeats=repeats,
            table=table, report=report), devices)
        rows += _spawn(_WORKER_PICKUP.format(
            n=n, bucket=bucket, table=table,
            density=SPARSE_DENSITY), devices)
    want = len(ROUTES) + 2 + 1       # tune rows + pickup rows + resolve
    if len(rows) != want:
        raise RuntimeError(f"expected {want} rows, parsed {len(rows)}")
    return rows


def check(rows, report_dir: str = _REPORT_DIR) -> bool:
    """Gate tuned >= untuned per key and cold-process pickup; report (do
    not gate) the top cost-model mispredictions."""
    ok = True
    for row in rows:
        kind = row.get("kind")
        if kind == "tune":
            speedup = float(row["speedup"])
            gate_ok = speedup >= 1.0
            status = "OK" if gate_ok else "FAIL"
            print(f"# autotune: {row['route']}/{row['dtype']} n={row['n']} "
                  f"tuned {speedup:.2f}x default "
                  f"(>= 1.0 floor) -- {status}")
            ok &= gate_ok
        elif kind == "pickup":
            gate_ok = row.get("picked") == "1" and row.get("executed") == "1"
            status = "OK" if gate_ok else "FAIL"
            print(f"# autotune: cold pickup {row['route']}/{row['dtype']} "
                  f"geometry={row['geometry']} -- {status}")
            ok &= gate_ok
        elif kind == "resolve":
            gate_ok = all(row.get(k) == "1" for k in
                          ("sparse", "campaign", "table_unchanged"))
            status = "OK" if gate_ok else "FAIL"
            print(f"# autotune: sparse/campaign winners resolve from the "
                  f"persisted table, file untouched -- {status}")
            ok &= gate_ok
    path = os.path.join(report_dir, "mispredict.json")
    try:
        with open(path) as f:
            worst = sorted(
                json.load(f)["rows"],
                key=lambda r: abs(1.0 - (r.get("mispredict_ratio") or 1.0)),
                reverse=True)[:3]
        for r in worst:
            print(f"# autotune: mispredict {r['route']}/n{r['n']}/"
                  f"{r['geometry']}: predicted {r['predicted_s']:.2e}s "
                  f"measured {r['measured_s']:.2e}s "
                  f"(ratio {r['mispredict_ratio']:.3f}) -- report only")
    except OSError:
        print(f"# autotune: no mispredict report at {path}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized: smaller n/bucket, fewer repeats")
    args = ap.parse_args()
    if args.fast:
        rows = run(n=N_FAST, bucket=BUCKET_FAST, top_k=1, repeats=1)
    else:
        rows = run()
    for row in rows:
        print("autotune," + ",".join(f"{k}={v}" for k, v in row.items()))
    if args.check:
        return 0 if check(rows) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
