"""Paper Table 5: sparse matrices with preprocessing on/off.

The paper uses 5 SuiteSparse matrices (mesh1e1, bcspwr02, bcsstk01,
mycielskian6, impcol_b); this container is offline, so we generate
structural stand-ins with matched (n, nnz) statistics plus the structured
families where preprocessing provably shines (banded -> DM no-op;
arrow/chain -> FM collapse; triangular-ish -> DM strips everything).

Columns mirror the paper: preprocessing None / +DM / +Both, execution time,
and the (n, nnz) after preprocessing.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import decompose as D
from repro.core import engine
from repro.core.oracle import perm_ryser_exact

# (name, n, nnz) of the paper's matrices; we synthesize matched stand-ins
PAPER_LIKE = [
    ("mesh1e1-like", 18, 0.13),
    ("bcspwr02-like", 19, 0.07),
    ("bcsstk01-like", 18, 0.17),
    ("mycielskian6-like", 17, 0.21),
    ("impcol_b-like", 20, 0.09),
]


def _synth(name: str, n: int, density: float, seed=0) -> np.ndarray:
    rng = np.random.default_rng(abs(hash((name, seed))) % 2**32)
    # banded + random off-band fill: mimics mesh/power-grid structure,
    # guaranteed structurally nonsingular (diagonal present)
    A = np.zeros((n, n))
    for i in range(n):
        A[i, i] = rng.uniform(0.5, 1.5)
        if i + 1 < n and rng.uniform() < 0.8:
            A[i, i + 1] = rng.uniform(0.5, 1.5)
            A[i + 1, i] = rng.uniform(0.5, 1.5)
    fill = rng.uniform(0, 1, (n, n)) < max(0.0, density - 2.0 / n)
    A = np.where(fill & (A == 0), rng.uniform(0.5, 1.5, (n, n)), A)
    return A


def run(seed: int = 0):
    rows = []
    for name, n, density in PAPER_LIKE:
        A = _synth(name, n, density, seed)
        nnz0 = int((A != 0).sum())
        ref = perm_ryser_exact(A)

        t0 = time.time()
        v_none = engine.permanent(A, preprocess=False)
        t_none = time.time() - t0

        Adm, removed = D.dm_eliminate(A)
        t0 = time.time()
        v_dm = engine.permanent(Adm, preprocess=False)
        t_dm = time.time() - t0

        t0 = time.time()
        v_both, rep = engine.permanent(A, preprocess=True,
                                       return_report=True)
        t_both = time.time() - t0

        for v in (v_none, v_dm, v_both):
            assert abs(v - ref) / max(abs(ref), 1e-300) < 1e-7, (name, v, ref)
        rows.append({
            "matrix": name, "n": n, "nnz": nnz0,
            "density": nnz0 / (n * n),
            "dm_removed": removed,
            "t_none": t_none, "t_dm": t_dm, "t_both": t_both,
            "fm_leaves": rep.fm_leaves,
            "leaf_sizes": rep.leaf_sizes[:8],
        })
    return rows


def main(csv: bool = True):
    rows = run()
    if csv:
        print("table5,matrix,n,nnz,density,dm_removed,t_none,t_dm,t_both,"
              "fm_leaves")
        for r in rows:
            print(f"table5,{r['matrix']},{r['n']},{r['nnz']},"
                  f"{r['density']:.3f},{r['dm_removed']},"
                  f"{r['t_none']:.3f},{r['t_dm']:.3f},{r['t_both']:.3f},"
                  f"{r['fm_leaves']}")
    return rows


if __name__ == "__main__":
    main()
