"""Permanent-kernel perf loop (EXPERIMENTS.md Sec. Perf).

No TPU in this container, so the "profile" is (a) trip-count-aware op
counts from the interpret-lowered HLO (VPU-class elementwise flops, MXU dot
flops, bytes) and (b) CPU wall time as a secondary signal.  The analytic
roofline projects the op counts onto TPU v5e throughput ceilings:

    VPU f32: 8x128 lanes x 4 ALUs x 1.5 GHz x (1 flop)  ~= 6.1 TF/s
    MXU bf16/f32: 197 TF/s (the kernel's dots are small -- boundary/init)

Variants (kernel modes):
    baseline  -- paper-faithful Alg. 3 + CEG (3n VPU ops/step/lane)
    schedmat  -- signed schedule columns precomputed (2n ops/step/lane)
    batched   -- window-batched matmul state generation (2n ops, no serial
                 X chain inside a window)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oracle import perm_ryser_exact
from repro.core.ryser import ryser_flops
from repro.core.stepspace import Geometry
from repro.kernels.ops import block_partials_pallas
from repro.utils.hlo_cost import analyze_hlo

VPU_F32 = 6.1e12    # assumed v5e VPU f32 ceiling (see module docstring)
MXU = 197e12


def profile_variant(A, mode: str, *, lanes=64, steps_per_chunk=64,
                    window=16, precision="dd", repeat=3):
    n = A.shape[0]
    geometry = Geometry(lanes, steps_per_chunk, window)

    def run():
        out, geo = block_partials_pallas(
            A, geometry=geometry, precision=precision, mode=mode)
        return out, geo

    f = jax.jit(lambda A_: block_partials_pallas(
        A_, geometry=geometry, precision=precision, mode=mode)[0])
    lowered = f.lower(jnp.asarray(A))
    compiled = lowered.compile()
    cost = analyze_hlo(compiled.as_text())

    out = compiled(jnp.asarray(A))
    t0 = time.time()
    for _ in range(repeat):
        out = compiled(jnp.asarray(A))
    jax.block_until_ready(out)
    wall = (time.time() - t0) / repeat

    space = 1 << (n - 1)
    ew_per_step = cost.elementwise_flops / space
    dot_per_step = cost.dot_flops / space
    # projected TPU time: VPU and MXU streams overlap; take max
    t_vpu = cost.elementwise_flops / VPU_F32
    t_mxu = cost.dot_flops / MXU
    return {
        "mode": mode, "n": n,
        "elementwise_flops": cost.elementwise_flops,
        "dot_flops": cost.dot_flops,
        "bytes": cost.bytes_accessed,
        "ew_per_step": ew_per_step,
        "dot_per_step": dot_per_step,
        "tpu_proj_s": max(t_vpu, t_mxu),
        "tpu_vpu_s": t_vpu, "tpu_mxu_s": t_mxu,
        "cpu_wall_s": wall,
        "useful_flops": ryser_flops(n),
        "roofline_frac": (ryser_flops(n) / VPU_F32) / max(t_vpu, t_mxu),
        "value": float(jnp.sum(out)),
    }


def run(n: int = 18, window: int = 16, steps: int = 64, lanes: int = 64,
        precision: str = "dd", seed: int = 0):
    rng = np.random.default_rng(seed)
    A = rng.uniform(-1, 1, (n, n))
    exact = perm_ryser_exact(A) if n <= 18 else None
    rows = []
    for mode in ("baseline", "schedmat", "batched"):
        r = profile_variant(A, mode, lanes=lanes, steps_per_chunk=steps,
                            window=window, precision=precision)
        rows.append(r)
    return rows


def main(csv: bool = True):
    rows = run()
    if csv:
        print("kernel_perf,mode,n,ew_flops_per_step,dot_flops_per_step,"
              "tpu_proj_s,roofline_frac,cpu_wall_s")
        for r in rows:
            print(f"kernel_perf,{r['mode']},{r['n']},"
                  f"{r['ew_per_step']:.1f},{r['dot_per_step']:.1f},"
                  f"{r['tpu_proj_s']:.4e},{r['roofline_frac']:.3f},"
                  f"{r['cpu_wall_s']:.3f}")
    return rows


if __name__ == "__main__":
    main()
