"""Sparse-bucket throughput: the SpaRyser kernel and mesh vs the jnp path.

ISSUE 5's tentpole gate: the sparse route no longer downgrades to the jnp
engine on any backend.  This benchmark measures perms/sec of a same-size
REAL sparse bucket (padded-CCS layout) executed

* **jnp**         -- the batched jnp SpaRyser engine on one device
  (``sparyser.perm_sparyser_batched``);
* **pallas**      -- the padded-CCS (batch, block)-grid SpaRyser kernel
  (``ops.permanent_pallas_sparse_batched``, interpret mode on CPU);
* **dist**        -- the same bucket batch-axis-sharded over a forced
  8-device host CPU mesh through the jnp engine's trace
  (``distributed.sparse_batch_permanents_on_mesh``);
* **mesh_pallas** -- the mesh path with ``backend="pallas"``: the SpaRyser
  kernel launched per device on its local sub-stack.

and asserts, per density of the 0.1 / 0.3 / 0.5 sweep,

* the sharded (jnp-body) values are BIT-IDENTICAL to the jnp ones (the
  ``distributed_batch`` contract), and
* the pallas and mesh_pallas values agree with jnp to 1e-9 relative (the
  kernel carries its own cache identity -- bitwise is jnp<->distributed's
  contract, not the kernel's),

re-checked for every precision mode at the gated density, plus a routing
probe: a sparse-routed bucket planned under ``backend="pallas"`` (and
under ``distributed`` with a mesh) must dispatch natively -- no
``pallas->jnp`` / ``distributed->jnp`` downgrade tag.

Acceptance gate (ISSUE 5): BOTH the pallas kernel and the sharded bucket
run at >= 0.9x the single-device jnp sparse path at the gated (last)
density.  Measured on an 8-device host mesh: pallas 5-15x, dist 1.3-3x.

Because XLA_FLAGS must be set before jax initializes, the measurement
runs in a subprocess; the parent parses its CSV.

    PYTHONPATH=src python -m benchmarks.batch_sparse [--check]
    PYTHONPATH=src python -m benchmarks.run --only batch_sparse --check
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

SPEEDUP_GATE = 0.9
DEVICES = 8
N = 12
BUCKET = 64
# pattern densities to measure; the LAST one is the gated row
DENSITIES = (0.1, 0.3, 0.5)

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import time

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core import distributed, sparyser
from repro.core.solver import PermanentSolver, SolverConfig
from repro.kernels import ops
from repro.launch.mesh import make_batch_mesh

n = {n}
B = {bucket}
densities = {densities!r}
repeats = {repeats}
precisions = ("dd", "dq_fast", "dq_acc", "qq", "kahan")
mesh = make_batch_mesh({devices})
rng = np.random.default_rng({seed})


def sparse_bucket(d, route_sparse=False):
    sps = []
    while len(sps) < B:
        mask = (rng.uniform(0, 1, (n, n)) < d) | np.eye(n, dtype=bool)
        if route_sparse and mask.sum() / (n * n) >= 0.29:
            continue                 # keep every leaf under DENSITY_SWITCH
        sps.append(sparyser.SparseMatrix.from_dense(
            rng.uniform(0.5, 1.5, (n, n)) * mask))
    return sps


ENGINES = dict(
    jnp=lambda sps, prec: np.asarray(
        sparyser.perm_sparyser_batched(sps, precision=prec)),
    pallas=lambda sps, prec: np.asarray(
        ops.permanent_pallas_sparse_batched(sps, precision=prec)),
    dist=lambda sps, prec: distributed.sparse_batch_permanents_on_mesh(
        sps, mesh, precision=prec),
    mesh_pallas=lambda sps, prec:
        distributed.sparse_batch_permanents_on_mesh(
            sps, mesh, precision=prec, backend="pallas"),
)


def best_time(fn, sps):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(sps, "dq_acc")
        best = min(best, time.perf_counter() - t0)
    return best


def rel_close(a, b, tol=1e-9):
    return bool(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-300)) < tol)


for d in densities:
    sps = sparse_bucket(d)
    vals = {{name: fn(sps, "dq_acc") for name, fn in ENGINES.items()}}
    secs = {{name: best_time(fn, sps) for name, fn in ENGINES.items()}}
    print(f"ROW,kind=perf,n={{n}},bucket={{B}},density={{d}},"
          f"devices={{{devices}}},"
          f"jnp_perms_per_s={{B / secs['jnp']:.0f}},"
          f"pallas_perms_per_s={{B / secs['pallas']:.0f}},"
          f"dist_perms_per_s={{B / secs['dist']:.0f}},"
          f"mesh_pallas_perms_per_s={{B / secs['mesh_pallas']:.0f}},"
          f"pallas_speedup={{secs['jnp'] / secs['pallas']:.2f}},"
          f"dist_speedup={{secs['jnp'] / secs['dist']:.2f}},"
          f"mesh_pallas_speedup={{secs['jnp'] / secs['mesh_pallas']:.2f}},"
          f"pallas_close={{int(rel_close(vals['pallas'], vals['jnp']))}},"
          f"dist_bitwise={{int(np.array_equal(vals['dist'], vals['jnp']))}},"
          f"mesh_pallas_close="
          f"{{int(rel_close(vals['mesh_pallas'], vals['jnp']))}}")

# identity per precision mode at the gated density (fresh bucket)
sps = sparse_bucket(densities[-1])
for prec in precisions:
    vj = ENGINES["jnp"](sps, prec)
    vp = ENGINES["pallas"](sps, prec)
    vd = ENGINES["dist"](sps, prec)
    print(f"ROW,kind=prec,precision={{prec}},density={{densities[-1]}},"
          f"pallas_close={{int(rel_close(vp, vj))}},"
          f"dist_bitwise={{int(np.array_equal(vd, vj))}}")

# routing probe: a sparse-routed bucket dispatches natively on the kernel
# and on the mesh -- the pallas->jnp sparse downgrade tag is gone
mats = [sp.to_dense() for sp in sparse_bucket(0.1, route_sparse=True)]
flags = []
for backend, ctx in (("pallas", None), ("distributed", mesh)):
    s = PermanentSolver(SolverConfig(backend=backend, cache=False,
                                     preprocess=False),
                        distributed_ctx=ctx)
    _, reports = s.execute(s.plan_batch(mats), return_report=True)
    tags = [t for r in reports for t in r.dispatch]
    native = (not s.stats()["downgrades"]
              and all(t.startswith("sparse_batch") and "->" not in t
                      for t in tags))
    flags.append(f"{{backend}}_native={{int(native)}}")
print("ROW,kind=route," + ",".join(flags))
"""


def run(densities=DENSITIES, devices: int = DEVICES, repeats: int = 5,
        seed: int = 0):
    """Measure in a forced-multi-device subprocess; returns CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    code = _WORKER.format(n=N, bucket=BUCKET, densities=tuple(densities),
                          repeats=repeats, devices=devices, seed=seed)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"batch_sparse worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if not line.startswith("ROW,"):
            continue
        rows.append(dict(kv.split("=", 1) for kv in line[4:].split(",")))
    want = len(tuple(densities)) + 5 + 1   # perf rows + precisions + route
    if len(rows) != want:
        raise RuntimeError(f"expected {want} rows, parsed {len(rows)}:\n"
                           f"{r.stdout[-2000:]}")
    return rows


def check(rows) -> bool:
    """ISSUE-5 gate: pallas AND mesh-sharded sparse buckets >= 0.9x the
    jnp sparse path at the gated density; dist bit-identical and the
    kernels 1e-9-close on every row (all precision modes); no sparse
    downgrade tags on native routes."""
    ok = True
    for row in rows:
        kind = row.get("kind")
        if kind in ("perf", "prec"):
            where = f"density={row.get('density')}" + (
                f" precision={row['precision']}" if kind == "prec" else "")
            if row.get("pallas_close") != "1":
                print(f"# batch_sparse: pallas NOT 1e-9-close ({where})"
                      f" -- FAIL")
                ok = False
            if row.get("dist_bitwise") != "1":
                print(f"# batch_sparse: sharded values NOT bit-identical "
                      f"({where}) -- FAIL")
                ok = False
            if row.get("mesh_pallas_close", "1") != "1":
                print(f"# batch_sparse: mesh pallas NOT 1e-9-close "
                      f"({where}) -- FAIL")
                ok = False
        if kind == "route":
            for key, val in row.items():
                if key.endswith("_native") and val != "1":
                    print(f"# batch_sparse: sparse bucket downgraded under "
                          f"{key[:-7]} -- FAIL")
                    ok = False
    gated = [r for r in rows if r.get("kind") == "perf"][-1]
    for which in ("pallas", "dist"):
        speedup = float(gated[f"{which}_speedup"])
        gate_ok = speedup >= SPEEDUP_GATE
        status = "OK" if gate_ok else "FAIL"
        print(f"# batch_sparse gate [{which}] (n={gated['n']} "
              f"bucket={gated['bucket']} density={gated['density']} "
              f"x{gated['devices']} devices): {speedup:.2f}x vs required "
              f"{SPEEDUP_GATE:.1f}x -- {status}")
        ok = ok and gate_ok
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="enforce the >= 0.9x + identity gates")
    args = ap.parse_args()

    rows = run(devices=args.devices, repeats=args.repeats)
    for r in rows:
        print("batch_sparse," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
