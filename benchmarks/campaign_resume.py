"""Campaign throughput vs direct mesh permanent + kill/resume identity.

ISSUE 6's tentpole: a single huge permanent routes through the planner's
``step_sharded`` campaign route -- checkpointed, preemption-safe waves of
``slice_sums_on_mesh`` -- instead of the one-shot ``permanent_on_mesh``
psum.  The resilience cannot be free, but it must be nearly free: the
campaign re-forms waves on the host and checkpoints twofloat partials
after each one, so its throughput is gated at >= 0.9x the direct
mesh path at the same forced device count.

Two measurements, both in subprocesses (XLA_FLAGS must be set before jax
initializes):

* **throughput** -- ``permanent_on_mesh`` vs ``run_campaign`` on the same
  8-device host mesh, same (lanes, slices) step-space geometry;
* **resume**     -- the ``repro.launch.campaign`` CLI is SIGKILLed
  mid-wave on a 2-device mesh and resumed on 8; the printed value must be
  bitwise-identical to an uninterrupted 8-device run (real and complex).

    PYTHONPATH=src python -m benchmarks.campaign_resume [--check] [--fast]
    PYTHONPATH=src python -m benchmarks.run --only campaign --check
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import tempfile

SPEEDUP_GATE = 0.9
DEVICES = 8
N_FULL = 18
N_FAST = 14

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import math
import time

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed as Dm
from repro.core.stepspace import plan_slices

n = {n}
repeats = {repeats}
devices = {devices}
mesh = Mesh(np.array(jax.devices()[:devices]), ("step",))
rng = np.random.default_rng({seed})
A = rng.uniform(0.2, 1.2, (n, n))

# identical step-space budget for both paths: the campaign's
# (slices x chunks) product equals the direct path's lane count
ts, cps, C = plan_slices(n, devices, 8, 128)
lanes = ts * cps // devices


def best(fn):
    b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b


v_direct = float(Dm.permanent_on_mesh(A, mesh, slices_per_device=8,
                                      lanes_per_device=lanes))
v_campaign, _ = Dm.run_campaign(A, mesh, total_slices=ts,
                                chunks_per_slice=cps, chunk_size=C)
t_direct = best(lambda: Dm.permanent_on_mesh(
    A, mesh, slices_per_device=8, lanes_per_device=lanes))
t_campaign = best(lambda: Dm.run_campaign(
    A, mesh, total_slices=ts, chunks_per_slice=cps, chunk_size=C))
rel = abs(v_campaign - v_direct) / abs(v_direct)
print(f"ROW,kind=throughput,n={{n}},devices={{devices}},waves={{ts // devices}},"
      f"t_direct_s={{t_direct:.4f}},t_campaign_s={{t_campaign:.4f}},"
      f"ratio={{t_direct / t_campaign:.3f}},rel_err={{rel:.2e}}")
"""


def _env(devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    return env


def _throughput_row(n: int, devices: int, repeats: int, seed: int):
    code = _WORKER.format(n=n, repeats=repeats, devices=devices, seed=seed)
    r = subprocess.run([sys.executable, "-c", code], env=_env(devices),
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"campaign_resume worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            return dict(kv.split("=", 1) for kv in line[4:].split(","))
    raise RuntimeError(f"no ROW in worker output:\n{r.stdout[-2000:]}")


def _cli_value(out: str) -> str:
    for line in out.splitlines():
        if "perm(A) =" in line:
            return line.split("perm(A) =")[1].split("  (")[0].strip()
    raise RuntimeError(f"no value line:\n{out[-2000:]}")


def _resume_row(n: int, devices: int, use_complex: bool, seed: int):
    """SIGKILL the campaign CLI mid-wave on 2 devices, resume on
    ``devices``; report whether the value is bitwise-identical to an
    uninterrupted run."""
    kind = "resume_complex" if use_complex else "resume_real"
    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "job.npz")
        base = [sys.executable, "-m", "repro.launch.campaign",
                "--n", str(n), "--slices", "64", "--lanes", "8",
                "--seed", str(seed)]
        if use_complex:
            base.append("--complex")
        ref = subprocess.run(
            [*base, "--checkpoint", os.path.join(tmp, "ref.npz")],
            env=_env(devices), capture_output=True, text=True, timeout=1200)
        if ref.returncode != 0:
            raise RuntimeError(ref.stdout + ref.stderr[-3000:])
        v_ref = _cli_value(ref.stdout)

        p = subprocess.Popen([*base, "--checkpoint", ckpt,
                              "--devices", "2"],
                             env=_env(devices), stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        try:
            for line in p.stdout:
                if "[campaign] wave" in line:
                    os.kill(p.pid, signal.SIGKILL)
                    break
            p.wait(timeout=300)
        finally:
            p.stdout.close()
            if p.poll() is None:
                p.kill()
                p.wait(timeout=300)

        res = subprocess.run([*base, "--checkpoint", ckpt],
                             env=_env(devices), capture_output=True,
                             text=True, timeout=1200)
        if res.returncode != 0:
            raise RuntimeError(res.stdout + res.stderr[-3000:])
        v_res = _cli_value(res.stdout)
        return {"kind": kind, "n": str(n), "devices": str(devices),
                "bitwise": str(int(v_res == v_ref))}


def run(n: int = N_FULL, devices: int = DEVICES, repeats: int = 3,
        seed: int = 0):
    rows = [_resume_row(max(12, n - 4), devices, False, seed),
            _resume_row(max(12, n - 4), devices, True, seed),
            _throughput_row(n, devices, repeats, seed)]
    return rows


def check(rows) -> bool:
    """ISSUE-6 gate: campaign >= 0.9x direct mesh throughput at equal
    device count; killed-and-resumed values bitwise-identical."""
    ok = True
    for row in rows:
        if row["kind"].startswith("resume"):
            if row["bitwise"] != "1":
                print(f"# campaign_resume: {row['kind']} NOT "
                      f"bitwise-identical -- FAIL")
                ok = False
            continue
        ratio = float(row["ratio"])
        gate_ok = ratio >= SPEEDUP_GATE
        status = "OK" if gate_ok else "FAIL"
        print(f"# campaign gate (n={row['n']} x{row['devices']} devices, "
              f"{row['waves']} waves): {ratio:.2f}x vs required "
              f"{SPEEDUP_GATE:.1f}x direct-mesh throughput -- {status}")
        if float(row["rel_err"]) > 1e-10:
            print(f"# campaign_resume: campaign/direct values diverge "
                  f"(rel_err={row['rel_err']}) -- FAIL")
            ok = False
        ok = ok and gate_ok
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--fast", action="store_true",
                    help=f"smaller matrix (n={N_FAST}) for quick checks")
    ap.add_argument("--check", action="store_true",
                    help="enforce the >= 0.9x + bitwise-resume gate")
    args = ap.parse_args()

    n = args.n if args.n is not None else (N_FAST if args.fast else N_FULL)
    rows = run(n=n, devices=args.devices, repeats=args.repeats)
    for r in rows:
        print("campaign_resume," + ",".join(f"{k}={v}"
                                            for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
