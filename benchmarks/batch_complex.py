"""Complex-bucket throughput: pallas and mesh-sharded vs the jnp path.

ISSUE 4's tentpole gate: complex permanents (boson-sampling amplitudes)
are first-class on every backend built in PRs 1-3.  This benchmark
measures perms/sec of a same-size dense COMPLEX bucket executed

* **jnp**    -- the split-plane complex engine on one device
  (``ryser.batched_values_complex``);
* **pallas** -- the split re/im plane (batch, block)-grid kernel
  (``ryser_complex.ryser_pallas_call_complex_batched``, interpret mode on
  CPU);
* **dist**   -- the same bucket batch-axis-sharded over a forced
  8-device host CPU mesh, re/im planes through the jnp engine's trace.

and asserts

* the sharded values are BIT-IDENTICAL to the jnp ones (the
  ``distributed_batch`` contract, complex included), and
* the pallas values agree with jnp to 1e-9 relative (the kernel carries
  its own cache identity, like the real kernel -- bitwise identity is
  jnp<->distributed's contract, not pallas's).

Acceptance gate (ISSUE 4): BOTH the pallas and the sharded bucket run at
>= 0.9x the single-device jnp complex path at the gated (n, B).
Measured on an 8-device host mesh: dist 2.2-2.8x, pallas 1.6-2.6x.

Because XLA_FLAGS must be set before jax initializes, the measurement
runs in a subprocess; the parent parses its CSV.

    PYTHONPATH=src python -m benchmarks.batch_complex [--check]
    PYTHONPATH=src python -m benchmarks.run --only batch_complex --check
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

SPEEDUP_GATE = 0.9
DEVICES = 8
# (n, bucket) pairs to measure; the LAST row is the gated one
SIZES = ((10, 64), (12, 64))

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import time

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.solver import PermanentSolver, SolverConfig
from repro.launch.mesh import make_batch_mesh

sizes = {sizes!r}
repeats = {repeats}
mesh = make_batch_mesh({devices})
rng = np.random.default_rng({seed})


def best_time(solver, plan):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        solver.execute(plan)
        best = min(best, time.perf_counter() - t0)
    return best


for n, B in sizes:
    mats = [rng.normal(size=(n, n)) + 1j * rng.normal(size=(n, n))
            for _ in range(B)]
    solvers = dict(
        jnp=PermanentSolver(SolverConfig(
            backend="jnp", cache=False, preprocess=False)),
        pallas=PermanentSolver(SolverConfig(
            backend="pallas", cache=False, preprocess=False)),
        dist=PermanentSolver(SolverConfig(
            backend="distributed", cache=False, preprocess=False),
            distributed_ctx=mesh),
    )
    vals, secs = dict(), dict()
    for name, s in solvers.items():
        plan = s.plan_batch(mats)
        vals[name] = s.execute(plan)        # warm / compile
        assert not s.stats()["downgrades"], (name, s.stats()["downgrades"])
        secs[name] = best_time(s, plan)
    bitwise = bool(np.array_equal(vals["jnp"], vals["dist"]))
    pallas_ok = bool(np.allclose(vals["jnp"], vals["pallas"], rtol=1e-9))
    print(f"ROW,n={{n}},bucket={{B}},devices={{{devices}}},"
          f"jnp_perms_per_s={{B / secs['jnp']:.0f}},"
          f"pallas_perms_per_s={{B / secs['pallas']:.0f}},"
          f"dist_perms_per_s={{B / secs['dist']:.0f}},"
          f"pallas_speedup={{secs['jnp'] / secs['pallas']:.2f}},"
          f"dist_speedup={{secs['jnp'] / secs['dist']:.2f}},"
          f"dist_bitwise={{int(bitwise)}},pallas_close={{int(pallas_ok)}}")
"""


def run(sizes=SIZES, devices: int = DEVICES, repeats: int = 5,
        seed: int = 0):
    """Measure in a forced-multi-device subprocess; returns CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    code = _WORKER.format(sizes=tuple(sizes), repeats=repeats,
                          devices=devices, seed=seed)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"batch_complex worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if not line.startswith("ROW,"):
            continue
        rows.append(dict(kv.split("=", 1) for kv in line[4:].split(",")))
    if len(rows) != len(tuple(sizes)):
        raise RuntimeError(f"expected {len(tuple(sizes))} rows, parsed "
                           f"{len(rows)}:\n{r.stdout[-2000:]}")
    return rows


def check(rows) -> bool:
    """ISSUE-4 gate: pallas AND sharded complex buckets >= 0.9x jnp at the
    gated size; dist bit-identical and pallas 1e-9-close everywhere."""
    ok = True
    for row in rows:
        if row["dist_bitwise"] != "1":
            print(f"# batch_complex: sharded values NOT bit-identical at "
                  f"n={row['n']} bucket={row['bucket']} -- FAIL")
            ok = False
        if row["pallas_close"] != "1":
            print(f"# batch_complex: pallas values NOT 1e-9-close at "
                  f"n={row['n']} bucket={row['bucket']} -- FAIL")
            ok = False
    gated = rows[-1]
    for which in ("pallas", "dist"):
        speedup = float(gated[f"{which}_speedup"])
        gate_ok = speedup >= SPEEDUP_GATE
        status = "OK" if gate_ok else "FAIL"
        print(f"# batch_complex gate [{which}] (n={gated['n']} "
              f"bucket={gated['bucket']} x{gated['devices']} devices): "
              f"{speedup:.2f}x vs required {SPEEDUP_GATE:.1f}x -- {status}")
        ok = ok and gate_ok
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="enforce the >= 0.9x + identity gates")
    args = ap.parse_args()

    rows = run(devices=args.devices, repeats=args.repeats)
    for r in rows:
        print("batch_complex," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
