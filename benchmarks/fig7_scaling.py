"""Paper Fig. 7: multi-device scaling of the permanent computation.

The paper shows near-linear speedup over 1/2/4/8 A100 nodes (communication
is one final reduce).  All fake devices here share ONE physical core, so
wall time cannot scale; the honest reproduction is **work division**: the
per-device compiled FLOPs (trip-count-aware) must fall as 1/D with a
constant tiny collective term (the single psum).  Wall time is reported as
a secondary column.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

N = 18
DEVICE_COUNTS = (1, 2, 4, 8)

_CHILD = textwrap.dedent("""
    import json, time, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P_
    from repro.core import distributed
    from repro.utils.compat import shard_map
    from repro.utils.hlo_cost import analyze_hlo
    n, d = int(sys.argv[1]), int(sys.argv[2])
    rng = np.random.default_rng(1234)
    A = rng.uniform(-1, 1, (n, n))
    mesh = jax.make_mesh((d,), ("data",))
    # warm-up (compile) + timed run
    val = float(distributed.permanent_on_mesh(A, mesh, lanes_per_device=256))
    t0 = time.time()
    val = float(distributed.permanent_on_mesh(A, mesh, lanes_per_device=256))
    dt = time.time() - t0
    # per-device work: lower the same shard_map body and analyze its HLO
    D = d
    total_slices, cps, C = distributed.plan_slices(n, D, 1, 256)
    spd = max(1, total_slices // D)
    table = np.arange(D * spd, dtype=np.int32).reshape(D, spd)
    dev_slices = jax.device_put(table, NamedSharding(mesh, P_(("data",))))

    def run(A, s):
        def body(A_rep, sl):
            parts = distributed._dyn_chunk_partials(
                A_rep, sl[0, 0] * cps, cps, C, "dq_acc")
            import jax as _j
            h = _j.lax.psum(jnp.sum(parts.hi), "data")
            return h
        return shard_map(body, mesh=mesh, in_specs=(P_(), P_(("data",))),
                         out_specs=P_())(A, s)

    comp = jax.jit(run).lower(jnp.asarray(A), dev_slices).compile()
    cost = analyze_hlo(comp.as_text())
    print(json.dumps({"devices": d, "seconds": dt, "value": val,
                      "flops_per_device": cost.dot_flops
                      + cost.elementwise_flops,
                      "collective_bytes": cost.collective_bytes}))
""")


def run(n: int = N, device_counts=DEVICE_COUNTS):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    rows = []
    for d in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env["PYTHONPATH"] = src
        r = subprocess.run([sys.executable, "-c", _CHILD, str(n), str(d)],
                           env=env, capture_output=True, text=True,
                           timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        rows.append(json.loads(r.stdout.strip().splitlines()[-1]))
    base = rows[0]["flops_per_device"]
    for r in rows:
        # work-division efficiency: per-device flops must fall as 1/D
        r["speedup"] = base / r["flops_per_device"]
        r["efficiency"] = r["speedup"] / r["devices"]
    vals = {round(r["value"], 6) for r in rows}
    assert len(vals) == 1, f"device counts disagree: {vals}"
    return rows


def main(csv: bool = True):
    rows = run()
    if csv:
        print("fig7,devices,flops_per_device,work_speedup,efficiency,"
              "coll_bytes,wall_s_one_core")
        for r in rows:
            print(f"fig7,{r['devices']},{r['flops_per_device']:.3e},"
                  f"{r['speedup']:.2f},{r['efficiency']:.2f},"
                  f"{r['collective_bytes']:.0f},{r['seconds']:.3f}")
    return rows


if __name__ == "__main__":
    main()
