"""Soak the always-on permanent service under open-loop Poisson load.

ISSUE 7's acceptance gate: drive ``repro.serve.PermanentService`` with
seeded Poisson arrivals (n=12 dense requests over a forced 8-device host
mesh), twice, in two cold subprocesses sharing one persistent XLA
compilation-cache directory, and assert

* **SLO**: p99 admission->result latency under the gate;
* **typed shedding**: sheds happen (a slice of requests carries an
  already-expired deadline) and every one carries a typed reason --
  nothing is dropped silently;
* **metrics consistency**: admitted == completed + shed + pending with
  pending 0 after drain, the latency histogram counts every completion,
  and cache-hit + queue-depth metrics are nonzero;
* **correctness**: sampled service values bit-match a fresh scalar
  solver on the same matrices;
* **no cold-start retrace storm**: run 1 populates the compilation
  cache during its warm-up pass (persistent misses > 0); run 2 -- a cold
  process, warm disk cache -- warms up with ZERO persistent misses, and
  in both runs the first dispatched bucket compiles nothing new;
* **tuned cold start** (ISSUE 9): a second, pallas-backend service in
  the same worker is configured with ``SolverConfig.tuning_table``
  pointing at a persisted table whose dense/n winner is a NON-default
  geometry.  Its warm-up plans through the table, so the warmed bucket
  programs ARE the tuned ones: run 2's tuned warm-up loads everything
  from disk (zero persistent misses), the tuned first bucket compiles
  nothing in either run, the dispatched leaves carry the tuned geometry
  tag, and the value still matches a fresh scalar solver.

Because ``XLA_FLAGS`` must be set before jax initializes (and because
"cold process" is the point), measurement runs in subprocesses; the
parent parses their CSV.

    PYTHONPATH=src python -m benchmarks.serve_soak [--check] [--fast]
    PYTHONPATH=src python -m benchmarks.run --only soak --check
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile

P99_GATE_S = 10.0      # host-CPU CI boxes are slow + shared; real SLOs
                       # are config, this gate just proves the loop keeps up
DEVICES = 8
N = 12
MAX_BATCH = 8
REQUESTS = 64
RATE_HZ = 50.0
EXPIRE_EVERY = 8       # every 8th request arrives already expired
# The synthetic table's dense/n winner: deliberately NOT the kernel
# default (128x64x16), so a tuned pickup is observable; validated
# against the PL007 auditor before the table is written.
TUNED_GEOMETRY = (64, 32, 8)

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import sys

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.solver import PermanentSolver, SolverConfig
from repro.launch.mesh import make_batch_mesh
from repro.serve import (PermanentService, ServiceConfig, compile_stats,
                         run_soak)

n = {n}
mesh = make_batch_mesh({devices})
svc = PermanentService(
    SolverConfig(backend="distributed", precision="dq_acc"),
    ServiceConfig(max_batch={max_batch}, quantize_buckets=True,
                  compile_cache_dir={cache_dir!r}, warmup_ns=(n,),
                  log_every_s=2.0),
    distributed_ctx=mesh, log=lambda s: print(s, file=sys.stderr))
warm = svc.warmup_report["compile"]

# first bucket after warm-up: must compile nothing new
s0 = compile_stats()
t_first = svc.submit(np.random.default_rng(99).uniform(-1, 1, (n, n)),
                     deadline_s=None)
svc.step()
s1 = compile_stats()
first_misses = s1["persistent_misses"] - s0["persistent_misses"]
assert t_first.done

out = run_soak(svc, requests={requests}, rate_hz={rate_hz}, n=n,
               repeat_pool=6, seed={seed}, expire_every={expire_every})
snap = out["snapshot"]
req = snap["requests"]

# sampled values vs a fresh scalar solver (bitwise: batch-shape
# independence + the distributed_batch bit-identity contract)
ref = PermanentSolver(SolverConfig(backend="jnp", cache=False))
done = [t for t in out["tickets"] if t.done]
values_ok = all(t.result() == ref.execute(ref.plan(t.matrix))
                for t in done[:3] + done[-3:])

lat = snap["latency_s"]["overall"]
consistent = (req["admitted"] == req["completed"] + req["shed_total"]
              + req["pending"]
              and req["pending"] == 0
              and lat["count"] == req["completed"]
              and all(k in ("queue_full", "cost_budget",
                            "deadline_expired", "shutdown")
                      for k in req["shed"]))
cache = snap["solver"]["cache"]

# tuned cold start: a pallas service whose warm-up resolves the
# persisted tuning table -- the warmed programs are the tuned ones, so
# with a warm disk cache the tuned first bucket compiles nothing
tuned = PermanentService(
    SolverConfig(backend="pallas", precision="dq_acc", preprocess=False,
                 tuning_table={table!r}),
    ServiceConfig(max_batch={max_batch}, quantize_buckets=True,
                  compile_cache_dir={cache_dir!r}, warmup_ns=(n,),
                  log_every_s=2.0),
    log=lambda s: print(s, file=sys.stderr))
tuned_warm = tuned.warmup_report["compile"]
tmat = np.random.default_rng(5).uniform(-1, 1, (n, n))
tleaf = tuned.solver.plan_batch([tmat]).leaves[0]
tuned_tag = tleaf.geometry.tag() if tleaf.geometry is not None else "-"
s0 = compile_stats()
t_tuned = tuned.submit(tmat, deadline_s=None)
tuned.step()
s1 = compile_stats()
tuned_first = s1["persistent_misses"] - s0["persistent_misses"]
tuned_value_ok = t_tuned.done and bool(np.isclose(
    t_tuned.result(), ref.execute(ref.plan(tmat)), rtol=1e-9))

print(f"ROW,devices={devices},n={{n}},requests={{req['admitted']}},"
      f"completed={{req['completed']}},shed={{req['shed_total']}},"
      f"shed_deadline={{req['shed'].get('deadline_expired', 0)}},"
      f"p50_ms={{lat['p50'] * 1e3:.0f}},p99_ms={{lat['p99'] * 1e3:.0f}},"
      f"dispatches={{snap['dispatches']}},"
      f"occupancy={{snap['bucket_occupancy']['mean']:.2f}},"
      f"depth_samples={{snap['queue_depth']['count']}},"
      f"depth_max={{snap['queue_depth']['max']:.0f}},"
      f"cache_hits={{cache['hits']}},cache_hit_rate={{cache['hit_rate']:.2f}},"
      f"warm_misses={{warm['persistent_misses']}},"
      f"warm_hits={{warm['persistent_hits']}},"
      f"first_misses={{first_misses}},"
      f"tuned_geometry={{tuned_tag}},"
      f"tuned_warm_misses={{tuned_warm['persistent_misses']}},"
      f"tuned_warm_hits={{tuned_warm['persistent_hits']}},"
      f"tuned_first_misses={{tuned_first}},"
      f"tuned_value_ok={{int(tuned_value_ok)}},"
      f"consistent={{int(consistent)}},values_ok={{int(values_ok)}}")
"""


def _write_tuning_table(path: str, n: int) -> None:
    """Persist a minimal, VALID table whose dense/n winner is the
    non-default ``TUNED_GEOMETRY`` (wildcard device kind, so the CPU CI
    host resolves it).  Timings are placeholders -- this table exercises
    the pickup path, not the tuner."""
    from repro.core.stepspace import Geometry
    from repro.tune.table import TableEntry, TuningTable

    table = TuningTable()
    table.put(TableEntry(
        route="dense", n=n, density_bucket="1.00", dtype="<f8",
        precision="dq_acc", device_kind="any",
        geometry=Geometry(*TUNED_GEOMETRY),
        predicted_s=1.0, measured_s=1.0, default_s=1.0))
    bad = table.validate()
    if bad:
        raise RuntimeError(f"synthetic tuning entry violates PL007: {bad}")
    table.save(path)


def _run_once(cache_dir: str, *, devices: int, requests: int,
              rate_hz: float, seed: int, table: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    code = _WORKER.format(n=N, devices=devices, max_batch=MAX_BATCH,
                          cache_dir=cache_dir, requests=requests,
                          rate_hz=rate_hz, seed=seed,
                          expire_every=EXPIRE_EVERY, table=table)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"serve_soak worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            return dict(kv.split("=", 1) for kv in line[4:].split(","))
    raise RuntimeError(f"serve_soak worker printed no ROW:\n"
                       f"{r.stdout[-2000:]}")


def run(devices: int = DEVICES, requests: int = REQUESTS,
        rate_hz: float = RATE_HZ, seed: int = 0, cache_dir: str | None = None):
    """Two cold subprocesses sharing one compilation-cache dir; returns
    [run1_row, run2_row] (run 1 cold cache, run 2 warm cache)."""
    ctx = tempfile.TemporaryDirectory() if cache_dir is None else None
    cdir = ctx.name if ctx else cache_dir
    try:
        table = os.path.join(cdir, "tuning_table.json")
        _write_tuning_table(table, N)
        rows = [_run_once(cdir, devices=devices, requests=requests,
                          rate_hz=rate_hz, seed=seed + i, table=table)
                for i in range(2)]
    finally:
        if ctx:
            ctx.cleanup()
    for i, row in enumerate(rows):
        row["run"] = str(i + 1)
    return rows


def check(rows, p99_gate_s: float = P99_GATE_S) -> bool:
    """The ISSUE-7 soak gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"# serve_soak: {msg} -- FAIL")
        ok = False

    for row in rows:
        tag = f"run {row['run']}"
        if row["consistent"] != "1":
            fail(f"{tag}: metrics inconsistent")
        if row["values_ok"] != "1":
            fail(f"{tag}: sampled values diverge from scalar solver")
        if int(row["shed"]) < 1 or int(row["shed_deadline"]) < 1:
            fail(f"{tag}: expected typed deadline sheds, got "
                 f"shed={row['shed']}")
        if int(row["cache_hits"]) < 1:
            fail(f"{tag}: result-cache hits = 0")
        if int(row["depth_samples"]) < 1:
            fail(f"{tag}: queue-depth histogram empty")
        p99 = float(row["p99_ms"]) / 1e3
        if p99 > p99_gate_s:
            fail(f"{tag}: p99 {p99:.2f}s over the {p99_gate_s:.1f}s gate")
        if int(row["first_misses"]) != 0:
            fail(f"{tag}: first bucket after warm-up recompiled "
                 f"({row['first_misses']} persistent misses)")
        want_tag = "x".join(str(v) for v in TUNED_GEOMETRY)
        if row["tuned_geometry"] != want_tag:
            fail(f"{tag}: tuned service planned geometry "
                 f"{row['tuned_geometry']}, table says {want_tag}")
        if int(row["tuned_first_misses"]) != 0:
            fail(f"{tag}: tuned first bucket recompiled "
                 f"({row['tuned_first_misses']} persistent misses)")
        if row["tuned_value_ok"] != "1":
            fail(f"{tag}: tuned service value diverged from scalar solver")
    if int(rows[0]["warm_misses"]) < 1:
        fail("run 1 warm-up compiled nothing (cache dir not cold?)")
    if int(rows[1]["warm_misses"]) != 0 or int(rows[1]["warm_hits"]) < 1:
        fail(f"run 2 (cold process, warm cache) recompiled during "
             f"warm-up: misses={rows[1]['warm_misses']} "
             f"hits={rows[1]['warm_hits']}")
    if int(rows[0]["tuned_warm_misses"]) < 1:
        fail("run 1 tuned warm-up compiled nothing -- the tuned bucket "
             "programs were already cached, gate is vacuous")
    if int(rows[1]["tuned_warm_misses"]) != 0 \
            or int(rows[1]["tuned_warm_hits"]) < 1:
        fail(f"run 2 tuned service recompiled during warm-up: "
             f"misses={rows[1]['tuned_warm_misses']} "
             f"hits={rows[1]['tuned_warm_hits']}")
    status = "OK" if ok else "FAIL"
    print(f"# serve_soak gate (n={rows[0]['n']} x{rows[0]['devices']} "
          f"devices, {rows[0]['requests']} reqs): run2 warm-up "
          f"misses={rows[1]['warm_misses']} hits={rows[1]['warm_hits']}, "
          f"tuned warm-up misses={rows[1]['tuned_warm_misses']} "
          f"hits={rows[1]['tuned_warm_hits']} "
          f"geometry={rows[1]['tuned_geometry']}, "
          f"p99={rows[0]['p99_ms']}/{rows[1]['p99_ms']}ms -- {status}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--requests", type=int, default=REQUESTS)
    ap.add_argument("--rate", type=float, default=RATE_HZ)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizing for CI")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile cache dir (default: fresh "
                         "tmpdir, removed afterwards)")
    ap.add_argument("--check", action="store_true",
                    help="enforce the ISSUE-7 soak gate")
    args = ap.parse_args()

    requests = 24 if args.fast else args.requests
    rows = run(devices=args.devices, requests=requests, rate_hz=args.rate,
               cache_dir=args.cache_dir)
    for r in rows:
        print("serve_soak," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
